"""Kernel-backend parity: Pallas (interpret) vs XLA for the three hot-path
primitives and both search procedures end-to-end.

Both backends are required to agree *bitwise* (ids AND distances) when run
inside jit — that is the contract that makes ``kernel_backend`` a pure
deployment knob (DESIGN.md §3).  The primitive-level tests therefore wrap
the calls in ``jax.jit``: the search stack always runs them under jit, and
outside jit XLA's op-by-op evaluation may fuse multiply-adds differently
at the last ulp.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import ANNConfig
from repro.core import hotpath as HP
from repro.core.diversify import build_tsdg
from repro.core.search_large import large_batch_search
from repro.core.search_small import small_batch_search
from repro.data.synthetic import make_clustered

METRICS = ("l2", "ip", "cos")


@functools.partial(jax.jit, static_argnames=("metric", "backend"))
def _nd(Q, X, idx, mask, metric, backend):
    return HP.neighbor_distances(Q, X, idx, metric=metric, mask=mask,
                                 backend=backend)


@functools.partial(jax.jit, static_argnames=("keep", "backend"))
def _rm(dists, ids, mask, keep, backend):
    return HP.rank_merge(dists, ids, keep=keep, mask=mask, backend=backend)


@functools.partial(jax.jit, static_argnames=("metric", "k", "backend"))
def _ss(Q, X, seeds, metric, k, backend):
    return HP.seed_select(Q, X, seeds, metric=metric, k=k, backend=backend)


# ----------------------------------------------------------------------
# primitive parity (non-multiple-of-tile shapes, all metrics)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("S,C,d", [(5, 7, 9), (33, 32, 16), (64, 33, 40),
                                   (130, 24, 128)])
@pytest.mark.parametrize("metric", METRICS)
def test_neighbor_distances_parity(rng, S, C, d, metric):
    N = 200
    X = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
    Q = jnp.asarray(rng.normal(size=(S, d)).astype(np.float32))
    # out-of-range ids (incl. the sentinel N) must come back INF
    idx = jnp.asarray(rng.integers(-2, N + 20, size=(S, C)).astype(np.int32))
    mask = jnp.asarray(rng.random((S, C)) > 0.3)
    a = _nd(Q, X, idx, mask, metric, "xla")
    b = _nd(Q, X, idx, mask, metric, "pallas")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # masked + invalid lanes are INF on both
    inv = ~(np.asarray(mask) & (np.asarray(idx) >= 0) & (np.asarray(idx) < N))
    assert (np.asarray(a)[inv] > 1e37).all()


@pytest.mark.parametrize("metric", METRICS)
def test_neighbor_distances_parity_3d(rng, metric):
    """The diversify-tile shape: [T, Kq, d] queries x [T, C] candidates."""
    T, K, d, N = 6, 5, 8, 40
    X = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
    nbr = jnp.asarray(rng.integers(0, N + 5, size=(T, K)).astype(np.int32))
    Q3 = X[jnp.clip(nbr, 0, N - 1)]
    a = _nd(Q3, X, nbr, None, metric, "xla")
    b = _nd(Q3, X, nbr, None, metric, "pallas")
    assert a.shape == (T, K, K)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("R,W,keep", [(7, 5, 3), (33, 48, 16), (64, 96, 64),
                                      (200, 17, 1)])
def test_rank_merge_parity(rng, R, W, keep):
    # duplicate distances exercise the shared (dist, id) tie-break
    dists = jnp.asarray(rng.integers(0, 6, size=(R, W)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 50, size=(R, W)).astype(np.int32))
    mask = jnp.asarray(rng.random((R, W)) > 0.2)
    for m in (None, mask):
        ad, ai = _rm(dists, ids, m, keep, "xla")
        bd, bi = _rm(dists, ids, m, keep, "pallas")
        np.testing.assert_array_equal(np.asarray(ad), np.asarray(bd))
        np.testing.assert_array_equal(np.asarray(ai), np.asarray(bi))
        # ascending by (dist, id)
        ad = np.asarray(ad)
        assert (np.diff(ad, axis=1) >= 0).all()


def test_rank_merge_validates_keep(rng):
    d = jnp.zeros((4, 8), jnp.float32)
    i = jnp.zeros((4, 8), jnp.int32)
    for backend in ("xla", "pallas"):
        with pytest.raises(ValueError, match="keep"):
            HP.rank_merge(d, i, keep=9, backend=backend)


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("k", [1, 5])
def test_seed_select_parity(rng, metric, k):
    N, S, C, d = 100, 21, 13, 12
    X = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
    Q = jnp.asarray(rng.normal(size=(S, d)).astype(np.float32))
    seeds = jnp.asarray(rng.integers(0, N + 10, size=(S, C)).astype(np.int32))
    ad, ai = _ss(Q, X, seeds, metric, k, "xla")
    bd, bi = _ss(Q, X, seeds, metric, k, "pallas")
    assert ad.shape == (S, k)
    np.testing.assert_array_equal(np.asarray(ad), np.asarray(bd))
    np.testing.assert_array_equal(np.asarray(ai), np.asarray(bi))
    # best seed really is the closest valid one (oracle check, row 0)
    dd = ((np.asarray(X)[np.clip(np.asarray(seeds)[0], 0, N - 1)]
           - np.asarray(Q)[0]) ** 2).sum(-1)
    if metric == "l2":
        valid = np.asarray(seeds)[0] < N
        assert abs(np.asarray(ad)[0, 0] - dd[valid].min()) < 1e-4


# ----------------------------------------------------------------------
# backend registry / resolution
# ----------------------------------------------------------------------

def test_resolve_backend():
    expect = "pallas" if jax.default_backend() == "tpu" else "xla"
    assert HP.resolve_backend("auto") == expect
    assert HP.resolve_backend(None) == expect
    assert HP.resolve_backend("xla") == "xla"
    assert HP.resolve_backend("pallas") == "pallas"
    with pytest.raises(ValueError, match="unknown kernel backend"):
        HP.resolve_backend("cuda")


def test_register_backend_roundtrip():
    class Probe:
        name = "probe"
        calls = []

        @staticmethod
        def neighbor_distances(Q, X, idx, **kw):
            Probe.calls.append("nd")
            return HP._XlaBackend.neighbor_distances(Q, X, idx, **kw)

        @staticmethod
        def rank_merge(d, i, **kw):
            Probe.calls.append("rm")
            return HP._XlaBackend.rank_merge(d, i, **kw)

    HP.register_backend("probe", Probe)
    try:
        assert "probe" in HP.backends()
        Q = jnp.zeros((2, 4))
        X = jnp.zeros((8, 4))
        idx = jnp.zeros((2, 3), jnp.int32)
        HP.seed_select(Q, X, idx, k=1, backend="probe")
        assert Probe.calls == ["nd", "rm"]
    finally:
        del HP._REGISTRY["probe"]


def test_config_has_kernel_backend():
    assert ANNConfig().kernel_backend == "auto"


# ----------------------------------------------------------------------
# end-to-end: identical (ids, dists) across backends for both regimes
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def index():
    ds = make_clustered(n=1200, d=12, n_queries=16, n_clusters=16,
                        noise=0.6, seed=0)
    cfg = dataclasses.replace(get_arch("tsdg-paper"), k_graph=10,
                              max_degree=12, lambda0=8, bridge_hubs=24,
                              bridge_k=4)
    X = jnp.asarray(ds.X)
    return ds, X, build_tsdg(X, cfg)


def test_small_batch_backend_parity(index):
    ds, X, g = index
    Q = jnp.asarray(ds.Q)
    for em in (False, True):
        a = small_batch_search(X, g, Q, k=10, t0=4, hops=4, width=16,
                               n_seeds=8, exact_merge=em, backend="xla")
        b = small_batch_search(X, g, Q, k=10, t0=4, hops=4, width=16,
                               n_seeds=8, exact_merge=em, backend="pallas")
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_large_batch_backend_parity(index):
    ds, X, g = index
    Q = jnp.asarray(ds.Q)
    for kw in ({}, dict(exact_visited=True), dict(gather_limit=6)):
        a = large_batch_search(X, g, Q, k=10, ef=32, hops=40,
                               backend="xla", **kw)
        b = large_batch_search(X, g, Q, k=10, ef=32, hops=40,
                               backend="pallas", **kw)
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_build_backend_parity(index):
    """The graph build (nn_descent + diversify tiles) agrees across
    backends too — the whole stack sits behind the seam."""
    ds, _, _ = index
    cfg = dataclasses.replace(get_arch("tsdg-paper"), k_graph=8,
                              max_degree=8, lambda0=6, bridge_hubs=16,
                              bridge_k=4)
    ga = build_tsdg(ds.X, dataclasses.replace(cfg, kernel_backend="xla"))
    gb = build_tsdg(ds.X, dataclasses.replace(cfg, kernel_backend="pallas"))
    np.testing.assert_array_equal(np.asarray(ga.neighbors),
                                  np.asarray(gb.neighbors))
    np.testing.assert_array_equal(np.asarray(ga.lambdas),
                                  np.asarray(gb.lambdas))


def test_engine_cache_key_includes_backend(index):
    from repro.serve.engine import ANNEngine

    ds, _, _ = index
    cfg = dataclasses.replace(get_arch("tsdg-paper"), k_graph=8,
                              max_degree=8, lambda0=6, bridge_hubs=16,
                              bridge_k=4, serve_buckets=(8,),
                              kernel_backend="xla")
    eng = ANNEngine(ds.X, cfg, k=5)
    assert eng.backend == "xla"
    eng.query(ds.Q[:2])
    assert all(key[3] == "xla" for key in eng._compiled)
