"""Kernel-backend parity: Pallas (interpret) vs XLA for the three hot-path
primitives and both search procedures end-to-end.

Both backends are required to agree *bitwise* (ids AND distances) when run
inside jit — that is the contract that makes ``kernel_backend`` a pure
deployment knob (DESIGN.md §3).  The primitive-level tests therefore wrap
the calls in ``jax.jit``: the search stack always runs them under jit, and
outside jit XLA's op-by-op evaluation may fuse multiply-adds differently
at the last ulp.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import ANNConfig
from repro.core import hotpath as HP
from repro.core.diversify import build_tsdg
from repro.core.search_large import large_batch_search
from repro.core.search_small import small_batch_search
from repro.data.synthetic import make_clustered

METRICS = ("l2", "ip", "cos")


@functools.partial(jax.jit, static_argnames=("metric", "backend"))
def _nd(Q, X, idx, mask, metric, backend):
    return HP.neighbor_distances(Q, X, idx, metric=metric, mask=mask,
                                 backend=backend)


@functools.partial(jax.jit, static_argnames=("keep", "backend"))
def _rm(dists, ids, mask, keep, backend):
    return HP.rank_merge(dists, ids, keep=keep, mask=mask, backend=backend)


@functools.partial(jax.jit, static_argnames=("metric", "k", "backend"))
def _ss(Q, X, seeds, metric, k, backend):
    return HP.seed_select(Q, X, seeds, metric=metric, k=k, backend=backend)


# ----------------------------------------------------------------------
# primitive parity (non-multiple-of-tile shapes, all metrics)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("S,C,d", [(5, 7, 9), (33, 32, 16), (64, 33, 40),
                                   (130, 24, 128)])
@pytest.mark.parametrize("metric", METRICS)
def test_neighbor_distances_parity(rng, S, C, d, metric):
    N = 200
    X = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
    Q = jnp.asarray(rng.normal(size=(S, d)).astype(np.float32))
    # out-of-range ids (incl. the sentinel N) must come back INF
    idx = jnp.asarray(rng.integers(-2, N + 20, size=(S, C)).astype(np.int32))
    mask = jnp.asarray(rng.random((S, C)) > 0.3)
    a = _nd(Q, X, idx, mask, metric, "xla")
    b = _nd(Q, X, idx, mask, metric, "pallas")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # masked + invalid lanes are INF on both
    inv = ~(np.asarray(mask) & (np.asarray(idx) >= 0) & (np.asarray(idx) < N))
    assert (np.asarray(a)[inv] > 1e37).all()


@pytest.mark.parametrize("metric", METRICS)
def test_neighbor_distances_parity_3d(rng, metric):
    """The diversify-tile shape: [T, Kq, d] queries x [T, C] candidates."""
    T, K, d, N = 6, 5, 8, 40
    X = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
    nbr = jnp.asarray(rng.integers(0, N + 5, size=(T, K)).astype(np.int32))
    Q3 = X[jnp.clip(nbr, 0, N - 1)]
    a = _nd(Q3, X, nbr, None, metric, "xla")
    b = _nd(Q3, X, nbr, None, metric, "pallas")
    assert a.shape == (T, K, K)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("R,W,keep", [(7, 5, 3), (33, 48, 16), (64, 96, 64),
                                      (200, 17, 1)])
def test_rank_merge_parity(rng, R, W, keep):
    # duplicate distances exercise the shared (dist, id) tie-break
    dists = jnp.asarray(rng.integers(0, 6, size=(R, W)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 50, size=(R, W)).astype(np.int32))
    mask = jnp.asarray(rng.random((R, W)) > 0.2)
    for m in (None, mask):
        ad, ai = _rm(dists, ids, m, keep, "xla")
        bd, bi = _rm(dists, ids, m, keep, "pallas")
        np.testing.assert_array_equal(np.asarray(ad), np.asarray(bd))
        np.testing.assert_array_equal(np.asarray(ai), np.asarray(bi))
        # ascending by (dist, id)
        ad = np.asarray(ad)
        assert (np.diff(ad, axis=1) >= 0).all()


def test_rank_merge_validates_keep(rng):
    d = jnp.zeros((4, 8), jnp.float32)
    i = jnp.zeros((4, 8), jnp.int32)
    for backend in ("xla", "pallas"):
        with pytest.raises(ValueError, match="keep"):
            HP.rank_merge(d, i, keep=9, backend=backend)


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("k", [1, 5])
def test_seed_select_parity(rng, metric, k):
    N, S, C, d = 100, 21, 13, 12
    X = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
    Q = jnp.asarray(rng.normal(size=(S, d)).astype(np.float32))
    seeds = jnp.asarray(rng.integers(0, N + 10, size=(S, C)).astype(np.int32))
    ad, ai = _ss(Q, X, seeds, metric, k, "xla")
    bd, bi = _ss(Q, X, seeds, metric, k, "pallas")
    assert ad.shape == (S, k)
    np.testing.assert_array_equal(np.asarray(ad), np.asarray(bd))
    np.testing.assert_array_equal(np.asarray(ai), np.asarray(bi))
    # best seed really is the closest valid one (oracle check, row 0)
    dd = ((np.asarray(X)[np.clip(np.asarray(seeds)[0], 0, N - 1)]
           - np.asarray(Q)[0]) ** 2).sum(-1)
    if metric == "l2":
        valid = np.asarray(seeds)[0] < N
        assert abs(np.asarray(ad)[0, 0] - dd[valid].min()) < 1e-4


# ----------------------------------------------------------------------
# backend registry / resolution
# ----------------------------------------------------------------------

def test_resolve_backend():
    expect = "pallas" if jax.default_backend() == "tpu" else "xla"
    assert HP.resolve_backend("auto") == expect
    assert HP.resolve_backend(None) == expect
    assert HP.resolve_backend("xla") == "xla"
    assert HP.resolve_backend("pallas") == "pallas"
    with pytest.raises(ValueError, match="unknown kernel backend"):
        HP.resolve_backend("cuda")


def test_register_backend_roundtrip():
    class Probe:
        name = "probe"
        calls = []

        @staticmethod
        def neighbor_distances(Q, X, idx, **kw):
            Probe.calls.append("nd")
            return HP._XlaBackend.neighbor_distances(Q, X, idx, **kw)

        @staticmethod
        def rank_merge(d, i, **kw):
            Probe.calls.append("rm")
            return HP._XlaBackend.rank_merge(d, i, **kw)

    HP.register_backend("probe", Probe)
    try:
        assert "probe" in HP.backends()
        Q = jnp.zeros((2, 4))
        X = jnp.zeros((8, 4))
        idx = jnp.zeros((2, 3), jnp.int32)
        HP.seed_select(Q, X, idx, k=1, backend="probe")
        assert Probe.calls == ["nd", "rm"]
    finally:
        del HP._REGISTRY["probe"]


def test_config_has_kernel_backend():
    assert ANNConfig().kernel_backend == "auto"


# ----------------------------------------------------------------------
# end-to-end: identical (ids, dists) across backends for both regimes
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def index():
    ds = make_clustered(n=1200, d=12, n_queries=16, n_clusters=16,
                        noise=0.6, seed=0)
    cfg = dataclasses.replace(get_arch("tsdg-paper"), k_graph=10,
                              max_degree=12, lambda0=8, bridge_hubs=24,
                              bridge_k=4)
    X = jnp.asarray(ds.X)
    return ds, X, build_tsdg(X, cfg)


def test_small_batch_backend_parity(index):
    ds, X, g = index
    Q = jnp.asarray(ds.Q)
    for em in (False, True):
        a = small_batch_search(X, g, Q, k=10, t0=4, hops=4, width=16,
                               n_seeds=8, exact_merge=em, backend="xla")
        b = small_batch_search(X, g, Q, k=10, t0=4, hops=4, width=16,
                               n_seeds=8, exact_merge=em, backend="pallas")
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_large_batch_backend_parity(index):
    ds, X, g = index
    Q = jnp.asarray(ds.Q)
    for kw in ({}, dict(exact_visited=True), dict(gather_limit=6)):
        a = large_batch_search(X, g, Q, k=10, ef=32, hops=40,
                               backend="xla", **kw)
        b = large_batch_search(X, g, Q, k=10, ef=32, hops=40,
                               backend="pallas", **kw)
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_build_backend_parity(index):
    """The graph build (nn_descent + diversify tiles) agrees across
    backends too — the whole stack sits behind the seam."""
    ds, _, _ = index
    cfg = dataclasses.replace(get_arch("tsdg-paper"), k_graph=8,
                              max_degree=8, lambda0=6, bridge_hubs=16,
                              bridge_k=4)
    ga = build_tsdg(ds.X, dataclasses.replace(cfg, kernel_backend="xla"))
    gb = build_tsdg(ds.X, dataclasses.replace(cfg, kernel_backend="pallas"))
    np.testing.assert_array_equal(np.asarray(ga.neighbors),
                                  np.asarray(gb.neighbors))
    np.testing.assert_array_equal(np.asarray(ga.lambdas),
                                  np.asarray(gb.lambdas))


def test_engine_cache_key_includes_backend(index):
    from repro.serve.engine import ANNEngine

    ds, _, _ = index
    cfg = dataclasses.replace(get_arch("tsdg-paper"), k_graph=8,
                              max_degree=8, lambda0=6, bridge_hubs=16,
                              bridge_k=4, serve_buckets=(8,),
                              kernel_backend="xla")
    eng = ANNEngine(ds.X, cfg, k=5)
    assert eng.backend == "xla"
    eng.query(ds.Q[:2])
    assert all(key[3] == "xla" for key in eng._compiled)


# ----------------------------------------------------------------------
# gather-fused path: in-kernel neighbor gather (scalar-prefetch DMA)
# ----------------------------------------------------------------------

from repro.kernels import l2dist as L2  # noqa: E402


@functools.partial(jax.jit, static_argnames=("metric", "backend", "gf"))
def _ndg(Q, X, idx, mask, metric, backend, gf):
    return HP.neighbor_distances(Q, X, idx, metric=metric, mask=mask,
                                 backend=backend, gather_fused=gf)


@pytest.mark.parametrize("d", [8, 100, 128, 960])
@pytest.mark.parametrize("metric", METRICS)
def test_gather_fused_parity_dims(rng, d, metric):
    """Fused DMA gather vs XLA oracle, bitwise, across dimensionalities
    including non-128-multiple d (100) and GIST-sized d (960)."""
    S, C, N = 13, 9, 150
    X = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
    Q = jnp.asarray(rng.normal(size=(S, d)).astype(np.float32))
    idx = jnp.asarray(rng.integers(-2, N + 20, size=(S, C)).astype(np.int32))
    mask = jnp.asarray(rng.random((S, C)) > 0.3)
    a = _ndg(Q, X, idx, mask, metric, "xla", None)
    b = _ndg(Q, X, idx, mask, metric, "pallas", "on")
    c = _ndg(Q, X, idx, mask, metric, "pallas", "off")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_gather_fused_degenerate_idx(rng):
    """All-(-1), all-duplicate, all-out-of-range, and fully masked idx
    arrays must agree with the oracle and return INF where invalid."""
    S, C, d, N = 7, 6, 16, 64
    X = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
    Q = jnp.asarray(rng.normal(size=(S, d)).astype(np.float32))
    cases = {
        "all_minus_one": np.full((S, C), -1, np.int32),
        "all_duplicate": np.full((S, C), 3, np.int32),
        "all_out_of_range": np.full((S, C), N + 7, np.int32),
        "sentinel_N": np.full((S, C), N, np.int32),
        "mixed": rng.integers(-5, N + 5, size=(S, C)).astype(np.int32),
    }
    for name, idx_np in cases.items():
        idx = jnp.asarray(idx_np)
        for mask in (None, jnp.zeros((S, C), bool)):
            a = _ndg(Q, X, idx, mask, "l2", "xla", None)
            b = _ndg(Q, X, idx, mask, "l2", "pallas", "on")
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)
            invalid = ~((idx_np >= 0) & (idx_np < N))
            if mask is not None:
                invalid |= True
            assert (np.asarray(a)[invalid] > 1e37).all(), name


@pytest.mark.parametrize("bs", [2, 4, 8])
def test_gather_fused_multi_tile_parity(rng, bs):
    """Force a multi-tile grid (bs < S) so the double-buffered DMA path —
    the @pl.when(i+1<n) prefetch and the slot rotation — actually executes
    (the auto-picked bs covers small test batches in one tile)."""
    S, C, d, N = 20, 6, 32, 120
    X = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
    Q3 = jnp.asarray(rng.normal(size=(S, 1, d)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, N, size=(S, C)).astype(np.int32))
    mask = jnp.asarray(rng.random((S, C)) > 0.2)
    a = _nd(Q3, X, idx, mask, "l2", "xla")
    b = L2.gather_block_distances_pallas(Q3, X, idx, mask, metric="l2",
                                         bs=bs, interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("metric", METRICS)
def test_gather_fused_self_q_parity(rng, metric):
    """The diversify-tile pairwise block via q_idx: BOTH operand sides are
    gathered in-kernel (no [T, K, d] materialization at all)."""
    T, K, d, N = 6, 5, 24, 80
    X = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
    nbr = jnp.asarray(rng.integers(0, N + 5, size=(T, K)).astype(np.int32))

    @functools.partial(jax.jit, static_argnames=("backend", "gf"))
    def pair(X, nbr, backend, gf):
        return HP.neighbor_distances(None, X, nbr, metric=metric,
                                     backend=backend, gather_fused=gf,
                                     q_idx=nbr)

    a = pair(X, nbr, "xla", None)
    b = pair(X, nbr, "pallas", "on")
    c = pair(X, nbr, "pallas", "off")
    assert a.shape == (T, K, K)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_gather_fused_e2e_small_batch(index):
    """End-to-end Algorithm 1: forced fused DMA path vs XLA oracle,
    bitwise ids AND dists."""
    ds, X, g = index
    Q = jnp.asarray(ds.Q)
    a = small_batch_search(X, g, Q, k=10, t0=4, hops=4, width=16,
                           n_seeds=8, backend="xla")
    b = small_batch_search(X, g, Q, k=10, t0=4, hops=4, width=16,
                           n_seeds=8, backend="pallas", gather_fused="on")
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_gather_fused_e2e_large_batch(index):
    """End-to-end Algorithm 2: forced fused DMA path vs XLA oracle."""
    ds, X, g = index
    Q = jnp.asarray(ds.Q)
    a = large_batch_search(X, g, Q, k=10, ef=32, hops=24, backend="xla")
    b = large_batch_search(X, g, Q, k=10, ef=32, hops=24, backend="pallas",
                           gather_fused="on")
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_gather_fused_hlo_elides_neighbor_buffer(rng):
    """The acceptance check: the [S, C, d] gathered-neighbor buffer exists
    in the lowered HLO of the gather-then-block path and does NOT exist in
    the fused path (the gather happens via in-kernel DMA)."""
    S, C, d, N = 11, 7, 19, 60
    X = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
    Q = jnp.asarray(rng.normal(size=(S, d)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, N, size=(S, C)).astype(np.int32))

    def lower(gf):
        f = jax.jit(lambda q, x, i, _g=gf: HP.neighbor_distances(
            q, x, i, metric="l2", backend="pallas", gather_fused=_g))
        return f.lower(Q, X, idx).as_text()

    buf = f"tensor<{S}x{C}x{d}xf32>"
    assert buf in lower("off")
    assert buf not in lower("on")


def test_gather_fused_hlo_e2e_search(rng):
    """Same check through a whole jitted search: the per-hop [B, M, d]
    neighbor buffer disappears from the HLO when the fused path is on."""
    from repro.core.diversify import PackedGraph

    B, N, M, d = 5, 90, 6, 22
    X = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
    Q = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32))
    g = PackedGraph(
        neighbors=jnp.asarray(
            rng.integers(0, N, size=(N, M)).astype(np.int32)),
        lambdas=jnp.zeros((N, M), jnp.int32),
        degrees=jnp.full((N,), M, jnp.int32))

    def lower(gf):
        f = jax.jit(functools.partial(
            large_batch_search, k=4, ef=8, hops=6, n_seeds=8,
            backend="pallas", gather_fused=gf))
        return f.lower(X, g, Q).as_text()

    buf = f"tensor<{B}x{M}x{d}xf32>"
    assert buf in lower("off")
    assert buf not in lower("on")


# ----------------------------------------------------------------------
# VMEM budgeting: _pick_bs never overflows, C-split keeps parity
# ----------------------------------------------------------------------

def test_pick_bs_never_exceeds_budget(rng):
    """Property: for any realistic (Kq, C, d) the chosen block set fits
    the VMEM budget — including the former overflow regime (the old code
    stopped halving at bs=8 and could pick ~17 MB blocks)."""
    for _ in range(300):
        Kq = int(rng.integers(1, 65))
        C = int(rng.integers(1, 513))
        d = int(rng.integers(1, 1025))
        bs, bc = L2._pick_bs(Kq, C, d)
        assert 1 <= bs <= 128 and 1 <= bc <= C
        assert L2._block_bytes(bs, Kq, bc, d) <= L2.VMEM_BUDGET, \
            (Kq, C, d, bs, bc)


def test_pick_bs_gist_regression():
    """GIST d=960 with a wide candidate set: the old halving loop stopped
    at bs=8 (8*(32*960 + 512*960 + 32*512)*4 ≈ 17 MB > 4 MB budget); the
    fix keeps halving to bs=1, which fits."""
    bs, bc = L2._pick_bs(32, 512, 960)
    assert L2._block_bytes(bs, 32, bc, 960) <= L2.VMEM_BUDGET
    assert bs == 1 and bc == 512
    # even wider: a single row exceeds the budget -> candidate axis split
    bs, bc = L2._pick_bs(64, 1024, 960)
    assert L2._block_bytes(bs, 64, bc, 960) <= L2.VMEM_BUDGET
    assert bs == 1 and bc < 1024


def test_block_distances_csplit_parity(rng):
    """Forcing the candidate-split grid (bc < C) stays bitwise-identical
    to the oracle — padded candidate lanes are masked INF."""
    S, Kq, C, d, N = 9, 3, 11, 20, 70
    X = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
    Q3 = jnp.asarray(rng.normal(size=(S, Kq, d)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, N + 5, size=(S, C)).astype(np.int32))
    a = _nd(Q3, X, idx, None, "l2", "xla")
    V = X[jnp.clip(idx, 0, N - 1)]
    m = (idx >= 0) & (idx < N)
    for bs, bc in ((2, 4), (1, 3), (4, 11)):
        b = L2.block_distances_pallas(Q3, V, m, metric="l2", bs=bs, bc=bc,
                                      interpret=True)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"bs={bs},bc={bc}")


def test_gather_fused_fits_budget_check():
    assert L2.gather_fused_fits(1, 32, 128)
    assert not L2.gather_fused_fits(1, 4096, 1024)
    # self_q drops the Q tile from the bill: this shape fits only when the
    # query side is gathered in-kernel from the same ids
    assert L2.gather_fused_fits(512, 256, 960, self_q=True)
    assert not L2.gather_fused_fits(512, 256, 960)


def test_gather_fused_fits_int8_headroom():
    """d=960 headroom regression (DESIGN.md §8): the fp32 bill for a wide
    GIST-shaped gather blows the VMEM budget, but the same tile over int8
    rows is ~4x smaller (1-byte candidate rows + a 4-byte scale per
    candidate) and fits — compressed residency widens the fused-gather
    regime, it never narrows it."""
    assert not L2.gather_fused_fits(64, 1024, 960)               # fp32
    assert L2.gather_fused_fits(64, 1024, 960, itemsize=1)       # int8
    # the byte bill itself must reflect the operand itemsize
    fp32 = L2._gather_tile_bytes(64, 1024, 960, self_q=False)
    int8 = L2._gather_tile_bytes(64, 1024, 960, self_q=False, itemsize=1)
    assert int8 < fp32
    # candidate-row DMA bytes (the 2*C*d double buffer) shrink exactly 4x
    assert 2 * 1024 * 960 * 4 - 2 * 1024 * 960 == fp32 - int8 + 1024 * 4


def test_pick_bs_itemsize_aware(rng):
    """The block picker bills actual operand bytes: int8 candidate tiles
    admit equal-or-larger blocks than fp32 at every shape with d >= 2
    (below that the 4-byte scale column outweighs the 3-byte/element row
    saving), and the chosen blocks always fit the budget under their own
    itemsize."""
    for _ in range(100):
        Kq = int(rng.integers(1, 65))
        C = int(rng.integers(1, 513))
        d = int(rng.integers(2, 1025))
        bs32, bc32 = L2._pick_bs(Kq, C, d)
        bs8, bc8 = L2._pick_bs(Kq, C, d, itemsize=1)
        assert bs8 * bc8 >= bs32 * bc32, (Kq, C, d)
        assert L2._block_bytes(bs8, Kq, bc8, d, itemsize=1) \
            <= L2.VMEM_BUDGET, (Kq, C, d, bs8, bc8)


def test_gather_dispatch_pinned():
    """The gather-fused placement decision, exhaustively pinned: "on"
    always fuses, "off" never, and "auto" fuses only off-interpret (real
    TPU) AND inside the VMEM budget — the regression for the auto path
    silently fusing under interpret-mode DMA emulation."""
    assert HP.gather_dispatch("auto", interp=True, fits=True) is False
    assert HP.gather_dispatch("auto", interp=True, fits=False) is False
    assert HP.gather_dispatch("auto", interp=False, fits=True) is True
    assert HP.gather_dispatch("auto", interp=False, fits=False) is False
    assert HP.gather_dispatch("on", interp=True, fits=True) is True
    assert HP.gather_dispatch("on", interp=True, fits=False) is True
    assert HP.gather_dispatch("off", interp=False, fits=True) is False
    with pytest.raises(ValueError, match="gather_fused"):
        HP.gather_dispatch("always", interp=False, fits=True)
