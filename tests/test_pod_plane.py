"""Pod execution plane: multi-process `jax.distributed` CPU serving.

The 2-process acceptance test launches two coordinated subprocesses (gloo
collectives, one emulated device each) that build and serve THE SAME pod
index SPMD; the parent asserts that

* every process materializes the identical replicated answer,
* the pod answers are bitwise a single-process 2-device mesh plane's
  (the pod plane is the mesh plane stretched over processes — collectives
  don't change a bit of the math),
* the artifact written from the pod (process 0 writes, all processes
  rendezvous) carries pod topology metadata and loads on a plain
  single-process setup through the documented gather-and-rebuild fallback.

The in-process tests cover the degenerate single-process pod (1-device
mesh) where no ``jax.distributed`` init is needed.
"""
import dataclasses
import json
import os
import socket
import subprocess
import sys
import warnings

import numpy as np
import pytest

from repro.ann import Index
from repro.configs import get_arch
from repro.data.synthetic import make_clustered, recall_at_k

ROOT = os.path.join(os.path.dirname(__file__), "..")

# keep every participant (pod processes, mesh reference, in-process tests)
# on the same corpus + config, or the bitwise comparisons are meaningless
_DATA = """
import dataclasses, numpy as np
from repro.configs import get_arch
from repro.data.synthetic import make_clustered
ds = make_clustered(n=1024, d=16, n_queries=64, n_clusters=16, noise=0.6,
                    seed=0)
cfg = dataclasses.replace(get_arch('tsdg-paper'), k_graph=8, max_degree=12,
                          lambda0=4, bridge_hubs=16, bridge_k=4, large_ef=32,
                          large_hops=16, serve_buckets=(8, 64))
THR = 8.0 * cfg.small_t0
"""


@pytest.fixture(scope="module")
def ds():
    return make_clustered(n=1024, d=16, n_queries=64, n_clusters=16,
                          noise=0.6, seed=0)


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(get_arch("tsdg-paper"), k_graph=8,
                               max_degree=12, lambda0=4, bridge_hubs=16,
                               bridge_k=4, large_ef=32, large_hops=16,
                               serve_buckets=(8, 64))


def _free_port() -> int:
    s = socket.socket()
    s.bind(("localhost", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _spawn(code: str, devices: int):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    return subprocess.Popen([sys.executable, "-c", code], env=env,
                            stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True)


def _run_pod(body: str, out: str, num: int = 2, timeout: int = 600):
    """Launch ``num`` coordinated jax.distributed processes all running
    ``body`` (tokens @PID@/@OUT@ substituted), with one device each."""
    port = _free_port()
    prelude = (
        "import repro.serve.pod as pod\n"
        f"pod.init_pod('localhost:{port}', num_processes={num}, "
        "process_id=@PID@)\n"
        "pod.init_pod()  # idempotent: a second call is a no-op\n"
        "import jax\n"
        f"assert jax.process_count() == {num}, jax.process_count()\n")
    procs = [_spawn((prelude + body).replace("@PID@", str(pid))
                    .replace("@OUT@", out), devices=1)
             for pid in range(num)]
    outs = [p.communicate(timeout=timeout)[0] for p in procs]
    for p, o in zip(procs, outs):
        assert p.returncode == 0, o
    return outs


def _run_single(code: str, devices: int = 2, timeout: int = 600):
    p = _spawn(code, devices=devices)
    out = p.communicate(timeout=timeout)[0]
    assert p.returncode == 0, out
    return out


_POD_BODY = _DATA + """
from repro.ann import Index
from repro.data.synthetic import recall_at_k

# a pod mesh must not shard queries across processes
try:
    pod.PodPlane(ds.X, cfg, mesh=jax.make_mesh((1, 2), ('data', 'model')))
    raise SystemExit('expected ValueError for a model axis on a pod')
except ValueError as e:
    assert 'model' in str(e), e

plane = pod.PodPlane(ds.X, cfg)
assert plane.topology()['n_processes'] == 2
assert plane.fingerprint()['n_processes'] == 2
assert plane.topology()['n_db_shards'] == 2

idx = Index(None, cfg, k=10, plane=plane, threshold=THR)
small = idx.search(ds.Q[:5])
large = idx.search(ds.Q)
compiles = idx.stats.compiles
again = idx.search(ds.Q[:5])
assert idx.stats.compiles == compiles      # bucket hit, no recompile
assert np.array_equal(np.asarray(small[0]), np.asarray(again[0]))
r = recall_at_k(np.asarray(large[0]), ds.gt, 10)
assert r > 0.8, r

np.save('@OUT@/ids_small_@PID@.npy', np.asarray(small[0]))
np.save('@OUT@/d_small_@PID@.npy', np.asarray(small[1]))
np.save('@OUT@/ids_large_@PID@.npy', np.asarray(large[0]))
np.save('@OUT@/d_large_@PID@.npy', np.asarray(large[1]))
idx.save('@OUT@/pod_ix')    # SPMD save: collective gather, pid 0 writes
print('POD OK @PID@')
"""

_MESH_REF = _DATA + """
import jax
from repro.ann import Index
mesh = jax.make_mesh((2,), ('data',))
mi = Index.build(ds.X, cfg, k=10, mesh=mesh, threshold=THR)
small = mi.search(ds.Q[:5]); large = mi.search(ds.Q)
np.save('@OUT@/ref_ids_small.npy', np.asarray(small[0]))
np.save('@OUT@/ref_d_small.npy', np.asarray(small[1]))
np.save('@OUT@/ref_ids_large.npy', np.asarray(large[0]))
np.save('@OUT@/ref_d_large.npy', np.asarray(large[1]))
print('REF OK')
"""


def test_pod_two_process_serving(ds, cfg, tmp_path):
    """THE pod acceptance: 2 coordinated jax.distributed CPU processes
    serve replicated answers that are identical on every process AND
    bitwise a single-process 2-device mesh plane's, both regimes; the
    pod-written artifact carries the topology and falls back cleanly on a
    plain single-process load."""
    out = str(tmp_path)
    logs = _run_pod(_POD_BODY, out)
    assert all("POD OK" in log for log in logs), logs

    # SPMD serving: every process holds the identical full answer
    for nm in ("ids_small", "d_small", "ids_large", "d_large"):
        a = np.load(tmp_path / f"{nm}_0.npy")
        b = np.load(tmp_path / f"{nm}_1.npy")
        assert np.array_equal(a, b), nm

    # cross-process collectives are bit-invisible: pod == mesh
    _run_single(_MESH_REF.replace("@OUT@", out), devices=2)
    for nm in ("ids_small", "ids_large"):
        assert np.array_equal(np.load(tmp_path / f"{nm}_0.npy"),
                              np.load(tmp_path / f"ref_{nm}.npy")), nm
    for nm in ("d_small", "d_large"):
        assert np.array_equal(
            np.load(tmp_path / f"{nm}_0.npy").view(np.uint32),
            np.load(tmp_path / f"ref_{nm}.npy").view(np.uint32)), nm

    # the artifact records the pod topology (and only process 0 wrote it)
    man = json.loads((tmp_path / "pod_ix" / "manifest.json").read_text())
    assert man["plane"] == "pod"
    assert man["topology"]["n_processes"] == 2
    assert man["topology"]["n_db_shards"] == 2

    # single-process fallback load: gather the shards, rebuild, still good
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        loaded = Index.load(tmp_path / "pod_ix")
    assert any("sharded artifact" in str(x.message) for x in w)
    r = recall_at_k(np.asarray(loaded.search(ds.Q)[0]), ds.gt, 10)
    assert r > 0.8, r


# ----------------------------------------------------------------------
# degenerate single-process pod (no jax.distributed init required)
# ----------------------------------------------------------------------

def test_pod_plane_single_process_matches_single_device(ds, cfg):
    """A 1-process 1-device pod is a 1-DB-shard mesh, which is bitwise the
    single-device plane (the PR 5 invariant) — the whole pod stack
    collapses cleanly when there's nothing to distribute."""
    from repro.serve.plane import get_plane

    thr = 8.0 * cfg.small_t0
    plane = get_plane("pod")(ds.X, cfg)
    assert plane.name == "pod"
    assert plane.topology()["n_processes"] == 1
    pi = Index(None, cfg, k=10, plane=plane, threshold=thr)
    si = Index.build(ds.X, cfg, k=10, threshold=thr)
    for B in (5, 64):
        got, ref = pi.search(ds.Q[:B]), si.search(ds.Q[:B])
        assert np.array_equal(np.asarray(got[0]), np.asarray(ref[0]))
        assert np.array_equal(np.asarray(got[1]).view(np.uint32),
                              np.asarray(ref[1]).view(np.uint32))


def test_pod_plane_lazy_registration():
    from repro.serve.plane import get_plane, planes

    assert get_plane("pod") is not None
    assert "pod" in planes()
