"""Micro-batcher QoS deadlines: expired requests fail with DeadlineExceeded
instead of occupying a coalesced-batch slot (ROADMAP queue-QoS item)."""
import dataclasses
import threading
import time

import numpy as np
import pytest

from repro.configs import get_arch
from repro.serve.queue import DeadlineExceeded, MicroBatcher


class _StubEngine:
    """Engine stand-in with a controllable per-dispatch delay."""

    def __init__(self, d: int = 4, delay_s: float = 0.0):
        self.X = np.zeros((16, d), np.float32)
        self.cfg = dataclasses.replace(
            get_arch("tsdg-paper"), queue_max_wait_ms=5.0,
            queue_max_batch=64)
        self.delay_s = delay_s
        self.served: list = []
        self._lock = threading.Lock()

    def query(self, Q, k=None):
        with self._lock:
            self.served.append(Q.shape[0])
        if self.delay_s:
            time.sleep(self.delay_s)
        k = 3 if k is None else k
        B = Q.shape[0]
        return (np.zeros((B, k), np.int32), np.zeros((B, k), np.float32))


def test_deadline_exceeded_while_queued_behind_slow_dispatch():
    """A request whose deadline elapses while the dispatcher is busy must
    fail with DeadlineExceeded, be counted in stats.expired, and never
    reach the engine."""
    eng = _StubEngine(delay_s=0.5)
    mb = MicroBatcher(eng, max_wait_ms=1, max_batch=4)
    try:
        f1 = mb.submit(np.zeros(4, np.float32))          # occupies 0.5s
        time.sleep(0.05)                                 # dispatcher has it
        f2 = mb.submit(np.zeros(4, np.float32), deadline_ms=100.0)
        f3 = mb.submit(np.zeros(4, np.float32))          # no deadline
        with pytest.raises(DeadlineExceeded):
            f2.result(timeout=30)
        assert f1.result(timeout=30)[0].shape == (3,)
        assert f3.result(timeout=30)[0].shape == (3,)    # still served
    finally:
        mb.close()
    snap = mb.stats.snapshot()
    assert snap["expired"] == 1
    # n_requests counts DISPATCHED requests; the expired one never
    # occupied a slot and its rows never hit the engine
    assert snap["n_requests"] == 2
    assert sum(eng.served) == 2


def test_deadline_not_reached_serves_normally():
    eng = _StubEngine()
    with MicroBatcher(eng, max_wait_ms=1, max_batch=8) as mb:
        f = mb.submit(np.zeros(4, np.float32), deadline_ms=60_000.0)
        ids, dists = f.result(timeout=30)
    assert ids.shape == (3,) and dists.shape == (3,)
    assert mb.stats.expired == 0


def test_deadline_checked_in_close_drain():
    """Requests still queued at close(drain=True) are expired, not served,
    once their deadline passed — stale answers are never computed."""
    from concurrent.futures import Future

    from repro.serve.queue import _Request

    eng = _StubEngine(delay_s=0.4)
    mb = MicroBatcher(eng, max_wait_ms=1, max_batch=4)
    f1 = mb.submit(np.zeros(4, np.float32))   # occupy the dispatcher
    time.sleep(0.05)
    closer = threading.Thread(target=mb.close)
    closer.start()
    time.sleep(0.05)                          # sentinel enqueued by now
    expired = _Request(Q=np.zeros((1, 4), np.float32), k=None, single=False,
                       future=Future(), deadline=time.monotonic() - 1.0)
    live = _Request(Q=np.zeros((2, 4), np.float32), k=None, single=False,
                    future=Future(), deadline=time.monotonic() + 60.0)
    mb._q.put(expired)                        # race: behind the sentinel
    mb._q.put(live)
    closer.join(timeout=60)
    assert f1.result(timeout=30)[0].shape == (3,)
    with pytest.raises(DeadlineExceeded):
        expired.future.result(timeout=30)
    assert live.future.result(timeout=30)[0].shape == (2, 3)
    assert mb.stats.expired == 1


def test_deadline_validation():
    eng = _StubEngine()
    with MicroBatcher(eng, max_wait_ms=1, max_batch=8) as mb:
        with pytest.raises(ValueError, match="deadline_ms"):
            mb.submit(np.zeros(4, np.float32), deadline_ms=0.0)
        with pytest.raises(ValueError, match="deadline_ms"):
            mb.submit(np.zeros(4, np.float32), deadline_ms=-5.0)


def test_expired_in_snapshot_consistency():
    """expired is part of the locked snapshot like every other counter."""
    eng = _StubEngine(delay_s=0.3)
    mb = MicroBatcher(eng, max_wait_ms=1, max_batch=4)
    try:
        mb.submit(np.zeros(4, np.float32))
        time.sleep(0.05)
        futs = [mb.submit(np.zeros(4, np.float32), deadline_ms=50.0)
                for _ in range(3)]
        for f in futs:
            with pytest.raises(DeadlineExceeded):
                f.result(timeout=30)
    finally:
        mb.close()
    snap = mb.stats.snapshot()
    assert snap["expired"] == 3
    assert snap["n_requests"] == 1      # only the first was dispatched


def test_deadline_on_real_engine_index_serve():
    """deadline_ms threads through Index.serve() on a real engine."""
    from repro.ann import Index
    from repro.data.synthetic import make_clustered

    ds = make_clustered(n=400, d=8, n_queries=8, n_clusters=8, noise=0.5,
                        seed=1)
    cfg = dataclasses.replace(get_arch("tsdg-paper"), k_graph=8,
                              max_degree=8, bridge_hubs=0, large_hops=8,
                              serve_buckets=(8,))
    index = Index.build(ds.X, cfg, k=5)
    index.warmup()
    with index.serve(max_wait_ms=1.0, max_batch=8) as mb:
        f = mb.submit(ds.Q[0], deadline_ms=60_000.0)
        ids, _ = f.result(timeout=120)
    assert ids.shape == (5,)
    assert mb.stats.expired == 0


# ----------------------------------------------------------------------
# close() lifecycle: idempotent, and submit-after-close fails loudly
# ----------------------------------------------------------------------

def test_close_is_idempotent():
    """A second close() must be a no-op that still waits for the first
    drain — not a re-drain, not an error."""
    eng = _StubEngine()
    mb = MicroBatcher(eng, max_wait_ms=1, max_batch=4)
    f = mb.submit(np.zeros(4, np.float32))
    mb.close()
    mb.close()          # second call: returns cleanly
    mb.close(drain=False)   # even with different args
    assert f.result(timeout=5)[0].shape == (3,)


def test_concurrent_close_waits_for_first_drain():
    """close() racing close(): the loser must BLOCK until the winner has
    resolved every pending future, so no caller observes a half-drained
    queue."""
    eng = _StubEngine(delay_s=0.3)
    mb = MicroBatcher(eng, max_wait_ms=1, max_batch=1)
    futs = [mb.submit(np.zeros(4, np.float32)) for _ in range(3)]
    t = threading.Thread(target=mb.close)
    t.start()
    time.sleep(0.05)         # first close is mid-drain
    mb.close()               # concurrent close: must wait, not return early
    assert all(f.done() for f in futs), "close() returned before drain"
    t.join(timeout=10)
    for f in futs:
        assert f.result(timeout=1)[0].shape == (3,)


@pytest.mark.parametrize("drain", [True, False])
def test_submit_after_close_raises(drain):
    """submit() on a closed batcher raises a clear RuntimeError instead of
    enqueueing a request nothing will ever dispatch (a hang)."""
    eng = _StubEngine()
    mb = MicroBatcher(eng, max_wait_ms=1, max_batch=4)
    mb.close(drain=drain)
    with pytest.raises(RuntimeError, match="MicroBatcher is closed"):
        mb.submit(np.zeros(4, np.float32))
