"""Registry + config sanity: all archs load; param counts match public
figures; every (arch x shape) cell is constructible."""
import jax
import numpy as np
import pytest

from repro.configs import get_arch, get_reduced, list_archs, shapes_for


def test_registry_complete():
    archs = list_archs()
    assert len(archs) == 11  # 10 assigned + the paper's own system
    for a in archs:
        cfg = get_arch(a)
        assert cfg.family in ("lm", "gnn", "recsys", "ann")
        get_reduced(a)  # must not raise


@pytest.mark.parametrize("arch,total_b,active_b", [
    ("olmoe-1b-7b", 7.0, 1.3),
    ("kimi-k2-1t-a32b", 1040.0, 32.0),
    ("starcoder2-7b", 7.2, 7.2),
    ("gemma3-27b", 27.0, 27.0),
    ("olmo-1b", 1.3, 1.3),
])
def test_lm_param_counts(arch, total_b, active_b):
    cfg = get_arch(arch)
    n = cfg.n_params() / 1e9
    na = cfg.n_active_params() / 1e9
    assert abs(n - total_b) / total_b < 0.25, f"{arch}: {n:.1f}B vs {total_b}B"
    assert abs(na - active_b) / active_b < 0.35, f"{arch}: {na:.1f}B active"


def test_cell_enumeration():
    from repro.launch.steps import all_cells

    cells = all_cells(include_ann=False)
    assert len(cells) == 40  # the assigned 10 archs x 4 shapes
    cells_all = all_cells()
    assert len(cells_all) == 44  # + tsdg's own 4


def test_shape_specs_complete():
    for a in list_archs():
        cfg = get_arch(a)
        shapes = shapes_for(cfg)
        assert len(shapes) == 4
        for name, s in shapes.items():
            assert s.kind in ("train", "prefill", "decode", "serve",
                              "retrieval", "build", "search")


def test_moe_configs():
    olmoe = get_arch("olmoe-1b-7b")
    assert olmoe.moe.n_experts == 64 and olmoe.moe.top_k == 8
    kimi = get_arch("kimi-k2-1t-a32b")
    assert kimi.moe.n_experts == 384 and kimi.moe.n_shared == 1


def test_head_dims():
    assert get_arch("starcoder2-7b").resolved_head_dim == 128
    assert get_arch("gemma3-27b").resolved_head_dim == 128  # explicit
    assert get_arch("olmo-1b").resolved_head_dim == 128
