"""Transformer correctness: serve path must reproduce the train-path logits
(the strongest KV-cache / RoPE / window-mask consistency check)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import transformer as T
from repro.models.module import init_params


def _consistency(arch: str, atol=2e-2):
    cfg = get_reduced(arch)
    cfg = dataclasses.replace(cfg, compute_dtype="float32", remat=False)
    params = init_params(T.schema(cfg), jax.random.key(0))
    S = 24
    toks = jax.random.randint(jax.random.key(1), (2, S), 0, cfg.vocab)
    logits_full, _ = T.forward(params, cfg, toks)

    # prefill on the first S-4 tokens, decode the rest one by one
    split = S - 4
    last, cache = T.prefill(params, cfg, toks[:, :split])
    cache = {k: {"k": jnp.pad(v["k"], ((0, 0), (0, 4), (0, 0), (0, 0))),
                 "v": jnp.pad(v["v"], ((0, 0), (0, 4), (0, 0), (0, 0)))}
             for k, v in cache.items()}
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(logits_full[:, split - 1]),
                               atol=atol, rtol=1e-3)
    for i in range(split, S):
        logits, cache = T.decode_step(params, cfg, cache, toks[:, i],
                                      jnp.int32(i))
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(logits_full[:, i]),
                                   atol=atol, rtol=1e-3)


def test_decode_matches_forward_dense():
    _consistency("olmo-1b")


def test_decode_matches_forward_gqa_window():
    _consistency("starcoder2-7b")  # sliding window + GQA


def test_decode_matches_forward_local_global():
    _consistency("gemma3-27b")     # 5:1 local:global + tied embeddings


def test_decode_matches_forward_moe():
    # MoE routing is capacity-bound; use generous capacity so the train
    # and decode paths route identically
    cfg = get_reduced("olmoe-1b-7b")
    cfg = dataclasses.replace(
        cfg, compute_dtype="float32", remat=False,
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = init_params(T.schema(cfg), jax.random.key(0))
    S = 16
    toks = jax.random.randint(jax.random.key(1), (2, S), 0, cfg.vocab)
    logits_full, _ = T.forward(params, cfg, toks)
    last, cache = T.prefill(params, cfg, toks[:, : S - 2])
    cache = {k: {"k": jnp.pad(v["k"], ((0, 0), (0, 2), (0, 0), (0, 0))),
                 "v": jnp.pad(v["v"], ((0, 0), (0, 2), (0, 0), (0, 0)))}
             for k, v in cache.items()}
    for i in range(S - 2, S):
        logits, cache = T.decode_step(params, cfg, cache, toks[:, i],
                                      jnp.int32(i))
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(logits_full[:, i]),
                                   atol=5e-2, rtol=1e-3)


def test_layer_windows_pattern():
    cfg = get_reduced("gemma3-27b")  # 6 layers, ratio 5:1
    w = T.layer_windows(cfg)
    assert list(w > 0) == [True] * 5 + [False]   # 5 local then 1 global
    cfg2 = get_reduced("starcoder2-7b")
    assert (T.layer_windows(cfg2) == cfg2.window).all()


def test_scan_vs_unrolled_layers_agree():
    cfg = get_reduced("olmo-1b")
    cfg = dataclasses.replace(cfg, compute_dtype="float32", remat=False)
    params = init_params(T.schema(cfg), jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab)
    a, _ = T.forward(params, cfg, toks)
    b, _ = T.forward(params, dataclasses.replace(cfg, scan_layers=False),
                     toks)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                               rtol=1e-4)


def test_unroll_mode_identical_math():
    cfg = get_reduced("olmo-1b")
    cfg = dataclasses.replace(cfg, compute_dtype="float32", remat=False)
    params = init_params(T.schema(cfg), jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 16), 0, cfg.vocab)
    a, _ = T.forward(params, cfg, toks)
    b, _ = T.forward(params, dataclasses.replace(cfg, unroll=True), toks)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                               rtol=1e-4)
