"""Serving engine: regime dispatch, shape buckets, compile cache, queue."""
import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.search_large import large_batch_search
from repro.core.search_small import small_batch_search
from repro.data.synthetic import make_clustered, recall_at_k
from repro.serve.engine import ANNEngine
from repro.serve.queue import MicroBatcher


@pytest.fixture(scope="module")
def ds():
    return make_clustered(n=3000, d=16, n_queries=128, n_clusters=24,
                          noise=0.6, seed=0)


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(get_arch("tsdg-paper"), k_graph=12,
                               max_degree=16, lambda0=8, bridge_hubs=32,
                               bridge_k=8, large_ef=48, large_hops=64,
                               serve_buckets=(8, 32, 128))


@pytest.fixture(scope="module")
def engine(ds, cfg):
    return ANNEngine(ds.X, cfg, k=10)


# ----------------------------------------------------------------------
# regime dispatch
# ----------------------------------------------------------------------

def test_regime_dispatch_boundary(engine, cfg):
    """small iff B * t0 < 4 * threshold, exactly at the configured split."""
    boundary = (4 * cfg.small_batch_threshold) // cfg.small_t0
    assert engine.regime(1) == "small"
    assert engine.regime(boundary - 1) == "small"
    assert engine.regime(boundary) == "large"
    assert engine.regime(boundary + 1) == "large"
    assert engine.regime(4096) == "large"


def test_regime_dispatch_updates_stats(ds, cfg, engine):
    before_small = engine.stats.small_batches
    before_large = engine.stats.large_batches
    engine.query(ds.Q[:2])
    engine.query(ds.Q[:64])
    assert engine.stats.small_batches == before_small + 1
    assert engine.stats.large_batches == before_large + 1


# ----------------------------------------------------------------------
# k validation (the `k or self.k` footgun)
# ----------------------------------------------------------------------

def test_k_none_uses_default(ds, engine):
    ids, _ = engine.query(ds.Q[:2], k=None)
    assert ids.shape == (2, 10)


@pytest.mark.parametrize("bad", [0, -1, 2.5, "7", True])
def test_k_invalid_raises(ds, engine, bad):
    with pytest.raises(ValueError, match="k must be a positive int"):
        engine.query(ds.Q[:2], k=bad)


def test_k_beyond_ef_raises_not_truncates(ds, cfg, engine):
    with pytest.raises(ValueError, match="exceeds large-batch ranking"):
        engine.query(ds.Q[:64], k=cfg.large_ef + 1)


def test_k_beyond_small_pool_raises(ds, cfg, engine):
    with pytest.raises(ValueError, match="exceeds small-batch candidate"):
        engine.query(ds.Q[:2], k=cfg.small_t0 * 32 + 1)


def test_kernel_k_validation():
    X = jnp.zeros((64, 4))
    from repro.core.diversify import PackedGraph
    g = PackedGraph(neighbors=jnp.zeros((64, 4), jnp.int32),
                    lambdas=jnp.zeros((64, 4), jnp.int32),
                    degrees=jnp.zeros((64,), jnp.int32), hubs=None)
    with pytest.raises(ValueError, match="exceeds the ranking array"):
        large_batch_search(X, g, X[:2], k=17, ef=16)
    with pytest.raises(ValueError, match="exceeds the candidate pool"):
        small_batch_search(X, g, X[:2], k=200, t0=2, hops=2, width=16)


# ----------------------------------------------------------------------
# shape buckets: padding correctness + compile cache
# ----------------------------------------------------------------------

def test_bucket_for_ladder(engine):
    assert engine.bucket_for(1) == 8
    assert engine.bucket_for(8) == 8
    assert engine.bucket_for(9) == 32
    assert engine.bucket_for(100) == 128
    assert engine.bucket_for(129) == 256   # beyond ladder: multiple of max
    assert engine.bucket_for(513) == 640


def test_padded_small_bitwise_matches_raw(ds, cfg, engine):
    """Bucket padding must not change the real rows' ids at all."""
    B = 5  # pads to bucket 8
    ids, dists = engine.query(ds.Q[:B])
    raw_ids, raw_d = small_batch_search(
        engine.X, engine.graph, jnp.asarray(ds.Q[:B]), k=10,
        t0=cfg.small_t0, hops=cfg.small_hops, hop_width=cfg.hop_width,
        n_seeds=cfg.n_seeds, lambda_limit=10, metric=cfg.metric)
    np.testing.assert_array_equal(ids, np.asarray(raw_ids))
    np.testing.assert_allclose(dists, np.asarray(raw_d))


def test_padded_large_bitwise_matches_raw(ds, cfg, engine):
    B = 33  # pads to bucket 128
    ids, dists = engine.query(ds.Q[:B])
    raw_ids, raw_d = large_batch_search(
        engine.X, engine.graph, jnp.asarray(ds.Q[:B]), k=10,
        ef=cfg.large_ef, hops=cfg.large_hops, lambda_limit=5,
        metric=cfg.metric, n_seeds=cfg.large_n_seeds,
        m_seg=cfg.queue_segments, seg=cfg.segment_size,
        mv_seg=cfg.visited_segments, delta=cfg.delta)
    np.testing.assert_array_equal(ids, np.asarray(raw_ids))
    np.testing.assert_allclose(dists, np.asarray(raw_d))


def test_mixed_stream_compiles_once_per_regime_bucket(ds, cfg):
    """B ∈ {1, 7, 33, 100, 513} interleaved, repeated: at most one compile
    per (regime, bucket, k) — the acceptance criterion of this subsystem."""
    small_cfg = dataclasses.replace(cfg, serve_buckets=(8, 32, 128),
                                    large_hops=24)
    eng = ANNEngine(ds.X, small_cfg, k=10)
    stream = [1, 7, 33, 100, 129] * 3
    rng = np.random.default_rng(0)
    for B in stream:
        sel = rng.integers(0, len(ds.Q), B)
        ids, _ = eng.query(ds.Q[sel])
        assert ids.shape == (B, 10)
    # buckets hit: (small, 8) by 1 and 7; (large, 128) by 33 and 100;
    # (large, 256) by 129 — three pairs, three compiles, never more
    assert eng.stats.compiles == 3
    assert eng.stats.bucket_misses == 3
    assert eng.stats.bucket_hits == len(stream) - 3
    # stats v2: warmup excluded from steady state
    st = eng.stats
    assert st.per_regime["small"].warmup_batches == 1
    assert st.per_regime["large"].warmup_batches == 2
    assert st.steady_queries == st.n_queries - (1 + 33 + 129)
    assert st.qps > 0
    p = st.per_regime["large"].percentiles()
    assert p["p50"] <= p["p99"]


def test_warmup_precompiles_all_reachable_pairs(ds, cfg):
    eng = ANNEngine(ds.X, dataclasses.replace(cfg, large_hops=24), k=10)
    n = eng.warmup()
    assert n == eng.stats.compiles >= 3
    # a following mixed stream never compiles again
    for B in (1, 7, 15, 16, 33, 100, 128):
        eng.query(ds.Q[:B])
    assert eng.stats.compiles == n


def test_padded_queries_counted(ds, engine):
    before = engine.stats.padded_queries
    engine.query(ds.Q[:5])  # bucket 8 -> 3 padded rows
    assert engine.stats.padded_queries == before + 3


def test_query_shape_validation(ds, engine):
    with pytest.raises(ValueError, match="empty query batch"):
        engine.query(np.zeros((0, 16), np.float32))
    with pytest.raises(ValueError, match="Q must be"):
        engine.query(np.zeros((4, 7), np.float32))


def test_engine_recall(ds, engine):
    ids, _ = engine.query(ds.Q)
    assert recall_at_k(ids, ds.gt, 10) > 0.85


def test_donated_query_buffer_steady_state(ds, cfg):
    """The padded query buffer is donated into each dispatch (off-CPU);
    repeated same-bucket traffic must neither recompile nor corrupt
    results when the engine hands jax arrays to a donating executable."""
    eng = ANNEngine(ds.X, cfg, k=10)
    first, _ = eng.query(ds.Q[:5])
    compiles = eng.stats.compiles
    Qj = jnp.asarray(ds.Q[:5])          # caller-owned device array
    for _ in range(6):
        ids, _ = eng.query(Qj)
        np.testing.assert_array_equal(ids, first)
    # caller's buffer survived (it must never be the donated operand)
    assert Qj.shape == (5, 16) and bool(jnp.isfinite(Qj).all())
    assert eng.stats.compiles == compiles  # steady state: zero recompiles
    # exact bucket hit (B == bucket) exercises the defensive-copy path
    ids8, _ = eng.query(ds.Q[:8])
    ids8b, _ = eng.query(jnp.asarray(ds.Q[:8]))
    np.testing.assert_array_equal(ids8, ids8b)
    assert eng.stats.compiles == compiles


# ----------------------------------------------------------------------
# micro-batching queue
# ----------------------------------------------------------------------

def test_queue_coalesces_concurrent_singles(ds, cfg):
    eng = ANNEngine(ds.X, cfg, k=10)
    eng.warmup()
    n = 24
    with MicroBatcher(eng, max_wait_ms=100, max_batch=64) as mb:
        futs = [mb.submit(ds.Q[i]) for i in range(n)]
        outs = [f.result(timeout=120) for f in futs]
    assert mb.stats.n_requests == n
    assert mb.stats.n_dispatches < n          # coalescing happened
    assert mb.stats.mean_coalesced > 1.0
    hits = 0
    for i, (ids, dists) in enumerate(outs):
        assert ids.shape == (10,) and dists.shape == (10,)
        hits += recall_at_k(ids[None], ds.gt[i:i + 1], 10)
    assert hits / n > 0.85


def test_queue_concurrent_threads(ds, cfg):
    eng = ANNEngine(ds.X, cfg, k=10)
    eng.warmup()
    results = {}

    def worker(tid):
        with_lock = [MB.submit(ds.Q[tid * 4 + j]) for j in range(4)]
        results[tid] = [f.result(timeout=120) for f in with_lock]

    with MicroBatcher(eng, max_wait_ms=50, max_batch=32) as MB:
        threads = [threading.Thread(target=worker, args=(t,))
                   for t in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
    assert sorted(results) == list(range(6))
    for tid, outs in results.items():
        for j, (ids, _) in enumerate(outs):
            r = recall_at_k(ids[None], ds.gt[tid * 4 + j:tid * 4 + j + 1],
                            10)
            assert ids.shape == (10,)


def test_queue_groups_by_k(ds, cfg):
    eng = ANNEngine(ds.X, cfg, k=10)
    with MicroBatcher(eng, max_wait_ms=30, max_batch=64) as mb:
        f5 = [mb.submit(ds.Q[i], k=5) for i in range(4)]
        f10 = [mb.submit(ds.Q[i], k=10) for i in range(4)]
        for f in f5:
            assert f.result(timeout=120)[0].shape == (5,)
        for f in f10:
            assert f.result(timeout=120)[0].shape == (10,)
    # k=5 and k=10 need different compiled shapes -> separate dispatches
    assert mb.stats.n_dispatches >= 2


def test_queue_batch_submissions(ds, cfg):
    eng = ANNEngine(ds.X, cfg, k=10)
    with MicroBatcher(eng, max_wait_ms=20) as mb:
        f = mb.submit(ds.Q[:6])
        ids, dists = f.result(timeout=120)
    assert ids.shape == (6, 10)


def test_queue_propagates_errors(ds, cfg):
    eng = ANNEngine(ds.X, cfg, k=10)
    with MicroBatcher(eng, max_wait_ms=10) as mb:
        f = mb.submit(ds.Q[0], k=cfg.small_t0 * 32 + 1)
        with pytest.raises(ValueError, match="exceeds small-batch"):
            f.result(timeout=120)
        # the dispatcher survived the failed dispatch and still serves
        ids, _ = mb.submit(ds.Q[1]).result(timeout=120)
        assert ids.shape == (10,)


def test_queue_rejects_wrong_dim_at_submit(ds, cfg):
    eng = ANNEngine(ds.X, cfg, k=10)
    with MicroBatcher(eng, max_wait_ms=10) as mb:
        with pytest.raises(ValueError, match="Q must be"):
            mb.submit(np.zeros((8,), np.float32))  # d mismatch (16 expected)
        ids, _ = mb.submit(ds.Q[0]).result(timeout=120)
        assert ids.shape == (10,)


def test_queue_rejects_after_close(ds, cfg):
    eng = ANNEngine(ds.X, cfg, k=10)
    mb = MicroBatcher(eng)
    mb.close()
    with pytest.raises(RuntimeError, match="closed"):
        mb.submit(ds.Q[0])


# ----------------------------------------------------------------------
# mesh backend (in-process 1-device mesh; multi-device lives in
# test_distributed.py subprocesses)
# ----------------------------------------------------------------------

def test_mesh_engine_same_api(ds, cfg):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    eng = ANNEngine(ds.X, dataclasses.replace(cfg, large_hops=24),
                    k=10, mesh=mesh)
    for B in (3, 33, 3, 33):
        ids, dists = eng.query(ds.Q[:B])
        assert ids.shape == (B, 10)
    assert eng.stats.compiles == 2
    assert eng.stats.bucket_hits == 2
    ids, _ = eng.query(ds.Q)
    assert recall_at_k(ids, ds.gt, 10) > 0.8


def test_mesh_engine_rejects_prebuilt_graph(ds, cfg, engine):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="mesh mode builds its own"):
        ANNEngine(ds.X, cfg, k=10, mesh=mesh, graph=engine.graph)


# ----------------------------------------------------------------------
# BatcherStats thread-safety + close(drain) race (regression)
# ----------------------------------------------------------------------

class _StubEngine:
    """Minimal engine stand-in so queue tests control timing exactly."""

    def __init__(self, d: int = 4, delay_s: float = 0.0,
                 max_wait_ms: float = 5.0):
        self.X = np.zeros((16, d), np.float32)
        self.cfg = dataclasses.replace(
            get_arch("tsdg-paper"), queue_max_wait_ms=max_wait_ms,
            queue_max_batch=64)
        self.delay_s = delay_s
        self.n_calls = 0

    def query(self, Q, k=None):
        import time
        self.n_calls += 1
        if self.delay_s:
            time.sleep(self.delay_s)
        k = 3 if k is None else k
        B = Q.shape[0]
        return (np.zeros((B, k), np.int32), np.zeros((B, k), np.float32))


def test_batcher_stats_snapshot_consistent_under_threads():
    """Counters are mutated by the dispatcher while callers read them; a
    snapshot must never show a torn state (n_dispatches bumped before the
    matching n_queries), and the final totals must add up exactly."""
    eng = _StubEngine(delay_s=0.002)
    n_threads, per_thread = 6, 20
    bad = []
    stop = threading.Event()

    def reader(mb):
        while not stop.is_set():
            s = mb.stats.snapshot()
            # invariants of any consistent view: every dispatch carries at
            # least one request and one query, requests >= dispatches,
            # queries >= dispatches, window sum <= total queries
            if not (s["n_requests"] >= s["n_dispatches"]
                    and s["n_queries"] >= s["n_dispatches"]
                    and sum(s["dispatch_sizes"]) <= s["n_queries"]
                    and (s["n_dispatches"] == 0
                         or s["mean_coalesced"] >= 1.0)):
                bad.append(s)

    with MicroBatcher(eng, max_wait_ms=2, max_batch=16) as mb:
        rt = threading.Thread(target=reader, args=(mb,))
        rt.start()
        futs = []

        def worker():
            for _ in range(per_thread):
                futs.append(mb.submit(np.zeros(4, np.float32)))

        threads = [threading.Thread(target=worker)
                   for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        for f in list(futs):
            f.result(timeout=60)
        stop.set()
        rt.join(timeout=60)
    assert not bad, bad[:3]
    snap = mb.stats.snapshot()
    assert snap["n_requests"] == n_threads * per_thread
    assert snap["n_queries"] == n_threads * per_thread
    assert snap["mean_coalesced"] == pytest.approx(
        snap["n_queries"] / snap["n_dispatches"])


def test_queue_close_drain_serves_racing_submit():
    """A request enqueued behind the shutdown sentinel (submit racing
    close) must be SERVED by close(drain=True), not failed."""
    from concurrent.futures import Future

    from repro.serve.queue import _Request

    eng = _StubEngine(delay_s=0.3)
    mb = MicroBatcher(eng, max_wait_ms=1, max_batch=4)
    # occupy the dispatcher inside engine.query for 0.3s
    f1 = mb.submit(np.zeros(4, np.float32))
    closer = threading.Thread(target=mb.close)
    import time
    time.sleep(0.05)          # let the dispatcher pick f1 up
    closer.start()
    time.sleep(0.05)          # close() has put its sentinel by now
    racer = _Request(Q=np.zeros((2, 4), np.float32), k=None, single=False,
                     future=Future())
    mb._q.put(racer)          # the race: enqueued behind the sentinel
    closer.join(timeout=60)
    ids, dists = f1.result(timeout=60)
    assert ids.shape == (3,)
    ids2, _ = racer.future.result(timeout=60)   # served, not failed
    assert ids2.shape == (2, 3)


def test_queue_close_no_drain_fails_racing_submit():
    from concurrent.futures import Future

    from repro.serve.queue import _Request

    eng = _StubEngine(delay_s=0.2)
    mb = MicroBatcher(eng, max_wait_ms=1, max_batch=4)
    f1 = mb.submit(np.zeros(4, np.float32))
    import time
    time.sleep(0.05)
    closer = threading.Thread(target=lambda: mb.close(drain=False))
    closer.start()
    time.sleep(0.05)
    racer = _Request(Q=np.zeros((2, 4), np.float32), k=None, single=False,
                     future=Future())
    mb._q.put(racer)
    closer.join(timeout=60)
    with pytest.raises(RuntimeError, match="closed"):
        racer.future.result(timeout=60)
