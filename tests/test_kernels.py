"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _arr(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32)).astype(dtype)


# ----------------------------------------------------------------------
# distance matrix
# ----------------------------------------------------------------------

@pytest.mark.parametrize("B,N,d", [(8, 16, 4), (70, 200, 48), (128, 256, 128),
                                   (1, 300, 33)])
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_l2dist_shapes(rng, B, N, d, metric):
    Q = _arr(rng, (B, d), jnp.float32)
    X = _arr(rng, (N, d), jnp.float32)
    a = ops.distance_matrix(Q, X, metric=metric, interpret=True)
    b = ref.distance_matrix_ref(Q, X, metric=metric)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_l2dist_dtypes(rng, dtype):
    Q = _arr(rng, (32, 64), dtype)
    X = _arr(rng, (64, 64), dtype)
    a = ops.distance_matrix(Q, X, metric="l2", interpret=True)
    b = ref.distance_matrix_ref(Q.astype(jnp.float32),
                                X.astype(jnp.float32), metric="l2")
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=tol, atol=tol * 64)


# ----------------------------------------------------------------------
# bitonic sort / top-k
# ----------------------------------------------------------------------

@pytest.mark.parametrize("R,W", [(3, 8), (37, 32), (64, 64), (17, 128),
                                 (200, 16)])
def test_bitonic_sort_shapes(rng, R, W):
    d = _arr(rng, (R, W), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 10_000, size=(R, W)).astype(np.int32))
    sd, si = ops.bitonic_sort(d, ids, interpret=True)
    rd, ri = ref.sort_ref(d, ids)
    np.testing.assert_array_equal(np.asarray(sd), np.asarray(rd))
    np.testing.assert_array_equal(np.asarray(si), np.asarray(ri))


def test_bitonic_sort_with_duplicates(rng):
    d = jnp.asarray(rng.integers(0, 4, size=(20, 32)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 8, size=(20, 32)).astype(np.int32))
    sd, si = ops.bitonic_sort(d, ids, interpret=True)
    rd, ri = ref.sort_ref(d, ids)
    np.testing.assert_array_equal(np.asarray(sd), np.asarray(rd))
    np.testing.assert_array_equal(np.asarray(si), np.asarray(ri))


def test_bitonic_topk(rng):
    d = _arr(rng, (16, 64), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 1000, size=(16, 64)).astype(np.int32))
    td, ti = ops.bitonic_topk(d, ids, 10, interpret=True)
    rd2, ri2 = ref.topk_ref(d, ids, 10)
    np.testing.assert_array_equal(np.asarray(td), np.asarray(rd2))


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,hd", [(1, 128, 4, 4, 16),
                                         (2, 256, 4, 2, 32),
                                         (1, 384, 8, 1, 64)])
@pytest.mark.parametrize("window", [0, 64])
def test_flash_attention_shapes(rng, B, S, H, KV, hd, window):
    q = _arr(rng, (B, S, H, hd), jnp.float32)
    k = _arr(rng, (B, S, KV, hd), jnp.float32)
    v = _arr(rng, (B, S, KV, hd), jnp.float32)
    a = ops.flash_attention(q, k, v, window=window, interpret=True)
    b = ref.attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16(rng):
    q = _arr(rng, (1, 128, 2, 32), jnp.bfloat16)
    k = _arr(rng, (1, 128, 2, 32), jnp.bfloat16)
    v = _arr(rng, (1, 128, 2, 32), jnp.bfloat16)
    a = ops.flash_attention(q, k, v, interpret=True).astype(jnp.float32)
    b = ref.attention_ref(q, k, v).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=5e-2, atol=5e-2)


def test_flash_matches_chunked_model_path(rng):
    """The model's XLA attention and the Pallas kernel must agree."""
    from repro.models.layers import chunked_attention

    q = _arr(rng, (2, 128, 4, 16), jnp.float32)
    k = _arr(rng, (2, 128, 2, 16), jnp.float32)
    v = _arr(rng, (2, 128, 2, 16), jnp.float32)
    for w in (0, 32):
        a = ops.flash_attention(q, k, v, window=w, interpret=True)
        b = chunked_attention(q, k, v, window=w, chunk_q=64, chunk_kv=64)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------------------
# embedding bag / packed spmm
# ----------------------------------------------------------------------

@pytest.mark.parametrize("V,E,B,bag", [(100, 8, 8, 3), (500, 16, 19, 7),
                                       (1000, 32, 64, 10)])
@pytest.mark.parametrize("combine", ["mean", "sum"])
def test_embedding_bag_shapes(rng, V, E, B, bag, combine):
    table = _arr(rng, (V, E), jnp.float32)
    ids = jnp.asarray(rng.integers(0, V, size=(B, bag)).astype(np.int32))
    a = ops.embedding_bag(table, ids, combine=combine, interpret=True)
    b = ref.embedding_bag_ref(table, ids, combine=combine)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("N,M,d,f", [(50, 6, 24, 8), (100, 16, 32, 16)])
@pytest.mark.parametrize("combine", ["sum", "mean"])
def test_packed_spmm(rng, N, M, d, f, combine):
    feat = _arr(rng, (N, d), jnp.float32)
    nbrs = jnp.asarray(rng.integers(0, N + 30, size=(N, M)).astype(np.int32))
    w = _arr(rng, (d, f), jnp.float32)
    a = ops.packed_spmm(nbrs, feat, w, combine=combine, interpret=True)
    b = ops.packed_spmm(nbrs, feat, w, combine=combine, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)
