"""MoE dispatch correctness: the sort-based scatter dispatch must equal a
naive per-token dense computation when capacity is unconstrained."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.models import moe as moe_lib


def _params(key, d, E, f):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d)
    return {
        "router": jax.random.normal(k1, (d, E)) * s,
        "w_gate": jax.random.normal(k2, (E, d, f)) * s,
        "w_up": jax.random.normal(k3, (E, d, f)) * s,
        "w_down": jax.random.normal(k4, (E, f, d)) / np.sqrt(f),
    }


def _naive(x, params, cfg):
    """Per-token loop over its top-k experts (oracle)."""
    logits = x @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    T = x.shape[0]
    out = np.zeros_like(np.asarray(x))
    for t in range(T):
        for s in range(cfg.top_k):
            e = int(top_e[t, s])
            h = np.asarray(x[t]) @ np.asarray(params["w_gate"][e])
            u = np.asarray(x[t]) @ np.asarray(params["w_up"][e])
            y = (np.asarray(jax.nn.silu(jnp.asarray(h))) * u) \
                @ np.asarray(params["w_down"][e])
            out[t] += float(top_p[t, s]) * y
    return out


def test_dispatch_matches_naive():
    cfg = MoEConfig(n_experts=8, top_k=2, d_expert=16, capacity_factor=8.0)
    key = jax.random.key(0)
    params = _params(key, 12, 8, 16)
    x = jax.random.normal(jax.random.key(1), (24, 12))
    y, aux = moe_lib.moe_ffn(x, params, cfg)
    assert float(aux["dropped_fraction"]) == 0.0
    np.testing.assert_allclose(np.asarray(y), _naive(x, params, cfg),
                               rtol=1e-4, atol=1e-4)


def test_capacity_drops_tokens():
    cfg = MoEConfig(n_experts=4, top_k=2, d_expert=8, capacity_factor=0.25)
    params = _params(jax.random.key(0), 8, 4, 8)
    x = jax.random.normal(jax.random.key(1), (64, 8))
    y, aux = moe_lib.moe_ffn(x, params, cfg)
    assert float(aux["dropped_fraction"]) > 0.0
    assert jnp.all(jnp.isfinite(y))


def test_aux_losses_finite_and_scaled():
    cfg = MoEConfig(n_experts=8, top_k=2, d_expert=8)
    params = _params(jax.random.key(0), 8, 8, 8)
    x = jax.random.normal(jax.random.key(1), (32, 8))
    _, aux = moe_lib.moe_ffn(x, params, cfg)
    # perfectly balanced load-balance loss would be aux_loss * 1.0
    assert 0.0 < float(aux["load_balance_loss"]) < 10 * cfg.aux_loss
    assert float(aux["router_z_loss"]) >= 0.0


def test_grad_flows_through_dispatch():
    cfg = MoEConfig(n_experts=4, top_k=2, d_expert=8, capacity_factor=4.0)
    params = _params(jax.random.key(0), 8, 4, 8)
    x = jax.random.normal(jax.random.key(1), (16, 8))

    def loss(p):
        y, _ = moe_lib.moe_ffn(x, p, cfg)
        return jnp.sum(y * y)

    g = jax.grad(loss)(params)
    for k, v in g.items():
        assert jnp.any(v != 0), f"zero grad for {k}"
        assert jnp.all(jnp.isfinite(v))
