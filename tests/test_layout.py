"""Locality-packed graph layout + in-kernel visited filter (DESIGN.md §10).

Three layers of contract:

* host layout algebra — ``locality_order`` is a permutation,
  ``apply_layout`` is a bitwise row gather with exactly relabeled
  adjacency, ``unpack_rows`` inverts it (per shard slice on the mesh),
  and the layout module's ``span_group`` agrees with the kernel's;
* kernel spans — a contiguous-span idx block through the grouped-DMA
  gather path is bitwise the XLA oracle (the coalesced copies move the
  same bytes), and the visited-filter Pallas kernel is bitwise its XLA
  scan reference;
* end-to-end equivariance — a packed index answers bitwise-identically
  to an unpacked one through the facade, in both regimes, on both
  planes, with and without the hash visited filter, across streaming
  mutations, compaction, and a v5 artifact round-trip (zero compiles).
"""
import dataclasses
import functools
import tempfile
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ann import Index
from repro.ann import layout as LY
from repro.configs.base import ANNConfig
from repro.core import hotpath as HP
from repro.kernels import l2dist as L2
from repro.kernels import visited as VF

PACKED_PIPE = ("knn", "diversify", "bridges", "layout")


@pytest.fixture(scope="module")
def base_kwargs():
    return dict(max_degree=8, hop_width=8, k_graph=12, n_seeds=4,
                small_t0=4, small_hops=3, large_ef=24, large_hops=10,
                serve_buckets=(8, 64), kernel_backend="xla")


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(7)
    X = rng.standard_normal((384, 24)).astype(np.float32)
    Qs = rng.standard_normal((4, 24)).astype(np.float32)
    Ql = rng.standard_normal((64, 24)).astype(np.float32)
    return X, Qs, Ql


@pytest.fixture(scope="module")
def built(corpus, base_kwargs):
    """One build of each variant, shared by the equivalence tests."""
    X, _, _ = corpus
    out = {}
    out["plain"] = Index.build(X, ANNConfig(**base_kwargs))
    out["packed"] = Index.build(
        X, ANNConfig(**base_kwargs, build_pipeline=PACKED_PIPE))
    out["hash"] = Index.build(
        X, ANNConfig(**base_kwargs, visited_filter="hash"))
    out["packed_hash"] = Index.build(
        X, ANNConfig(**base_kwargs, build_pipeline=PACKED_PIPE,
                     visited_filter="hash"))
    return out


def _bitwise(a, b):
    return (bool(np.array_equal(a[0], b[0]))
            and bool(np.array_equal(np.asarray(a[1]).view(np.uint32),
                                    np.asarray(b[1]).view(np.uint32))))


# ----------------------------------------------------------------------
# host layout algebra
# ----------------------------------------------------------------------

def test_locality_order_is_permutation(rng):
    N, M = 97, 6
    nb = rng.integers(0, N + 1, size=(N, M)).astype(np.int32)
    perm = LY.locality_order(nb)
    assert perm.dtype == np.int32
    assert sorted(perm.tolist()) == list(range(N))
    inv = LY.inverse_permutation(perm)
    np.testing.assert_array_equal(inv[perm], np.arange(N))


def test_locality_order_starts_first(rng):
    N = 40
    nb = np.full((N, 4), N, np.int32)  # edgeless: order = starts then scan
    perm = LY.locality_order(nb, starts=[7, 3])
    assert perm[0] == 7 and perm[1] == 3 and perm[2] == 0


def test_apply_layout_bitwise_rows_and_exact_relabel(rng):
    N, M, d = 64, 5, 12
    X = rng.standard_normal((N, d)).astype(np.float32)
    nb = rng.integers(-1, N + 1, size=(N, M)).astype(np.int32)
    nb[nb < 0] = N  # sentinel for absent
    lam = rng.standard_normal((N, M)).astype(np.float32)
    deg = (nb < N).sum(1).astype(np.int32)
    hubs = np.array([3, 9, 41], np.int32)
    perm = LY.locality_order(nb, starts=hubs)
    X2, nb2, lam2, deg2, hubs2 = LY.apply_layout(perm, X, nb, lam, deg, hubs)
    inv = LY.inverse_permutation(perm)
    # rows are the SAME bits, just moved
    np.testing.assert_array_equal(X2.view(np.uint32), X[perm].view(np.uint32))
    np.testing.assert_array_equal(deg2, deg[perm])
    # hubs keep pointing at the same vectors
    np.testing.assert_array_equal(X2[hubs2].view(np.uint32),
                                  X[hubs].view(np.uint32))
    # each packed row holds the same neighbor SET, relabeled, sentinel kept
    for i in range(N):
        old = nb[perm[i]]
        want = sorted(int(inv[v]) if v < N else N for v in old)
        assert nb2[i].tolist() == want
        # λ follows its lane through the re-sort
        lam_of = {int(inv[v]) if v < N else N: set() for v in old}
        for v, l in zip(old, lam[perm[i]]):
            lam_of[int(inv[v]) if v < N else N].add(np.float32(l))
        for v, l in zip(nb2[i], lam2[i]):
            assert np.float32(l) in lam_of[int(v)]


@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_unpack_rows_roundtrip(rng, n_shards):
    N, d = 48, 7
    X = rng.standard_normal((N, d)).astype(np.float32)
    n_local = N // n_shards
    perms = [np.random.default_rng(s).permutation(n_local).astype(np.int32)
             for s in range(n_shards)]
    packed = np.concatenate(
        [X[s * n_local:(s + 1) * n_local][p] for s, p in enumerate(perms)])
    out = LY.unpack_rows(packed, np.concatenate(perms), n_shards=n_shards)
    np.testing.assert_array_equal(out.view(np.uint32), X.view(np.uint32))


def test_unpack_rows_rejects_ragged_shards(rng):
    with pytest.raises(ValueError, match="not divisible"):
        LY.unpack_rows(np.zeros((10, 2), np.float32),
                       np.arange(10), n_shards=3)


def test_span_group_matches_kernel():
    for C in range(1, 65):
        assert LY.span_group(C) == L2.span_group(C), C
    assert LY.span_group(32) == 8
    assert LY.span_group(24) == 8
    assert LY.span_group(12) == 4
    assert LY.span_group(7) == 1


def test_span_stats_contiguous_vs_shuffled(rng):
    N, C = 32, 16  # G = 8
    contig = (np.arange(N)[:, None] % (N - C) + np.arange(C)).astype(np.int32)
    st = LY.span_stats(contig)
    assert st["group"] == 8
    assert st["frac_coalesced"] == 1.0
    assert st["rows_per_copy"] == 8.0
    shuf = rng.permuted(contig, axis=1).astype(np.int32)
    st2 = LY.span_stats(shuf)
    assert st2["rows_per_copy"] < st["rows_per_copy"]
    # layout actually raises the metric on a real graph (degree >= 2*G so
    # a row's fresh run can cover whole aligned groups)
    from repro.data.synthetic import make_clustered
    ds = make_clustered(n=1024, d=16, n_queries=4, n_clusters=24,
                        noise=0.6, seed=0)
    cfg = ANNConfig(max_degree=16, k_graph=24, kernel_backend="xla")
    g_plain = Index(ds.X, cfg).graph
    before = LY.span_stats(np.asarray(g_plain.neighbors))
    g_packed = Index(ds.X, dataclasses.replace(
        cfg, build_pipeline=PACKED_PIPE)).graph
    after = LY.span_stats(np.asarray(g_packed.neighbors))
    assert after["rows_per_copy"] > before["rows_per_copy"]
    assert after["rows_per_copy"] > 1.0


# ----------------------------------------------------------------------
# kernel spans: coalesced-DMA gather parity
# ----------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("backend", "gf"))
def _ndg(Q, X, idx, mask, backend, gf):
    return HP.neighbor_distances(Q, X, idx, metric="l2", mask=mask,
                                 backend=backend, gather_fused=gf)


@pytest.mark.parametrize("C", [8, 16, 24, 32])
def test_gather_fused_span_parity(rng, C):
    """Fully-contiguous, partially-contiguous, and shuffled idx blocks all
    agree bitwise with the XLA oracle — the span fast path and the per-row
    fallback move the same bytes."""
    S, d, N = 12, 32, 200
    X = jnp.asarray(rng.normal(size=(N, d)).astype(np.float32))
    Q = jnp.asarray(rng.normal(size=(S, d)).astype(np.float32))
    base = rng.integers(0, N - C, size=(S, 1))
    cases = {
        "contig": base + np.arange(C),
        "shuffled": rng.permuted(base + np.arange(C), axis=1),
        "mixed": np.where(np.arange(C) < C // 2,
                          base + np.arange(C),
                          rng.integers(-2, N + 9, size=(S, C))),
        "boundary": np.clip(base + np.arange(C), 0, N - 1) * 0 + (N - C)
        + np.arange(C),  # span ending exactly at N
    }
    for name, idx_np in cases.items():
        idx = jnp.asarray(idx_np.astype(np.int32))
        mask = jnp.asarray(rng.random((S, C)) > 0.2)
        a = _ndg(Q, X, idx, mask, "xla", None)
        b = _ndg(Q, X, idx, mask, "pallas", "on")
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"C={C} {name}")


def test_gather_fused_span_parity_int8(rng):
    """Quantized rows ride the same span detector (1 byte/row element)."""
    S, C, d, N = 9, 16, 24, 150
    X = rng.normal(size=(N, d)).astype(np.float32)
    from repro.ann.quantize import quantize_rows
    codes, scales = quantize_rows(jnp.asarray(X))
    Q = jnp.asarray(rng.normal(size=(S, d)).astype(np.float32))
    base = rng.integers(0, N - C, size=(S, 1))
    idx = jnp.asarray((base + np.arange(C)).astype(np.int32))
    mask = jnp.asarray(np.ones((S, C), bool))

    @functools.partial(jax.jit, static_argnames=("backend", "gf"))
    def nd(Q, Xc, idx, mask, sc, backend, gf):
        return HP.neighbor_distances(Q, Xc, idx, metric="l2", mask=mask,
                                     backend=backend, gather_fused=gf,
                                     scales=sc)

    a = nd(Q, codes, idx, mask, scales, "xla", None)
    b = nd(Q, codes, idx, mask, scales, "pallas", "on")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------------------
# visited filter primitive
# ----------------------------------------------------------------------

def _vf_case(rng, B, M, W, S, id_bound):
    table = np.full((B, W, S), VF.VF_EMPTY, np.int32)
    ids = rng.integers(0, id_bound, size=(B, M)).astype(np.int32)
    valid = rng.random((B, M)) > 0.25
    return jnp.asarray(table), jnp.asarray(ids), jnp.asarray(valid)


@pytest.mark.parametrize("B,M,W,S", [(3, 5, 2, 8), (16, 24, 8, 64),
                                     (13, 17, 4, 32)])
def test_visited_filter_backend_parity(rng, B, M, W, S):
    table, ids, valid = _vf_case(rng, B, M, W, S, id_bound=40)
    a_t, a_f = jax.jit(VF.visited_filter_xla)(table, ids, valid)
    b_t, b_f = VF.visited_filter_pallas(table, ids, valid, interpret=True)
    np.testing.assert_array_equal(np.asarray(a_t), np.asarray(b_t))
    np.testing.assert_array_equal(np.asarray(a_f), np.asarray(b_f))


def test_visited_filter_semantics():
    # duplicates within a call: only the FIRST lane of an id is fresh
    table = jnp.full((1, 2, 8), VF.VF_EMPTY, jnp.int32)
    ids = jnp.asarray([[5, 5, 9, 5]], jnp.int32)
    valid = jnp.ones((1, 4), bool)
    t2, fresh = VF.visited_filter_xla(table, ids, valid)
    assert fresh.tolist() == [[True, False, True, False]]
    # a second call re-presenting the ids sees them all as visited
    _, fresh2 = VF.visited_filter_xla(t2, ids, valid)
    assert not bool(np.asarray(fresh2).any())
    # invalid lanes are never fresh and never inserted
    _, fresh3 = VF.visited_filter_xla(table, ids, jnp.zeros((1, 4), bool))
    assert not bool(np.asarray(fresh3).any())


def test_visited_filter_full_bucket_drops():
    """W ids in one bucket fill it; the (W+1)-th distinct id hashing there
    reports not-fresh (a safe drop, never a duplicate)."""
    W, S = 2, 8
    shift = VF.shift_for(S)
    bucket0 = [i for i in range(1000)
               if int(VF.hash_bucket(jnp.int32(i), shift)) == 0][:W + 1]
    table = jnp.full((1, W, S), VF.VF_EMPTY, jnp.int32)
    ids = jnp.asarray([bucket0], jnp.int32)
    valid = jnp.ones((1, W + 1), bool)
    _, fresh = VF.visited_filter_xla(table, ids, valid)
    assert fresh.tolist() == [[True] * W + [False]]


def test_visited_table_sizing():
    tab = HP.visited_table(4, 100)
    B, W, S = tab.shape
    assert B == 4 and S & (S - 1) == 0
    assert W * S >= 2 * 100  # load factor <= 1/2
    assert int(jnp.min(tab)) == VF.VF_EMPTY


# ----------------------------------------------------------------------
# end-to-end equivariance through the facade
# ----------------------------------------------------------------------

def test_packed_graph_carries_perm(built):
    g = built["packed"].graph
    assert g.perm is not None
    assert sorted(np.asarray(g.perm).tolist()) == list(range(g.n))
    assert built["plain"].graph.perm is None
    # the perm rides the operand list last
    assert built["packed"].plane.operands()[-1] is g.perm


@pytest.mark.parametrize("pair", [("plain", "packed"),
                                  ("hash", "packed_hash")])
def test_packed_vs_unpacked_bitwise_single_plane(built, corpus, pair):
    X, Qs, Ql = corpus
    a_i, b_i = built[pair[0]], built[pair[1]]
    for Q in (Qs, Ql):
        assert _bitwise(a_i.search(Q, k=5), b_i.search(Q, k=5))


def test_packed_vs_unpacked_bitwise_mesh_1x1(corpus, base_kwargs):
    X, Qs, Ql = corpus
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg_p = ANNConfig(**base_kwargs, build_pipeline=PACKED_PIPE,
                      visited_filter="hash")
    cfg_u = ANNConfig(**base_kwargs, visited_filter="hash")
    i_p = Index.build(X, cfg_p, mesh=mesh)
    i_u = Index.build(X, cfg_u, mesh=mesh)
    assert i_p.graph.perm is not None
    for Q in (Qs, Ql):
        assert _bitwise(i_u.search(Q, k=5), i_p.search(Q, k=5))


def test_packed_streaming_tombstones_external_ids(corpus, base_kwargs):
    """delete()/add() speak EXTERNAL ids on a packed plane; compaction
    un-permutes before cutting the corpus so id_map stays external."""
    X, Qs, _ = corpus
    cfg = ANNConfig(**base_kwargs, build_pipeline=PACKED_PIPE,
                    visited_filter="hash")
    idx = Index.build(X, cfg)
    victim = int(idx.search(Qs, k=1)[0][0, 0])
    new_ids = idx.add(np.random.default_rng(3).standard_normal(
        (3, X.shape[1])).astype(np.float32))
    idx.delete([victim, int(new_ids[0])])
    ids, _ = idx.search(Qs, k=5)
    assert victim not in ids and int(new_ids[0]) not in ids
    id_map = idx.compact()
    assert id_map[victim] == -1 and id_map[int(new_ids[0])] == -1
    assert idx.generation == 1
    # post-compaction: packed again, victim still gone
    assert idx.graph.perm is not None
    ids2, _ = idx.search(Qs, k=5)
    assert victim not in np.asarray(ids2)


def test_packed_compaction_bitwise_cold_build(corpus, base_kwargs):
    X, Qs, _ = corpus
    cfg = ANNConfig(**base_kwargs, build_pipeline=PACKED_PIPE)
    idx = Index.build(X, cfg)
    idx.delete([0, 1])
    idx.compact()
    cold = Index.build(X[2:], cfg)
    a = idx.search(Qs, k=5)
    b = cold.search(Qs, k=5)
    # compaction densified: new ids == positions in the trimmed corpus
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]).view(np.uint32),
                                  np.asarray(b[1]).view(np.uint32))


def test_v5_artifact_roundtrip_zero_compiles(corpus, base_kwargs, tmp_path):
    X, Qs, _ = corpus
    cfg = ANNConfig(**base_kwargs, build_pipeline=PACKED_PIPE,
                    visited_filter="hash")
    idx = Index.build(X, cfg)
    a = idx.search(Qs, k=5)
    idx.save(tmp_path / "v5", extra_ks=(5,))
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # fingerprint mismatch would warn
        idx2 = Index.load(tmp_path / "v5")
    assert idx2.graph.perm is not None
    b = idx2.search(Qs, k=5)
    assert _bitwise(a, b)
    assert idx2.stats.compiles == 0
    assert idx2.stats.aot_primed > 0


def test_v5_mesh_artifact_roundtrip(corpus, base_kwargs, tmp_path):
    X, Qs, _ = corpus
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = ANNConfig(**base_kwargs, build_pipeline=PACKED_PIPE)
    idx = Index.build(X, cfg, mesh=mesh)
    a = idx.search(Qs, k=5)
    idx.save(tmp_path / "m5", extra_ks=(5,))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        idx2 = Index.load(tmp_path / "m5",
                          mesh=jax.make_mesh((1, 1), ("data", "model")))
    b = idx2.search(Qs, k=5)
    assert _bitwise(a, b)
    assert idx2.stats.compiles == 0


def test_h2d_staging_counter(corpus, base_kwargs):
    X, Qs, Ql = corpus
    idx = Index.build(X, ANNConfig(**base_kwargs))
    for _ in range(2):
        idx.search(Qs, k=5)
        idx.search(Ql, k=5)
    st = idx.stats
    assert st.h2d_staged == 4
    # both bucket shapes were re-hit on round 2: the staging route is
    # per-(shape, dtype) cached, not rebuilt per call
    assert st.h2d_stage_reuses >= 2
    assert st.snapshot()["h2d_stage_reuses"] == st.h2d_stage_reuses


def test_gather_limit_rejected_on_packed_graph(base_kwargs):
    with pytest.raises(ValueError, match="gather_limit"):
        ANNConfig(**base_kwargs, build_pipeline=PACKED_PIPE,
                  gather_limit=4)
