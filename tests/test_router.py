"""Request router (DESIGN.md §9): replicated/sharded dispatch, bitwise
parity, failover under replica kills, health-check eject/readmit, and
aggregated stats.

In-process tests drive real :class:`ANNEngine` endpoints.  The sharded
router <-> mesh plane parity acceptance runs in a subprocess with two
emulated devices (device count is locked at jax init), mirroring
``tests/test_mesh_plane``.  The regime threshold is pinned static in every
parity test — dispatch must agree across endpoints for the comparison to
be meaningful (the pod-plane caveat in ``repro/serve/pod.py`` applies to
routers the same way).
"""
import dataclasses
import os
import subprocess
import sys
import time
from concurrent.futures import wait

import numpy as np
import pytest

from repro.ann import Index
from repro.configs import get_arch
from repro.core.distributed import merge_shard_results
from repro.data.synthetic import make_clustered, recall_at_k
from repro.serve.router import (NoHealthyReplicas, PartialResultError,
                                ReplicaDead, Router, RouterConfig,
                                parse_router_spec, replicate_engine,
                                shard_engines)

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str, devices: int = 2):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def _bitwise(a, b):
    return (bool(np.array_equal(a[0], b[0]))
            and bool(np.array_equal(np.asarray(a[1]).view(np.uint32),
                                    np.asarray(b[1]).view(np.uint32))))


@pytest.fixture(scope="module")
def ds():
    return make_clustered(n=1024, d=16, n_queries=64, n_clusters=16,
                          noise=0.6, seed=0)


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(get_arch("tsdg-paper"), k_graph=8,
                               max_degree=12, lambda0=4, bridge_hubs=16,
                               bridge_k=4, large_ef=32, large_hops=16,
                               serve_buckets=(8, 64))


@pytest.fixture(scope="module")
def thresh(cfg):
    # population rule B*t0 < 4*thr: B < 32 -> small, B >= 32 -> large
    return 8.0 * cfg.small_t0


@pytest.fixture(scope="module")
def idx(ds, cfg, thresh):
    index = Index.build(ds.X, cfg, k=10, threshold=thresh)
    index.warmup()
    return index


# ----------------------------------------------------------------------
# config + construction validation
# ----------------------------------------------------------------------

def test_router_config_did_you_mean():
    with pytest.raises(ValueError, match="did you mean 'replicated'"):
        RouterConfig(mode="replcated")
    with pytest.raises(ValueError, match="did you mean 'least_loaded'"):
        RouterConfig(policy="least_loded")
    with pytest.raises(ValueError, match="replicas"):
        RouterConfig(replicas=0)
    with pytest.raises(ValueError, match="endpoint_names"):
        RouterConfig(replicas=2, endpoint_names=("lonely",))
    with pytest.raises(ValueError, match="readmit_probes"):
        RouterConfig(readmit_probes=0)
    with pytest.raises(ValueError, match="probe_timeout_s"):
        RouterConfig(probe_timeout_s=0.0)


def test_parse_router_spec():
    rc = parse_router_spec("replicated:3")
    assert rc.mode == "replicated" and rc.replicas == 3
    assert parse_router_spec("sharded:2").mode == "sharded"
    assert parse_router_spec("replicated:2",
                             health_interval_s=0.5).health_interval_s == 0.5
    with pytest.raises(ValueError, match="did you mean 'sharded'"):
        parse_router_spec("shardd:2")
    with pytest.raises(ValueError, match="MODE:N"):
        parse_router_spec("replicated")
    with pytest.raises(ValueError, match="positive int"):
        parse_router_spec("replicated:0")


def test_router_endpoint_validation(idx):
    eps = replicate_engine(idx.engine, 2)
    try:
        with pytest.raises(ValueError, match="replicas=3"):
            Router(eps, RouterConfig(replicas=3, health_interval_s=0.0))
    finally:
        for e in eps:
            e.close()
    with pytest.raises(ValueError, match="at least one endpoint"):
        Router([], RouterConfig(replicas=1))
    eps = replicate_engine(idx.engine, 2, names=("twin", "twin"))
    try:
        with pytest.raises(ValueError, match="unique"):
            Router(eps, RouterConfig(replicas=2, health_interval_s=0.0))
    finally:
        for e in eps:
            e.close()


def test_shard_engines_requires_equal_cut(cfg):
    X = np.zeros((10, 4), np.float32)
    with pytest.raises(ValueError, match="do not split evenly"):
        shard_engines(X, cfg, shards=3)


# ----------------------------------------------------------------------
# replicated mode: parity, shared cache, policies
# ----------------------------------------------------------------------

def test_replicated_bitwise_parity_both_regimes(ds, idx):
    """Acceptance: a replicated router answers bitwise-identically to a
    single directly-queried replica (= the donor index), both regimes."""
    rc = RouterConfig(mode="replicated", replicas=2, health_interval_s=0.0)
    with idx.serve(router=rc) as r:
        for B in (5, 64):
            ref = idx.search(ds.Q[:B])
            assert _bitwise(r.query(ds.Q[:B]), ref), B
        # single-vector convenience strips the leading axis
        gi, gd = r.query(ds.Q[0])
        ref = idx.search(ds.Q[:1])
        assert gi.shape == (10,)
        assert np.array_equal(gi, ref[0][0])
        assert np.array_equal(np.asarray(gd).view(np.uint32),
                              np.asarray(ref[1][0]).view(np.uint32))


def test_replicated_shared_cache_zero_compiles(ds, idx):
    """Replicas share the donor's plane AND compile cache: a router over a
    warmed index serves with aggregated compiles == 0, and the snapshot
    sums per-replica engine/queue counters consistently."""
    rc = RouterConfig(mode="replicated", replicas=3, policy="round_robin",
                      health_interval_s=0.0)
    # max_batch caps coalesced groups at the largest warmed bucket —
    # otherwise a 64-row submit coalesced with a 5-row one lands on a
    # (large, 128) shape the warmup sweep never compiled
    with idx.serve(router=rc, max_batch=64) as r:
        futs = [r.submit(ds.Q[:5]) for _ in range(6)]
        futs.append(r.submit(ds.Q[:64]))
        done, not_done = wait(futs, timeout=120)
        assert not not_done
        assert all(f.exception() is None for f in futs)
        snap = r.snapshot()
    agg, reps, rt = snap["aggregate"], snap["replicas"], snap["router"]
    assert agg["compiles"] == 0
    assert agg["n_replicas"] == 3 and agg["healthy_replicas"] == 3
    assert rt["n_requests"] == 7 and rt["n_dispatches"] == 7
    assert rt["retries"] == 0 and rt["lost_futures"] == 0
    assert agg["n_queries"] == sum(v["engine"]["n_queries"]
                                   for v in reps.values())
    # round-robin spreads the stream across every endpoint
    assert all(v["dispatches"] >= 2 for v in reps.values())
    assert agg["large_p50_ms"] > 0.0


def test_serve_router_accepts_spec_string(ds, idx):
    with idx.serve(router="replicated:2", max_wait_ms=0.5) as r:
        assert r.cfg.mode == "replicated" and r.cfg.replicas == 2
        ids, _ = r.query(ds.Q[:3])
        assert np.array_equal(ids, idx.search(ds.Q[:3])[0])


# ----------------------------------------------------------------------
# replicated mode: failure handling (acceptance: zero lost futures)
# ----------------------------------------------------------------------

def test_kill_replica_mid_stream_zero_lost_futures(ds, idx):
    """Acceptance: a replica killed under live traffic loses ZERO futures
    — every request (including ones already coalesced into the victim's
    queue) fails over to the healthy peer and answers bitwise-correctly."""
    rc = RouterConfig(mode="replicated", replicas=2, health_interval_s=0.0,
                      max_retries=2, backoff_s=0.001)
    with idx.serve(router=rc) as r:
        futs = []
        for i in range(30):
            futs.append(r.submit(ds.Q[:5]))
            if i == 10:
                r.endpoints[0].kill()
        done, not_done = wait(futs, timeout=120)
        assert not not_done
        for f in futs:
            assert f.exception() is None
            ids, dists = f.result()
            # coalesced requests sit at varying row offsets in the merged
            # batch, so per-row seeding makes answers offset-dependent —
            # assert quality, not bitwise identity (that's the
            # uncoalesced parity test's job)
            assert np.asarray(ids).shape == (5, 10)
            assert recall_at_k(np.asarray(ids), ds.gt[:5], 10) > 0.5
        snap = r.snapshot()
    rt = snap["router"]
    assert rt["lost_futures"] == 0
    assert rt["ejects"] == 1
    assert rt["retries"] >= 1
    assert snap["replicas"]["r0"]["healthy"] is False
    assert snap["aggregate"]["healthy_replicas"] == 1


def test_all_replicas_dead_fails_request(ds, idx):
    rc = RouterConfig(mode="replicated", replicas=2, health_interval_s=0.0,
                      max_retries=1, backoff_s=0.0)
    with idx.serve(router=rc) as r:
        for e in r.endpoints:
            e.kill()
        fut = r.submit(ds.Q[:5])
        with pytest.raises(ReplicaDead):
            fut.result(timeout=60)
        # both ejected now: the next request fails fast, no healthy pool
        fut2 = r.submit(ds.Q[:5])
        with pytest.raises(NoHealthyReplicas):
            fut2.result(timeout=60)
        snap = r.snapshot()
    assert snap["router"]["lost_futures"] == 2
    assert snap["router"]["ejects"] == 2
    assert snap["aggregate"]["healthy_replicas"] == 0


def test_user_error_propagates_without_retry(ds, idx):
    """Malformed requests are the caller's bug: they raise (synchronously
    for shape errors, through the future for engine validation) and never
    burn the retry budget or eject a replica."""
    rc = RouterConfig(mode="replicated", replicas=2, health_interval_s=0.0)
    with idx.serve(router=rc) as r:
        with pytest.raises(ValueError, match="Q must be"):
            r.submit(np.zeros((0, 16), np.float32))
        with pytest.raises(ValueError, match="Q must be"):
            r.submit(np.zeros((2, 7), np.float32))
        fut = r.submit(ds.Q[:2], k=10 ** 6)
        with pytest.raises(ValueError):
            fut.result(timeout=60)
        snap = r.snapshot()
    assert snap["router"]["retries"] == 0
    assert snap["router"]["lost_futures"] == 0
    assert snap["router"]["ejects"] == 0
    assert snap["aggregate"]["healthy_replicas"] == 2


def test_health_probe_eject_and_readmit(idx):
    """The prober ejects a dead replica within one probe interval (plus
    scheduling slack) and readmits it after ``readmit_probes`` consecutive
    successful probes; RouterStats reflects both transitions."""
    rc = RouterConfig(mode="replicated", replicas=2, health_interval_s=0.05,
                      probe_timeout_s=30.0, readmit_probes=2)
    with idx.serve(router=rc) as r:
        r.endpoints[0].kill()
        t0 = time.monotonic()
        while "r0" in r.healthy_replicas():
            assert time.monotonic() - t0 < 10, "probe failed to eject"
            time.sleep(0.005)
        r.endpoints[0].revive()
        t0 = time.monotonic()
        while "r0" not in r.healthy_replicas():
            assert time.monotonic() - t0 < 10, "probe failed to readmit"
            time.sleep(0.005)
        snap = r.snapshot()
    rt = snap["router"]
    assert rt["ejects"] >= 1 and rt["readmits"] >= 1
    assert rt["probes"] >= 2 and rt["probe_failures"] >= 1
    assert snap["aggregate"]["healthy_replicas"] == 2


def test_router_close_is_idempotent(ds, idx):
    rc = RouterConfig(mode="replicated", replicas=2, health_interval_s=0.0)
    r = idx.serve(router=rc)
    assert np.asarray(r.query(ds.Q[:3])[0]).shape == (3, 10)
    r.close()
    r.close()  # second close returns immediately, no re-drain
    with pytest.raises(RuntimeError, match="closed"):
        r.submit(ds.Q[:3])


# ----------------------------------------------------------------------
# sharded mode: merge semantics + partial results
# ----------------------------------------------------------------------

def test_sharded_router_merges_shards(ds, idx):
    """The routed answer is exactly merge_shard_results over the per-shard
    engines' raw answers (global ids, best-copy dedup, (dist, id) order)."""
    rc = RouterConfig(mode="sharded", replicas=2, health_interval_s=0.0)
    with idx.serve(router=rc) as r:
        got = r.query(ds.Q[:5])
        pools, offsets, n_rows = [], [], []
        for e in r.endpoints:
            ids, dists = e.engine.query(ds.Q[:5])
            pools.append((np.asarray(ids), np.asarray(dists)))
            offsets.append(e.id_offset)
            n_rows.append(e.n_rows)
    ref = merge_shard_results(pools, offsets, n_rows, k=10, batch=5)
    assert _bitwise(got, ref)
    # shard endpoints really are row slices with global offsets
    assert offsets == [0, 512] and n_rows == [512, 512]


def test_sharded_partial_result_error(ds, idx):
    """Acceptance: a killed shard (no peer holds its rows) fails the
    request with a typed PartialResultError carrying the SURVIVING shards'
    merged top-k."""
    rc = RouterConfig(mode="sharded", replicas=2, health_interval_s=0.0,
                      max_retries=1, backoff_s=0.001)
    with idx.serve(router=rc) as r:
        survivor = r.endpoints[0]
        r.endpoints[1].kill()
        fut = r.submit(ds.Q[:5])
        with pytest.raises(PartialResultError) as ei:
            fut.result(timeout=60)
        err = ei.value
        assert err.failed == ("s1",) and err.survivors == ("s0",)
        sids, sdists = survivor.engine.query(ds.Q[:5])
        ref = merge_shard_results(
            [(np.asarray(sids), np.asarray(sdists))],
            [survivor.id_offset], [survivor.n_rows], k=10, batch=5)
        assert np.array_equal(err.ids, ref[0])
        assert np.array_equal(np.asarray(err.dists).view(np.uint32),
                              np.asarray(ref[1]).view(np.uint32))
        snap = r.snapshot()
    rt = snap["router"]
    assert rt["partial_results"] == 1
    assert rt["lost_futures"] == 0     # a partial is an answer, not a loss
    assert rt["retries"] >= 1          # the same shard was retried first
    assert snap["replicas"]["s1"]["healthy"] is False


def test_sharded_all_shards_dead(ds, idx):
    rc = RouterConfig(mode="sharded", replicas=2, health_interval_s=0.0,
                      max_retries=0, backoff_s=0.0)
    with idx.serve(router=rc) as r:
        for e in r.endpoints:
            e.kill()
        fut = r.submit(ds.Q[:2])
        with pytest.raises(PartialResultError) as ei:
            fut.result(timeout=60)
        # nothing survived: the carried top-k is all-PAD
        assert ei.value.survivors == ()
        assert (np.asarray(ei.value.dists) >= np.float32(3.4e38)).all()


# ----------------------------------------------------------------------
# sharded router <-> mesh plane parity (2-device subprocess)
# ----------------------------------------------------------------------

_SETUP = """
import dataclasses, numpy as np, jax
from repro.ann import Index
from repro.configs import get_arch
from repro.data.synthetic import make_clustered
ds = make_clustered(n=1024, d=16, n_queries=64, n_clusters=16, noise=0.6,
                    seed=0)
cfg = dataclasses.replace(get_arch('tsdg-paper'), k_graph=8, max_degree=12,
                          lambda0=4, bridge_hubs=16, bridge_k=4, large_ef=32,
                          large_hops=16, serve_buckets=(8, 64))
THR = 8.0 * cfg.small_t0
def bitwise(a, b):
    return (np.array_equal(a[0], b[0])
            and np.array_equal(np.asarray(a[1]).view(np.uint32),
                               np.asarray(b[1]).view(np.uint32)))
"""


def test_sharded_router_matches_mesh():
    """THE sharded acceptance criterion: a router over P equal row slices
    answers bitwise-identically to a P-DB-shard mesh plane over the
    concatenated corpus, both regimes — the host-side
    merge_shard_results mirrors the mesh's in-collective merge_topk
    exactly (same validity mask, same global-id mapping, same
    (dist, id) dedup order)."""
    out = _run(_SETUP + """
from repro.serve.router import Router, RouterConfig, shard_engines
mesh = jax.make_mesh((2,), ('data',))
mi = Index.build(ds.X, cfg, k=10, mesh=mesh, threshold=THR)
eps = shard_engines(ds.X, cfg, shards=2, k=10, threshold=THR)
r = Router(eps, RouterConfig(mode='sharded', replicas=2,
                             health_interval_s=0.0))
try:
    for B, regime in ((5, 'small'), (64, 'large')):
        assert mi.regime(B) == regime, (B, mi.regime(B))
        got = r.query(ds.Q[:B], timeout=300)
        ref = mi.search(ds.Q[:B])
        assert bitwise(got, ref), (B, regime)
finally:
    r.close()
print('SHARDED PARITY OK')
""")
    assert "SHARDED PARITY OK" in out
