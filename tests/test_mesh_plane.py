"""Execution planes: protocol, cross-shard merge oracle, mesh<->single
bitwise parity, sharded artifact round-trips, regime calibration.

Single-device-safe tests run in-process (1x1 meshes exercise the full mesh
code path on one device); the genuinely multi-device acceptance tests run
in subprocesses with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(device count is locked at jax init), mirroring ``tests/test_distributed``.
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.ann import Index
from repro.ann.dispatch import Calibration, calibrate, regime_for
from repro.configs import get_arch
from repro.core.distributed import merge_topk
from repro.data.synthetic import make_clustered, recall_at_k
from repro.serve.engine import ANNEngine
from repro.serve.plane import (ExecutionPlane, MeshPlane, SingleDevicePlane,
                               get_plane, planes)

ROOT = os.path.join(os.path.dirname(__file__), "..")
INF = np.float32(3.4e38)


def _run(code: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


@pytest.fixture(scope="module")
def ds():
    return make_clustered(n=2000, d=16, n_queries=64, n_clusters=24,
                          noise=0.6, seed=0)


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(get_arch("tsdg-paper"), k_graph=12,
                               max_degree=16, lambda0=8, bridge_hubs=32,
                               bridge_k=8, large_ef=48, large_hops=24,
                               serve_buckets=(8, 32, 128))


def _bitwise(a, b):
    return (bool(np.array_equal(a[0], b[0]))
            and bool(np.array_equal(np.asarray(a[1]).view(np.uint32),
                                    np.asarray(b[1]).view(np.uint32))))


# ----------------------------------------------------------------------
# cross-shard dedup-top-k merge vs explicit-set oracle
# ----------------------------------------------------------------------

def _oracle_merge(all_ids, all_d, k):
    """Python-set semantics: drop PAD/INF lanes, keep the best copy per
    id, top-k ascending by (dist, id)."""
    B = all_ids.shape[0]
    out_i = np.full((B, k), -1, np.int32)
    out_d = np.full((B, k), INF, np.float32)
    for b in range(B):
        best = {}
        for ii, dd in zip(all_ids[b].tolist(), all_d[b].tolist()):
            if ii < 0 or dd >= float(INF):
                continue
            if ii not in best or dd < best[ii]:
                best[ii] = dd
        top = sorted((dd, ii) for ii, dd in best.items())[:k]
        for j, (dd, ii) in enumerate(top):
            out_i[b, j] = ii
            out_d[b, j] = np.float32(dd)
    return out_i, out_d


def _check_merge(all_ids, all_d, k):
    got_i, got_d = merge_topk(np.asarray(all_ids, np.int32),
                              np.asarray(all_d, np.float32), k)
    ref_i, ref_d = _oracle_merge(np.asarray(all_ids, np.int32),
                                 np.asarray(all_d, np.float32), k)
    np.testing.assert_array_equal(np.asarray(got_i), ref_i)
    np.testing.assert_array_equal(np.asarray(got_d).view(np.uint32),
                                  ref_d.view(np.uint32))


def test_merge_duplicate_ids_across_shards():
    """The same global id surfacing from several shards/searches (bridge
    splices, the small-regime t0 split) must occupy exactly one slot,
    keeping the best copy."""
    rng = np.random.default_rng(0)
    B, shards, k = 5, 4, 8
    ids = rng.integers(0, 40, size=(B, shards * k)).astype(np.int32)
    d = rng.random((B, shards * k)).astype(np.float32)
    # force exact duplicates with different dists AND with equal dists
    ids[:, 1] = ids[:, 0]
    d[:, 1] = d[:, 0] + 1.0
    ids[:, 3] = ids[:, 2]
    d[:, 3] = d[:, 2]
    _check_merge(ids, d, k)


def test_merge_all_pad_shards():
    """Shards with zero valid candidates (tiny shards, λ-masked rows)
    contribute nothing; rows short of k pad with (PAD_ID, INF)."""
    rng = np.random.default_rng(1)
    B, k = 4, 6
    ids = np.full((B, 24), -1, np.int32)
    d = np.full((B, 24), INF, np.float32)
    # one shard of 6 entries is valid in row 0 and 2 only; row 3 all-PAD
    for b in (0, 2):
        ids[b, 6:10] = rng.integers(0, 100, 4)
        d[b, 6:10] = rng.random(4).astype(np.float32)
    _check_merge(ids, d, k)


def test_merge_small_regime_t0_split():
    """The small regime's layout: n_db x n_q candidate lists per query,
    each a locally-deduped top-k, heavy overlap between the t0 columns
    (they search the same sub-index)."""
    rng = np.random.default_rng(2)
    B, n_db, n_q, k = 3, 2, 4, 10
    pool = []
    for shard in range(n_db):
        base = shard * 1000  # global offset: DB shards never collide
        for _ in range(n_q):
            ids = base + rng.integers(0, 30, size=(B, k)).astype(np.int32)
            d = (ids % 97).astype(np.float32) / 97.0  # id-determined dist
            pool.append((ids, d))
    all_ids = np.concatenate([p[0] for p in pool], axis=1)
    all_d = np.concatenate([p[1] for p in pool], axis=1)
    _check_merge(all_ids, all_d, k)


def test_merge_fuzz_roundtrip():
    rng = np.random.default_rng(3)
    for _ in range(10):
        B = int(rng.integers(1, 6))
        n = int(rng.integers(1, 8)) * 5
        k = int(rng.integers(1, 12))
        ids = rng.integers(-1, 25, size=(B, n)).astype(np.int32)
        d = rng.random((B, n)).astype(np.float32)
        d[ids < 0] = INF
        _check_merge(ids, d, k)


# ----------------------------------------------------------------------
# plane protocol + registry
# ----------------------------------------------------------------------

def test_planes_registered():
    assert {"single", "mesh"} <= set(planes())
    assert get_plane("single") is not None
    # "pod" is not pre-registered but resolves via the lazy import seam
    assert get_plane("pod") is not None and "pod" in planes()
    with pytest.raises(KeyError, match="unknown execution plane"):
        get_plane("hexapod")


def test_single_plane_protocol(ds, cfg):
    plane = SingleDevicePlane(ds.X, cfg)
    assert isinstance(plane, ExecutionPlane)
    assert plane.name == "single"
    assert plane.batch_multiple() == 1
    assert plane.topology() is None
    assert plane.shardings() == {}
    fp = plane.fingerprint()
    assert fp["plane"] == "single" and fp["kernel_backend"] == plane.backend
    ops = plane.operands()
    assert ops[0] is plane.X and len(ops) in (4, 5)
    exe = plane.compile("small", 8, 10)
    ids, dists = exe(np.zeros((8, 16), np.float32))
    assert ids.shape == (8, 10)


def test_mesh_plane_protocol_1x1(ds, cfg):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    plane = MeshPlane(ds.X, cfg, mesh)
    assert isinstance(plane, ExecutionPlane)
    assert plane.name == "mesh"
    assert plane.batch_multiple() == 1
    topo = plane.topology()
    assert topo["n_db_shards"] == 1 and topo["axes"] == {"data": 1,
                                                         "model": 1}
    assert plane.fingerprint()["mesh_axes"] == topo["axes"]
    sh = plane.shardings()
    assert {"X", "neighbors", "query_small", "query_large"} <= set(sh)
    exe = plane.compile("large", 32, 10)
    ids, _ = exe(np.zeros((32, 16), np.float32))
    assert ids.shape == (32, 10)


def test_mesh_plane_requires_db_axis(ds, cfg):
    mesh = jax.make_mesh((1,), ("model",))
    with pytest.raises(ValueError, match="no DB axis"):
        MeshPlane(ds.X, cfg, mesh)


def test_engine_accepts_prebuilt_plane(ds, cfg):
    plane = SingleDevicePlane(ds.X, cfg)
    eng = ANNEngine(None, cfg, k=10, plane=plane)
    assert eng.plane is plane and eng.X is plane.X
    ids, _ = eng.query(ds.Q[:3])
    assert ids.shape == (3, 10)
    with pytest.raises(ValueError, match="plane= already fixes"):
        ANNEngine(ds.X, cfg, k=10, plane=plane,
                  mesh=jax.make_mesh((1, 1), ("data", "model")))


def test_engine_same_cache_and_stats_surface_over_mesh_plane(ds, cfg):
    """The engine machinery (bucket ladder, compile cache, stats v2) must
    be identical over a mesh plane — that is the point of the refactor."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    eng = ANNEngine(ds.X, cfg, k=10, mesh=mesh)
    for B in (3, 33, 3, 33):
        ids, _ = eng.query(ds.Q[:B])
        assert ids.shape == (B, 10)
    assert eng.stats.compiles == 2
    assert eng.stats.bucket_hits == 2
    st = eng.stats.snapshot()
    assert st["small_batches"] == 2 and st["large_batches"] == 2
    p = eng.stats.per_regime["large"].percentiles()
    assert p["p50"] <= p["p99"]


class _MultiplePlane(SingleDevicePlane):
    """Single-device plane reporting a non-trivial batch multiple — the
    bucket geometry of a 3-query-shard mesh without needing 3 devices."""

    def batch_multiple(self) -> int:
        return 3


def test_warmup_covers_rounded_buckets(ds, cfg):
    """Regression: with a batch multiple that does not divide the ladder,
    probe batches must stay at the RAW ladder step (a rounded probe batch
    falls through to the next rung) while the recorded bucket is the
    rounded one a request actually compiles — so warmup covers every
    reachable pair and a post-warmup stream never compiles."""
    small = dataclasses.replace(cfg, serve_buckets=(8, 32), large_hops=8)
    plane = _MultiplePlane(ds.X, small)
    eng = ANNEngine(None, small, k=10, plane=plane)
    assert eng.bucket_for(8) == 9 and eng.bucket_for(9) == 33
    for kind, bucket, probe in eng.warmup_probes():
        assert bucket % 3 == 0
        assert eng.bucket_for(probe) == bucket   # probe maps to its label
    n = eng.warmup()
    assert n == eng.stats.compiles
    for B in (1, 8, 9, 20, 32):
        eng.query(ds.Q[:B])
    assert eng.stats.compiles == n               # fully pre-compiled


# ----------------------------------------------------------------------
# regime calibration
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def fast_cfg(cfg):
    return dataclasses.replace(cfg, large_hops=8, small_hops=3,
                               small_t0=8, serve_buckets=(8, 32))


def test_calibrate_returns_fit(ds, fast_cfg):
    plane = SingleDevicePlane(ds.X, fast_cfg)
    cal = calibrate(plane, fast_cfg, k=10, probe_batches=(4, 16), repeats=1)
    assert cal.threshold > 0 and np.isfinite(cal.threshold)
    assert cal.d == 16 and cal.cores >= 1
    assert set(cal.probes) == {"small", "large"}
    assert all(t > 0 for _, t in cal.probes["small"])
    if not cal.degenerate:
        # the paper's (a·cores + b)/d form reproduces the division point
        assert (cal.a * cal.cores + cal.b) / cal.d == pytest.approx(
            cal.crossover_batch)
    rt = Calibration.from_manifest(
        json.loads(json.dumps(cal.to_manifest())))
    assert rt.threshold == cal.threshold and rt.probes == cal.probes


def test_probe_calibration_at_engine_init(ds, fast_cfg):
    cfg_p = dataclasses.replace(fast_cfg, regime_calibration="probe")
    eng = ANNEngine(ds.X, cfg_p, k=10)
    assert eng.calibration is not None
    assert eng.threshold == eng.calibration.threshold
    # dispatch follows the fitted threshold, via the shared rule
    for b in (1, 4, 40, 400):
        assert eng.regime(b) == regime_for(cfg_p, b,
                                           threshold=eng.threshold)


def test_threshold_override_rewires_dispatch(ds, fast_cfg):
    plane = SingleDevicePlane(ds.X, fast_cfg)
    eng_lo = ANNEngine(None, fast_cfg, k=10, plane=plane, threshold=1.0)
    assert eng_lo.regime(1) == "large"
    eng_hi = ANNEngine(None, fast_cfg, k=10, plane=plane, threshold=1e9)
    assert eng_hi.regime(5000) == "small"


def test_calibrated_threshold_cached_in_artifact(ds, fast_cfg, tmp_path):
    cfg_p = dataclasses.replace(fast_cfg, regime_calibration="probe")
    idx = Index.build(ds.X, cfg_p, k=10)
    idx.save(tmp_path / "cal", aot=False)
    man = json.loads((tmp_path / "cal" / "manifest.json").read_text())
    assert man["calibrated_threshold"] == idx.engine.threshold
    loaded = Index.load(tmp_path / "cal")
    # restored from the manifest — no re-probe at load
    assert loaded.engine.threshold == idx.engine.threshold
    assert loaded.calibration is None


def test_bad_calibration_knob_rejected():
    from repro.configs import ANNConfig

    with pytest.raises(ValueError, match="regime_calibration"):
        ANNConfig(regime_calibration="probs")


# ----------------------------------------------------------------------
# sharded artifact round-trip (1x1 mesh: full code path on one device;
# the multi-shard matrix runs in the 8-device subprocess tests below)
# ----------------------------------------------------------------------

def test_mesh_roundtrip_1x1_zero_compiles(ds, cfg, tmp_path):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    idx = Index.build(ds.X, cfg, k=10, mesh=mesh)
    idx.warmup()
    ref_s = idx.search(ds.Q[:5])
    ref_l = idx.search(ds.Q)
    idx.save(tmp_path / "mx", extra_ks=[5])
    loaded = Index.load(tmp_path / "mx", mesh=mesh)
    assert loaded.stats.aot_primed > 0
    assert _bitwise(ref_s, loaded.search(ds.Q[:5]))
    assert _bitwise(ref_l, loaded.search(ds.Q))
    ids5, _ = loaded.search(ds.Q[:5], k=5)     # extra_ks primed too
    assert ids5.shape == (5, 5)
    assert loaded.stats.compiles == 0
    assert loaded.warmup() == 0
    assert loaded.stats.compiles == 0


def test_mesh_artifact_without_mesh_rebuilds_single(ds, cfg, tmp_path):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    Index.build(ds.X, cfg, k=10, mesh=mesh).save(tmp_path / "mx", aot=False)
    with pytest.warns(UserWarning, match="without mesh="):
        loaded = Index.load(tmp_path / "mx")
    assert loaded.plane.name == "single"
    ids, _ = loaded.search(ds.Q)
    assert recall_at_k(ids, ds.gt, 10) > 0.8


def test_single_artifact_onto_mesh_reshards(ds, cfg, tmp_path):
    Index.build(ds.X, cfg, k=10).save(tmp_path / "sx", aot=False)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.warns(UserWarning, match="resharding"):
        loaded = Index.load(tmp_path / "sx", mesh=mesh)
    assert loaded.plane.name == "mesh"
    ids, _ = loaded.search(ds.Q)
    assert recall_at_k(ids, ds.gt, 10) > 0.8


def test_mesh_fingerprint_mismatch_recompiles(ds, cfg, tmp_path):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    idx = Index.build(ds.X, cfg, k=10, mesh=mesh)
    idx.warmup()
    ref = idx.search(ds.Q[:5])
    idx.save(tmp_path / "mx")
    mpath = tmp_path / "mx" / "manifest.json"
    manifest = json.loads(mpath.read_text())
    manifest["fingerprint"]["jax"] = "0.0.0-other"
    mpath.write_text(json.dumps(manifest))
    with pytest.warns(UserWarning, match="fingerprint mismatch"):
        loaded = Index.load(tmp_path / "mx", mesh=mesh)
    assert loaded.stats.aot_primed == 0
    got = loaded.search(ds.Q[:5])
    assert _bitwise(ref, got)
    assert loaded.stats.compiles == 1          # recompiled, not primed


def test_extra_ks_validated_before_write(ds, cfg, tmp_path):
    idx = Index.build(ds.X, cfg, k=10)
    with pytest.raises(ValueError, match="exceeds large-batch"):
        idx.save(tmp_path / "bad", extra_ks=[cfg.large_ef + 1])
    assert not (tmp_path / "bad").exists() or \
        not list((tmp_path / "bad").iterdir())


# ----------------------------------------------------------------------
# 8-device acceptance (subprocess: device count locked at jax init)
# ----------------------------------------------------------------------

_SETUP = """
import dataclasses, numpy as np, jax
from repro.ann import Index
from repro.configs import get_arch
from repro.data.synthetic import make_clustered, recall_at_k
ds = make_clustered(n=2048, d=16, n_queries=64, n_clusters=24, noise=0.6,
                    seed=0)
cfg = dataclasses.replace(get_arch('tsdg-paper'), k_graph=12, max_degree=16,
                          lambda0=8, bridge_hubs=32, bridge_k=8, large_ef=48,
                          large_hops=24, serve_buckets=(8, 32, 128))
def bitwise(a, b):
    return (np.array_equal(a[0], b[0])
            and np.array_equal(np.asarray(a[1]).view(np.uint32),
                               np.asarray(b[1]).view(np.uint32)))
"""


def test_mesh_plane_bitwise_matches_single_plane():
    """THE plane acceptance criterion: on a mesh with one DB shard, the
    model-axis parallelism (query fan-out in the large regime, the t0
    population split in the small regime) is bit-invisible — the mesh
    plane answers exactly like the single-device plane, both regimes."""
    out = _run(_SETUP + """
single = Index.build(ds.X, cfg, k=10)
for nm in (2, 4):
    mesh = jax.make_mesh((1, nm), ('data', 'model'))
    mi = Index.build(ds.X, cfg, k=10, mesh=mesh)
    for B, regime in ((5, 'small'), (64, 'large')):
        assert mi.regime(B) == regime
        got = mi.search(ds.Q[:B]); ref = single.search(ds.Q[:B])
        assert bitwise(got, ref), (nm, B, regime)
print('PARITY OK')
""")
    assert "PARITY OK" in out


def test_sharded_roundtrip_8dev_zero_compiles(tmp_path):
    """THE artifact acceptance criterion: a 4x2-sharded index round-trips
    build -> save -> load -> serve with ServeStats.compiles == 0 and
    bitwise-identical answers; a topology-mismatched mesh falls back to
    gather-and-reshard with a warning."""
    d = str(tmp_path / "ix")
    out = _run(_SETUP + f"""
import warnings
mesh = jax.make_mesh((4, 2), ('data', 'model'))
idx = Index.build(ds.X, cfg, k=10, mesh=mesh)
idx.warmup()
ref_s = idx.search(ds.Q[:5]); ref_l = idx.search(ds.Q)
idx.save({d!r}, extra_ks=[5])
loaded = Index.load({d!r}, mesh=mesh)
assert loaded.stats.aot_primed > 0
assert bitwise(ref_s, loaded.search(ds.Q[:5]))
assert bitwise(ref_l, loaded.search(ds.Q))
ids5, _ = loaded.search(ds.Q[:5], k=5)
assert ids5.shape == (5, 5)
assert loaded.stats.compiles == 0, loaded.stats.compiles
assert loaded.warmup() == 0 and loaded.stats.compiles == 0
r = recall_at_k(loaded.search(ds.Q)[0], ds.gt, 10)
assert r > 0.8, r
mesh2 = jax.make_mesh((2,), ('data',))
with warnings.catch_warnings(record=True) as w:
    warnings.simplefilter('always')
    re2 = Index.load({d!r}, mesh=mesh2)
assert any('topology mismatch' in str(x.message) for x in w)
r2 = recall_at_k(re2.search(ds.Q)[0], ds.gt, 10)
assert r2 > 0.8, r2
print('ROUNDTRIP OK')
""")
    assert "ROUNDTRIP OK" in out
