"""repro.ann facade: build pipeline, regime dispatch, save/load artifact
(bitwise round-trips, corruption/version rejection, AOT fingerprint
fallback), queue QoS bypass lane, config validation, arch suggestions."""
import dataclasses
import json
import threading
import time

import numpy as np
import pytest

from repro.ann import ArtifactError, Index, build_graph, regime_for
from repro.ann.pipeline import BuildState, build_stages, register_stage
from repro.configs import ANNConfig, get_arch
from repro.data.synthetic import make_clustered, recall_at_k
from repro.serve.queue import MicroBatcher


@pytest.fixture(scope="module")
def ds():
    return make_clustered(n=3000, d=16, n_queries=64, n_clusters=24,
                          noise=0.6, seed=0)


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(get_arch("tsdg-paper"), k_graph=12,
                               max_degree=16, lambda0=8, bridge_hubs=32,
                               bridge_k=8, large_ef=48, large_hops=64,
                               serve_buckets=(8, 32, 128))


@pytest.fixture(scope="module")
def index(ds, cfg):
    return Index.build(ds.X, cfg, k=10)


def _bitwise_equal(a, b):
    ids_eq = bool(np.array_equal(a[0], b[0]))
    d_eq = bool(np.array_equal(np.asarray(a[1]).view(np.uint32),
                               np.asarray(b[1]).view(np.uint32)))
    return ids_eq and d_eq


# ----------------------------------------------------------------------
# build pipeline
# ----------------------------------------------------------------------

def test_build_matches_legacy_build_tsdg(ds, cfg, index):
    """The staged pipeline IS the old build: bit-identical packed graph."""
    from repro.core.diversify import build_tsdg

    g_old = build_tsdg(ds.X, cfg)
    g_new = index.graph
    np.testing.assert_array_equal(np.asarray(g_old.neighbors),
                                  np.asarray(g_new.neighbors))
    np.testing.assert_array_equal(np.asarray(g_old.lambdas),
                                  np.asarray(g_new.lambdas))
    np.testing.assert_array_equal(np.asarray(g_old.degrees),
                                  np.asarray(g_new.degrees))
    np.testing.assert_array_equal(np.asarray(g_old.hubs),
                                  np.asarray(g_new.hubs))


def test_default_stages_registered():
    assert {"knn", "diversify", "bridges"} <= set(build_stages())


def test_register_stage_runs_in_pipeline(ds, cfg):
    seen = []

    @register_stage("test_probe")
    def _probe(state: BuildState) -> None:
        seen.append(state.neighbors is not None)

    try:
        g = build_graph(ds.X, cfg,
                        stages=("knn", "diversify", "bridges", "test_probe"))
        assert seen == [True]          # ran, after the graph existed
        assert g.neighbors.shape == (ds.X.shape[0], cfg.max_degree)
    finally:
        from repro.ann import pipeline
        pipeline._STAGES.pop("test_probe", None)


def test_unknown_stage_suggests_close_match(ds, cfg):
    with pytest.raises(KeyError, match="diversify"):
        build_graph(ds.X, cfg, stages=("knn", "diversfy"))


def test_pipeline_without_graph_stage_rejected(ds, cfg):
    with pytest.raises(ValueError, match="no graph"):
        build_graph(ds.X, cfg, stages=("knn",))


def test_stages_with_prebuilt_graph_rejected(ds, cfg, index):
    with pytest.raises(ValueError, match="stages"):
        Index(ds.X, cfg, graph=index.graph, stages=("knn", "diversify"))


# ----------------------------------------------------------------------
# search + regime dispatch
# ----------------------------------------------------------------------

def test_search_dispatches_both_regimes(ds, cfg, index):
    small_before = index.stats.small_batches
    large_before = index.stats.large_batches
    index.search(ds.Q[:2])
    index.search(ds.Q)
    assert index.regime(2) == "small" and index.regime(64) == "large"
    assert index.stats.small_batches == small_before + 1
    assert index.stats.large_batches == large_before + 1


def test_regime_rule_shared_with_engine(cfg, index):
    for b in (1, 7, 16, 17, 64, 300):
        assert index.regime(b) == index.engine.regime(b) \
            == regime_for(cfg, b)


def test_search_recall(ds, index):
    ids, _ = index.search(ds.Q)
    assert recall_at_k(ids, ds.gt, 10) > 0.85


def test_facade_matches_raw_procedure_bitwise(ds, cfg, index):
    """Index.search == calling the (deprecated shim) procedure directly."""
    from repro.core.search_small import small_batch_search

    B = 8                     # == bucket: no padding
    got = index.search(ds.Q[:B])
    raw = small_batch_search(
        index.X, index.graph, np.asarray(ds.Q[:B]), k=10, t0=cfg.small_t0,
        hops=cfg.small_hops, hop_width=cfg.hop_width, n_seeds=cfg.n_seeds,
        lambda_limit=10, metric=cfg.metric, backend=index.backend,
        gather_fused=index.engine.gather_fused)
    assert _bitwise_equal(got, (np.asarray(raw[0]), np.asarray(raw[1])))


# ----------------------------------------------------------------------
# save / load artifact
# ----------------------------------------------------------------------

def test_save_load_bitwise_with_zero_compiles(ds, cfg, index, tmp_path):
    """The acceptance criterion: a loaded index answers bitwise-identically
    with ZERO new compiles — the warmup sweep is restored from disk."""
    index.warmup()
    ref_small = index.search(ds.Q[:5])
    ref_large = index.search(ds.Q)
    index.save(tmp_path / "ix")

    loaded = Index.load(tmp_path / "ix")
    assert loaded.stats.aot_primed > 0
    got_small = loaded.search(ds.Q[:5])
    got_large = loaded.search(ds.Q)
    assert _bitwise_equal(ref_small, got_small)
    assert _bitwise_equal(ref_large, got_large)
    assert loaded.stats.compiles == 0          # nothing compiled, ever
    assert loaded.warmup() == 0                # sweep fully pre-primed
    assert loaded.stats.compiles == 0


def test_save_load_restores_config_and_graph(ds, cfg, index, tmp_path):
    index.save(tmp_path / "ix", aot=False)
    loaded = Index.load(tmp_path / "ix")
    assert loaded.cfg == index.cfg
    assert loaded.k == index.k
    np.testing.assert_array_equal(np.asarray(loaded.X), np.asarray(index.X))
    np.testing.assert_array_equal(np.asarray(loaded.graph.neighbors),
                                  np.asarray(index.graph.neighbors))
    assert loaded.stats.aot_primed == 0        # aot=False wrote no blobs


def test_load_rejects_non_artifact(tmp_path):
    with pytest.raises(ArtifactError, match="manifest"):
        Index.load(tmp_path / "nowhere")


def test_load_rejects_version_mismatch(ds, cfg, index, tmp_path):
    index.save(tmp_path / "ix", aot=False)
    mpath = tmp_path / "ix" / "manifest.json"
    manifest = json.loads(mpath.read_text())
    manifest["format_version"] = 999
    mpath.write_text(json.dumps(manifest))
    with pytest.raises(ArtifactError, match="version"):
        Index.load(tmp_path / "ix")


def test_load_rejects_wrong_magic(ds, cfg, index, tmp_path):
    index.save(tmp_path / "ix", aot=False)
    mpath = tmp_path / "ix" / "manifest.json"
    manifest = json.loads(mpath.read_text())
    manifest["magic"] = "something-else"
    mpath.write_text(json.dumps(manifest))
    with pytest.raises(ArtifactError):
        Index.load(tmp_path / "ix")


def test_load_rejects_corrupt_arrays(ds, cfg, index, tmp_path):
    index.save(tmp_path / "ix", aot=False)
    apath = tmp_path / "ix" / "arrays.npz"
    blob = bytearray(apath.read_bytes())
    blob[len(blob) // 2] ^= 0xFF
    apath.write_bytes(bytes(blob))
    with pytest.raises(ArtifactError, match="checksum"):
        Index.load(tmp_path / "ix")


def test_load_rejects_corrupt_aot_blob(ds, cfg, index, tmp_path):
    index.warmup()
    index.save(tmp_path / "ix")
    blobs = sorted((tmp_path / "ix" / "aot").glob("*.jaxexp"))
    assert blobs
    raw = bytearray(blobs[0].read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    blobs[0].write_bytes(bytes(raw))
    with pytest.raises(ArtifactError, match="checksum"):
        Index.load(tmp_path / "ix")


def test_fingerprint_mismatch_falls_back_to_recompile(ds, cfg, index,
                                                      tmp_path):
    """Stale executables are never served: a foreign fingerprint loads the
    index fine but skips the AOT cache, recompiling on demand with
    identical results."""
    index.warmup()
    ref = index.search(ds.Q[:5])
    index.save(tmp_path / "ix")
    mpath = tmp_path / "ix" / "manifest.json"
    manifest = json.loads(mpath.read_text())
    manifest["fingerprint"]["jax"] = "0.0.0-other"
    mpath.write_text(json.dumps(manifest))

    with pytest.warns(UserWarning, match="fingerprint mismatch"):
        loaded = Index.load(tmp_path / "ix")
    assert loaded.stats.aot_primed == 0
    got = loaded.search(ds.Q[:5])
    assert _bitwise_equal(ref, got)
    assert loaded.stats.compiles == 1          # recompiled, not primed


def test_mesh_index_save_round_trips(ds, cfg, tmp_path):
    """Sharded indexes now save/load as first-class artifacts (execution
    planes): shard-major layout, topology in the manifest, AOT primed on a
    topology match.  (Earlier revisions rejected mesh saves; the full
    multi-shard matrix lives in tests/test_mesh_plane.py.)"""
    import jax

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    idx = Index.build(ds.X, dataclasses.replace(cfg, large_hops=24),
                      k=10, mesh=mesh)
    ref = idx.search(ds.Q[:3])         # mesh serving works via the facade
    assert ref[0].shape == (3, 10)
    idx.warmup()
    idx.save(tmp_path / "mx")
    manifest = json.loads((tmp_path / "mx" / "manifest.json").read_text())
    assert manifest["plane"] == "mesh"
    assert manifest["topology"]["n_db_shards"] == 1
    loaded = Index.load(tmp_path / "mx", mesh=mesh)
    assert loaded.stats.aot_primed > 0
    got = loaded.search(ds.Q[:3])
    assert _bitwise_equal(ref, got)
    assert loaded.stats.compiles == 0


# ----------------------------------------------------------------------
# deprecation shims
# ----------------------------------------------------------------------

def test_old_entry_points_warn_once_and_match():
    from repro.core import diversify, search_large, search_small
    from repro.utils import deprecation

    ds = make_clustered(n=400, d=8, n_queries=4, n_clusters=8, noise=0.5,
                        seed=1)
    cfg = dataclasses.replace(get_arch("tsdg-paper"), k_graph=8,
                              max_degree=8, bridge_hubs=0)
    deprecation._seen.clear()
    with pytest.warns(DeprecationWarning, match="Index.build"):
        g = diversify.build_tsdg(ds.X, cfg)
    with pytest.warns(DeprecationWarning, match="Index.search"):
        out = search_small.small_batch_search(
            np.asarray(ds.X, np.float32), g, ds.Q, k=5, t0=4, hops=3)
    with pytest.warns(DeprecationWarning, match="Index.search"):
        search_large.large_batch_search(
            np.asarray(ds.X, np.float32), g, ds.Q, k=5, ef=16, hops=8)
    ref = search_small._small_batch_search(
        np.asarray(ds.X, np.float32), g, ds.Q, k=5, t0=4, hops=3)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(ref[0]))
    # second calls: silent (warn-once)
    import warnings as _w
    with _w.catch_warnings(record=True) as caught:
        _w.simplefilter("always")
        diversify.build_tsdg(ds.X, cfg)
        search_small.small_batch_search(
            np.asarray(ds.X, np.float32), g, ds.Q, k=5, t0=4, hops=3)
        search_large.large_batch_search(
            np.asarray(ds.X, np.float32), g, ds.Q, k=5, ef=16, hops=8)
    assert not [w for w in caught
                if issubclass(w.category, DeprecationWarning)
                and "deprecated entry point" in str(w.message)]


# ----------------------------------------------------------------------
# queue QoS bypass lane
# ----------------------------------------------------------------------

class _SlowStubEngine:
    """Stands in for ANNEngine: records dispatches, configurable delay."""

    def __init__(self, d=4, delay_s=0.0):
        self.cfg = ANNConfig(serve_buckets=(), queue_max_wait_ms=1e3,
                             queue_max_batch=8)
        self.X = np.zeros((16, d), np.float32)
        self.delay_s = delay_s
        self.calls = []
        self._lock = threading.Lock()

    def query(self, Q, *, k=None):
        with self._lock:
            self.calls.append(Q.shape[0])
        if self.delay_s and Q.shape[0] >= 8:   # only bulk work is slow
            time.sleep(self.delay_s)
        B = Q.shape[0]
        kk = k or 5
        return (np.zeros((B, kk), np.int32), np.zeros((B, kk), np.float32))


def test_bypass_lane_skips_coalescing_wait():
    """A >= max_batch submit must resolve long before the FIFO lane's
    coalescing window closes, and must be counted in stats.bypass."""
    eng = _SlowStubEngine()
    mb = MicroBatcher(eng, max_wait_ms=60_000.0, max_batch=8)
    try:
        # occupy the FIFO lane: a single that will wait for co-riders
        f_small = mb.submit(np.zeros((4,), np.float32))
        t0 = time.perf_counter()
        f_bulk = mb.submit(np.zeros((8, 4), np.float32))   # == max_batch
        f_bulk.result(timeout=10)
        assert time.perf_counter() - t0 < 5           # not the 60s window
        assert mb.stats.bypass == 1
        assert not f_small.done()                     # still coalescing
    finally:
        mb.close()
    assert f_small.result(timeout=1)[0].shape == (5,)  # drained on close
    snap = mb.stats.snapshot()
    assert snap["bypass"] == 1
    assert snap["n_requests"] == 2


def test_bypass_does_not_block_dispatcher():
    """While a slow bulk bypass runs, latency traffic keeps flowing."""
    eng = _SlowStubEngine(delay_s=1.0)
    with MicroBatcher(eng, max_wait_ms=1.0, max_batch=8) as mb:
        f_bulk = mb.submit(np.zeros((32, 4), np.float32))
        t0 = time.perf_counter()
        f_fast = mb.submit(np.zeros((4,), np.float32))
        f_fast.result(timeout=10)
        fast_latency = time.perf_counter() - t0
        f_bulk.result(timeout=10)
    assert fast_latency < 0.9      # did not queue behind the 1s bulk job
    assert mb.stats.bypass == 1


def test_bypass_rejected_after_close():
    eng = _SlowStubEngine()
    mb = MicroBatcher(eng, max_wait_ms=1.0, max_batch=4)
    mb.close()
    with pytest.raises(RuntimeError, match="closed"):
        mb.submit(np.zeros((4, 4), np.float32))


def test_close_joins_inflight_bypass():
    eng = _SlowStubEngine(delay_s=0.5)
    mb = MicroBatcher(eng, max_wait_ms=1.0, max_batch=4)
    fut = mb.submit(np.zeros((4, 4), np.float32))
    mb.close()                                  # must wait for the thread
    assert fut.done()
    assert fut.result()[0].shape == (4, 5)


def test_bypass_on_real_engine(ds, cfg, index):
    with index.serve(max_wait_ms=1.0, max_batch=8) as mb:
        fut = mb.submit(np.asarray(ds.Q[:16]))
        ids, dists = fut.result(timeout=120)
    assert ids.shape == (16, 10)
    assert mb.stats.bypass == 1
    ref_ids, _ = index.search(ds.Q[:16])
    np.testing.assert_array_equal(ids, ref_ids)


# ----------------------------------------------------------------------
# config validation + arch suggestions
# ----------------------------------------------------------------------

@pytest.mark.parametrize("bad", [
    dict(metric="l3"), dict(metric="cosine"),
    dict(kernel_backend="cuda"), dict(gather_fused="maybe"),
])
def test_annconfig_rejects_bad_knobs_at_construction(bad):
    with pytest.raises(ValueError, match=next(iter(bad))):
        ANNConfig(**bad)


def test_annconfig_accepts_registered_backend():
    from repro.core import hotpath

    hotpath.register_backend("test_backend_cfg", object())
    try:
        assert ANNConfig(kernel_backend="test_backend_cfg") is not None
    finally:
        hotpath._REGISTRY.pop("test_backend_cfg", None)


def test_annconfig_valid_defaults():
    cfg = ANNConfig()
    assert cfg.metric == "l2" and cfg.build_pipeline == (
        "knn", "diversify", "bridges")


def test_get_arch_suggests_close_match():
    with pytest.raises(KeyError, match="tsdg-paper"):
        get_arch("tsdg-papr")
    with pytest.raises(KeyError, match="did you mean"):
        get_arch("gemma3-27")


def test_get_arch_unknown_still_lists_known():
    with pytest.raises(KeyError, match="known"):
        get_arch("zzz-nothing-close")
