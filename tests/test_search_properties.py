"""Property-based tests (hypothesis) on the search invariants."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="optional dev dep (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import get_arch
from repro.core.diversify import PackedGraph, build_tsdg
from repro.core.knn_build import exact_knn
from repro.core.search_large import large_batch_search
from repro.core.search_small import small_batch_search

SETTINGS = dict(max_examples=8, deadline=None)


def _full_graph(n: int):
    """Complete graph: every node links every other (λ = 0)."""
    nbrs = np.tile(np.arange(n, dtype=np.int32), (n, 1))
    # drop self by shifting: row i lists all j != i, padded with sentinel
    out = np.full((n, n - 1), n, np.int32)
    for i in range(n):
        out[i] = np.concatenate([np.arange(i), np.arange(i + 1, n)])
    lam = np.zeros_like(out)
    deg = np.full((n,), n - 1, np.int32)
    return PackedGraph(neighbors=jnp.asarray(out), lambdas=jnp.asarray(lam),
                       degrees=jnp.asarray(deg), hubs=None)


@given(n=st.integers(20, 60), d=st.integers(2, 12),
       seed=st.integers(0, 10_000))
@settings(**SETTINGS)
def test_complete_graph_finds_exact_nn_large(n, d, seed):
    """On a complete graph, best-first search is exhaustive-equivalent:
    the true nearest neighbor MUST be found."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d)).astype(np.float32)
    Q = rng.normal(size=(4, d)).astype(np.float32)
    g = _full_graph(n)
    ids, dists = large_batch_search(jnp.asarray(X), g, jnp.asarray(Q),
                                    k=1, ef=16, hops=n + 8, seed=seed)
    true = np.argmin(((X[None] - Q[:, None]) ** 2).sum(-1), axis=1)
    np.testing.assert_array_equal(np.asarray(ids)[:, 0], true)


@given(n=st.integers(20, 60), d=st.integers(2, 8),
       seed=st.integers(0, 10_000))
@settings(**SETTINGS)
def test_small_batch_valid_outputs(n, d, seed):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d)).astype(np.float32)
    Q = rng.normal(size=(3, d)).astype(np.float32)
    g = _full_graph(n)
    k = 5
    ids, dists = small_batch_search(jnp.asarray(X), g, jnp.asarray(Q),
                                    k=k, t0=4, hops=4, hop_width=16,
                                    width=16, n_seeds=8, seed=seed)
    ids, dists = np.asarray(ids), np.asarray(dists)
    assert ids.shape == (3, k)
    valid = ids < n
    assert valid[:, 0].all()                      # at least one result
    # distances ascending among valid
    for r in range(3):
        dv = dists[r][valid[r]]
        assert (np.diff(dv) >= -1e-5).all()
        # reported distances match actual distances
        actual = ((X[ids[r][valid[r]]] - Q[r]) ** 2).sum(-1)
        np.testing.assert_allclose(dv, actual, rtol=1e-4, atol=1e-4)


@given(seed=st.integers(0, 1000))
@settings(max_examples=4, deadline=None)
def test_build_invariants_random_data(seed):
    """TSDG build invariants hold on arbitrary gaussian data."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(300, 8)).astype(np.float32)
    cfg = dataclasses.replace(get_arch("tsdg-paper"), k_graph=8,
                              max_degree=12, lambda0=6, bridge_hubs=16,
                              bridge_k=4)
    g = build_tsdg(jnp.asarray(X), cfg)
    nbrs = np.asarray(g.neighbors)
    lam = np.asarray(g.lambdas)
    n = X.shape[0]
    assert nbrs.shape == (n, 12)
    # no self loops among valid edges
    rows = np.arange(n)[:, None]
    assert not ((nbrs == rows) & (nbrs < n)).any()
    # λ ascending per row over valid prefix
    for r in range(0, n, 37):
        row = lam[r][nbrs[r] < n]
        assert (np.diff(row) >= 0).all()
    # degrees within bounds
    deg = np.asarray(g.degrees)
    assert (deg >= 0).all() and (deg <= 12).all()
