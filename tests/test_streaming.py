"""Streaming mutability (DESIGN.md §7): add/delete/compact across the
facade, the execution planes, and the artifact layer.

Correctness bars pinned here:

* pre-compaction searches are recall-equivalent to a brute-force oracle
  over the effective corpus (live base rows + live delta rows), and
  tombstoned ids NEVER appear in results;
* post-compaction searches are bitwise-identical to a fresh ``Index.build``
  over the same vectors, on both planes;
* a same-shape generation hot-swap recompiles NOTHING
  (``ServeStats.compiles == 0`` across the swap) and drops no in-flight
  requests under a live MicroBatcher;
* artifact format v3 round-trips the mutation state bitwise and still
  reads v1/v2 (frozen, generation-0) artifacts;
* ``merge_topk`` — the one fuse point between base and delta results —
  matches an explicit-set reference on pools < k, all-invalid shards, and
  duplicate ids across shards.
"""
import dataclasses
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ann import Index
from repro.ann.compaction import effective_corpus
from repro.ann.delta import DeltaShard, StreamState
from repro.ann.dispatch import regime_for
from repro.configs import get_arch
from repro.core.distributed import PAD_ID, merge_topk
from repro.data.synthetic import make_clustered
from repro.serve.plane import StaleGeneration

INF = np.float32(3.4e38)


@pytest.fixture(scope="module")
def ds():
    return make_clustered(n=1200, d=16, n_queries=64, n_clusters=16,
                          noise=0.6, seed=3)


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(get_arch("tsdg-paper"), k_graph=12,
                               max_degree=16, lambda0=8, bridge_hubs=32,
                               bridge_k=8, large_ef=48, large_hops=64,
                               serve_buckets=(8, 32), delta_min_cap=64)


@pytest.fixture(scope="module")
def base(ds, cfg):
    """One shared build; mutating tests wrap the same graph in fresh
    Index objects (graph= skips the pipeline) so each starts clean."""
    return Index.build(ds.X, cfg, k=5)


@pytest.fixture()
def index(ds, cfg, base):
    return Index(ds.X, cfg, k=5, graph=base.graph)


def _oracle(X_eff, gids, Q, k):
    """Explicit brute-force top-k over an effective corpus."""
    D = ((Q[:, None, :].astype(np.float64)
          - X_eff[None].astype(np.float64)) ** 2).sum(-1)
    order = np.argsort(D, axis=1, kind="stable")[:, :k]
    return gids[order]


def _effective(idx, X):
    st = idx.engine.stream
    count = st.delta.count
    X_eff = np.concatenate(
        [X[st.base_alive], st.delta.X[:count][st.delta.alive[:count]]])
    gids = np.concatenate(
        [np.arange(st.n_base)[st.base_alive],
         (st.n_base + np.arange(count))[st.delta.alive[:count]]])
    return X_eff, gids


# ----------------------------------------------------------------------
# merge_topk: the base+delta fuse point, vs an explicit-set reference
# ----------------------------------------------------------------------

def _merge_reference(ids, d, k):
    """Explicit per-row reference: drop invalid lanes (id < 0 or INF),
    keep the best copy of each id, sort by (distance, id), pad."""
    out_i, out_d = [], []
    for row_i, row_d in zip(ids, d):
        best = {}
        for i, dist in zip(row_i.tolist(), row_d.tolist()):
            if i < 0 or dist >= INF:
                continue
            if i not in best or dist < best[i]:
                best[i] = dist
        ranked = sorted(best.items(), key=lambda t: (t[1], t[0]))[:k]
        ri = [i for i, _ in ranked] + [PAD_ID] * (k - len(ranked))
        rd = [t for _, t in ranked] + [float(INF)] * (k - len(ranked))
        out_i.append(ri)
        out_d.append(rd)
    return np.asarray(out_i, np.int32), np.asarray(out_d, np.float32)


def test_merge_topk_pool_smaller_than_k():
    ids = np.array([[3, 7]], np.int32)
    d = np.array([[0.5, 0.25]], np.float32)
    mi, md = merge_topk(jnp.asarray(ids), jnp.asarray(d), 5)
    ri, rd = _merge_reference(ids, d, 5)
    np.testing.assert_array_equal(np.asarray(mi), ri)
    np.testing.assert_array_equal(np.asarray(md), rd)


def test_merge_topk_all_invalid_row():
    """An all-tombstoned shard contributes only PAD/INF lanes; the merge
    must yield a fully padded row, not garbage ids."""
    ids = np.full((2, 6), PAD_ID, np.int32)
    d = np.full((2, 6), INF, np.float32)
    mi, md = merge_topk(jnp.asarray(ids), jnp.asarray(d), 3)
    assert (np.asarray(mi) == PAD_ID).all()
    assert (np.asarray(md) >= INF).all()


def test_merge_topk_duplicate_ids_keep_best_copy():
    """The same id arriving from base and delta (or two shards) must keep
    the smaller distance and never occupy two output slots."""
    ids = np.array([[4, 9, 4, 2]], np.int32)
    d = np.array([[1.0, 0.1, 0.4, 0.2]], np.float32)
    mi, md = merge_topk(jnp.asarray(ids), jnp.asarray(d), 4)
    ri, rd = _merge_reference(ids, d, 4)
    np.testing.assert_array_equal(np.asarray(mi), ri)
    np.testing.assert_array_equal(np.asarray(md), rd)


def test_merge_topk_negative_ids_invalid():
    """ANY negative id is an invalid lane (delta padding uses PAD_ID=-1,
    but defensive: -2 etc. must not leak either)."""
    ids = np.array([[-2, 5, -1]], np.int32)
    d = np.array([[0.0, 0.5, 0.1]], np.float32)
    mi, _ = merge_topk(jnp.asarray(ids), jnp.asarray(d), 2)
    assert np.asarray(mi).tolist() == [[5, PAD_ID]]


def test_merge_topk_k_nonpositive_raises():
    ids = jnp.zeros((1, 4), jnp.int32)
    d = jnp.zeros((1, 4), jnp.float32)
    with pytest.raises(ValueError, match="k must be >= 1"):
        merge_topk(ids, d, 0)


def test_merge_topk_fuzz_vs_reference(rng):
    for trial in range(20):
        B = int(rng.integers(1, 5))
        W = int(rng.integers(1, 12))
        k = int(rng.integers(1, 8))
        ids = rng.integers(-1, 10, size=(B, W)).astype(np.int32)
        d = rng.uniform(0, 4, size=(B, W)).astype(np.float32)
        d = np.where(ids < 0, INF, d)
        # sprinkle invalid distances on valid ids too
        kill = rng.uniform(size=d.shape) < 0.2
        d = np.where(kill, INF, d)
        mi, md = merge_topk(jnp.asarray(ids), jnp.asarray(d), k)
        ri, rd = _merge_reference(np.where(d >= INF, -1, ids), d, k)
        np.testing.assert_array_equal(np.asarray(mi), ri, err_msg=f"trial {trial}")
        np.testing.assert_allclose(np.asarray(md), rd, err_msg=f"trial {trial}")


# ----------------------------------------------------------------------
# host-side state: DeltaShard / StreamState
# ----------------------------------------------------------------------

def test_delta_shard_doubles_capacity():
    sh = DeltaShard(4, min_cap=2)
    sh.append(np.ones((3, 4), np.float32))
    assert sh.cap == 4 and sh.count == 3
    sh.append(np.ones((6, 4), np.float32))
    assert sh.cap == 16 and sh.count == 9
    assert sh.n_alive() == 9


def test_stream_state_delete_validation():
    st = StreamState(10, 4, min_cap=4)
    ids = st.add(np.zeros((2, 4), np.float32))
    assert ids.tolist() == [10, 11]
    with pytest.raises(KeyError, match="out of range"):
        st.delete([12])
    with pytest.raises(KeyError, match="out of range"):
        st.delete([-1])
    with pytest.raises(KeyError, match="duplicate"):
        st.delete([3, 3])
    with pytest.raises(KeyError, match="integers"):
        st.delete(np.array([1.5]))
    st.delete([3, 10])
    with pytest.raises(KeyError, match="already deleted"):
        st.delete([3])
    # all-or-nothing: the valid id 4 must survive a rejected batch
    with pytest.raises(KeyError):
        st.delete([4, 3])
    assert st.base_alive[4]
    assert st.n_active() == 10  # 9 base + 1 delta


def test_effective_corpus_id_map():
    st = StreamState(4, 2, min_cap=2)
    st.add(np.arange(4, dtype=np.float32).reshape(2, 2) + 100)
    st.delete([1, 4])
    X = np.arange(8, dtype=np.float32).reshape(4, 2)
    X_eff, id_map = effective_corpus(st, X)
    assert X_eff.shape == (4, 2)
    np.testing.assert_array_equal(id_map, [0, -1, 1, 2, -1, 3])
    np.testing.assert_array_equal(X_eff[3], [102, 103])


# ----------------------------------------------------------------------
# input validation at the facade (satellite 1)
# ----------------------------------------------------------------------

def test_search_wrong_dim_raises(index):
    with pytest.raises(ValueError, match="must be"):
        index.search(np.zeros((2, 7), np.float32))


def test_search_wrong_dtype_raises(index):
    with pytest.raises(ValueError, match="numeric"):
        index.search(np.array([["a"] * 16, ["b"] * 16]))


def test_add_wrong_dim_raises(index):
    with pytest.raises(ValueError, match="vectors must be"):
        index.add(np.zeros((2, 7), np.float32))
    with pytest.raises(ValueError, match="empty add"):
        index.add(np.zeros((0, 16), np.float32))


def test_add_wrong_dtype_raises(index):
    with pytest.raises(ValueError, match="numeric"):
        index.add(np.array([["x"] * 16]))


def test_delete_unknown_id_raises(index):
    with pytest.raises(KeyError, match="out of range"):
        index.delete([10 ** 6])


def test_delete_twice_raises(index):
    index.delete([5])
    with pytest.raises(KeyError, match="already deleted"):
        index.delete([5])


# ----------------------------------------------------------------------
# lifecycle: add / delete / search, vs the brute-force oracle
# ----------------------------------------------------------------------

def test_add_returns_stable_global_ids(ds, index):
    n = ds.X.shape[0]
    ids1 = index.add(ds.Q[:3])
    ids2 = index.add(ds.Q[3:5])
    assert ids1.tolist() == [n, n + 1, n + 2]
    assert ids2.tolist() == [n + 3, n + 4]
    assert index.n_active == n + 5


def test_added_vectors_are_found(ds, index):
    """An exact duplicate of the query inserted via add() must come back
    as its top-1 at distance ~0, in both regimes."""
    new = index.add(ds.Q[:4])
    for B in (4, 64):  # small and large regimes
        ids, dists = index.search(ds.Q[:B])
        for r in range(4):
            assert ids[r, 0] == new[r]
            assert dists[r, 0] <= 1e-4
    assert index.stats.stream_batches > 0


def test_deleted_ids_never_returned(ds, index):
    ids0, _ = index.search(ds.Q)
    victims = sorted({int(ids0[r, 0]) for r in range(ds.Q.shape[0])})
    index.delete(victims)
    for B in (8, 64):
        ids, _ = index.search(ds.Q[:B])
        assert not (set(np.unique(ids)) & set(victims))


def test_precompaction_recall_vs_oracle(ds, index):
    """Streamed state (adds + deletes) must stay recall-equivalent to the
    brute-force oracle over the effective corpus."""
    rng = np.random.default_rng(7)
    index.add(ds.Q[:8] + rng.normal(scale=1e-3, size=(8, 16)).astype(np.float32))
    ids0, _ = index.search(ds.Q[:16])
    index.delete(sorted({int(i) for i in ids0[:, 0]}))
    X_eff, gids = _effective(index, ds.X)
    want = _oracle(X_eff, gids, ds.Q, 5)
    for B in (16, 64):
        got, _ = index.search(ds.Q[:B])
        hit = np.mean([len(set(got[r]) & set(want[r])) / 5
                       for r in range(B)])
        assert hit >= 0.9, f"B={B}: recall {hit} vs oracle"


def test_delta_only_queries_brute_force_exact(ds, cfg, base):
    """With every base row deleted from the candidate answers' vicinity
    impossible to arrange cheaply, instead check the delta is EXACT: any
    query whose true top-1 lives in the delta must surface it first."""
    index = Index(ds.X, cfg, k=5, graph=base.graph)
    new = index.add(ds.Q[:6] * 1.0)   # exact copies
    ids, dists = index.search(ds.Q[:6])
    np.testing.assert_array_equal(ids[:, 0], new)
    assert (dists[:, 0] <= 1e-4).all()


def test_regime_counts_delta_population(ds, cfg, base):
    index = Index(ds.X, cfg, k=5, graph=base.graph)
    boundary = (4 * cfg.small_batch_threshold) // cfg.small_t0
    assert index.regime(boundary - 1) == "small"
    # a big delta shard adds brute-force work per query: the same batch
    # should now dispatch large
    index.engine.stream = StreamState(ds.X.shape[0], 16, min_cap=64)
    index.engine.stream.add(np.zeros((40 * cfg.hop_width, 16), np.float32))
    assert index.regime(boundary - 1) == "large"
    # the pure function stays paper-exact at n_delta=0
    assert regime_for(cfg, boundary - 1, n_delta=0) == "small"
    assert regime_for(cfg, boundary) == "large"


# ----------------------------------------------------------------------
# compaction: bitwise parity with a fresh build + zero-recompile hot-swap
# ----------------------------------------------------------------------

def test_compaction_bitwise_vs_fresh_build(ds, cfg, base):
    index = Index(ds.X, cfg, k=5, graph=base.graph)
    added = index.add(ds.Q[:8])
    ids0, _ = index.search(ds.Q[:8])
    index.delete([int(added[0]), 3, 11])
    X_eff, _ = _effective(index, ds.X)

    id_map = index.compact()
    assert index.generation == 1
    assert index.engine.stream is None and not index.plane.stream_active
    assert id_map.shape == (ds.X.shape[0] + 8,)
    assert (id_map < 0).sum() == 3

    fresh = Index.build(X_eff, cfg, k=5)
    for B in (8, 64):  # both regimes
        a, da = index.search(ds.Q[:B])
        b, db = fresh.search(ds.Q[:B])
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(da, db)


def test_compact_noop_when_clean(ds, index):
    id_map = index.compact()
    assert index.generation == 0  # nothing happened
    np.testing.assert_array_equal(id_map, np.arange(ds.X.shape[0]))


def test_compact_all_deleted_raises(ds, cfg, base):
    # drive the engine's stream directly (deleting 1200 ids one by one
    # through the facade would dominate the test's runtime)
    index = Index(ds.X, cfg, k=5, graph=base.graph)
    index.engine.stream = StreamState(ds.X.shape[0], 16)
    index.engine.stream.base_alive[:] = False
    index.engine._push_stream()
    with pytest.raises(ValueError, match="empty index"):
        index.compact()


def test_hot_swap_zero_recompiles(ds, cfg, base):
    """The acceptance bar: a generation swap that preserves operand shapes
    must re-bind every cached executable — ServeStats.compiles is UNCHANGED
    across the swap for already-warm (regime, bucket, k) shapes."""
    index = Index(ds.X, cfg, k=5, graph=base.graph)
    index.search(ds.Q[:8])          # warm frozen small
    index.search(ds.Q[:64])         # warm frozen large
    added = index.add(ds.Q[:4])     # delta cap = delta_min_cap
    index.search(ds.Q[:8])          # warm streaming small
    index.search(ds.Q[:64])         # warm streaming large
    # delete exactly as many base rows as were added: the effective corpus
    # keeps the base shape, so the swapped-in generation re-binds
    index.delete([0, 1, 2, 3])
    compiles_before = index.stats.compiles
    index.compact()
    ids, _ = index.search(ds.Q[:8])
    index.search(ds.Q[:64])
    assert index.stats.compiles == compiles_before, \
        "same-shape generation swap must not recompile"
    assert index.generation == 1
    # the swapped-in index actually serves the new corpus: the added
    # vectors (exact query copies) survived compaction under new ids
    assert (np.asarray(ids[:4, 0]) >= ds.X.shape[0] - 4).all()


def test_same_cap_mutations_zero_recompiles(ds, cfg, base):
    index = Index(ds.X, cfg, k=5, graph=base.graph)
    v = index.add(ds.Q[:4])
    index.search(ds.Q[:8])
    before = index.stats.compiles
    index.delete(list(map(int, v[:2])))
    index.add(ds.Q[4:6])
    index.search(ds.Q[:8])
    assert index.stats.compiles == before


def test_stale_generation_surfaces_and_engine_retries(ds, cfg, base):
    """A plane-level executable bound to a superseded generation raises
    StaleGeneration; engine.query re-dispatches instead of failing."""
    index = Index(ds.X, cfg, k=5, graph=base.graph)
    plane = index.plane
    exe = plane.compile("small", 8, 5)
    # shrink the corpus: old executable's token no longer matches
    from repro.ann.pipeline import build_graph
    X2 = ds.X[:600]
    plane.rebind(X2, build_graph(jnp.asarray(X2), cfg))
    with pytest.raises(StaleGeneration):
        exe(jnp.asarray(ds.Q[:8]))
    ids, _ = index.search(ds.Q[:8])   # engine path recompiles transparently
    assert ids.shape == (8, 5)
    assert int(np.max(ids)) < 600


# ----------------------------------------------------------------------
# hot swap under a live MicroBatcher (in-flight futures survive)
# ----------------------------------------------------------------------

def test_hot_swap_under_live_batcher(ds, cfg, base):
    index = Index(ds.X, cfg, k=5, graph=base.graph)
    added = index.add(ds.Q[:4])
    index.delete([0, 1, 2, 3])      # keep the compacted shape identical
    index.search(ds.Q[:8])          # warm the streaming path
    stop = threading.Event()
    futures, errs = [], []

    with index.serve(max_wait_ms=1.0) as mb:
        def pump():
            while not stop.is_set():
                try:
                    futures.append(mb.submit(ds.Q[:4]))
                except Exception as e:  # noqa: BLE001
                    errs.append(e)
        t = threading.Thread(target=pump, daemon=True)
        t.start()
        index.compact()             # swap generations under live traffic
        # a few more submits against the new generation
        for _ in range(5):
            futures.append(mb.submit(ds.Q[:4]))
        stop.set()
        t.join(timeout=30)
    assert not errs
    assert futures
    n = ds.X.shape[0]
    for fut in futures:
        ids, dists = fut.result(timeout=30)   # no future may be dropped
        assert ids.shape == (4, 5)
        # pre-swap answers name delta ids (< n + 4), post-swap answers the
        # renumbered corpus (< n) — never garbage, never a dropped future
        assert (ids >= 0).all() and (ids < n + 4).all()
        # exact query copies exist in every generation (delta pre-swap,
        # compacted rows post-swap); the graph search may miss an exact
        # copy on an occasional row, but not across the board
        assert (np.asarray(dists[:, 0]) <= 1e-4).sum() >= 3
    assert index.generation == 1


# ----------------------------------------------------------------------
# artifact format v3 (+ v1/v2 backward-load regression)
# ----------------------------------------------------------------------

def test_artifact_v3_roundtrip_streaming_state(ds, cfg, base, tmp_path):
    index = Index(ds.X, cfg, k=5, graph=base.graph)
    index.add(ds.Q[:3])
    index.delete([9, int(ds.X.shape[0])])   # one base + one delta id
    a, da = index.search(ds.Q[:8])

    p = tmp_path / "art"
    index.save(p)
    manifest = json.loads((p / "manifest.json").read_text())
    from repro.ann.artifact import FORMAT_VERSION
    assert manifest["format_version"] == FORMAT_VERSION
    assert manifest["generation"] == 0
    assert "streaming" in manifest

    loaded = Index.load(p)
    assert loaded.engine.stream is not None
    assert loaded.plane.stream_active
    assert loaded.n_active == index.n_active
    b, db = loaded.search(ds.Q[:8])
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(da, db)
    # the restored log keeps mutating correctly
    with pytest.raises(KeyError, match="already deleted"):
        loaded.delete([9])


def test_artifact_v3_generation_persists(ds, cfg, base, tmp_path):
    index = Index(ds.X, cfg, k=5, graph=base.graph)
    index.add(ds.Q[:2])
    index.compact()
    p = tmp_path / "gen"
    index.save(p)
    manifest = json.loads((p / "manifest.json").read_text())
    assert manifest["generation"] == 1
    assert "streaming" not in manifest   # compacted = clean
    loaded = Index.load(p)
    assert loaded.generation == 1
    assert loaded.engine.stream is None
    a, _ = index.search(ds.Q[:8])
    b, _ = loaded.search(ds.Q[:8])
    np.testing.assert_array_equal(a, b)


def test_artifact_v2_backward_load(ds, cfg, base, tmp_path):
    """A frozen pre-streaming artifact (format v2) must still load — as a
    generation-0 frozen index."""
    index = Index(ds.X, cfg, k=5, graph=base.graph)
    p = tmp_path / "v2"
    index.save(p, aot=False)
    mpath = p / "manifest.json"
    manifest = json.loads(mpath.read_text())
    manifest["format_version"] = 2
    manifest.pop("generation")
    mpath.write_text(json.dumps(manifest))
    loaded = Index.load(p)
    assert loaded.generation == 0 and loaded.engine.stream is None
    a, _ = index.search(ds.Q[:8])
    b, _ = loaded.search(ds.Q[:8])
    np.testing.assert_array_equal(a, b)


def test_artifact_v1_backward_load(ds, cfg, base, tmp_path):
    """v1 = pre-plane layout: no plane key, format_version 1."""
    index = Index(ds.X, cfg, k=5, graph=base.graph)
    p = tmp_path / "v1"
    index.save(p, aot=False)
    mpath = p / "manifest.json"
    manifest = json.loads(mpath.read_text())
    manifest["format_version"] = 1
    manifest.pop("generation")
    manifest.pop("plane")
    mpath.write_text(json.dumps(manifest))
    loaded = Index.load(p)
    a, _ = index.search(ds.Q[:8])
    b, _ = loaded.search(ds.Q[:8])
    np.testing.assert_array_equal(a, b)


# ----------------------------------------------------------------------
# mesh plane (1x1 mesh exercises the full sharded code path in-process)
# ----------------------------------------------------------------------

def test_mesh_plane_streaming_parity_and_compaction(cfg):
    """1-DB-shard mesh: streaming answers match the single plane bitwise,
    and mesh compaction is bitwise a fresh mesh build."""
    ds = make_clustered(n=512, d=16, n_queries=16, n_clusters=8,
                        noise=0.6, seed=11)
    mesh = jax.make_mesh((1,), ("data",))
    m = Index.build(ds.X, cfg, k=5, mesh=mesh)
    s = Index.build(ds.X, cfg, k=5)

    for idx in (m, s):
        idx.search(ds.Q[:8])       # warm the FROZEN executables so the
        idx.search(ds.Q[:16])      # post-compaction swap has entries to
        idx.add(ds.Q[:4])          # re-bind (the zero-recompile bar)
        idx.delete([0, 1, 2, 3])
    for B in (8, 16):
        a, da = m.search(ds.Q[:B])
        b, db = s.search(ds.Q[:B])
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(da, db)

    compiles_before = m.stats.compiles
    m.compact()
    assert m.generation == 1
    m.search(ds.Q[:8])
    assert m.stats.compiles == compiles_before  # same-shape swap

    fresh = Index.build(np.concatenate([ds.X[4:], ds.Q[:4]]), cfg, k=5,
                        mesh=mesh)
    a, da = m.search(ds.Q[:16])
    b, db = fresh.search(ds.Q[:16])
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(da, db)


def test_mesh_compaction_indivisible_raises(cfg):
    """A >1-shard mesh refuses a compaction whose effective corpus cannot
    split evenly (clear error instead of a deep reshape failure).  On a
    1-device host every size divides, so drive the check directly."""
    ds = make_clustered(n=256, d=16, n_queries=4, n_clusters=4,
                        noise=0.5, seed=13)
    mesh = jax.make_mesh((1,), ("data",))
    m = Index.build(ds.X, cfg, k=5, mesh=mesh)
    m.add(ds.Q[:1])
    m.plane.n_db_shards = 2   # simulate a 2-shard cut: 257 % 2 != 0
    try:
        with pytest.raises(ValueError, match="not divisible"):
            m.compact()
    finally:
        m.plane.n_db_shards = 1
