"""Multi-device tests (subprocess: device count is locked at jax init)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_distributed_build_and_search():
    out = _run("""
import numpy as np, jax, jax.numpy as jnp, dataclasses
from repro.data.synthetic import make_clustered, recall_at_k
from repro.core import distributed as D
from repro.configs import get_arch
from jax.sharding import NamedSharding, PartitionSpec as P
mesh = jax.make_mesh((4, 2), ('data', 'model'))
ds = make_clustered(n=4096, d=16, n_queries=32, n_clusters=24, noise=0.6, seed=0)
cfg = dataclasses.replace(get_arch('tsdg-paper'), k_graph=12, max_degree=16,
                          lambda0=8, bridge_hubs=32, bridge_k=8,
                          large_ef=48, large_hops=64)
X = jax.device_put(jnp.asarray(ds.X), NamedSharding(mesh, P('data', None)))
nbrs, lams, degs, hubs = D.make_build_fn(mesh, cfg)(X)
search = D.make_search_fn(mesh, cfg, kind='large', k=10)
Q = jax.device_put(jnp.asarray(ds.Q), NamedSharding(mesh, P('model', None)))
ids, dist = search(X, nbrs, lams, degs, hubs, Q)
r = recall_at_k(np.asarray(ids), ds.gt, 10)
assert r > 0.7, r
print('RECALL', r)
""")
    assert "RECALL" in out


def test_compressed_psum_matches_exact():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.optim import compression as C
from repro.utils.compat import shard_map
mesh = jax.make_mesh((4,), ('data',))
g = jnp.arange(64, dtype=jnp.float32).reshape(4, 16) / 7.0
err = jnp.zeros((4, 16))
def f(gs, es):
    out, new_e = C.compressed_psum({'g': gs[0]}, {'g': es[0]}, 'data')
    return out['g'], new_e['g']
fn = jax.jit(shard_map(f, mesh=mesh, in_specs=(P('data'), P('data')),
             out_specs=(P(), P('data')), check_vma=False))
out, new_err = fn(g[:, None], err[:, None])
exact = jnp.sum(g, axis=0)
rel = float(jnp.max(jnp.abs(out[0] - exact) / (jnp.abs(exact) + 1e-6)))
assert rel < 0.05, rel
# error feedback: second round with same grads corrects toward exact
out2, _ = fn(g[:, None], new_err)
print('OK', rel)
""")


def test_elastic_restore_different_mesh(tmp_path):
    d = str(tmp_path / "elastic")
    # save on a 4-device mesh
    _run(f"""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train import checkpoint as ckpt
mesh = jax.make_mesh((4,), ('data',))
x = jax.device_put(jnp.arange(32.0).reshape(8, 4),
                   NamedSharding(mesh, P('data', None)))
ckpt.save({{'x': x}}, 3, {d!r})
""", devices=4)
    # restore on a 2-device mesh with new shardings
    _run(f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train import checkpoint as ckpt
mesh = jax.make_mesh((2,), ('data',))
tmpl = {{'x': jnp.zeros((8, 4))}}
shard = {{'x': NamedSharding(mesh, P('data', None))}}
state, step = ckpt.restore({d!r}, tmpl, shardings=shard)
assert step == 3
np.testing.assert_array_equal(np.asarray(state['x']),
                              np.arange(32.0).reshape(8, 4))
print('ELASTIC OK')
""", devices=2)


@pytest.mark.slow
def test_reduced_bundle_lowering_multidevice():
    """Representative (arch x shape) bundles lower+compile on a real
    multi-device mesh (reduced configs; full configs live in dryrun.py)."""
    _run("""
import jax, dataclasses
from repro.configs import get_reduced
from repro.configs.base import ShapeSpec
from repro.launch import steps as S
mesh = jax.make_mesh((4, 2), ('data', 'model'))
cfg = get_reduced('olmoe-1b-7b')
shp = ShapeSpec('train', 'train', dict(seq_len=64, global_batch=8))
S.build_lm_bundle(cfg, shp, mesh).lower(mesh).compile()
shp = ShapeSpec('decode', 'decode', dict(seq_len=128, global_batch=8))
S.build_lm_bundle(cfg, shp, mesh).lower(mesh).compile()
cfg = get_reduced('gatedgcn')
shp = ShapeSpec('full_graph_sm', 'train', dict(n_nodes=256, n_edges=1024, d_feat=16))
S.build_gnn_bundle(cfg, shp, mesh).lower(mesh).compile()
cfg = get_reduced('wide-deep')
shp = ShapeSpec('train_batch', 'train', dict(batch=32))
S.build_recsys_bundle(cfg, shp, mesh).lower(mesh).compile()
print('BUNDLES OK')
""")
