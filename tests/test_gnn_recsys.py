"""GNN aggregation correctness + recsys substrate pieces."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.data import graphs as DG
from repro.data.sampler import NeighborSampler
from repro.models import gnn as G
from repro.models import recsys as R
from repro.models.gnn import aggregate
from repro.models.module import init_params


def test_aggregate_matches_dense_adjacency(rng):
    n, e, d = 40, 200, 8
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    feat = rng.normal(size=(n, d)).astype(np.float32)
    A = np.zeros((n, n), np.float32)
    for s, t in zip(src, dst):
        A[t, s] += 1.0
    for kind in ("sum", "mean"):
        got = aggregate(jnp.asarray(feat)[jnp.asarray(src)],
                        jnp.asarray(dst), n, kind=kind)
        want = A @ feat
        if kind == "mean":
            want = want / np.maximum(A.sum(1, keepdims=True), 1.0)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4,
                                   atol=1e-4)


def test_aggregate_edge_mask(rng):
    n = 10
    src = np.array([0, 1, 2], np.int32)
    dst = np.array([5, 5, 5], np.int32)
    feat = rng.normal(size=(n, 4)).astype(np.float32)
    mask = jnp.asarray([True, False, True])
    got = aggregate(jnp.asarray(feat)[jnp.asarray(src)], jnp.asarray(dst),
                    n, kind="sum", edge_mask=mask)
    np.testing.assert_allclose(np.asarray(got)[5], feat[0] + feat[2],
                               rtol=1e-5)


def test_gin_learns_communities():
    cfg = get_reduced("gin-tu")
    g = DG.make_community_graph(400, 2000, 16, n_classes=4, seed=0)
    batch = {k: jnp.asarray(v) for k, v in g.items()}
    from repro.optim.api import OptimizerConfig, make_optimizer
    from repro.train.trainer import make_train_step

    params = init_params(G.schema(cfg, 16, 4), jax.random.key(0))
    opt = make_optimizer(OptimizerConfig(lr=3e-3, schedule="constant"))
    st = opt.init(params)
    step = jax.jit(make_train_step(lambda p, b: G.loss_fn(p, cfg, b), opt))
    accs = []
    for _ in range(25):
        params, st, m = step(params, st, batch)
        accs.append(float(m["acc"]))
    assert accs[-1] > 0.8, accs[-1]


def test_neighbor_sampler_validity(rng):
    g = DG.make_community_graph(200, 1000, 8, n_classes=4, seed=2)
    sampler = NeighborSampler(g["edge_src"], g["edge_dst"], 200)
    seeds = rng.integers(0, 200, 16)
    sub = sampler.sample_subgraph(seeds, (4, 3), np.random.default_rng(0))
    n_exp = 16 * (1 + 4 + 12)
    assert len(sub["node_ids"]) == n_exp
    assert sub["seed_mask"][:16].all() and not sub["seed_mask"][16:].any()
    # every edge destination is an earlier-layer node
    assert (sub["edge_dst"] < sub["edge_src"]).all()
    # sampled neighbors are actual graph in-neighbors (or self-loops)
    nbr_sets = {}
    for s, t in zip(g["edge_src"], g["edge_dst"]):
        nbr_sets.setdefault(int(t), set()).add(int(s))
    ids = sub["node_ids"]
    for e_s, e_d in zip(sub["edge_src"][:64], sub["edge_dst"][:64]):
        child, parent = int(ids[e_s]), int(ids[e_d])
        assert child == parent or child in nbr_sets.get(parent, set())


def test_embedding_bag_vs_manual(rng):
    table = jnp.asarray(rng.normal(size=(50, 8)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 50, size=(6, 4)).astype(np.int32))
    got = R.embedding_bag(table, ids, combine="mean")
    want = np.stack([np.asarray(table)[np.asarray(ids[i])].mean(0)
                     for i in range(6)])
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5)


def test_wide_hash_in_range_and_deterministic():
    cfg = get_reduced("wide-deep")
    from repro.data.recsys import CTRStream

    b = {k: jnp.asarray(v) for k, v in next(CTRStream(cfg, 16)).items()}
    params = init_params(R.schema(cfg), jax.random.key(0))
    l1 = R.forward(params, cfg, b)
    l2 = R.forward(params, cfg, b)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_recsys_trains():
    from repro.data.recsys import CTRStream
    from repro.optim.api import OptimizerConfig, make_optimizer
    from repro.train.trainer import make_train_step

    cfg = get_reduced("wide-deep")
    stream = CTRStream(cfg, 256, seed=0)
    params = init_params(R.schema(cfg), jax.random.key(0))
    opt = make_optimizer(OptimizerConfig(lr=3e-3, schedule="constant"))
    st = opt.init(params)
    step = jax.jit(make_train_step(lambda p, b: R.loss_fn(p, cfg, b), opt))
    losses = []
    for _ in range(15):
        b = {k: jnp.asarray(v) for k, v in next(stream).items()}
        params, st, m = step(params, st, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
