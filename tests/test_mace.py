"""MACE physics invariants: E(3) symmetry of predicted energies."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.data import graphs as DG
from repro.models import mace as MC
from repro.models.module import init_params


def _setup(l_max=2, corr=3):
    import dataclasses

    cfg = get_reduced("mace")
    cfg = dataclasses.replace(cfg, l_max=l_max, correlation_order=corr,
                              d_hidden=8)
    mol = {k: jnp.asarray(v)
           for k, v in DG.make_molecules(4, 8, 16, seed=1).items()}
    params = init_params(MC.schema(cfg), jax.random.key(0))
    return cfg, params, mol


def _rotmat(seed):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(3, 3))
    Q, _ = np.linalg.qr(A)
    if np.linalg.det(Q) < 0:
        Q[:, 0] *= -1
    return Q.astype(np.float32)


def test_rotation_invariance():
    cfg, params, mol = _setup()
    e1 = MC.forward(params, cfg, mol)
    for seed in (1, 2, 3):
        R = jnp.asarray(_rotmat(seed))
        mol2 = dict(mol)
        mol2["positions"] = mol["positions"] @ R.T
        e2 = MC.forward(params, cfg, mol2)
        np.testing.assert_allclose(np.asarray(e1), np.asarray(e2),
                                   rtol=1e-3, atol=1e-3)


def test_translation_invariance():
    cfg, params, mol = _setup()
    e1 = MC.forward(params, cfg, mol)
    mol2 = dict(mol)
    mol2["positions"] = mol["positions"] + jnp.asarray([10.0, -3.0, 7.0])
    e2 = MC.forward(params, cfg, mol2)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2),
                               rtol=1e-3, atol=1e-3)


def test_reflection_changes_nothing_for_even_model():
    # energies are scalars: O(3) invariance includes parity
    cfg, params, mol = _setup()
    e1 = MC.forward(params, cfg, mol)
    mol2 = dict(mol)
    mol2["positions"] = -mol["positions"]
    e2 = MC.forward(params, cfg, mol2)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2),
                               rtol=1e-3, atol=1e-3)


def test_forces_are_translation_free():
    """Autodiff forces must sum to ~0 (Newton's third law under the
    pairwise graph)."""
    cfg, params, mol = _setup()

    def energy(pos):
        b = dict(mol)
        b["positions"] = pos
        return jnp.sum(MC.forward(params, cfg, b))

    f = -jax.grad(energy)(mol["positions"])
    # per-graph force sums vanish
    tot = jax.ops.segment_sum(f, mol["graph_ids"], 4)
    np.testing.assert_allclose(np.asarray(tot), 0.0, atol=1e-3)


def test_l1_correlation2_variant():
    cfg, params, mol = _setup(l_max=1, corr=2)
    e = MC.forward(params, cfg, mol)
    assert np.isfinite(np.asarray(e)).all()
