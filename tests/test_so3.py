"""SO(3) machinery: CG/SH consistency and rotation equivariance."""
import numpy as np
import pytest

from repro.utils.so3 import (cg_complex, irrep_slices, real_cg,
                             spherical_harmonics)


def _rotmat(rng):
    A = rng.normal(size=(3, 3))
    Q, _ = np.linalg.qr(A)
    if np.linalg.det(Q) < 0:
        Q[:, 0] *= -1
    return Q


def test_cg_complex_orthogonality():
    # sum_{m1,m2} <j1 m1 j2 m2|j3 m3><j1 m1 j2 m2|j3' m3'> = delta
    j1 = j2 = 1
    for j3 in (0, 1, 2):
        for j3p in (0, 1, 2):
            for m3 in range(-j3, j3 + 1):
                for m3p in range(-j3p, j3p + 1):
                    s = sum(
                        cg_complex(j1, m1, j2, m2, j3, m3)
                        * cg_complex(j1, m1, j2, m2, j3p, m3p)
                        for m1 in range(-1, 2) for m2 in range(-1, 2))
                    expect = 1.0 if (j3 == j3p and m3 == m3p) else 0.0
                    assert abs(s - expect) < 1e-12


@pytest.mark.parametrize("l1,l2,l3", [(1, 1, 0), (1, 1, 2), (2, 1, 1),
                                      (2, 2, 2), (2, 2, 0)])
def test_real_cg_is_real(l1, l2, l3):
    C = real_cg(l1, l2, l3)
    assert C.dtype == np.float64
    assert np.abs(C).max() > 0


def test_sh_product_decomposition():
    """Y1 x Y1 contracted with CG(1,1,2) is proportional to Y2 pointwise."""
    rng = np.random.default_rng(0)
    v = rng.normal(size=(20, 3))
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    Y = spherical_harmonics(v, 2)
    C = real_cg(1, 1, 2)
    T = np.einsum("ni,nj,ijk->nk", Y[:, 1:4], Y[:, 1:4], C)
    ratio = T / Y[:, 4:9]
    assert np.ptp(ratio) < 1e-10


def test_invariant_contraction_is_dot():
    rng = np.random.default_rng(1)
    v = rng.normal(size=(10, 3))
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    w = rng.normal(size=(10, 3))
    w /= np.linalg.norm(w, axis=1, keepdims=True)
    C0 = real_cg(1, 1, 0)
    Y1v = spherical_harmonics(v, 1)[:, 1:4]
    Y1w = spherical_harmonics(w, 1)[:, 1:4]
    inv = np.einsum("ni,nj,ijk->nk", Y1v, Y1w, C0)[:, 0]
    dots = np.sum(v * w, axis=1)
    ratio = inv / dots
    assert np.ptp(ratio) < 1e-10


@pytest.mark.parametrize("l", [1, 2, 3])
def test_sh_rotation_invariant_norms(l):
    """||Y_l(Rv)|| == ||Y_l(v)|| for any rotation (equivariance necessary
    condition; the full MACE energy-invariance test is in test_mace)."""
    rng = np.random.default_rng(2)
    v = rng.normal(size=(16, 3))
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    R = _rotmat(rng)
    sl = irrep_slices(l)
    Y = spherical_harmonics(v, l)
    YR = spherical_harmonics(v @ R.T, l)
    for (ll, a, b) in sl:
        n1 = np.linalg.norm(Y[:, a:b], axis=1)
        n2 = np.linalg.norm(YR[:, a:b], axis=1)
        np.testing.assert_allclose(n1, n2, rtol=1e-10)
