"""Small-batch merge dedup: explicit-set reference regression tests.

A node reachable through two edges (duplicate graph lanes, bridge splices)
must occupy exactly ONE ranking slot — the half-merge used to let it take
two, shrinking the effective ranking width.  The reference implementation
here maintains R_ij with explicit python-set semantics; the search must
match it bitwise (ids AND dists) because all distance evaluations go
through the same jitted hotpath primitives.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import hotpath as HP
from repro.core.diversify import PackedGraph, build_tsdg
from repro.core.search_small import small_batch_search


def _full_graph(n: int):
    """Complete graph: every node links every other (λ = 0)."""
    out = np.full((n, n - 1), n, np.int32)
    for i in range(n):
        out[i] = np.concatenate([np.arange(i), np.arange(i + 1, n)])
    lam = np.zeros_like(out)
    deg = np.full((n,), n - 1, np.int32)
    return PackedGraph(neighbors=jnp.asarray(out), lambdas=jnp.asarray(lam),
                       degrees=jnp.asarray(deg), hubs=None)


INF = np.float32(3.4e38)


@functools.partial(jax.jit, static_argnames=("metric", "backend"))
def _nd_jit(Q, X, idx, mask, metric, backend):
    return HP.neighbor_distances(Q, X, idx, metric=metric, mask=mask,
                                 backend=backend)


@functools.partial(jax.jit, static_argnames=("metric", "k", "backend"))
def _ss_jit(Q, X, seeds, metric, k, backend):
    return HP.seed_select(Q, X, seeds, metric=metric, k=k, backend=backend)


def _dup_graph(n: int, deg: int, seed: int) -> PackedGraph:
    """Adjacency whose rows list every neighbor TWICE (the duplicate-lane
    shape bridge splicing can produce) — each duplicate must still occupy
    only one ranking slot."""
    rng = np.random.default_rng(seed)
    half_rows = rng.integers(0, n, size=(n, deg // 2)).astype(np.int32)
    half_rows = np.where(half_rows == np.arange(n)[:, None],
                         (half_rows + 1) % n, half_rows)
    nbrs = np.concatenate([half_rows, half_rows], axis=1)
    perm = rng.permutation(deg)
    nbrs = nbrs[:, perm]
    return PackedGraph(neighbors=jnp.asarray(nbrs),
                       lambdas=jnp.zeros((n, deg), jnp.int32),
                       degrees=np.full((n,), deg, np.int32), hubs=None)


def _ref_small_search(X, g, Q, *, k, t0, hops, hop_width, width, n_seeds,
                      lambda_limit, seed, exact_merge, backend):
    """Algorithm 1 with R_ij maintained under explicit-set semantics
    (python sets/dicts for membership + dedup, sorted lists for ranking).
    Distance evaluations go through the same jitted hotpath primitives the
    implementation uses, so ids AND dists must match bitwise."""
    Xj = jnp.asarray(X)
    N, _ = X.shape
    B = Q.shape[0]
    S = B * t0
    half = width // 2
    key = jax.random.fold_in(jax.random.key(seed), 0)
    row_keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(S))
    Qs = jnp.repeat(jnp.asarray(Q), t0, axis=0)
    seeds = jax.vmap(
        lambda rk: jax.random.randint(rk, (n_seeds,), 0, N, jnp.int32))(
        row_keys)
    sd1, si1 = _ss_jit(Qs, Xj, seeds, "l2", 1, backend)
    u = np.asarray(si1)[:, 0].copy()
    R = [[(float(np.asarray(sd1)[s, 0]), int(u[s]))]
         + [(float(INF), N)] * (width - 1) for s in range(S)]
    active = np.ones(S, bool)
    nbrs_all = np.asarray(g.neighbors)
    lams_all = np.asarray(g.lambdas)
    M = nbrs_all.shape[1]
    n_chunks = max(1, -(-M // hop_width))
    pad_m = n_chunks * hop_width - M
    for _ in range(hops):
        nbrs = nbrs_all[u]
        visit = lams_all[u] < lambda_limit
        dists = np.asarray(_nd_jit(Qs, Xj, jnp.asarray(nbrs),
                                   jnp.asarray(visit), "l2", backend))
        if pad_m:
            dists = np.concatenate(
                [dists, np.full((S, pad_m), INF, np.float32)], 1)
            nbrs = np.concatenate(
                [nbrs, np.full((S, pad_m), N, np.int32)], 1)
        cd = dists.reshape(S, n_chunks, hop_width)
        ci = nbrs.reshape(S, n_chunks, hop_width)
        la = np.argmin(cd, axis=1)
        rt_d = np.take_along_axis(cd, la[:, None, :], 1)[:, 0]
        rt_i = np.take_along_axis(ci, la[:, None, :], 1)[:, 0]
        if hop_width < width:
            pad = width - hop_width
            rt_d = np.concatenate(
                [rt_d, np.full((S, pad), INF, np.float32)], 1)
            rt_i = np.concatenate([rt_i, np.full((S, pad), N, np.int32)], 1)
        for s in range(S):
            entries = sorted(zip(rt_d[s].tolist(), rt_i[s].tolist()))
            new_u = entries[0][1]
            Rs = sorted(R[s])
            barrier = Rs if exact_merge else Rs[:half]
            barrier_ids = {i for dd, i in barrier if dd < float(INF)}
            seen: set = set()
            rt_u = []
            for dd, ii in entries:  # dedup by id, keep best copy
                if ii < N and ii not in seen and ii not in barrier_ids:
                    seen.add(ii)
                    rt_u.append((dd, ii))
                else:
                    rt_u.append((float(INF), N))
            rt_u = sorted(rt_u)
            if exact_merge:
                new_R = sorted(Rs + rt_u)[:width]
                improved = any(new_R[j][0] < Rs[j][0] for j in range(width))
            else:
                new_R = sorted(Rs[:half] + rt_u[:half])
                improved = any(rt_u[j][0] < Rs[half + j][0]
                               for j in range(half))
            if active[s]:
                R[s] = new_R
                u[s] = new_u
            active[s] = active[s] and improved
    out_ids = np.full((B, k), N, np.int64)
    out_d = np.full((B, k), INF, np.float32)
    for b in range(B):
        best: dict = {}
        for j in range(t0):
            for dd, ii in R[b * t0 + j]:
                if ii < N and (ii not in best or dd < best[ii]):
                    best[ii] = dd
        top = sorted((dd, ii) for ii, dd in best.items())[:k]
        for j, (dd, ii) in enumerate(top):
            out_ids[b, j] = ii
            out_d[b, j] = np.float32(dd)
    return out_ids, out_d


@pytest.mark.parametrize("exact_merge", [False, True])
@pytest.mark.parametrize("graph_kind", ["dup", "full"])
def test_small_batch_matches_explicit_set_reference(exact_merge, graph_kind):
    n, d, B, k = 64, 6, 3, 6
    t0, width, hop_width, hops, n_seeds = 4, 16, 16, 5, 8
    rng = np.random.default_rng(7)
    X = rng.normal(size=(n, d)).astype(np.float32)
    Q = rng.normal(size=(B, d)).astype(np.float32)
    g = _dup_graph(n, 12, 3) if graph_kind == "dup" else _full_graph(n)
    kwargs = dict(k=k, t0=t0, hops=hops, hop_width=hop_width, width=width,
                  n_seeds=n_seeds, lambda_limit=10, seed=0,
                  exact_merge=exact_merge)
    for backend in ("xla", "pallas"):
        ids, dists = small_batch_search(jnp.asarray(X), g, jnp.asarray(Q),
                                        backend=backend, **kwargs)
        rids, rd = _ref_small_search(X, g, Q, backend=backend, **kwargs)
        ids, dists = np.asarray(ids), np.asarray(dists)
        # every returned id is unique within its row (the dedup contract)
        for r in range(B):
            valid = ids[r][ids[r] < n]
            assert len(valid) == len(set(valid.tolist())), backend
        np.testing.assert_array_equal(ids, rids, err_msg=backend)
        np.testing.assert_array_equal(dists, rd, err_msg=backend)


def test_small_batch_output_ids_unique(rng=None):
    """e2e uniqueness on a built TSDG graph with bridge splices (the
    duplicate-edge source in production graphs)."""
    rng = np.random.default_rng(11)
    X = rng.normal(size=(400, 8)).astype(np.float32)
    cfg = dataclasses.replace(get_arch("tsdg-paper"), k_graph=8,
                              max_degree=12, lambda0=6, bridge_hubs=64,
                              bridge_k=6)
    g = build_tsdg(jnp.asarray(X), cfg)
    Q = rng.normal(size=(8, 8)).astype(np.float32)
    ids, _ = small_batch_search(jnp.asarray(X), g, jnp.asarray(Q), k=10,
                                t0=4, hops=6, width=16, n_seeds=8)
    ids = np.asarray(ids)
    for r in range(ids.shape[0]):
        valid = ids[r][ids[r] < 400]
        assert len(valid) == len(set(valid.tolist()))
