"""Per-arch smoke tests (deliverable f): each assigned architecture at a
REDUCED config runs one forward/train step on CPU — shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.data import graphs as DG
from repro.data.recsys import CTRStream
from repro.models import gnn as G
from repro.models import mace as MC
from repro.models import recsys as R
from repro.models import transformer as T
from repro.models.module import init_params

LM_ARCHS = ["olmoe-1b-7b", "kimi-k2-1t-a32b", "starcoder2-7b", "gemma3-27b",
            "olmo-1b"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_train_step(arch):
    cfg = get_reduced(arch)
    params = init_params(T.schema(cfg), jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 33), 0, cfg.vocab)
    loss, metrics = jax.jit(lambda p, b: T.loss_fn(p, cfg, b))(
        params, {"tokens": toks})
    assert jnp.isfinite(loss)
    assert 0.0 <= float(metrics["acc"]) <= 1.0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_serve_path(arch):
    cfg = get_reduced(arch)
    params = init_params(T.schema(cfg), jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    last, cache = jax.jit(lambda p, t: T.prefill(p, cfg, t))(params, toks)
    assert last.shape == (2, cfg.vocab)
    # extend cache and decode one token
    cache = {k: {"k": jnp.pad(v["k"], ((0, 0), (0, 8), (0, 0), (0, 0))),
                 "v": jnp.pad(v["v"], ((0, 0), (0, 8), (0, 0), (0, 0)))}
             for k, v in cache.items()}
    logits, cache2 = jax.jit(
        lambda p, c, t: T.decode_step(p, cfg, c, t, jnp.int32(16)))(
        params, cache, toks[:, -1])
    assert logits.shape == (2, cfg.vocab)
    assert jnp.all(jnp.isfinite(logits))


@pytest.mark.parametrize("arch", ["gin-tu", "gatedgcn"])
def test_gnn_train_step(arch):
    cfg = get_reduced(arch)
    g = DG.make_community_graph(300, 1200, 16, n_classes=6, seed=0)
    batch = {k: jnp.asarray(v) for k, v in g.items()}
    params = init_params(G.schema(cfg, 16, 6), jax.random.key(0))
    loss, m = jax.jit(lambda p, b: G.loss_fn(p, cfg, b))(params, batch)
    assert jnp.isfinite(loss)
    logits = G.forward(params, cfg, batch)
    assert logits.shape == (300, 6)
    assert jnp.all(jnp.isfinite(logits))


def test_graphsage_minibatch_step():
    from repro.data.sampler import SampledStream, subgraph_sizes

    cfg = get_reduced("graphsage-reddit")
    g = DG.make_community_graph(500, 4000, 16, n_classes=6, seed=1)
    stream = SampledStream(g, batch_nodes=16, fanouts=(5, 3), seed=0)
    b = next(iter(stream))
    n, e = subgraph_sizes(16, (5, 3))
    assert b["node_feat"].shape == (n, 16)
    assert b["edge_src"].shape == (e,)
    batch = {k: jnp.asarray(v) for k, v in b.items()}
    params = init_params(G.schema(cfg, 16, 6), jax.random.key(0))
    loss, m = jax.jit(lambda p, bb: G.loss_fn(p, cfg, bb))(params, batch)
    assert jnp.isfinite(loss)


def test_mace_molecule_step():
    cfg = get_reduced("mace")
    mol = {k: jnp.asarray(v)
           for k, v in DG.make_molecules(4, 8, 16, seed=1).items()}
    params = init_params(MC.schema(cfg), jax.random.key(0))
    loss, m = jax.jit(lambda p, b: MC.loss_fn(p, cfg, b))(params, mol)
    assert jnp.isfinite(loss)
    e = MC.forward(params, cfg, mol)
    assert e.shape == (4,)
    assert jnp.all(jnp.isfinite(e))


def test_wide_deep_train_and_serve():
    cfg = get_reduced("wide-deep")
    b = {k: jnp.asarray(v) for k, v in next(CTRStream(cfg, 32, seed=0)).items()}
    params = init_params(R.schema(cfg), jax.random.key(0))
    loss, m = jax.jit(lambda p, bb: R.loss_fn(p, cfg, bb))(params, b)
    assert jnp.isfinite(loss)
    probs = jax.jit(lambda p, bb: R.serve_step(p, cfg, bb))(params, b)
    assert probs.shape == (32,)
    assert jnp.all((probs >= 0) & (probs <= 1))


def test_wide_deep_retrieval_exact():
    """retrieval_step must return the true top-scoring candidates."""
    cfg = get_reduced("wide-deep")
    b = {k: jnp.asarray(v[:1])
         for k, v in next(CTRStream(cfg, 4, seed=0)).items()}
    rng = np.random.default_rng(0)
    items = jnp.asarray(rng.normal(size=(500, R.RETRIEVAL_DIM))
                        .astype(np.float32))
    b["item_vectors"] = items
    params = init_params(R.schema(cfg), jax.random.key(0))
    idx, scores = jax.jit(lambda p, bb: R.retrieval_step(p, cfg, bb))(
        params, b)
    deep, _ = R.user_tower(params, cfg, b)
    u = deep @ params["retrieval_proj"]
    full = np.asarray((u @ items.T)[0])
    true_top = np.argsort(-full)[:100]
    assert set(np.asarray(idx).tolist()) == set(true_top.tolist())
