"""TSDG core behaviour: diversification invariants + end-to-end recall."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import metrics as M
from repro.core import search_ref
from repro.core.diversify import (PackedGraph, append_reverse, build_gd_baseline,
                                  build_tsdg, relaxed_gd, soft_gd)
from repro.core.knn_build import exact_knn, nn_descent, reverse_neighbors
from repro.core.search_large import large_batch_search
from repro.core.search_small import small_batch_search
from repro.data.synthetic import make_clustered, recall_at_k


@pytest.fixture(scope="module")
def ds():
    return make_clustered(n=4000, d=16, n_queries=48, n_clusters=24,
                          noise=0.6, seed=0)


@pytest.fixture(scope="module")
def knn(ds):
    return exact_knn(jnp.asarray(ds.X), 16)


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(get_arch("tsdg-paper"), k_graph=16,
                               max_degree=24, lambda0=8, bridge_hubs=64,
                               bridge_k=8)


@pytest.fixture(scope="module")
def graph(ds, knn, cfg):
    return build_tsdg(jnp.asarray(ds.X), cfg, knn_ids=knn[0],
                      knn_dists=knn[1])


# ----------------------------------------------------------------------
# graph construction
# ----------------------------------------------------------------------

def test_exact_knn_matches_ground_truth(ds, knn):
    ids, dists = knn
    # ground truth was computed in float64 numpy; spot check rows
    X64 = ds.X.astype(np.float64)
    for r in range(0, 4000, 511):
        d = ((X64 - X64[r]) ** 2).sum(1)
        d[r] = np.inf
        true = set(np.argsort(d)[:16].tolist())
        got = set(np.asarray(ids[r]).tolist())
        assert len(true & got) >= 15  # fp32 tie tolerance


def test_nn_descent_converges(ds, knn):
    ids_a, _ = nn_descent(jnp.asarray(ds.X), 16, iters=6)
    hits = 0
    for r in range(0, 4000, 97):
        hits += len(set(np.asarray(ids_a[r]).tolist())
                    & set(np.asarray(knn[0][r]).tolist())) / 16
    assert hits / len(range(0, 4000, 97)) > 0.85


def test_reverse_neighbors_correct():
    ids = jnp.asarray([[1, 2], [2, 3], [0, 3], [0, 1]], jnp.int32)
    rev = reverse_neighbors(ids, ids < 4, cap=4)
    rev = np.asarray(rev)
    # node 0 is pointed to by 2 and 3
    assert set(rev[0][rev[0] < 4].tolist()) == {2, 3}
    assert set(rev[3][rev[3] < 4].tolist()) == {1, 2}


def test_relaxed_gd_keeps_closest_and_prunes(ds, knn):
    X = jnp.asarray(ds.X)
    keep = relaxed_gd(X, knn[0], knn[1], alpha=1.2, metric="l2")
    keep = np.asarray(keep)
    assert keep[:, 0].all()          # closest neighbor always kept
    frac = keep.mean()
    assert 0.05 < frac < 0.9         # meaningful pruning (paper: 6-26%)


def test_alpha_one_prunes_more_than_relaxed(ds, knn):
    X = jnp.asarray(ds.X)
    k_relaxed = np.asarray(relaxed_gd(X, knn[0], knn[1], alpha=1.2,
                                      metric="l2")).mean()
    k_plain = np.asarray(relaxed_gd(X, knn[0], knn[1], alpha=1.0,
                                    metric="l2")).mean()
    assert k_relaxed >= k_plain      # relaxation keeps more edges (paper §3.2)


def test_lambda_sorted_rows(graph, ds):
    lam = np.asarray(graph.lambdas)
    nbrs = np.asarray(graph.neighbors)
    N = ds.X.shape[0]
    for r in range(0, N, 211):
        row = lam[r][nbrs[r] < N]
        assert (np.diff(row) >= 0).all()


def test_degrees_match_valid_entries(graph, ds):
    N = ds.X.shape[0]
    deg = np.asarray(graph.degrees)
    valid = (np.asarray(graph.neighbors) < N).sum(1)
    np.testing.assert_array_equal(deg, valid)


def test_tsdg_denser_than_gd_baseline(ds, knn, cfg):
    X = jnp.asarray(ds.X)
    g_tsdg = build_tsdg(X, cfg, knn_ids=knn[0], knn_dists=knn[1])
    g_gd = build_gd_baseline(X, cfg, knn_ids=knn[0], knn_dists=knn[1])
    assert g_tsdg.avg_degree() > g_gd.avg_degree()


def test_degree_at_lambda_monotone(graph):
    d1 = np.asarray(graph.degree_at(1))
    d5 = np.asarray(graph.degree_at(5))
    d10 = np.asarray(graph.degree_at(10))
    assert (d1 <= d5).all() and (d5 <= d10).all()


# ----------------------------------------------------------------------
# search procedures
# ----------------------------------------------------------------------

def test_small_batch_recall(ds, graph):
    ids, dists = small_batch_search(jnp.asarray(ds.X), graph,
                                    jnp.asarray(ds.Q), k=10, t0=16, hops=6)
    r = recall_at_k(np.asarray(ids), ds.gt, 10)
    assert r > 0.85, r


def test_large_batch_recall(ds, graph):
    ids, dists = large_batch_search(jnp.asarray(ds.X), graph,
                                    jnp.asarray(ds.Q), k=10, ef=64, hops=96)
    r = recall_at_k(np.asarray(ids), ds.gt, 10)
    assert r > 0.8, r


def test_reference_search_recall(ds, graph):
    ids, _ = search_ref.search_batch(ds.X, graph, ds.Q[:24], k=10, ef=64)
    r = recall_at_k(ids, ds.gt[:24], 10)
    assert r > 0.6, r


def test_search_results_sorted_and_unique(ds, graph):
    ids, dists = large_batch_search(jnp.asarray(ds.X), graph,
                                    jnp.asarray(ds.Q), k=10, ef=64, hops=96)
    ids, dists = np.asarray(ids), np.asarray(dists)
    for r in range(ids.shape[0]):
        valid = (ids[r] >= 0) & (ids[r] < ds.X.shape[0]) \
            & np.isfinite(dists[r]) & (dists[r] < 1e37)
        assert (np.diff(dists[r][valid]) >= -1e-5).all()
        vals = ids[r][valid]
        assert len(set(vals.tolist())) == len(vals)


def test_lambda_limit_tradeoff(ds, graph):
    """Visiting more edges (higher λ limit) must not hurt recall."""
    X, Q = jnp.asarray(ds.X), jnp.asarray(ds.Q)
    r = {}
    for lim in (2, 10):
        ids, _ = small_batch_search(X, graph, Q, k=10, t0=16, hops=6,
                                    lambda_limit=lim, seed=3)
        r[lim] = recall_at_k(np.asarray(ids), ds.gt, 10)
    assert r[10] >= r[2] - 0.02, r


def test_exact_merge_at_least_as_good(ds, graph):
    X, Q = jnp.asarray(ds.X), jnp.asarray(ds.Q)
    r = {}
    for em in (False, True):
        ids, _ = small_batch_search(X, graph, Q, k=10, t0=8, hops=6,
                                    exact_merge=em, seed=5)
        r[em] = recall_at_k(np.asarray(ids), ds.gt, 10)
    assert r[True] >= r[False] - 0.02, r


def test_exact_visited_recall_parity(ds, graph):
    """Beyond-paper bitset-V: same recall as the paper's lossy circular V."""
    X, Q = jnp.asarray(ds.X), jnp.asarray(ds.Q)
    r = {}
    for ev in (False, True):
        ids, _ = large_batch_search(X, graph, Q, k=10, ef=64, hops=96,
                                    exact_visited=ev)
        r[ev] = recall_at_k(np.asarray(ids), ds.gt, 10)
    assert r[True] >= r[False] - 0.03, r


def test_metrics_ip_cos():
    ds = make_clustered(n=2000, d=16, n_queries=24, n_clusters=16,
                        noise=0.6, metric="cos", seed=1)
    cfg = dataclasses.replace(get_arch("tsdg-paper"), k_graph=12,
                              max_degree=16, lambda0=8, metric="cos",
                              bridge_hubs=32, bridge_k=8)
    g = build_tsdg(jnp.asarray(ds.X), cfg)
    ids, _ = small_batch_search(jnp.asarray(ds.X), g, jnp.asarray(ds.Q),
                                k=10, t0=16, hops=6, metric="cos")
    assert recall_at_k(np.asarray(ids), ds.gt, 10) > 0.8
