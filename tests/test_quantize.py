"""Compressed residency (DESIGN.md §8): per-row int8 quantization,
in-kernel scoring parity, exact fp32 re-rank, streaming, artifact v4.

Structure:
  * property tests on the row quantizer (error bound, scale edge cases);
  * backend parity — the quantized scoring primitives and both end-to-end
    regimes must be BITWISE identical between the pallas and xla backends
    (the same dequantize-then-score formulation funnels both);
  * recall — int8 + exact re-rank stays within 0.01 of fp32 recall@10;
  * streaming parity with quantization on (add / delete / compact);
  * artifact format v4 round-trip + doctored v3 backward-load;
  * the optim.compression deprecation shim.
"""
import dataclasses
import json
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.ann import Index
from repro.ann.quantize import (dequantize, dequantize_rows, quantize,
                                quantize_rows)
from repro.configs import get_arch
from repro.ann.artifact import FORMAT_VERSION
from repro.configs.base import ANNConfig
from repro.core import hotpath
from repro.data.synthetic import make_clustered

INF = np.float32(3.4e38)


@pytest.fixture(scope="module")
def ds():
    return make_clustered(n=1200, d=16, n_queries=64, n_clusters=16,
                          noise=0.6, seed=7)


def _cfg(**kw):
    return dataclasses.replace(
        get_arch("tsdg-paper"), k_graph=12, max_degree=16, lambda0=8,
        bridge_hubs=32, bridge_k=8, large_ef=48, large_hops=64,
        serve_buckets=(8, 32), delta_min_cap=64, **kw)


# ----------------------------------------------------------------------
# row quantizer properties
# ----------------------------------------------------------------------

def test_quantize_rows_error_bound(rng):
    """Per-component reconstruction error of symmetric round-to-nearest
    is at most half a quantization step (= scale/2) on every row."""
    for _ in range(20):
        X = rng.normal(scale=rng.uniform(1e-3, 1e3),
                       size=(64, 24)).astype(np.float32)
        codes, scales = quantize_rows(X)
        assert codes.dtype == jnp.int8 and scales.dtype == jnp.float32
        deq = np.asarray(dequantize_rows(codes, scales))
        err = np.abs(deq - X)
        bound = np.asarray(scales)[:, None] / 2 * (1 + 1e-6)
        assert (err <= bound).all()


def test_quantize_rows_zero_row():
    """An all-zero row must round-trip exactly (scale falls back to 1.0
    rather than dividing by zero)."""
    X = np.zeros((3, 8), np.float32)
    X[1] = 1.0
    codes, scales = quantize_rows(X)
    assert float(scales[0]) == 1.0 and float(scales[2]) == 1.0
    np.testing.assert_array_equal(np.asarray(codes[0]), 0)
    deq = np.asarray(dequantize_rows(codes, scales))
    np.testing.assert_array_equal(deq[0], 0.0)
    np.testing.assert_array_equal(deq[2], 0.0)


def test_quantize_rows_max_magnitude():
    """The max-|x| component of every row lands exactly on code ±127, and
    codes never overflow int8 — including float32-max magnitude rows."""
    X = np.array([[np.finfo(np.float32).max, -1.0, 0.5],
                  [-np.finfo(np.float32).max, 2.0, 0.0],
                  [3.0, -3.0, 3.0]], np.float32)
    codes, scales = quantize_rows(X)
    c = np.asarray(codes)
    assert c.min() >= -127 and c.max() <= 127
    assert c[0, 0] == 127 and c[1, 0] == -127
    assert c[2, 0] == 127 and c[2, 1] == -127


def test_per_tensor_quantize_roundtrip(rng):
    x = rng.normal(size=(5, 7)).astype(np.float32)
    q, scale = quantize(jnp.asarray(x))
    deq = np.asarray(dequantize(q, scale))
    assert np.abs(deq - x).max() <= float(scale) / 2 * (1 + 1e-6)


def test_compression_shim_delegates_with_warning():
    """repro.optim.compression re-exports the lifted helpers behind a
    warn-once deprecation shim pointing at repro.ann.quantize."""
    import repro.optim.compression as C
    x = jnp.arange(6, dtype=jnp.float32).reshape(2, 3)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        q, scale = C.quantize(x)
        deq = C.dequantize(q, scale)
    qq, ss = quantize(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qq))
    assert float(scale) == float(ss)
    np.testing.assert_array_equal(np.asarray(deq),
                                  np.asarray(dequantize(qq, ss)))


# ----------------------------------------------------------------------
# config knobs
# ----------------------------------------------------------------------

def test_config_quantization_validation():
    assert ANNConfig().quantization == "none"
    assert ANNConfig(quantization="int8").rerank_mult >= 1
    with pytest.raises(ValueError, match="quantization"):
        ANNConfig(quantization="fp8")
    with pytest.raises(ValueError, match="rerank_mult"):
        ANNConfig(rerank_mult=0)


# ----------------------------------------------------------------------
# kernel-level backend parity (pallas interpret vs xla, bitwise)
# ----------------------------------------------------------------------

def test_neighbor_distances_int8_backend_parity(rng):
    S, Kq, C, d, N = 6, 4, 24, 16, 300
    X = rng.normal(size=(N, d)).astype(np.float32)
    codes, scales = quantize_rows(X)
    Q3 = jnp.asarray(rng.normal(size=(S, Kq, d)).astype(np.float32))
    idx = jnp.asarray(rng.integers(-2, N + 4, size=(S, C)).astype(np.int32))
    a = hotpath.neighbor_distances(Q3, codes, idx, metric="l2",
                                   backend="xla", scales=scales)
    for fused in ("off", "on"):
        b = hotpath.neighbor_distances(Q3, codes, idx, metric="l2",
                                       backend="pallas", gather_fused=fused,
                                       scales=scales)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"gather_fused={fused}")
    # and the scored values are the dequantized oracle, not the raw codes.
    # Not bitwise: the quantized path routes norms through dot_general
    # (cross-program-stable) while the fp32 path uses multiply-then-sum,
    # so the two formulations legitimately differ by ~1 ulp.
    deq = dequantize_rows(codes, scales)
    c = hotpath.neighbor_distances(Q3, deq, idx, metric="l2", backend="xla")
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-6)


def test_scan_distances_int8_backend_parity(rng):
    B, n, d = 5, 40, 16
    X = rng.normal(size=(n, d)).astype(np.float32)
    codes, scales = quantize_rows(X)
    Q = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32))
    mask = jnp.asarray(rng.integers(0, 2, size=(n,)).astype(bool))
    a = hotpath.scan_distances(Q, codes, metric="l2", mask=mask,
                               backend="xla", scales=scales)
    b = hotpath.scan_distances(Q, codes, metric="l2", mask=mask,
                               backend="pallas", scales=scales)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert (np.asarray(a)[:, ~np.asarray(mask)] == INF).all()


# ----------------------------------------------------------------------
# end-to-end: both regimes, both backends, recall gate
# ----------------------------------------------------------------------

def _recall(ids, gt_k):
    return np.mean([len(set(a) & set(b)) / len(b)
                    for a, b in zip(ids, gt_k)])


def test_e2e_int8_backend_parity_and_recall(ds):
    """The quantized serving path is bitwise identical across backends in
    BOTH regimes, and int8 + exact re-rank holds recall@10 within 0.01 of
    the fp32 baseline (the ISSUE's acceptance gate, CI-enforced via
    benchmarks/run.py quantization_recall)."""
    k = 10
    gt = ds.gt[:, :k]
    out = {}
    for backend in ("xla", "pallas"):
        ix = Index.build(ds.X, _cfg(kernel_backend=backend,
                                    quantization="int8"), k=k)
        small = ix.search(ds.Q[:8])
        large = ix.search(np.repeat(ds.Q, 4, axis=0))
        assert ix.regime(8) == "small" and ix.regime(len(ds.Q) * 4) == "large"
        out[backend] = (small, large)
    for a, b in zip(out["xla"], out["pallas"]):
        np.testing.assert_array_equal(a[0], b[0])
        np.testing.assert_array_equal(a[1], b[1])

    fp32 = Index.build(ds.X, _cfg(kernel_backend="xla"), k=k)
    r_fp = _recall(fp32.search(np.repeat(ds.Q, 4, axis=0))[0][::4], gt)
    r_q = _recall(out["xla"][1][0][::4], gt)
    assert r_q >= r_fp - 0.01, (r_q, r_fp)


def test_e2e_rerank_returns_exact_distances(ds):
    """Returned distances on the quantized path are exact fp32 distances
    of the returned ids (the re-rank re-scores survivors against the fp32
    rows), not the approximate int8 scores."""
    ix = Index.build(ds.X, _cfg(kernel_backend="xla",
                                quantization="int8"), k=5)
    ids, dists = ix.search(ds.Q[:8])
    X64 = ds.X.astype(np.float64)
    for r in range(8):
        for c in range(5):
            if ids[r, c] < 0:
                continue
            exact = np.float32(
                ((ds.Q[r].astype(np.float64) - X64[ids[r, c]]) ** 2).sum())
            assert abs(dists[r, c] - exact) <= 1e-3 * max(1.0, exact)


# ----------------------------------------------------------------------
# streaming parity with quantization on
# ----------------------------------------------------------------------

def test_streaming_quantized_add_delete_compact(ds):
    """Mutations behave identically under quantization: added rows are
    findable (delta codes are quantized on push), deleted rows never
    surface, and compaction re-quantizes the new generation (post-compact
    search is bitwise a cold quantized build over the same corpus)."""
    cfg = _cfg(kernel_backend="xla", quantization="int8")
    ix = Index.build(ds.X, cfg, k=5)
    ids0, _ = ix.search(ds.Q[:8])

    new = ix.add(ds.Q[:3])                      # exact query copies
    i1, d1 = ix.search(ds.Q[:8])
    for r in range(3):
        assert new[r] in i1[r], "added exact copy must be found"
        assert d1[r, list(i1[r]).index(new[r])] <= 1e-4
    ix.delete([int(new[0]), int(ids0[4, 0])])
    i2, _ = ix.search(ds.Q[:8])
    assert int(new[0]) not in i2.ravel()
    assert int(ids0[4, 0]) not in i2[4]

    ix.compact()
    i3, d3 = ix.search(ds.Q[:8])
    # cold build over the compacted corpus must answer bitwise identically
    cold = Index.build(np.asarray(ix.X), cfg, k=5)
    i4, d4 = cold.search(ds.Q[:8])
    np.testing.assert_array_equal(i3, i4)
    np.testing.assert_array_equal(d3, d4)
    # and the plane's resident codes are the fresh generation's
    np.testing.assert_array_equal(
        np.asarray(ix.plane.codes), np.asarray(quantize_rows(ix.X)[0]))


# ----------------------------------------------------------------------
# artifact format v4 (+ doctored v3 backward-load)
# ----------------------------------------------------------------------

def test_artifact_v4_roundtrip_quantized(ds, tmp_path):
    """A quantized index persists codes+scales (format v4) and load
    re-binds them without re-quantizing — bitwise answers, zero compiles,
    and the loaded plane's codes are byte-equal to the saved ones."""
    cfg = _cfg(kernel_backend="xla", quantization="int8")
    ix = Index.build(ds.X, cfg, k=5)
    a, da = ix.search(ds.Q[:8])

    p = tmp_path / "art"
    ix.save(p)
    manifest = json.loads((p / "manifest.json").read_text())
    assert manifest["format_version"] == FORMAT_VERSION  # v4 fields persist
    assert manifest["fingerprint"]["quantization"] == "int8"
    with np.load(p / "arrays.npz") as arrs:
        assert arrs["codes"].dtype == np.int8
        assert arrs["codes"].shape == ds.X.shape
        assert arrs["scales"].shape == (ds.X.shape[0],)
        saved_codes = arrs["codes"].copy()

    loaded = Index.load(p)
    assert loaded.stats.compiles == 0
    np.testing.assert_array_equal(np.asarray(loaded.plane.codes),
                                  saved_codes)
    b, db = loaded.search(ds.Q[:8])
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(da, db)
    assert loaded.stats.compiles == 0, "primed executables must serve"


def test_artifact_v4_unquantized_has_no_codes(ds, tmp_path):
    """quantization="none" artifacts carry no quantization payload — the
    arrays are byte-compatible with what v3 wrote."""
    ix = Index.build(ds.X, _cfg(kernel_backend="xla"), k=5)
    p = tmp_path / "art"
    ix.save(p, aot=False)
    with np.load(p / "arrays.npz") as arrs:
        assert "codes" not in arrs.files and "scales" not in arrs.files


def test_artifact_v3_doctored_backward_load(ds, tmp_path):
    """A pre-quantization artifact (doctored to format v3: no codes in the
    arrays, no quantization fingerprint field) still loads; with a
    quantized config the plane derives the codes at install and answers
    match the v4 path bitwise."""
    cfg = _cfg(kernel_backend="xla", quantization="int8")
    ix = Index.build(ds.X, cfg, k=5)
    a, da = ix.search(ds.Q[:8])
    p = tmp_path / "art"
    ix.save(p, aot=False)

    # strip the v4 payload back to the v3 layout
    with np.load(p / "arrays.npz") as arrs:
        v3 = {k: arrs[k] for k in arrs.files if k not in ("codes", "scales")}
    np.savez(p / "arrays.npz", **v3)
    manifest = json.loads((p / "manifest.json").read_text())
    manifest["format_version"] = 3
    manifest["fingerprint"].pop("quantization")
    import hashlib
    manifest["arrays"]["sha256"] = hashlib.sha256(
        (p / "arrays.npz").read_bytes()).hexdigest()
    (p / "manifest.json").write_text(json.dumps(manifest))

    loaded = Index.load(p)
    b, db = loaded.search(ds.Q[:8])
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(da, db)
