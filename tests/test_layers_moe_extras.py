"""Equivalence tests for the §Perf optimizations: the optimized paths must
compute the same math as their baselines."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.models import moe as moe_lib
from repro.models.layers import chunked_attention, windowed_chunked_attention


@pytest.mark.parametrize("window", [32, 100, 512])
def test_windowed_chunk_skipping_exact(rng, window):
    """Static-window chunk skipping == mask-only chunking (§Perf cell 4)."""
    q = jnp.asarray(rng.normal(size=(2, 300, 4, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 300, 2, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 300, 2, 16)).astype(np.float32))
    a = windowed_chunked_attention(q, k, v, window=window, chunk_q=64,
                                   chunk_kv=64)
    b = chunked_attention(q, k, v, window=window, chunk_q=64, chunk_kv=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)


def test_windowed_attention_with_offset(rng):
    q = jnp.asarray(rng.normal(size=(1, 128, 2, 8)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 8)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 8)).astype(np.float32))
    a = windowed_chunked_attention(q, k, v, window=64, q_offset=128,
                                   chunk_q=32, chunk_kv=32)
    b = chunked_attention(q, k, v, window=64, q_offset=128, chunk_q=32,
                          chunk_kv=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)


def _moe_params(key, d, E, f):
    ks = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d)
    return {"router": jax.random.normal(ks[0], (d, E)) * s,
            "w_gate": jax.random.normal(ks[1], (E, d, f)) * s,
            "w_up": jax.random.normal(ks[2], (E, d, f)) * s,
            "w_down": jax.random.normal(ks[3], (E, f, d)) / np.sqrt(f)}


@pytest.mark.parametrize("groups", [2, 4, 8])
def test_grouped_dispatch_bit_exact(groups):
    """Group-local dispatch == global dispatch at ample capacity (§Perf
    cell 2) — the 24x collective win costs zero accuracy."""
    params = _moe_params(jax.random.key(0), 16, 8, 32)
    x = jax.random.normal(jax.random.key(1), (64, 16))
    base = MoEConfig(n_experts=8, top_k=2, d_expert=32, capacity_factor=8.0,
                     dispatch_groups=1)
    y1, a1 = moe_lib.moe_ffn(x, params, base)
    yg, ag = moe_lib.moe_ffn(
        x, params, dataclasses.replace(base, dispatch_groups=groups))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(yg), atol=1e-5)
    assert float(ag["dropped_fraction"]) == 0.0


def test_grouped_dispatch_falls_back_on_indivisible():
    params = _moe_params(jax.random.key(0), 8, 4, 8)
    x = jax.random.normal(jax.random.key(1), (30, 8))  # 30 % 4 != 0
    cfg = MoEConfig(n_experts=4, top_k=2, d_expert=8, capacity_factor=8.0,
                    dispatch_groups=4)
    y, aux = moe_lib.moe_ffn(x, params, cfg)  # must not raise
    assert jnp.all(jnp.isfinite(y))


def test_large_seed_count_improves_recall():
    """The wide-seeding beyond-paper default (EXPERIMENTS §Perf)."""
    import dataclasses as dc

    from repro.configs import get_arch
    from repro.core.diversify import build_tsdg
    from repro.core.knn_build import exact_knn
    from repro.core.search_large import large_batch_search
    from repro.data.synthetic import make_clustered, recall_at_k

    ds = make_clustered(n=6000, d=24, n_queries=48, n_clusters=48,
                        noise=0.5, seed=2)
    X = jnp.asarray(ds.X)
    ids_e, d_e = exact_knn(X, 16)
    cfg = dc.replace(get_arch("tsdg-paper"), k_graph=16, max_degree=24,
                     lambda0=8)
    g = build_tsdg(X, cfg, knn_ids=ids_e, knn_dists=d_e)
    r = {}
    for ns in (32, 128):
        out, _ = large_batch_search(X, g, jnp.asarray(ds.Q), k=10, ef=64,
                                    hops=96, n_seeds=ns)
        r[ns] = recall_at_k(np.asarray(out), ds.gt, 10)
    assert r[128] >= r[32], r
