"""Trainer + checkpoint/restart + fault-tolerance substrate."""
import os
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.data.lm import LMStream
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.models.module import init_params
from repro.optim.api import OptimizerConfig, make_optimizer
from repro.train import checkpoint as ckpt
from repro.train.trainer import TrainConfig, Trainer, make_train_step


@pytest.fixture
def tmp_ckpt(tmp_path):
    return str(tmp_path / "ckpt")


def _trainer(tmp_ckpt, steps=12, micro=1):
    cfg = get_reduced("olmo-1b")
    return cfg, Trainer(
        schema=T.schema(cfg),
        loss_fn=lambda p, b: T.loss_fn(p, cfg, b),
        mesh=make_host_mesh(),
        opt_cfg=OptimizerConfig(lr=1e-3, warmup_steps=3, total_steps=steps),
        train_cfg=TrainConfig(steps=steps, log_every=4, ckpt_every=6,
                              ckpt_dir=tmp_ckpt, ckpt_async=False,
                              microbatches=micro))


def test_loss_decreases(tmp_ckpt):
    cfg, tr = _trainer(tmp_ckpt, steps=16)
    data = iter(LMStream(cfg.vocab, 32, 8, seed=0))
    _, hist = tr.run(data)
    assert hist[-1][1]["loss"] < hist[0][1]["loss"]


def test_resume_from_checkpoint(tmp_ckpt):
    cfg, tr = _trainer(tmp_ckpt, steps=12)
    data = iter(LMStream(cfg.vocab, 32, 8, seed=0))
    tr.run(data)
    assert ckpt.latest_step(tmp_ckpt) == 12
    # simulated restart
    cfg2, tr2 = _trainer(tmp_ckpt, steps=4)
    state2, hist2 = tr2.run(iter(LMStream(cfg.vocab, 32, 8, seed=1)),
                            resume=True)
    assert len(hist2) > 0


def test_grad_accumulation_equivalence():
    """microbatches=2 over the same tokens == one full batch step."""
    cfg = get_reduced("olmo-1b")
    import dataclasses

    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    schema = T.schema(cfg)
    params = init_params(schema, jax.random.key(0))
    opt = make_optimizer(OptimizerConfig(lr=1e-3, schedule="constant"))
    st = opt.init(params)
    toks = np.asarray(
        jax.random.randint(jax.random.key(1), (8, 33), 0, cfg.vocab))

    loss_fn = lambda p, b: T.loss_fn(p, cfg, b)
    step1 = jax.jit(make_train_step(loss_fn, opt, microbatches=1))
    step2 = jax.jit(make_train_step(loss_fn, opt, microbatches=2))
    p1, _, m1 = step1(params, st, {"tokens": jnp.asarray(toks)})
    p2, _, m2 = step2(params, st,
                      {"tokens": jnp.asarray(toks.reshape(2, 4, 33))})
    np.testing.assert_allclose(float(m1["nll"]), float(m2["nll"]), rtol=1e-4)
    flat1 = jax.tree.leaves(p1)
    flat2 = jax.tree.leaves(p2)
    for a, b in zip(flat1, flat2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_checkpoint_roundtrip(tmp_path):
    state = {
        "params": {"w": jnp.arange(12.0).reshape(3, 4),
                   "b": jnp.ones((4,), jnp.bfloat16)},
        "opt": {"count": jnp.int32(7)},
    }
    d = str(tmp_path / "rt")
    ckpt.save(state, 5, d)
    restored, step = ckpt.restore(d, state)
    assert step == 5
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a.astype(jnp.float32)),
                                      np.asarray(b.astype(jnp.float32)))


def test_checkpoint_async_and_latest(tmp_path):
    d = str(tmp_path / "as")
    state = {"x": jnp.ones((8,))}
    t = ckpt.save(state, 1, d, async_save=True)
    t.join()
    ckpt.save(state, 2, d)
    assert ckpt.latest_step(d) == 2
    _, step = ckpt.restore(d, state)
    assert step == 2


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "mm")
    ckpt.save({"x": jnp.ones((4,))}, 1, d)
    with pytest.raises(ValueError):
        ckpt.restore(d, {"x": jnp.ones((5,))})


def test_atomic_publish_no_partial(tmp_path):
    """A tmp-dir from a dead save must not be visible as a checkpoint."""
    d = str(tmp_path / "at")
    os.makedirs(os.path.join(d, "tmp-99"))
    ckpt.save({"x": jnp.ones((2,))}, 1, d)
    assert ckpt.latest_step(d) == 1
