"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = the figure's quality
axis: recall / avg-degree / dominant roofline term).  Sizes are scaled to
CPU (the TPU target numbers come from the dry-run roofline artifacts, which
`roofline_table` re-emits at the end).

  PYTHONPATH=src python -m benchmarks.run            # full
  REPRO_BENCH_QUICK=1 PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

QUICK = bool(os.environ.get("REPRO_BENCH_QUICK"))

ROWS: list = []


def emit(name: str, us: float, derived: str):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}", flush=True)


def _timeit(fn, *args, repeat: int = 3):
    fn(*args)  # compile / warm
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeat * 1e6, out


def _dataset(n=None, d=32, nq=128):
    from repro.data.synthetic import make_clustered

    n = n or (4000 if QUICK else 20000)
    return make_clustered(n=n, d=d, n_queries=nq, n_clusters=64, noise=0.6,
                          seed=0)


def _cfg(**kw):
    from repro.configs import get_arch

    base = dict(k_graph=24, max_degree=32, lambda0=8, bridge_hubs=128,
                bridge_k=8)
    base.update(kw)
    return dataclasses.replace(get_arch("tsdg-paper"), **base)


# ==========================================================================
# Table 2: graph diversification time
# ==========================================================================

def table2_diversification_time():
    from repro.ann.pipeline import build_graph
    from repro.core.diversify import (append_reverse, build_gd_baseline,
                                      relaxed_gd, soft_gd)
    from repro.core.knn_build import exact_knn

    ds = _dataset()
    X = jnp.asarray(ds.X)
    ids, dists = exact_knn(X, 24)
    jax.block_until_ready(ids)

    cfg = _cfg()

    def tsdg():
        g = build_graph(X, cfg, knn_ids=ids, knn_dists=dists)
        jax.block_until_ready(g.neighbors)
        return g

    def gd():
        g = build_gd_baseline(X, cfg, knn_ids=ids, knn_dists=dists)
        jax.block_until_ready(g.neighbors)
        return g

    def soft_only():  # DPG-like: stage 2 applied directly to the k-NN graph
        adj_i, adj_d = append_reverse(X, ids, dists,
                                      jnp.ones(ids.shape, bool),
                                      rev_cap=24, metric="l2")
        out = soft_gd(X, adj_i, adj_d, lambda0=cfg.lambda0,
                      max_degree=cfg.max_degree, metric="l2")
        jax.block_until_ready(out[0])
        return out

    us, g = _timeit(tsdg)
    emit("table2/tsdg_build", us, f"avg_degree={g.avg_degree():.1f}")
    us, g2 = _timeit(gd)
    emit("table2/gd_build", us, f"avg_degree={g2.avg_degree():.1f}")
    us, _ = _timeit(soft_only)
    emit("table2/softonly_build_dpg_like", us, "stage2_only")


# ==========================================================================
# Fig 4: CPU search (reference best-first) recall vs throughput
# ==========================================================================

def fig4_cpu_search():
    from repro.core import search_ref
    from repro.ann.pipeline import build_graph
    from repro.core.diversify import build_gd_baseline
    from repro.core.knn_build import exact_knn
    from repro.data.synthetic import recall_at_k

    ds = _dataset(n=3000 if QUICK else 8000, nq=32)
    X = jnp.asarray(ds.X)
    ids, dists = exact_knn(X, 24)
    cfg = _cfg()
    graphs = {
        "tsdg": build_graph(X, cfg, knn_ids=ids, knn_dists=dists),
        "gd": build_gd_baseline(X, cfg, knn_ids=ids, knn_dists=dists),
    }
    for name, g in graphs.items():
        for ef in ((32,) if QUICK else (32, 64, 128)):
            t0 = time.perf_counter()
            out, _ = search_ref.search_batch(ds.X, g, ds.Q, k=10, ef=ef)
            dt = time.perf_counter() - t0
            r = recall_at_k(out, ds.gt, 10)
            emit(f"fig4/cpu_{name}_ef{ef}", dt / len(ds.Q) * 1e6,
                 f"recall@10={r:.3f}")


# ==========================================================================
# Fig 5: degree / λ-limit sweep (one graph, many operating points)
# ==========================================================================

def fig5_degree_sweep():
    from repro.ann.pipeline import build_graph
    from repro.core.knn_build import exact_knn
    from repro.core.search_small import \
        _small_batch_search as small_batch_search
    from repro.data.synthetic import recall_at_k

    ds = _dataset(nq=64)
    X = jnp.asarray(ds.X)
    ids, dists = exact_knn(X, 24)
    g = build_graph(X, _cfg(), knn_ids=ids, knn_dists=dists)
    Q = jnp.asarray(ds.Q)
    for lam_limit in (2, 5, 10):
        fn = lambda: small_batch_search(X, g, Q, k=10, t0=16, hops=6,
                                        lambda_limit=lam_limit)[0]
        us, out = _timeit(fn)
        r = recall_at_k(np.asarray(out), ds.gt, 10)
        emit(f"fig5/lambda_limit_{lam_limit}", us / len(ds.Q),
             f"recall@10={r:.3f}")


# ==========================================================================
# Figs 6-9: small-batch search on accelerator (batch 1 / 10 / 100)
# ==========================================================================

def fig6_small_batch():
    from repro.ann.pipeline import build_graph
    from repro.core.knn_build import exact_knn
    from repro.core.search_small import \
        _small_batch_search as small_batch_search
    from repro.data.synthetic import recall_at_k

    ds = _dataset(nq=100)
    X = jnp.asarray(ds.X)
    ids, dists = exact_knn(X, 24)
    g = build_graph(X, _cfg(), knn_ids=ids, knn_dists=dists)
    for B in ((1, 10) if QUICK else (1, 10, 100)):
        Q = jnp.asarray(ds.Q[:B])
        gt = ds.gt[:B]
        for k in (10, 100):
            fn = lambda: small_batch_search(X, g, Q, k=k, t0=32, hops=6)[0]
            us, out = _timeit(fn)
            r = recall_at_k(np.asarray(out), ds.gt[:B], k)
            emit(f"fig6-9/small_bs{B}_k{k}", us / B, f"recall@{k}={r:.3f}")


# ==========================================================================
# Figs 10-11: large-batch search (scaled 10k regime)
# ==========================================================================

def fig10_large_batch():
    from repro.ann.pipeline import build_graph
    from repro.core.knn_build import exact_knn
    from repro.core.search_large import \
        _large_batch_search as large_batch_search
    from repro.data.synthetic import make_clustered, recall_at_k

    ds = make_clustered(n=4000 if QUICK else 20000, d=32,
                        n_queries=256 if QUICK else 1024, n_clusters=64,
                        noise=0.6, seed=0)
    X = jnp.asarray(ds.X)
    ids, dists = exact_knn(X, 24)
    g = build_graph(X, _cfg(), knn_ids=ids, knn_dists=dists)
    Q = jnp.asarray(ds.Q)
    for k, ef, ns in ((10, 64, 32), (10, 64, 128), (100, 128, 128)):
        fn = lambda: large_batch_search(X, g, Q, k=k, ef=ef, hops=128,
                                        lambda_limit=5, n_seeds=ns)[0]
        us, out = _timeit(fn, repeat=2)
        r = recall_at_k(np.asarray(out), ds.gt, k)
        emit(f"fig10-11/large_bs{Q.shape[0]}_k{k}_seeds{ns}",
             us / Q.shape[0], f"recall@{k}={r:.3f}")


# ==========================================================================
# ablations: the paper's two diversification knobs (α, λ0)
# ==========================================================================

def ablation_alpha_lambda():
    from repro.ann.pipeline import build_graph
    from repro.core.knn_build import exact_knn
    from repro.core.search_large import \
        _large_batch_search as large_batch_search
    from repro.data.synthetic import recall_at_k

    ds = _dataset(n=3000 if QUICK else 8000, nq=64)
    X = jnp.asarray(ds.X)
    ids, dists = exact_knn(X, 24)
    Q = jnp.asarray(ds.Q)
    for alpha in ((1.0, 1.2) if QUICK else (1.0, 1.1, 1.2, 1.4)):
        cfg = _cfg(alpha=alpha)
        g = build_graph(X, cfg, knn_ids=ids, knn_dists=dists)
        out, _ = large_batch_search(X, g, Q, k=10, ef=64, hops=96)
        r = recall_at_k(np.asarray(out), ds.gt, 10)
        emit(f"ablation/alpha_{alpha}", 0.0,
             f"avg_degree={g.avg_degree():.1f};recall@10={r:.3f}")
    for lam0 in ((2, 8) if QUICK else (0, 2, 8, 16)):
        cfg = _cfg(lambda0=lam0)
        g = build_graph(X, cfg, knn_ids=ids, knn_dists=dists)
        out, _ = large_batch_search(X, g, Q, k=10, ef=64, hops=96)
        r = recall_at_k(np.asarray(out), ds.gt, 10)
        emit(f"ablation/lambda0_{lam0}", 0.0,
             f"avg_degree={g.avg_degree():.1f};recall@10={r:.3f}")


# ==========================================================================
# serving engine: regime dispatch end-to-end
# ==========================================================================

def serve_engine_mixed():
    from repro.ann import Index
    from repro.data.synthetic import recall_at_k

    ds = _dataset(nq=128)
    eng = Index.build(ds.X, _cfg(), k=10)
    rng = np.random.default_rng(0)
    hits, total = 0.0, 0
    t0 = time.perf_counter()
    for _ in range(4 if QUICK else 12):
        B = int(rng.choice([1, 4, 16, 128]))
        sel = rng.integers(0, len(ds.Q), B)
        ids, _ = eng.search(ds.Q[sel])
        hits += recall_at_k(ids, ds.gt[sel], 10) * B
        total += B
    dt = time.perf_counter() - t0
    emit("serve/mixed_batches", dt / total * 1e6,
         f"recall@10={hits / total:.3f};small={eng.stats.small_batches};"
         f"large={eng.stats.large_batches}")


def serve_bucketed_vs_raw():
    """Mixed-batch-size stream: shape-bucketed engine (compiles once per
    (regime, bucket), steady state never re-traces) vs calling the search
    kernels directly on raw shapes (every distinct B re-traces/compiles)."""
    from repro.core.search_large import \
        _large_batch_search as large_batch_search
    from repro.core.search_small import \
        _small_batch_search as small_batch_search
    from repro.ann import Index

    ds = _dataset(nq=600)
    cfg = _cfg(serve_buckets=(8, 32, 128, 512),
               large_hops=32 if QUICK else 64)
    eng = Index.build(ds.X, cfg, k=10)
    X, graph = eng.X, eng.graph
    rng = np.random.default_rng(0)
    # bursty traffic over many *distinct* batch sizes — the serving reality
    # the bucket ladder exists for
    sizes = [1, 7, 33, 100, 513] if not QUICK else [1, 7, 33]
    stream = []
    for rep in range(3 if QUICK else 6):
        for B in sizes:
            B_jit = min(max(1, B + int(rng.integers(-3, 4))), len(ds.Q))
            stream.append(rng.integers(0, len(ds.Q), B_jit))

    def raw_call(Q):
        Q = jnp.asarray(Q)
        if eng.regime(Q.shape[0]) == "small":
            out = small_batch_search(
                X, graph, Q, k=10, t0=cfg.small_t0, hops=cfg.small_hops,
                hop_width=cfg.hop_width, n_seeds=cfg.n_seeds,
                lambda_limit=10, metric=cfg.metric)
        else:
            out = large_batch_search(
                X, graph, Q, k=10, ef=cfg.large_ef, hops=cfg.large_hops,
                lambda_limit=5, metric=cfg.metric, n_seeds=cfg.large_n_seeds,
                m_seg=cfg.queue_segments, seg=cfg.segment_size,
                mv_seg=cfg.visited_segments, delta=cfg.delta)
        jax.block_until_ready(out[0])
        return out

    # raw path: each distinct (regime, B) pays its own trace+compile
    t0 = time.perf_counter()
    n_raw = 0
    for sel in stream:
        raw_call(ds.Q[sel])
        n_raw += len(sel)
    raw_us = (time.perf_counter() - t0) / n_raw * 1e6
    emit("serve/raw_shapes_stream", raw_us,
         f"distinct_shapes={len({len(s) for s in stream})}")

    # bucketed engine: same stream; steady-state excludes the few warmups
    for sel in stream:
        eng.search(ds.Q[sel])
    st = eng.stats
    eng_us = 1e6 / max(st.qps, 1e-9)
    emit("serve/bucketed_engine_steady", eng_us,
         f"compiles={st.compiles};hit_rate={st.bucket_hit_rate:.2f};"
         f"speedup_vs_raw={raw_us / max(eng_us, 1e-9):.1f}x")


def serve_aot_reload():
    """Cold start vs artifact restart: warmup compile sweep from scratch
    against Index.load priming the persisted AOT executables (zero
    compiles).  The row value is the restart's time-to-first-steady-query."""
    import shutil
    import tempfile

    from repro.ann import Index

    ds = _dataset(n=2000 if QUICK else 6000, nq=32)
    cfg = _cfg(serve_buckets=(8, 32), large_hops=16 if QUICK else 32)
    index = Index.build(ds.X, cfg, k=10)
    t0 = time.perf_counter()
    n_cold = index.warmup()
    cold_s = time.perf_counter() - t0
    emit("serve/cold_warmup_sweep", cold_s * 1e6, f"compiles={n_cold}")

    td = tempfile.mkdtemp(prefix="repro_aot_bench_")
    try:
        index.save(td)
        t0 = time.perf_counter()
        loaded = Index.load(td)
        loaded.search(ds.Q[:4])           # first real query, steady-state
        warm_s = time.perf_counter() - t0
        emit("serve/aot_reload_first_query", warm_s * 1e6,
             f"compiles={loaded.stats.compiles};"
             f"aot_primed={loaded.stats.aot_primed};"
             f"speedup_vs_cold={cold_s / max(warm_s, 1e-9):.1f}x")
    finally:
        shutil.rmtree(td, ignore_errors=True)


def streaming_ingest():
    """Streaming mutability (DESIGN.md §7) vs the frozen baseline.

    Four serving phases over the same corpus and query stream, each row
    reporting steady QPS + p50/p99 batch latency:

    * ``frozen``  — the untouched generation-0 index (baseline);
    * ``add_heavy``    — interleaved add / search (delta brute-force fused
      into every answer);
    * ``delete_heavy`` — interleaved delete / search (tombstone mask
      threaded through the kernels);
    * ``compact_concurrent`` — searches racing a background compaction,
      timed across the generation hot-swap (the row's derived field shows
      compiles across the swap — 0 when shapes are preserved).
    """
    import threading

    from repro.ann import Index

    ds = _dataset(n=2000 if QUICK else 6000, nq=128)
    cfg = _cfg(serve_buckets=(8, 32), large_hops=16 if QUICK else 32,
               delta_min_cap=256)
    B, reps = 8, (6 if QUICK else 20)
    rng = np.random.default_rng(0)

    def _phase(index, mutate=None):
        lat = []
        index.search(ds.Q[:B])                       # warm / compile
        for r in range(reps):
            if mutate is not None:
                mutate(r)
            sel = rng.integers(0, len(ds.Q), B)
            t0 = time.perf_counter()
            index.search(ds.Q[sel])
            lat.append(time.perf_counter() - t0)
        lat = np.asarray(lat)
        qps = B / max(float(lat.mean()), 1e-9)
        return qps, float(np.percentile(lat, 50)) * 1e3, \
            float(np.percentile(lat, 99)) * 1e3

    index = Index.build(ds.X, cfg, k=10)
    qps, p50, p99 = _phase(index)
    emit("streaming/frozen_baseline", 1e6 / qps,
         f"qps={qps:.0f};p50_ms={p50:.2f};p99_ms={p99:.2f}")

    qps, p50, p99 = _phase(index, mutate=lambda r: index.add(
        ds.Q[rng.integers(0, len(ds.Q), 4)]))
    emit("streaming/add_heavy", 1e6 / qps,
         f"qps={qps:.0f};p50_ms={p50:.2f};p99_ms={p99:.2f};"
         f"n_added={index.stats.n_added}")

    added = index.stats.n_added
    victims = iter(range(ds.X.shape[0], ds.X.shape[0] + added))
    qps, p50, p99 = _phase(index, mutate=lambda r: index.delete(
        [next(victims), next(victims)]))
    emit("streaming/delete_heavy", 1e6 / qps,
         f"qps={qps:.0f};p50_ms={p50:.2f};p99_ms={p99:.2f};"
         f"n_deleted={index.stats.n_deleted}")

    compiles_before = index.stats.compiles
    bg = threading.Thread(target=index.compact, daemon=True)
    bg.start()
    qps, p50, p99 = _phase(index)
    bg.join(timeout=600)
    emit("streaming/compact_concurrent", 1e6 / qps,
         f"qps={qps:.0f};p50_ms={p50:.2f};p99_ms={p99:.2f};"
         f"generation={index.stats.generation};"
         f"swap_compiles={index.stats.compiles - compiles_before}")


# ==========================================================================
# mesh execution plane: single-device vs 2/4/8-shard host meshes
# ==========================================================================

def _steady_us(index, Q, B, repeat=3):
    """Per-query steady-state latency for batch B via the engine cache
    (first call may compile; timed calls are all bucket hits)."""
    index.search(Q[:B])                      # warm / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        index.search(Q[:B])
    return (time.perf_counter() - t0) / (repeat * B) * 1e6


def mesh_serve():
    """Both regimes served through the mesh plane at 2/4/8 DB shards vs the
    single-device plane — same engine machinery (buckets, AOT cache,
    stats), only the execution plane differs.  Requires a multi-device
    process (CI runs this tier under
    XLA_FLAGS=--xla_force_host_platform_device_count=8); with fewer
    devices the missing rows are emitted as skips, never silently
    dropped."""
    from repro.ann import Index
    from repro.data.synthetic import recall_at_k

    ds = _dataset(n=4096 if QUICK else 16384, d=32, nq=256)
    cfg = _cfg(serve_buckets=(8, 64, 256),
               large_hops=32 if QUICK else 64)
    B_small, B_large = 8, 256
    single = Index.build(ds.X, cfg, k=10)
    for regime, B in (("small", B_small), ("large", B_large)):
        us = _steady_us(single, ds.Q, B)
        r = recall_at_k(single.search(ds.Q[:B])[0], ds.gt[:B], 10)
        emit(f"mesh_serve/single_{regime}_B{B}", us,
             f"plane=single;recall@10={r:.3f}")
    for shards in (2, 4, 8):
        if jax.device_count() < shards:
            emit(f"mesh_serve/shards{shards}_SKIPPED", 0.0,
                 f"needs {shards} devices, have {jax.device_count()} "
                 "(set XLA_FLAGS=--xla_force_host_platform_device_count)")
            continue
        mesh = jax.make_mesh((shards,), ("data",))
        mi = Index.build(ds.X, cfg, k=10, mesh=mesh)
        for regime, B in (("small", B_small), ("large", B_large)):
            us = _steady_us(mi, ds.Q, B)
            r = recall_at_k(mi.search(ds.Q[:B])[0], ds.gt[:B], 10)
            emit(f"mesh_serve/shards{shards}_{regime}_B{B}", us,
                 f"plane=mesh;db_shards={shards};recall@10={r:.3f};"
                 f"compiles={mi.stats.compiles}")


def router_serve():
    """Concurrent serving throughput through the request router (DESIGN.md
    §9): a replicated router (N queues, shared plane + compile cache) vs
    one micro-batcher, and the sharded router's fan-out + host merge vs
    the in-collective mesh merge it mirrors."""
    from concurrent.futures import wait

    from repro.ann import Index
    from repro.serve.router import RouterConfig

    ds = _dataset(n=4096 if QUICK else 16384, d=32, nq=256)
    cfg = _cfg(serve_buckets=(8, 64), large_hops=32 if QUICK else 64)
    idx = Index.build(ds.X, cfg, k=10)
    idx.warmup()
    n_req = 64 if QUICK else 256

    def pump(front):
        futs = [front.submit(ds.Q[i % ds.Q.shape[0]]) for i in range(n_req)]
        wait(futs, timeout=600)
        return [f.result() for f in futs]

    with idx.serve(max_wait_ms=1.0) as mb:
        pump(mb)  # warm
        t0 = time.perf_counter()
        pump(mb)
        us = (time.perf_counter() - t0) / n_req * 1e6
    emit("router_serve/queue_1x", us, "front=microbatcher")

    for n in (2, 4):
        rc = RouterConfig(mode="replicated", replicas=n,
                          health_interval_s=0.0)
        with idx.serve(router=rc, max_wait_ms=1.0) as r:
            pump(r)  # warm
            t0 = time.perf_counter()
            pump(r)
            us = (time.perf_counter() - t0) / n_req * 1e6
            agg = r.snapshot()["aggregate"]
        emit(f"router_serve/replicated_{n}x", us,
             f"compiles={agg['compiles']};qps={agg['qps']:.0f}")

    rc = RouterConfig(mode="sharded", replicas=2, health_interval_s=0.0)
    with idx.serve(router=rc, max_wait_ms=1.0) as r:
        pump(r)  # warm
        t0 = time.perf_counter()
        pump(r)
        us = (time.perf_counter() - t0) / n_req * 1e6
    emit("router_serve/sharded_2x", us, "merge=host;shards=2")


def mesh_aot_reload():
    """Sharded cold start vs sharded artifact restart: the mesh plane's
    warmup compile sweep from scratch against Index.load(mesh=) priming
    the persisted shard-mapped executables.  The derived column asserts
    the acceptance criterion: compiles == 0 after a sharded load."""
    import shutil
    import tempfile

    from repro.ann import Index

    shards = 2
    if jax.device_count() < shards:
        emit("mesh_serve/aot_reload_SKIPPED", 0.0,
             f"needs {shards} devices, have {jax.device_count()}")
        return
    ds = _dataset(n=2048 if QUICK else 8192, d=32, nq=64)
    cfg = _cfg(serve_buckets=(8, 64), large_hops=16 if QUICK else 32)
    mesh = jax.make_mesh((shards, 1), ("data", "model"))
    index = Index.build(ds.X, cfg, k=10, mesh=mesh)
    t0 = time.perf_counter()
    n_cold = index.warmup()
    cold_s = time.perf_counter() - t0
    emit("mesh_serve/cold_warmup_sweep", cold_s * 1e6,
         f"compiles={n_cold};db_shards={shards}")
    td = tempfile.mkdtemp(prefix="repro_mesh_aot_bench_")
    try:
        index.save(td)
        t0 = time.perf_counter()
        loaded = Index.load(td, mesh=mesh)
        loaded.search(ds.Q[:4])          # first real query, steady-state
        warm_s = time.perf_counter() - t0
        assert loaded.stats.compiles == 0, loaded.stats.compiles
        emit("mesh_serve/aot_reload_first_query", warm_s * 1e6,
             f"compiles={loaded.stats.compiles};"
             f"aot_primed={loaded.stats.aot_primed};"
             f"speedup_vs_cold={cold_s / max(warm_s, 1e-9):.1f}x")
    finally:
        shutil.rmtree(td, ignore_errors=True)


# ==========================================================================
# compressed residency: int8 scoring vs fp32, recall gate (DESIGN.md §8)
# ==========================================================================

def quantization_recall():
    """fp32 vs int8-resident scoring through the serving engine, both
    regimes, with (rerank_mult=4) and without (rerank_mult=1) the exact
    fp32 re-rank.  Rows report steady per-query latency + recall@10; the
    analytic row restates the residency win (bytes DMA'd per candidate
    tile at the paper's d=960 shape, itemsize 4 vs 1).

    This bench is also the regression gate the CI quick tier runs: if the
    re-ranked int8 recall@10 drops more than 0.01 below fp32 in either
    regime, the process exits non-zero (SystemExit deliberately bypasses
    the harness's per-bench try/except)."""
    from repro.ann import Index
    from repro.data.synthetic import recall_at_k
    from repro.kernels.l2dist import _gather_tile_bytes

    ds = _dataset(n=4000 if QUICK else 12000, nq=256)
    cfg = _cfg(serve_buckets=(8, 64, 256), large_hops=32 if QUICK else 64)
    B_small, B_large = 8, 256
    recalls: dict = {}
    variants = [("fp32", dict(quantization="none")),
                ("int8_rerank", dict(quantization="int8", rerank_mult=4)),
                ("int8_raw", dict(quantization="int8", rerank_mult=1))]
    for name, kw in variants:
        index = Index.build(ds.X, dataclasses.replace(cfg, **kw), k=10)
        for regime, B in (("small", B_small), ("large", B_large)):
            us = _steady_us(index, ds.Q, B)
            r = recall_at_k(index.search(ds.Q[:B])[0], ds.gt[:B], 10)
            recalls[(name, regime)] = r
            emit(f"quantization/{name}_{regime}_B{B}", us,
                 f"recall@10={r:.3f}")
    d960 = _gather_tile_bytes(1, 1024, 960, self_q=False, itemsize=4) / \
        _gather_tile_bytes(1, 1024, 960, self_q=False, itemsize=1)
    emit("quantization/dma_bytes_ratio_d960", 0.0,
         f"fp32_over_int8={d960:.2f}x")
    for regime in ("small", "large"):
        fp, q = recalls[("fp32", regime)], recalls[("int8_rerank", regime)]
        ok = q >= fp - 0.01
        emit(f"quantization/recall_gate_{regime}", 0.0,
             f"fp32={fp:.3f};int8_rerank={q:.3f};pass={ok}")
        if not ok:
            raise SystemExit(
                f"quantization recall gate failed ({regime}): "
                f"int8_rerank={q:.3f} < fp32={fp:.3f} - 0.01")


# ==========================================================================
# locality-packed layout + visited filter (DESIGN.md §10)
# ==========================================================================

def layout_packing():
    """The "layout" build stage + hash visited filter, measured end to end.

    Rows: span coalescing of the adjacency before/after packing (host
    mirror of the kernel's grouped-DMA rule, at the kernel group width
    and the finer G=2/4 sub-widths the ROADMAP names), the DMA copy
    counts those spans collapse, the per-hop merge work the visited
    filter removes (static shapes), and steady per-query latency through
    the serving engine for plain / packed / packed+hash in both regimes.

    On CPU the latency rows are directional only: the hash filter's win
    is structural (it deletes the O(width²) dedup scans + re-rank merge
    the TPU bitonic path pays), but the XLA-CPU emulation pays the
    probe scans without that saving, so expect hash rows slower here
    and read the DMA/merge accounting rows for the TPU story.

    This bench is also a CI quick-tier regression gate: the packed
    graph's rows-per-copy must exceed 1.0 (the layout stage actually
    coalesces) and packed results must stay bitwise-identical to
    unpacked — either failure exits non-zero."""
    from repro.ann import Index
    from repro.ann import layout as LY
    from repro.serve.plane import SMALL_WIDTH

    ds = _dataset(n=2048 if QUICK else 8192, nq=256)
    cfg = _cfg(max_degree=16, k_graph=24, serve_buckets=(8, 64),
               large_hops=24 if QUICK else 48)
    packed_pipe = ("knn", "diversify", "bridges", "layout")
    variants = [
        ("plain", dict()),
        ("packed", dict(build_pipeline=packed_pipe)),
        ("packed_hash", dict(build_pipeline=packed_pipe,
                             visited_filter="hash")),
    ]
    built = {}
    for name, kw in variants:
        built[name] = Index.build(ds.X, dataclasses.replace(cfg, **kw),
                                  k=10)

    # -- span coalescing: host mirror of the kernel's grouped-DMA rule --
    nb_plain = np.asarray(built["plain"].graph.neighbors)
    nb_packed = np.asarray(built["packed"].graph.neighbors)
    stats = {}
    for tag, nb in (("before", nb_plain), ("after", nb_packed)):
        st = LY.span_stats(nb)
        stats[tag] = st
        emit(f"layout/span_{tag}", 0.0,
             f"group={st['group']};rows_per_copy={st['rows_per_copy']:.3f}"
             f";frac_coalesced={st['frac_coalesced']:.3f}"
             f";dma_copies={st['dma_copies']}")
    # sub-group histogram: how much coalescing finer span widths would see
    hist = ";".join(
        f"G{g}={LY.span_stats(nb_packed, group=g)['frac_coalesced']:.3f}"
        for g in (2, 4, 8))
    emit("layout/span_histogram", 0.0, hist)

    # -- merge work the visited filter removes (static shapes) --
    W = SMALL_WIDTH  # the small regime's compiled ranking width
    emit("layout/visited_merge_width", 0.0,
         f"dedup_path=scan{W}x{W}+rerank_merge{2 * W}"
         f";hash_path=merge{W};probes_per_lane=8")

    # -- steady-state serving, packed vs plain, both regimes --
    qps = {}
    for name, _ in variants:
        for regime, B in (("small", 8), ("large", 64)):
            us = _steady_us(built[name], ds.Q, B)
            qps[(name, regime)] = us
            emit(f"layout/{name}_{regime}_B{B}", us,
                 f"qps={1e6 / us:.0f}")

    # -- gates --
    rpc = stats["after"]["rows_per_copy"]
    ok_rpc = rpc > 1.0
    bitwise = all(
        np.array_equal(built["plain"].search(ds.Q[:B])[i],
                       built["packed"].search(ds.Q[:B])[i])
        for B in (8, 64) for i in (0, 1))
    emit("layout/gate", 0.0,
         f"rows_per_copy={rpc:.3f};pass={ok_rpc}"
         f";packed_bitwise={bitwise}")
    if not ok_rpc:
        raise SystemExit(
            f"layout gate failed: packed rows-per-copy {rpc:.3f} <= 1.0 "
            "(layout stage coalesced nothing)")
    if not bitwise:
        raise SystemExit(
            "layout gate failed: packed results diverge from unpacked")


# ==========================================================================
# kernel microbenches — Pallas timed alongside the XLA refs
# ==========================================================================

def _pallas_tag():
    """On CPU the Pallas kernels run in interpret mode; say so in the row."""
    return ("backend=pallas" if jax.default_backend() == "tpu"
            else "backend=pallas_interpret")


def kernel_micro():
    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    Q = jnp.asarray(rng.normal(size=(256, 128)).astype(np.float32))
    X = jnp.asarray(rng.normal(size=(8192, 128)).astype(np.float32))
    f = jax.jit(lambda a, b: ref.distance_matrix_ref(a, b, metric="l2"))
    us, _ = _timeit(f, Q, X)
    emit("kernel/l2dist_256x8192x128", us, "backend=xla_ref")
    f = jax.jit(lambda a, b: ops.distance_matrix(a, b, metric="l2"))
    us, _ = _timeit(f, Q, X)
    emit("kernel/l2dist_256x8192x128", us, _pallas_tag())

    d = jnp.asarray(rng.normal(size=(2048, 64)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 1 << 20, size=(2048, 64))
                      .astype(np.int32))
    f = jax.jit(lambda a, b: ref.sort_ref(a, b))
    us, _ = _timeit(f, d, ids)
    emit("kernel/bitonic_sort_2048x64", us, "backend=xla_ref")
    f = jax.jit(lambda a, b: ops.bitonic_sort(a, b))
    us, _ = _timeit(f, d, ids)
    emit("kernel/bitonic_sort_2048x64", us, _pallas_tag())

    q = jnp.asarray(rng.normal(size=(2, 512, 8, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 512, 2, 64)).astype(np.float32))
    f = jax.jit(lambda a, b, c: ref.attention_ref(a, b, c, window=256))
    us, _ = _timeit(f, q, k, k)
    emit("kernel/flash_attn_512_gqa", us, "backend=xla_ref")
    f = jax.jit(lambda a, b, c: ops.flash_attention(a, b, c, window=256))
    us, _ = _timeit(f, q, k, k)
    emit("kernel/flash_attn_512_gqa", us, _pallas_tag())


# ==========================================================================
# hot-path primitives + end-to-end search: pallas vs xla backend
# ==========================================================================

def hotpath_micro():
    """The three hotpath primitives, timed under both backends."""
    import functools

    from repro.core import hotpath as HP

    rng = np.random.default_rng(0)
    S, C, d_dim, N = (512, 32, 64, 100_000)
    X = jnp.asarray(rng.normal(size=(N, d_dim)).astype(np.float32))
    Q = jnp.asarray(rng.normal(size=(S, d_dim)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, N, size=(S, C)).astype(np.int32))
    mask = jnp.asarray(rng.random((S, C)) > 0.1)
    dists = jnp.asarray(rng.normal(size=(S, 96)).astype(np.float32))
    mids = jnp.asarray(rng.integers(0, N, size=(S, 96)).astype(np.int32))

    for backend in ("xla", "pallas"):
        tag = _pallas_tag() if backend == "pallas" else "backend=xla"
        f = jax.jit(lambda q, x, i, m, _b=backend: HP.neighbor_distances(
            q, x, i, metric="l2", mask=m, backend=_b))
        us, _ = _timeit(f, Q, X, idx, mask)
        emit(f"hotpath/neighbor_distances_{S}x{C}x{d_dim}", us, tag)
        f = jax.jit(functools.partial(
            HP.rank_merge, keep=64, backend=backend))
        us, _ = _timeit(f, dists, mids)
        emit(f"hotpath/rank_merge_{S}x96_keep64", us, tag)
        f = jax.jit(functools.partial(
            HP.seed_select, metric="l2", k=1, backend=backend))
        us, _ = _timeit(f, Q, X, idx)
        emit(f"hotpath/seed_select_{S}x{C}", us, tag)

    # gather placement within the Pallas backend: in-kernel scalar-prefetch
    # DMA gather (gather_fused) vs the XLA-gather-then-block path — the
    # ROADMAP "In-kernel neighbor gather" item's measured comparison.  On
    # CPU the fused path runs its DMAs in interpret mode (tagged as such);
    # on TPU this row is the [S, C, d]-buffer-elision win.
    Sf, Cf = (64, 16) if QUICK else (256, 32)
    Qf, idxf, maskf = Q[:Sf], idx[:Sf, :Cf], mask[:Sf, :Cf]
    times = {}
    for variant, gf in (("gather_then_block", "off"), ("gather_fused", "on")):
        f = jax.jit(lambda q, x, i, m, _g=gf: HP.neighbor_distances(
            q, x, i, metric="l2", mask=m, backend="pallas",
            gather_fused=_g))
        us, _ = _timeit(f, Qf, X, idxf, maskf)
        times[variant] = us
        emit(f"hotpath/neighbor_distances_{Sf}x{Cf}x{d_dim}", us,
             f"{_pallas_tag()};variant={variant}")
    emit(f"hotpath/neighbor_distances_fused_vs_gather_{Sf}x{Cf}x{d_dim}",
         0.0,
         f"fused_us={times['gather_fused']:.1f};"
         f"gather_us={times['gather_then_block']:.1f};"
         f"fused_speedup={times['gather_then_block'] / max(times['gather_fused'], 1e-9):.2f}x")


def search_backend_compare():
    """Both search regimes end-to-end under kernel_backend pallas vs xla —
    same graph, same queries; rows also record cross-backend id parity."""
    from repro.ann.pipeline import build_graph
    from repro.core.knn_build import exact_knn
    from repro.core.search_large import \
        _large_batch_search as large_batch_search
    from repro.core.search_small import \
        _small_batch_search as small_batch_search
    from repro.data.synthetic import recall_at_k

    ds = _dataset(n=2000 if QUICK else 6000, nq=32)
    X = jnp.asarray(ds.X)
    ids, dists = exact_knn(X, 24)
    g = build_graph(X, _cfg(), knn_ids=ids, knn_dists=dists)
    Q = jnp.asarray(ds.Q)
    outs = {"small": {}, "large": {}}
    for backend in ("xla", "pallas"):
        tag = _pallas_tag() if backend == "pallas" else "backend=xla"
        fn = lambda: small_batch_search(X, g, Q, k=10, t0=8, hops=6,
                                        backend=backend)[0]
        us, out = _timeit(fn)
        outs["small"][backend] = np.asarray(out)
        r = recall_at_k(outs["small"][backend], ds.gt, 10)
        emit(f"hotpath/small_batch_e2e_{backend}", us / len(ds.Q),
             f"{tag};recall@10={r:.3f}")
        fn = lambda: large_batch_search(X, g, Q, k=10, ef=64,
                                        hops=32 if QUICK else 64,
                                        backend=backend)[0]
        us, out = _timeit(fn, repeat=2)
        outs["large"][backend] = np.asarray(out)
        r = recall_at_k(outs["large"][backend], ds.gt, 10)
        emit(f"hotpath/large_batch_e2e_{backend}", us / len(ds.Q),
             f"{tag};recall@10={r:.3f}")
    for regime, o in outs.items():
        match = bool((o["xla"] == o["pallas"]).all())
        emit(f"hotpath/{regime}_backend_parity", 0.0,
             f"ids_identical={match}")


# ==========================================================================
# roofline table from the dry-run artifacts
# ==========================================================================

def roofline_table():
    art = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")
    for path in sorted(glob.glob(os.path.join(art, "*__single.json"))):
        with open(path) as f:
            rec = json.load(f)
        r = rec.get("roofline", {})
        if not r:
            continue
        name = f"roofline/{rec['arch']}__{rec['shape']}"
        t_dom = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        emit(name, t_dom * 1e6,
             f"dominant={r['dominant']};flops={r['flops']:.2e};"
             f"coll={r['coll_bytes']:.2e}")


BENCHES = [table2_diversification_time, fig4_cpu_search, fig5_degree_sweep,
           fig6_small_batch, fig10_large_batch, ablation_alpha_lambda,
           serve_engine_mixed, serve_bucketed_vs_raw, serve_aot_reload,
           streaming_ingest,
           mesh_serve, router_serve, mesh_aot_reload,
           quantization_recall, layout_packing,
           kernel_micro,
           hotpath_micro, search_backend_compare, roofline_table]


def _persist_rows(tier: str) -> str:
    """Append this run's rows to ``BENCH_<tier>.json`` at the repo root —
    a timestamped history so regressions are diffable across commits
    (bounded to the last 50 runs per tier).  Returns the file path."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, f"BENCH_{tier}.json")
    history = {"tier": tier, "runs": []}
    if os.path.isfile(path):
        try:
            with open(path) as f:
                history = json.load(f)
        except ValueError:
            pass  # corrupt history: start fresh rather than fail the run
    history.setdefault("runs", []).append({
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "quick": QUICK,
        "rows": [{"name": n, "us_per_call": us, "derived": d}
                 for n, us, d in ROWS],
    })
    history["runs"] = history["runs"][-50:]
    with open(path, "w") as f:
        json.dump(history, f, indent=1)
    return path


def main() -> None:
    # REPRO_BENCH_ONLY=serve runs just the benches whose name contains the
    # substring (the CI serving smoke uses this)
    only = os.environ.get("REPRO_BENCH_ONLY", "")
    print("name,us_per_call,derived")
    for bench in BENCHES:
        if only and only not in bench.__name__:
            continue
        try:
            bench()
        except Exception as e:  # noqa: BLE001
            emit(f"{bench.__name__}/ERROR", -1.0, repr(e)[:120])
    print(f"# {len(ROWS)} rows", flush=True)
    path = _persist_rows(only or "all")
    print(f"# rows persisted to {path}", flush=True)


if __name__ == "__main__":
    main()
