#!/usr/bin/env bash
# Quick CI tier: the fast test suite + a serving smoke benchmark.
#
# Excludes @slow tests and the multi-minute distributed subprocess tests
# (those run in the full tier: `PYTHONPATH=src python -m pytest -q`).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== quick test tier =="
python -m pytest -q -m "not slow" --ignore=tests/test_distributed.py

echo "== serving smoke bench =="
REPRO_BENCH_QUICK=1 REPRO_BENCH_ONLY=serve python -m benchmarks.run
