#!/usr/bin/env bash
# CI tiers.  Usage: scripts/ci.sh [quick|sharded|router|all]   (default: all)
#
# quick — kernel-backend parity (including the gather-fused scalar-prefetch
#   DMA path, exercised in interpret mode), the facade save/load round-trip
#   tier, queue QoS (deadlines + bypass), compressed residency (int8
#   parity + re-rank + artifact v4 + the recall@10 regression gate and a
#   --quantization int8 save/load smoke), the locality-packed layout +
#   visited filter tier (packed/unpacked bitwise parity, span-coalescing
#   rows-per-copy gate, artifact v5), the fast test suite, and smoke
#   benchmarks (bucketed serving + AOT reload rows, an explicit
#   kernel_backend=xla serve run, the fused-vs-gather hotpath rows, and the
#   facade build->save->load->serve->query smoke through the launcher and
#   quickstart example).
#
# sharded — the mesh execution plane on 8 emulated host devices
#   (XLA_FLAGS=--xla_force_host_platform_device_count=8): plane protocol +
#   cross-shard merge oracle + mesh<->single bitwise parity + sharded
#   artifact round-trip tests, the mesh_serve/mesh_aot_reload benchmark
#   rows, and a sharded build->save->load->serve launcher smoke asserting
#   zero compiles after a topology-matched load.
#
# router — pod-scale serving (DESIGN.md §9): the request router suite
#   (replicated/sharded parity, failover, health eject/readmit) and the
#   2-process jax.distributed CPU pod tests, the router_serve benchmark
#   rows, a 3-replica launcher smoke that reloads an AOT artifact and
#   kills one replica mid-stream (greps aggregated compiles=0,
#   lost_futures=0, ejects=1), and the pod_serving example.
#
# Excludes @slow tests and the multi-minute distributed subprocess tests
# (those run in the full tier: `PYTHONPATH=src python -m pytest -q`).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
TIER="${1:-all}"

quick_tier() {
    echo "== kernel backend + gather-fused parity (Pallas interpret vs XLA) =="
    python -m pytest -q tests/test_hotpath.py tests/test_search_dedup.py

    echo "== facade: save/load round-trip, AOT priming, QoS bypass =="
    python -m pytest -q tests/test_ann_facade.py tests/test_queue_qos.py

    echo "== streaming mutability: add/delete/compact lifecycle =="
    python -m pytest -q tests/test_streaming.py

    echo "== compressed residency: int8 parity, re-rank, artifact v4 =="
    python -m pytest -q tests/test_quantize.py

    echo "== layout + visited filter: packed bitwise parity, artifact v5 =="
    python -m pytest -q tests/test_layout.py

    echo "== quick test tier =="
    python -m pytest -q -m "not slow" --ignore=tests/test_distributed.py \
        --ignore=tests/test_hotpath.py --ignore=tests/test_search_dedup.py \
        --ignore=tests/test_ann_facade.py --ignore=tests/test_queue_qos.py \
        --ignore=tests/test_streaming.py --ignore=tests/test_quantize.py \
        --ignore=tests/test_layout.py \
        --ignore=tests/test_mesh_plane.py --ignore=tests/test_router.py \
        --ignore=tests/test_pod_plane.py

    echo "== serving smoke bench (incl. serve/aot_reload rows) =="
    REPRO_BENCH_QUICK=1 REPRO_BENCH_ONLY=serve python -m benchmarks.run

    echo "== streaming ingest bench (frozen vs add/delete/compact rows) =="
    REPRO_BENCH_QUICK=1 REPRO_BENCH_ONLY=streaming python -m benchmarks.run

    echo "== hotpath micro bench (fused vs gather-then-block rows) =="
    REPRO_BENCH_QUICK=1 REPRO_BENCH_ONLY=hotpath python -m benchmarks.run

    echo "== quantization bench + recall gate (int8 within 0.01 of fp32) =="
    REPRO_BENCH_QUICK=1 REPRO_BENCH_ONLY=quantization python -m benchmarks.run \
        | tee /tmp/quant_bench.log
    grep -q "recall_gate_small.*pass=True" /tmp/quant_bench.log
    grep -q "recall_gate_large.*pass=True" /tmp/quant_bench.log
    rm -f /tmp/quant_bench.log

    echo "== layout bench + span gate (packed rows-per-copy > 1, bitwise) =="
    REPRO_BENCH_QUICK=1 REPRO_BENCH_ONLY=layout python -m benchmarks.run \
        | tee /tmp/layout_bench.log
    grep -q "layout/gate.*pass=True" /tmp/layout_bench.log
    grep -q "layout/gate.*packed_bitwise=True" /tmp/layout_bench.log
    rm -f /tmp/layout_bench.log

    echo "== int8 smoke: build -> save -> load (v4 artifact, 0 compiles) =="
    QXDIR="$(mktemp -d)/qx"
    python -m repro.launch.serve --n 4000 --d 16 --batches 4 --backend xla \
        --quantization int8 --save-index "$QXDIR"
    python -m repro.launch.serve --n 4000 --d 16 --batches 6 --backend xla \
        --load-index "$QXDIR" | tee /tmp/quant_reload.log
    grep -q "compiles=0" /tmp/quant_reload.log
    rm -rf "$(dirname "$QXDIR")" /tmp/quant_reload.log

    echo "== facade smoke: build -> save -> load -> serve -> query =="
    IXDIR="$(mktemp -d)/ix"
    python -m repro.launch.serve --n 4000 --d 16 --batches 4 --backend xla \
        --save-index "$IXDIR"
    python -m repro.launch.serve --n 4000 --d 16 --batches 6 --backend xla \
        --load-index "$IXDIR"
    rm -rf "$(dirname "$IXDIR")"

    echo "== examples smoke: quickstart (canonical facade demo) =="
    REPRO_QUICKSTART_N=4000 python examples/quickstart.py

    echo "== examples smoke: streaming ingest (add/delete/compact demo) =="
    REPRO_STREAMING_N=3000 python examples/streaming_ingest.py
}

sharded_tier() {
    export XLA_FLAGS="--xla_force_host_platform_device_count=8"

    echo "== mesh plane: protocol, merge oracle, parity, round-trips =="
    python -m pytest -q tests/test_mesh_plane.py

    echo "== mesh serving bench (mesh_serve + mesh_aot_reload rows) =="
    REPRO_BENCH_QUICK=1 REPRO_BENCH_ONLY=mesh python -m benchmarks.run

    echo "== sharded smoke: build -> save -> load -> serve (4x2 mesh) =="
    MXDIR="$(mktemp -d)/mx"
    python -m repro.launch.serve --n 4096 --d 16 --batches 4 --backend xla \
        --mesh 4x2 --save-index "$MXDIR"
    # topology-matched reload must serve with ZERO compiles (AOT primed)
    python -m repro.launch.serve --n 4096 --d 16 --batches 6 --backend xla \
        --mesh 4x2 --load-index "$MXDIR" | tee /tmp/mesh_reload.log
    grep -q "compiles=0" /tmp/mesh_reload.log
    rm -rf "$(dirname "$MXDIR")" /tmp/mesh_reload.log

    echo "== examples smoke: distributed_search (sharded facade demo) =="
    python examples/distributed_search.py
}

router_tier() {
    echo "== request router: parity, failover, health, stats =="
    python -m pytest -q tests/test_router.py

    echo "== pod plane: 2-process jax.distributed CPU serving =="
    python -m pytest -q tests/test_pod_plane.py

    echo "== router serving bench (queue vs replicated vs sharded rows) =="
    REPRO_BENCH_QUICK=1 REPRO_BENCH_ONLY=router python -m benchmarks.run

    echo "== router smoke: AOT reload -> 3 replicas -> kill one mid-stream =="
    RXDIR="$(mktemp -d)/rx"
    python -m repro.launch.serve --n 4000 --d 16 --batches 4 --backend xla \
        --save-index "$RXDIR"
    # replicas share the donor's compile cache: aggregated compiles must be
    # ZERO after a topology-matched AOT reload, and the chaos kill must
    # lose no futures (retry on a healthy peer) with exactly one eject
    python -m repro.launch.serve --n 4000 --d 16 --batches 8 --backend xla \
        --load-index "$RXDIR" --router replicated:3 --health-interval 0.2 \
        --kill-replica 2 | tee /tmp/router_smoke.log
    grep -q "compiles=0" /tmp/router_smoke.log
    grep -q "lost_futures=0" /tmp/router_smoke.log
    grep -q "ejects=1" /tmp/router_smoke.log
    rm -rf "$(dirname "$RXDIR")" /tmp/router_smoke.log

    echo "== examples smoke: pod_serving (router + failover demo) =="
    REPRO_POD_N=3000 python examples/pod_serving.py
}

case "$TIER" in
    quick)   quick_tier ;;
    sharded) sharded_tier ;;
    router)  router_tier ;;
    all)     quick_tier; sharded_tier; router_tier ;;
    *) echo "unknown tier '$TIER' (quick|sharded|router|all)" >&2; exit 2 ;;
esac
