#!/usr/bin/env bash
# Quick CI tier: kernel-backend parity (including the gather-fused
# scalar-prefetch DMA path, exercised in interpret mode), the facade
# save/load round-trip tier, the fast test suite, and smoke benchmarks
# (bucketed serving + AOT reload rows, an explicit kernel_backend=xla
# serve run, the fused-vs-gather hotpath rows, and the facade
# build->save->load->serve->query smoke through the launcher and
# quickstart example).
#
# Excludes @slow tests and the multi-minute distributed subprocess tests
# (those run in the full tier: `PYTHONPATH=src python -m pytest -q`).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== kernel backend + gather-fused parity (Pallas interpret vs XLA) =="
python -m pytest -q tests/test_hotpath.py tests/test_search_dedup.py

echo "== facade: save/load round-trip, AOT priming, QoS bypass =="
python -m pytest -q tests/test_ann_facade.py

echo "== quick test tier =="
python -m pytest -q -m "not slow" --ignore=tests/test_distributed.py \
    --ignore=tests/test_hotpath.py --ignore=tests/test_search_dedup.py \
    --ignore=tests/test_ann_facade.py

echo "== serving smoke bench (incl. serve/aot_reload rows) =="
REPRO_BENCH_QUICK=1 REPRO_BENCH_ONLY=serve python -m benchmarks.run

echo "== hotpath micro bench (fused vs gather-then-block rows) =="
REPRO_BENCH_QUICK=1 REPRO_BENCH_ONLY=hotpath python -m benchmarks.run

echo "== facade smoke: build -> save -> load -> serve -> query =="
IXDIR="$(mktemp -d)/ix"
python -m repro.launch.serve --n 4000 --d 16 --batches 4 --backend xla \
    --save-index "$IXDIR"
python -m repro.launch.serve --n 4000 --d 16 --batches 6 --backend xla \
    --load-index "$IXDIR"
rm -rf "$(dirname "$IXDIR")"

echo "== examples smoke: quickstart (canonical facade demo) =="
REPRO_QUICKSTART_N=4000 python examples/quickstart.py
