#!/usr/bin/env bash
# Quick CI tier: kernel-backend parity (including the gather-fused
# scalar-prefetch DMA path, exercised in interpret mode), the fast test
# suite, and smoke benchmarks (bucketed serving, an explicit
# kernel_backend=xla serve run, and the fused-vs-gather hotpath rows).
#
# Excludes @slow tests and the multi-minute distributed subprocess tests
# (those run in the full tier: `PYTHONPATH=src python -m pytest -q`).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== kernel backend + gather-fused parity (Pallas interpret vs XLA) =="
python -m pytest -q tests/test_hotpath.py tests/test_search_dedup.py

echo "== quick test tier =="
python -m pytest -q -m "not slow" --ignore=tests/test_distributed.py \
    --ignore=tests/test_hotpath.py --ignore=tests/test_search_dedup.py

echo "== serving smoke bench =="
REPRO_BENCH_QUICK=1 REPRO_BENCH_ONLY=serve python -m benchmarks.run

echo "== hotpath micro bench (fused vs gather-then-block rows) =="
REPRO_BENCH_QUICK=1 REPRO_BENCH_ONLY=hotpath python -m benchmarks.run

echo "== kernel_backend=xla serving smoke =="
python -m repro.launch.serve --n 4000 --d 16 --batches 6 --backend xla
