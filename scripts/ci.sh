#!/usr/bin/env bash
# Quick CI tier: kernel-backend parity, the fast test suite, and two smoke
# benchmarks (bucketed serving + an explicit kernel_backend=xla serve run).
#
# Excludes @slow tests and the multi-minute distributed subprocess tests
# (those run in the full tier: `PYTHONPATH=src python -m pytest -q`).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== kernel backend parity (Pallas interpret vs XLA) =="
python -m pytest -q tests/test_hotpath.py

echo "== quick test tier =="
python -m pytest -q -m "not slow" --ignore=tests/test_distributed.py \
    --ignore=tests/test_hotpath.py

echo "== serving smoke bench =="
REPRO_BENCH_QUICK=1 REPRO_BENCH_ONLY=serve python -m benchmarks.run

echo "== kernel_backend=xla serving smoke =="
python -m repro.launch.serve --n 4000 --d 16 --batches 6 --backend xla
