"""repro — TSDG (Graph-based ANN Search: A Revisit) as a multi-pod JAX framework."""
__version__ = "1.0.0"
