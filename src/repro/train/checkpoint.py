"""Checkpointing: per-leaf npy shards + msgpack manifest, async, atomic.

No orbax in this environment.  Properties needed at scale and provided here:
  * atomic publish — write to ``<dir>/tmp-<step>`` then ``os.rename`` so a
    preempted save never corrupts the latest checkpoint;
  * async save — a background thread serializes a host-fetched snapshot, the
    train loop never blocks on disk;
  * elastic restore — arrays are loaded host-side and ``device_put`` against
    *target* shardings computed from the *current* mesh, so a job restarted
    on a different device count resumes seamlessly (tested);
  * manifest carries step / pytree structure / shapes+dtypes for validation.
"""
from __future__ import annotations

import os
import shutil
import threading

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _leaf_path(i: int) -> str:
    return f"leaf_{i:05d}.npy"


def _keystr(path) -> str:
    return jax.tree_util.keystr(path)


def save(state, step: int, directory: str, *, async_save: bool = False):
    """Snapshot `state` (pytree of arrays) at `step` into `directory`."""
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(state)
    # fetch to host *before* returning control (snapshot semantics)
    host_leaves = [(p, np.asarray(jax.device_get(x)))
                   for p, x in leaves_with_paths]

    def write():
        os.makedirs(directory, exist_ok=True)
        tmp = os.path.join(directory, f"tmp-{step}")
        final = os.path.join(directory, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": []}
        for i, (p, arr) in enumerate(host_leaves):
            dtype = str(arr.dtype)
            if arr.dtype == jnp.bfloat16:  # numpy can't persist ml_dtypes
                arr = arr.view(np.uint16)
            np.save(os.path.join(tmp, _leaf_path(i)), arr)
            manifest["leaves"].append({
                "key": _keystr(p), "file": _leaf_path(i),
                "shape": list(arr.shape), "dtype": dtype})
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb(manifest))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        with open(os.path.join(directory, "latest.tmp"), "w") as f:
            f.write(os.path.basename(final))
        os.replace(os.path.join(directory, "latest.tmp"),
                   os.path.join(directory, "latest"))

    if async_save:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_step(directory: str) -> int | None:
    latest = os.path.join(directory, "latest")
    if not os.path.exists(latest):
        return None
    with open(latest) as f:
        name = f.read().strip()
    return int(name.split("_")[-1])


def restore(directory: str, template, *, step: int | None = None,
            shardings=None):
    """Load into the structure of `template`; `shardings` (same structure,
    NamedShardings from the *current* mesh) enables elastic resharding."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    by_key = {m["key"]: m for m in manifest["leaves"]}
    out = []
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves_with_paths))
    for (p, tmpl), shard in zip(leaves_with_paths, shard_leaves):
        m = by_key.get(_keystr(p))
        if m is None:
            raise KeyError(f"checkpoint missing leaf {_keystr(p)}")
        arr = np.load(os.path.join(path, m["file"]))
        if m["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        if tuple(arr.shape) != tuple(jnp.shape(tmpl)):
            raise ValueError(
                f"shape mismatch for {_keystr(p)}: ckpt {arr.shape} vs "
                f"template {jnp.shape(tmpl)}")
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]
