"""Generic distributed trainer: grad accumulation, clipping, checkpoint/
restart, step retry (straggler/fault hook), optional EF-int8 gradient
compression on the data-parallel reduction.

The same trainer drives every family (LM / GNN / recsys): a family provides
``loss_fn(params, batch) -> (loss, metrics)`` plus a param schema; sharding
comes from the schema's logical axes resolved against the active mesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.module import init_params, schema_shapes
from repro.optim.api import Optimizer, OptimizerConfig, make_optimizer
from repro.optim.clip import clip_by_global_norm
from repro.parallel.sharding import schema_pspecs
from repro.train import checkpoint as ckpt


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    microbatches: int = 1          # grad-accumulation factor
    log_every: int = 10
    ckpt_every: int = 0            # 0 = disabled
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_async: bool = True
    max_retries: int = 2           # per-step retry (transient-fault hook)
    seed: int = 0


def make_train_step(loss_fn: Callable, optimizer: Optimizer,
                    max_grad_norm: float = 1.0, microbatches: int = 1,
                    unroll: bool = False):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    With microbatches > 1, `batch` must have a leading [microbatches, ...]
    axis; gradients are accumulated with a lax.scan (constant memory).
    """

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, metrics

    def step(params, opt_state, batch):
        if microbatches > 1:
            def acc(carry, mb):
                g_acc, m_acc = carry
                g, m = grads_of(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                m_acc = jax.tree.map(jnp.add, m_acc, m)
                return (g_acc, m_acc), None

            zeros_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            g0, m0 = grads_of(params, jax.tree.map(lambda x: x[0], batch))
            (grads, metrics), _ = jax.lax.scan(
                acc, (jax.tree.map(jnp.add, zeros_g, g0), m0),
                jax.tree.map(lambda x: x[1:], batch), unroll=unroll)
            inv = 1.0 / microbatches
            grads = jax.tree.map(lambda g: g * inv, grads)
            metrics = jax.tree.map(lambda m: m * inv, metrics)
        else:
            grads, metrics = grads_of(params, batch)

        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        params, opt_state = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return step


class Trainer:
    def __init__(self, *, schema, loss_fn, mesh: Mesh,
                 opt_cfg: OptimizerConfig, train_cfg: TrainConfig,
                 batch_pspec=None):
        self.schema = schema
        self.loss_fn = loss_fn
        self.mesh = mesh
        self.opt = make_optimizer(opt_cfg)
        self.cfg = train_cfg
        self.opt_cfg = opt_cfg
        self.param_pspecs = schema_pspecs(schema, mesh)
        self.batch_pspec = batch_pspec
        self._step_fn = None

    # ---- state ------------------------------------------------------------

    def init_state(self):
        key = jax.random.key(self.cfg.seed)

        def init():
            params = init_params(self.schema, key)
            opt_state = self.opt.init(params)
            return params, opt_state

        shard = jax.tree.map(lambda p: NamedSharding(self.mesh, p),
                             self.param_pspecs,
                             is_leaf=lambda x: isinstance(x, P))
        out_shardings = (shard, self._opt_shardings(shard))
        with self.mesh:
            params, opt_state = jax.jit(init, out_shardings=out_shardings)()
        return {"params": params, "opt_state": opt_state}

    def _opt_shardings(self, param_shard):
        """Optimizer-state shardings: slots mirror params (ZeRO)."""
        from repro.parallel.opt_sharding import opt_pspecs

        specs = opt_pspecs(self.schema, self.opt, self.mesh)
        return jax.tree.map(lambda p: NamedSharding(self.mesh, p), specs,
                            is_leaf=lambda x: isinstance(x, P))

    # ---- step -------------------------------------------------------------

    def compiled_step(self):
        if self._step_fn is None:
            step = make_train_step(self.loss_fn, self.opt,
                                   self.opt_cfg.max_grad_norm,
                                   self.cfg.microbatches)
            self._step_fn = jax.jit(step, donate_argnums=(0, 1))
        return self._step_fn

    def run(self, data_iter, *, resume: bool = False, state=None,
            on_metrics: Callable | None = None):
        if state is None:
            if resume and ckpt.latest_step(self.cfg.ckpt_dir) is not None:
                state = self.init_state()
                shard = jax.tree.map(lambda x: x.sharding, state)
                state, start = ckpt.restore(self.cfg.ckpt_dir, state,
                                            shardings=shard)
                print(f"[trainer] resumed from step {start}")
            else:
                state = self.init_state()
        step_fn = self.compiled_step()
        params, opt_state = state["params"], state["opt_state"]
        history = []
        with self.mesh:
            for i in range(self.cfg.steps):
                batch = next(data_iter)
                for attempt in range(self.cfg.max_retries + 1):
                    try:
                        params, opt_state, metrics = step_fn(
                            params, opt_state, batch)
                        break
                    except jax.errors.JaxRuntimeError:
                        if attempt == self.cfg.max_retries:
                            raise
                        print(f"[trainer] step {i} retry {attempt + 1}")
                if self.cfg.log_every and i % self.cfg.log_every == 0:
                    host = {k: float(v) for k, v in metrics.items()}
                    history.append((i, host))
                    if on_metrics:
                        on_metrics(i, host)
                if self.cfg.ckpt_every and (i + 1) % self.cfg.ckpt_every == 0:
                    ckpt.save({"params": params, "opt_state": opt_state},
                              i + 1, self.cfg.ckpt_dir,
                              async_save=self.cfg.ckpt_async)
        return {"params": params, "opt_state": opt_state}, history
