"""Aggregate dry-run artifacts into the EXPERIMENTS.md tables.

  PYTHONPATH=src python -m repro.analysis.report [--artifacts DIR]

Emits markdown: §Dry-run (memory/collective per cell, both meshes) and
§Roofline (three terms + dominant + MODEL_FLOPS ratio, single-pod).  For
scan-bearing steps the roofline row uses the `__roofline` (unrolled) artifact
— cost_analysis counts a while body once, so the scanned variant would
undercount; memory comes from the scanned (deployable) variant.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.analysis.roofline import HBM_BW, LINK_BW, PEAK_FLOPS

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "benchmarks", "artifacts", "dryrun")


def load(art_dir: str) -> dict:
    recs = {}
    for p in glob.glob(os.path.join(art_dir, "*.json")):
        with open(p) as f:
            r = json.load(f)
        key = (r["arch"], r["shape"], r["mesh"],
               bool(r.get("roofline_mode")))
        recs[key] = r
    return recs


def _gb(x) -> str:
    return f"{x / 2**30:.2f}"


def _fmt_s(x: float) -> str:
    if x <= 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.0f}µs"
    if x < 1.0:
        return f"{x * 1e3:.1f}ms"
    return f"{x:.2f}s"


def model_flops_per_chip(rec) -> float | None:
    meta = rec.get("meta", {})
    chips = rec["chips"]
    n_act = meta.get("n_active_params")
    toks = meta.get("tokens")
    if not n_act or not toks:
        return None
    shape = rec["shape"]
    if shape.startswith("train"):
        return 6.0 * n_act * toks / chips
    return 2.0 * n_act * toks / chips  # prefill & decode: fwd only


def dryrun_table(recs) -> str:
    rows = ["| arch | shape | mesh | chips | lower+compile (s) | "
            "args/dev (GiB) | temps/dev (GiB) | collective bytes/dev | "
            "#coll ops |",
            "|---|---|---|---|---|---|---|---|---|"]
    for key in sorted(recs):
        arch, shape, mesh, roof = key
        if roof:
            continue
        r = recs[key]
        mem = r.get("memory", {})
        ro = r.get("roofline", {})
        rows.append(
            f"| {arch} | {shape} | {mesh} | {r['chips']} "
            f"| {r['lower_s']}+{r['compile_s']} "
            f"| {_gb(mem.get('argument_size_in_bytes', 0))} "
            f"| {_gb(mem.get('temp_size_in_bytes', 0))} "
            f"| {ro.get('coll_bytes', 0):.2e} "
            f"| {ro.get('coll_detail', {}).get('count', 0)} |")
    return "\n".join(rows)


def roofline_table(recs) -> str:
    rows = ["| arch | shape | t_compute | t_memory | t_collective | "
            "dominant | HLO flops/chip | MODEL/HLO flops |",
            "|---|---|---|---|---|---|---|---|"]
    seen = set()
    for key in sorted(recs):
        arch, shape, mesh, roof = key
        if mesh != "single" or (arch, shape) in seen:
            continue
        # prefer the unrolled roofline artifact when it exists
        r = recs.get((arch, shape, "single", True)) \
            or recs.get((arch, shape, "single", False))
        seen.add((arch, shape))
        ro = r.get("roofline", {})
        if not ro:
            continue
        mf = model_flops_per_chip(r)
        ratio = f"{mf / ro['flops']:.2f}" if (mf and ro["flops"]) else "n/a"
        rows.append(
            f"| {arch} | {shape} | {_fmt_s(ro['t_compute_s'])} "
            f"| {_fmt_s(ro['t_memory_s'])} | {_fmt_s(ro['t_collective_s'])} "
            f"| **{ro['dominant']}** | {ro['flops']:.2e} | {ratio} |")
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default=ARTIFACTS)
    args = ap.parse_args()
    recs = load(args.artifacts)
    n_single = sum(1 for k in recs if k[2] == "single" and not k[3])
    n_multi = sum(1 for k in recs if k[2] == "multi" and not k[3])
    print(f"## Dry-run ({n_single} single-pod cells / {n_multi} "
          f"multi-pod cells)\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod, v5e: "
          f"{PEAK_FLOPS / 1e12:.0f} TF bf16, {HBM_BW / 1e9:.0f} GB/s HBM, "
          f"{LINK_BW / 1e9:.0f} GB/s link)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
