"""Roofline terms from a compiled AOT step (no hardware needed).

v5e constants (assigned): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

  compute   = HLO_FLOPs / (chips * peak)
  memory    = HLO_bytes / (chips * hbm_bw)
  collective= collective_bytes / (chips * link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``.  Collective bytes are NOT
in cost_analysis: we parse the post-SPMD optimized HLO text and sum operand
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op.  cost_analysis is per-device under SPMD, so the terms
below are per-chip step latencies (seconds).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
LINK_BW = 50e9             # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  f32[1024,128]{1,0}  or bf16[8]
_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[( ]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum *output* operand bytes per collective kind from optimized HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_txt, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(shape_txt)
        out["count"] += 1
    return out


@dataclasses.dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    coll_bytes: float
    coll_detail: dict
    chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "bytes": self.bytes_accessed,
            "coll_bytes": self.coll_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant, "chips": self.chips,
            "coll_detail": {k: v for k, v in self.coll_detail.items()
                            if v},
        }


def analyze(compiled, mesh_devices: int) -> Roofline:
    """cost_analysis is per-device post-SPMD; HLO text likewise."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older API returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    try:
        text = compiled.as_text()
    except Exception:
        text = ""
    coll = collective_bytes(text)
    total_coll = sum(v for k, v in coll.items() if k in _COLLECTIVES)
    return Roofline(flops=flops, bytes_accessed=byts, coll_bytes=total_coll,
                    coll_detail=coll, chips=mesh_devices)


def model_flops_train(n_params: int, n_tokens: int) -> float:
    """6·N·D (fwd+bwd)."""
    return 6.0 * n_params * n_tokens


def model_flops_decode(n_params: int, n_tokens: int) -> float:
    """2·N per generated token (fwd only)."""
    return 2.0 * n_params * n_tokens


def memory_summary(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out
