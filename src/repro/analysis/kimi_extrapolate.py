import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""kimi-k2 roofline via layer extrapolation.

The 61-layer fully-unrolled kimi module exceeds the CPU compile budget, so
we lower the *same* step with n_layers=2 and n_layers=4 (unrolled, full
dims) and extrapolate linearly: every kimi layer is identical (homogeneous
MoE stack), so  term(L) = term(2) + (L-2)/2 · (term(4) - term(2))  is exact
for per-layer costs and attributes the residual (embed/head/optimizer) to
the intercept.  Writes standard __roofline artifacts with provenance.
"""
import dataclasses
import json
import time

ART = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "benchmarks", "artifacts", "dryrun")


def measure(shape_name: str, n_layers: int):
    from repro.analysis import roofline as rl
    from repro.configs import get_arch
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import get_bundle

    mesh = make_production_mesh()
    cfg = dataclasses.replace(get_arch("kimi-k2-1t-a32b"),
                              n_layers=n_layers)
    b = get_bundle("kimi-k2-1t-a32b", shape_name, mesh, cfg=cfg,
                   roofline=True)
    comp = b.lower(mesh).compile()
    return rl.analyze(comp, mesh.devices.size), b.meta


def main() -> None:
    from repro.configs import get_arch

    L = get_arch("kimi-k2-1t-a32b").n_layers
    for shape in ("train_4k", "prefill_32k"):
        t0 = time.time()
        r2, _ = measure(shape, 2)
        r4, meta = measure(shape, 4)
        ex = {}
        for k in ("flops", "bytes_accessed", "coll_bytes"):
            v2, v4 = getattr(r2, k), getattr(r4, k)
            ex[k] = v2 + (L - 2) / 2.0 * (v4 - v2)
        from repro.analysis.roofline import Roofline

        roof = Roofline(flops=ex["flops"],
                        bytes_accessed=ex["bytes_accessed"],
                        coll_bytes=ex["coll_bytes"], coll_detail={},
                        chips=256)
        # meta from the 4-layer bundle has reduced params; recompute
        cfg_full = get_arch("kimi-k2-1t-a32b")
        rec = {
            "arch": "kimi-k2-1t-a32b", "shape": shape, "mesh": "single",
            "chips": 256, "roofline_mode": True,
            "provenance": "layer-extrapolated (2 vs 4 unrolled layers)",
            "lower_s": 0, "compile_s": round(time.time() - t0, 1),
            "memory": {},
            "roofline": roof.as_dict(),
            "meta": {"n_params": cfg_full.n_params(),
                     "n_active_params": cfg_full.n_active_params(),
                     "tokens": meta["tokens"]},
        }
        path = os.path.join(
            ART, f"kimi-k2-1t-a32b__{shape}__single__roofline.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[ok] {shape}: comp {roof.t_compute:.3g}s "
              f"mem {roof.t_memory:.3g}s coll {roof.t_collective:.3g}s "
              f"({time.time() - t0:.0f}s)", flush=True)


if __name__ == "__main__":
    main()
