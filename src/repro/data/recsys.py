"""Synthetic CTR data with a planted linear signal (learnable)."""
from __future__ import annotations

import numpy as np


class CTRStream:
    def __init__(self, cfg, batch: int, seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.rng = np.random.default_rng(seed)
        # hidden per-field value weights (hash-bucketed to bound memory)
        self.hbuckets = 4096
        self.hidden = self.rng.normal(
            size=(cfg.n_sparse, self.hbuckets)).astype(np.float32) * 0.5
        self.dense_w = self.rng.normal(size=cfg.n_dense).astype(np.float32)

    def _ids(self, vocab, size):
        # zipf-ish: squared uniform concentrates mass on small ids
        u = self.rng.random(size)
        return np.minimum((u * u * vocab).astype(np.int64), vocab - 1)

    def __next__(self):
        cfg, B = self.cfg, self.batch
        sparse = np.stack([self._ids(v, B) for v in cfg.vocab_sizes],
                          axis=1)
        bags = np.stack(
            [self._ids(cfg.vocab_sizes[f], (B, cfg.bag_size))
             for f in cfg.multi_hot_fields], axis=1)
        dense = self.rng.normal(size=(B, cfg.n_dense)).astype(np.float32)
        logit = dense @ self.dense_w
        for i in range(cfg.n_sparse):
            logit += self.hidden[i, sparse[:, i] % self.hbuckets]
        labels = (self.rng.random(B)
                  < 1.0 / (1.0 + np.exp(-logit))).astype(np.float32)
        return {
            "sparse_ids": sparse.astype(np.int32),
            "bags": bags.astype(np.int32),
            "dense": dense,
            "labels": labels,
        }

    def __iter__(self):
        return self
