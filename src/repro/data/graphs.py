"""Synthetic graph datasets matching the assigned GNN shape specs."""
from __future__ import annotations

import numpy as np


def make_community_graph(n_nodes: int, n_edges: int, d_feat: int,
                         n_classes: int = 16, p_intra: float = 0.9,
                         seed: int = 0):
    """SBM-ish node-classification graph: label = community (learnable)."""
    rng = np.random.default_rng(seed)
    comm = rng.integers(0, n_classes, size=n_nodes)
    centers = rng.normal(size=(n_classes, d_feat)).astype(np.float32)
    feat = centers[comm] + 0.8 * rng.normal(size=(n_nodes, d_feat)) \
        .astype(np.float32)
    src = rng.integers(0, n_nodes, size=n_edges)
    intra = rng.random(n_edges) < p_intra
    # intra edges: pick a random node of the same community via shuffled index
    by_comm = [np.flatnonzero(comm == c) for c in range(n_classes)]
    dst = rng.integers(0, n_nodes, size=n_edges)
    for c in range(n_classes):
        m = intra & (comm[src] == c)
        if m.sum() and len(by_comm[c]):
            dst[m] = rng.choice(by_comm[c], size=m.sum())
    return {
        "node_feat": feat.astype(np.float32),
        "edge_src": src.astype(np.int32),
        "edge_dst": dst.astype(np.int32),
        "node_mask": np.ones(n_nodes, bool),
        "edge_mask": np.ones(n_edges, bool),
        "labels": comm.astype(np.int32),
    }


def make_molecules(batch: int, n_nodes: int, n_edges: int,
                   n_species: int = 10, r_cut: float = 5.0, seed: int = 0,
                   with_forces: bool = False):
    """Batched point-cloud molecules; energy = softened LJ pair sum
    (a real geometric target so MACE training reduces loss)."""
    rng = np.random.default_rng(seed)
    G, Nn, Ne = batch, n_nodes, n_edges
    pos = rng.uniform(0, 4.0, size=(G, Nn, 3)).astype(np.float32)
    species = rng.integers(0, n_species, size=(G, Nn)).astype(np.int32)
    # per-graph radius-ish edges: take Ne closest pairs
    src = np.zeros((G, Ne), np.int32)
    dst = np.zeros((G, Ne), np.int32)
    emask = np.zeros((G, Ne), bool)
    energy = np.zeros((G,), np.float32)
    for g in range(G):
        diff = pos[g][:, None] - pos[g][None, :]
        dist = np.sqrt((diff ** 2).sum(-1) + 1e-12)
        iu = np.triu_indices(Nn, k=1)
        order = np.argsort(dist[iu])
        take = order[: Ne // 2]
        s, d = iu[0][take], iu[1][take]
        both_s = np.concatenate([s, d])[:Ne]
        both_d = np.concatenate([d, s])[:Ne]
        src[g, : len(both_s)] = both_s
        dst[g, : len(both_d)] = both_d
        emask[g, : len(both_s)] = True
        r = dist[s, d]
        r6 = (1.2 / np.maximum(r, 0.7)) ** 6
        energy[g] = np.sum(r6 * r6 - 2 * r6).astype(np.float32)
    # flatten to one packed batch
    offs = (np.arange(G) * Nn)[:, None]
    batch_out = {
        "positions": pos.reshape(G * Nn, 3),
        "species": species.reshape(-1),
        "edge_src": (src + offs).reshape(-1).astype(np.int32),
        "edge_dst": (dst + offs).reshape(-1).astype(np.int32),
        "edge_mask": emask.reshape(-1),
        "node_mask": np.ones(G * Nn, bool),
        "graph_ids": np.repeat(np.arange(G, dtype=np.int32), Nn),
        # standardized energies (O(1) regression target)
        "energies": ((energy - energy.mean())
                     / max(energy.std(), 1e-6)).astype(np.float32),
    }
    return batch_out


def molecule_batch_for_gnn(batch: int, n_nodes: int, n_edges: int,
                           d_feat: int = 16, n_classes: int = 8,
                           seed: int = 0):
    """Graph-classification variant for GIN/GatedGCN molecule cells."""
    rng = np.random.default_rng(seed)
    G = batch
    mol = make_molecules(batch, n_nodes, n_edges, seed=seed)
    feat = rng.normal(size=(G * n_nodes, d_feat)).astype(np.float32)
    labels = rng.integers(0, n_classes, size=G).astype(np.int32)
    return {
        "node_feat": feat,
        "edge_src": mol["edge_src"], "edge_dst": mol["edge_dst"],
        "edge_mask": mol["edge_mask"], "node_mask": mol["node_mask"],
        "graph_ids": mol["graph_ids"],
        "labels": labels,
    }
