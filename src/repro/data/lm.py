"""Synthetic LM data pipeline: deterministic, shardable token streams.

Token sequences follow a Zipfian unigram + Markov bigram mixture so the loss
actually *decreases* during the example runs (pure uniform noise has no
learnable signal).  Batches are produced host-side (numpy) and device_put
against the batch sharding, mimicking a real per-host data loader.
"""
from __future__ import annotations

import numpy as np


class LMStream:
    def __init__(self, vocab: int, seq_len: int, batch: int,
                 microbatches: int = 1, seed: int = 0):
        self.vocab = vocab
        self.seq_len = seq_len
        self.batch = batch
        self.micro = microbatches
        self.rng = np.random.default_rng(seed)
        # Zipf unigram distribution
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        self.p = (1.0 / ranks) / np.sum(1.0 / ranks)
        # deterministic "grammar": token t is followed by (t*7+3)%vocab wp .5
        self.next_tok = (np.arange(vocab) * 7 + 3) % vocab

    def __iter__(self):
        return self

    def __next__(self):
        shape = ((self.micro, self.batch // self.micro, self.seq_len + 1)
                 if self.micro > 1 else (self.batch, self.seq_len + 1))
        toks = self.rng.choice(self.vocab, size=shape, p=self.p)
        follow = self.rng.random(shape[:-1] + (self.seq_len,)) < 0.5
        toks = toks.astype(np.int32)
        toks[..., 1:] = np.where(follow, self.next_tok[toks[..., :-1]],
                                 toks[..., 1:])
        return {"tokens": toks}
