"""Synthetic datasets with exact ground truth.

Clustered Gaussians mimic the paper's SIFT/DEEP/GIST regimes (the occlusion
phenomenon of Fig. 1 only appears with cluster structure); LID is tunable via
cluster count / noise.  Ground truth = brute force (numpy, float64-stable).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Dataset:
    X: np.ndarray        # [N, d] float32 candidates
    Q: np.ndarray        # [B, d] float32 queries
    gt: np.ndarray       # [B, k_gt] int32 true NN ids (ascending distance)
    metric: str


def make_clustered(n: int = 20000, d: int = 32, n_queries: int = 200,
                   n_clusters: int = 64, noise: float = 0.15,
                   metric: str = "l2", k_gt: int = 100,
                   seed: int = 0) -> Dataset:
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_clusters, d)).astype(np.float32)
    assign = rng.integers(0, n_clusters, size=n)
    X = centers[assign] + noise * rng.normal(size=(n, d)).astype(np.float32)
    qa = rng.integers(0, n_clusters, size=n_queries)
    Q = centers[qa] + noise * rng.normal(size=(n_queries, d)).astype(np.float32)
    X = X.astype(np.float32)
    Q = Q.astype(np.float32)
    if metric == "cos":
        X = X / np.maximum(np.linalg.norm(X, axis=1, keepdims=True), 1e-12)
        Q = Q / np.maximum(np.linalg.norm(Q, axis=1, keepdims=True), 1e-12)
    gt = brute_force_gt(X, Q, k_gt, metric)
    return Dataset(X=X, Q=Q, gt=gt, metric=metric)


def make_uniform(n: int = 10000, d: int = 16, n_queries: int = 100,
                 metric: str = "l2", k_gt: int = 100, seed: int = 0) -> Dataset:
    rng = np.random.default_rng(seed)
    X = rng.uniform(-1, 1, size=(n, d)).astype(np.float32)
    Q = rng.uniform(-1, 1, size=(n_queries, d)).astype(np.float32)
    gt = brute_force_gt(X, Q, k_gt, metric)
    return Dataset(X=X, Q=Q, gt=gt, metric=metric)


def brute_force_gt(X: np.ndarray, Q: np.ndarray, k: int,
                   metric: str) -> np.ndarray:
    out = np.empty((Q.shape[0], k), np.int32)
    X64 = X.astype(np.float64)
    for i in range(0, Q.shape[0], 256):
        q = Q[i:i + 256].astype(np.float64)
        if metric in ("ip", "cos"):
            dist = -(q @ X64.T)
        else:
            dist = ((q ** 2).sum(1)[:, None] + (X64 ** 2).sum(1)[None, :]
                    - 2 * q @ X64.T)
        out[i:i + 256] = np.argsort(dist, axis=1)[:, :k].astype(np.int32)
    return out


def recall_at_k(found_ids: np.ndarray, gt: np.ndarray, k: int) -> float:
    """Paper Eq. 3."""
    hits = 0
    for f, g in zip(found_ids, gt):
        hits += len(set(f[:k].tolist()) & set(g[:k].tolist()))
    return hits / (gt.shape[0] * k)
