"""GraphSAGE fanout neighbor sampler (the `minibatch_lg` substrate).

Host-side CSR + with-replacement layered sampling, producing *fixed-shape*
subgraph batches (padded/self-looped) so the device step compiles once.
"""
from __future__ import annotations

import numpy as np


class NeighborSampler:
    def __init__(self, edge_src: np.ndarray, edge_dst: np.ndarray,
                 n_nodes: int):
        order = np.argsort(edge_dst, kind="stable")
        self.nbr = edge_src[order]  # neighbors grouped by dst
        counts = np.bincount(edge_dst, minlength=n_nodes)
        self.offsets = np.concatenate([[0], np.cumsum(counts)])
        self.n_nodes = n_nodes

    def sample_neighbors(self, nodes: np.ndarray, fanout: int,
                         rng: np.random.Generator) -> np.ndarray:
        """[B] -> [B, fanout] sampled in-neighbors (self-loop when isolated)."""
        starts = self.offsets[nodes]
        degs = self.offsets[nodes + 1] - starts
        r = rng.integers(0, 2 ** 31, size=(len(nodes), fanout))
        idx = starts[:, None] + r % np.maximum(degs, 1)[:, None]
        out = self.nbr[np.minimum(idx, len(self.nbr) - 1)]
        return np.where(degs[:, None] > 0, out, nodes[:, None])

    def sample_subgraph(self, seeds: np.ndarray, fanouts,
                        rng: np.random.Generator):
        """Layered fanout sample -> packed local subgraph (fixed shapes).

        Nodes: [seeds | layer-1 samples | layer-2 samples | ...] with
        duplicates kept (fixed shapes); edges point sampled->parent.
        """
        layers = [seeds.astype(np.int64)]
        src_l, dst_l = [], []
        base = 0
        for f in fanouts:
            parents = layers[-1]
            nbrs = self.sample_neighbors(parents, f, rng)     # [P, f]
            child_base = base + len(parents)
            src = (child_base
                   + np.arange(parents.size * f)).astype(np.int64)
            dst = (base + np.repeat(np.arange(parents.size), f)).astype(
                np.int64)
            src_l.append(src)
            dst_l.append(dst)
            layers.append(nbrs.reshape(-1))
            base = child_base
        nodes = np.concatenate(layers)
        seed_mask = np.zeros(len(nodes), bool)
        seed_mask[: len(seeds)] = True
        return {
            "node_ids": nodes.astype(np.int64),
            "edge_src": np.concatenate(src_l).astype(np.int32),
            "edge_dst": np.concatenate(dst_l).astype(np.int32),
            "seed_mask": seed_mask,
        }


def subgraph_sizes(batch_nodes: int, fanouts) -> tuple:
    """(n_sub_nodes, n_sub_edges) for fixed-shape compilation."""
    n, e, layer = batch_nodes, 0, batch_nodes
    for f in fanouts:
        e += layer * f
        layer *= f
        n += layer
    return n, e


class SampledStream:
    """Iterator of device-ready minibatches over a big host graph."""

    def __init__(self, graph: dict, batch_nodes: int, fanouts,
                 seed: int = 0):
        self.g = graph
        self.sampler = NeighborSampler(graph["edge_src"], graph["edge_dst"],
                                       graph["node_feat"].shape[0])
        self.batch_nodes = batch_nodes
        self.fanouts = tuple(fanouts)
        self.rng = np.random.default_rng(seed)

    def __iter__(self):
        return self

    def __next__(self):
        n = self.g["node_feat"].shape[0]
        seeds = self.rng.integers(0, n, size=self.batch_nodes)
        sub = self.sampler.sample_subgraph(seeds, self.fanouts, self.rng)
        ids = sub["node_ids"]
        return {
            "node_feat": self.g["node_feat"][ids],
            "edge_src": sub["edge_src"],
            "edge_dst": sub["edge_dst"],
            "edge_mask": np.ones(len(sub["edge_src"]), bool),
            "node_mask": np.ones(len(ids), bool),
            "labels": self.g["labels"][ids],
            "seed_mask": sub["seed_mask"],
        }
