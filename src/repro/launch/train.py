"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
      --steps 50 [--mesh-data 4 --mesh-model 2]

Sets the XLA latency-hiding-scheduler flags (compute/communication overlap)
before jax initializes, builds the mesh, wires the per-family data pipeline
into the Trainer, and runs with checkpoint/restart enabled.
"""
import os

_FLAGS = (
    "--xla_tpu_enable_data_parallel_all_reduce_opt=true "
    "--xla_tpu_data_parallel_opt_different_sized_ops=true "
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
)
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " " + _FLAGS).strip()

import argparse  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh-data", type=int, default=0)
    ap.add_argument("--mesh-model", type=int, default=0)
    args = ap.parse_args()

    import jax

    from repro.configs import get_arch, get_reduced
    from repro.launch.mesh import make_host_mesh, make_mesh
    from repro.optim.api import OptimizerConfig
    from repro.train.trainer import TrainConfig, Trainer

    cfg = get_reduced(args.arch) if args.reduced else get_arch(args.arch)
    if args.mesh_data and args.mesh_model:
        mesh = make_mesh((args.mesh_data, args.mesh_model),
                         ("data", "model"))
    else:
        mesh = make_host_mesh()
    print(f"[train] arch={cfg.name} family={cfg.family} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    if cfg.family == "lm":
        from repro.data.lm import LMStream
        from repro.models import transformer as T

        schema = T.schema(cfg)
        loss_fn = lambda p, b: T.loss_fn(p, cfg, b)
        data = iter(LMStream(cfg.vocab, args.seq, args.batch,
                             microbatches=args.microbatches))
        opt = OptimizerConfig(
            name="adafactor" if cfg.name.startswith("kimi") else "adamw",
            lr=3e-4, warmup_steps=max(5, args.steps // 20),
            total_steps=args.steps)
    elif cfg.family == "gnn":
        import jax.numpy as jnp

        from repro.data import graphs as DG
        from repro.models import gnn as G
        from repro.models import mace as MC

        if cfg.kind == "mace":
            schema = MC.schema(cfg)
            loss_fn = lambda p, b: MC.loss_fn(p, cfg, b)
            mol = {k: jnp.asarray(v)
                   for k, v in DG.make_molecules(16, 12, 32).items()}
            data = _repeat(mol)
        else:
            schema = G.schema(cfg, 32, 8)
            loss_fn = lambda p, b: G.loss_fn(p, cfg, b)
            g = {k: jnp.asarray(v) for k, v in DG.make_community_graph(
                2000, 12000, 32, n_classes=8).items()}
            data = _repeat(g)
        opt = OptimizerConfig(lr=1e-3, warmup_steps=5,
                              total_steps=args.steps)
    elif cfg.family == "recsys":
        import jax.numpy as jnp

        from repro.data.recsys import CTRStream
        from repro.models import recsys as R

        schema = R.schema(cfg)
        loss_fn = lambda p, b: R.loss_fn(p, cfg, b)
        stream = CTRStream(cfg, max(args.batch, 64))
        data = ({k: jnp.asarray(v) for k, v in next(stream).items()}
                for _ in iter(int, 1))
        opt = OptimizerConfig(lr=1e-3, warmup_steps=5,
                              total_steps=args.steps)
    else:
        raise SystemExit(f"--arch {args.arch}: use examples/quickstart.py "
                         "for the ANN system")

    trainer = Trainer(
        schema=schema, loss_fn=loss_fn, mesh=mesh, opt_cfg=opt,
        train_cfg=TrainConfig(steps=args.steps, log_every=10,
                              ckpt_every=max(10, args.steps // 4),
                              ckpt_dir=args.ckpt_dir,
                              microbatches=args.microbatches))
    _, hist = trainer.run(
        data, resume=args.resume,
        on_metrics=lambda s, m: print(
            f"step {s:5d} " + " ".join(f"{k}={v:.4f}"
                                       for k, v in m.items())))
    if hist:
        print(f"[train] loss {hist[0][1]['loss']:.3f} -> "
              f"{hist[-1][1]['loss']:.3f}")


def _repeat(batch):
    while True:
        yield batch


if __name__ == "__main__":
    main()
