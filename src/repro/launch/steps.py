"""Step builders: one StepBundle per (arch x shape x mesh) cell.

A bundle carries everything the dry-run / launcher needs:
  fn            — the step function to jit
  args          — ShapeDtypeStruct pytree (no allocation; weak-type-correct)
  in_shardings / out_shardings — resolved against the mesh
  donate        — argnums whose buffers the step consumes
  meta          — model-FLOPs etc. for the roofline report

Per-arch choices documented in DESIGN.md §4: kimi-k2 uses Adafactor
(momentum off, factored second moments) because AdamW-fp32 state for 1T
params cannot fit 512 x 16 GB; big archs use grad-accumulation microbatches
sized to keep the scanned residual-stream carry within HBM.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (ANNConfig, GNNConfig, RecsysConfig,
                                ShapeSpec, TransformerConfig, get_arch,
                                shapes_for)
from repro.models import gnn as gnn_lib
from repro.models import mace as mace_lib
from repro.models import recsys as recsys_lib
from repro.models import transformer as tfm
from repro.models.module import schema_shapes
from repro.optim.api import OptimizerConfig, make_optimizer
from repro.parallel.opt_sharding import opt_pspecs
from repro.parallel.sharding import logical_to_pspec, schema_pspecs
from repro.train.trainer import make_train_step


@dataclasses.dataclass
class StepBundle:
    name: str
    fn: Callable
    args: tuple
    in_shardings: Any
    out_shardings: Any
    donate: tuple = ()
    meta: dict = dataclasses.field(default_factory=dict)

    def lower(self, mesh: Mesh):
        if self.in_shardings is None:  # pre-jitted (shard_map) function
            with mesh:
                return self.fn.lower(*self.args)
        jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings,
                         donate_argnums=self.donate)
        with mesh:
            return jitted.lower(*self.args)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _shard_tree(tree_axes, tree_shapes, mesh):
    """logical-axes pytree + ShapeDtypeStruct pytree -> NamedSharding tree."""
    return jax.tree.map(
        lambda ax, s: NamedSharding(
            mesh, logical_to_pspec(s.shape, ax, mesh)),
        tree_axes, tree_shapes,
        is_leaf=lambda x: isinstance(x, tuple) or x is None)


# ==========================================================================
# LM family
# ==========================================================================

def _lm_optimizer(cfg: TransformerConfig) -> OptimizerConfig:
    if cfg.name.startswith("kimi"):
        # 1T params: factored second moments, no momentum (DESIGN.md §4)
        return OptimizerConfig(name="adafactor", lr=1e-3, momentum=0.0)
    return OptimizerConfig(name="adamw", lr=3e-4)


def _lm_microbatches(cfg: TransformerConfig, shape: ShapeSpec,
                     mesh: Mesh) -> int:
    """Grad-accum factor keeping the per-device scanned carry bounded."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    B, S = shape.dims["global_batch"], shape.dims["seq_len"]
    per_dev_tokens = B * S / dp
    # target <= ~8k tokens per device per microbatch
    mb = max(1, int(per_dev_tokens // 8192))
    while B % mb != 0:  # microbatch count must divide the global batch
        mb -= 1
    return mb


def build_lm_bundle(cfg: TransformerConfig, shape: ShapeSpec,
                    mesh: Mesh, roofline: bool = False) -> StepBundle:
    if roofline:
        # unroll every scan so cost_analysis counts all trips (XLA costs a
        # while body once); grad-accum dropped — its cost scales linearly
        cfg = dataclasses.replace(cfg, unroll=True)
    if cfg.moe is not None and cfg.moe.dispatch_groups == 1:
        # group-local MoE dispatch aligned with the data-parallel shards
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        dp = sizes.get("pod", 1) * sizes.get("data", 1)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch_groups=dp))
    schema = tfm.schema(cfg)
    p_shapes = schema_shapes(schema)
    p_ps = schema_pspecs(schema, mesh)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_ps,
                           is_leaf=lambda x: isinstance(x, P))
    S = shape.dims["seq_len"]
    B = shape.dims["global_batch"]
    meta = {
        "n_params": cfg.n_params(),
        "n_active_params": cfg.n_active_params(),
        "tokens": B * S if shape.kind != "decode" else B,
    }

    if shape.kind == "train":
        opt = make_optimizer(_lm_optimizer(cfg))
        o_shapes = jax.eval_shape(opt.init, p_shapes)
        o_shard = jax.tree.map(lambda p: NamedSharding(mesh, p),
                               opt_pspecs(schema, opt, mesh),
                               is_leaf=lambda x: isinstance(x, P))
        mb = 1 if roofline else _lm_microbatches(cfg, shape, mesh)
        tok_shape = ((mb, B // mb, S + 1) if mb > 1 else (B, S + 1))
        tok_axes = ((None, "batch", None) if mb > 1 else ("batch", None))
        batch_shapes = {"tokens": _sds(tok_shape, jnp.int32)}
        batch_shard = {"tokens": NamedSharding(
            mesh, logical_to_pspec(tok_shape, tok_axes, mesh))}
        step = make_train_step(
            lambda p, b: tfm.loss_fn(p, cfg, b), opt, microbatches=mb)
        metrics_shard = jax.tree.map(
            lambda _: NamedSharding(mesh, P()),
            jax.eval_shape(step, p_shapes, o_shapes, batch_shapes)[2])
        meta["microbatches"] = mb
        return StepBundle(
            name=f"{cfg.name}:{shape.name}",
            fn=step,
            args=(p_shapes, o_shapes, batch_shapes),
            in_shardings=(p_shard, o_shard, batch_shard),
            out_shardings=(p_shard, o_shard, metrics_shard),
            donate=(0, 1),
            meta=meta)

    if shape.kind == "prefill":
        toks = _sds((B, S), jnp.int32)
        toks_shard = NamedSharding(mesh, logical_to_pspec(
            (B, S), ("batch", None), mesh))

        def prefill_fn(params, tokens):
            return tfm.prefill(params, cfg, tokens)

        out_shape = jax.eval_shape(prefill_fn, p_shapes, toks)
        cache_ax = tfm.cache_logical_axes(cfg)
        logits_shard = NamedSharding(mesh, logical_to_pspec(
            out_shape[0].shape, ("batch", "vocab"), mesh))
        cache_shard = jax.tree.map(
            lambda s: NamedSharding(
                mesh, logical_to_pspec(s.shape, cache_ax, mesh)),
            out_shape[1])
        return StepBundle(
            name=f"{cfg.name}:{shape.name}", fn=prefill_fn,
            args=(p_shapes, toks),
            in_shardings=(p_shard, toks_shard),
            out_shardings=(logits_shard, cache_shard),
            meta=meta)

    # decode
    cache_shapes = jax.eval_shape(
        lambda: tfm.init_cache(cfg, B, S))
    cache_ax = tfm.cache_logical_axes(cfg)
    cache_shard = jax.tree.map(
        lambda s: NamedSharding(mesh,
                                logical_to_pspec(s.shape, cache_ax, mesh)),
        cache_shapes)
    tok = _sds((B,), jnp.int32)
    tok_shard = NamedSharding(mesh, logical_to_pspec((B,), ("batch",), mesh))
    pos = _sds((), jnp.int32)
    pos_shard = NamedSharding(mesh, P())

    def decode_fn(params, cache, token, p):
        return tfm.decode_step(params, cfg, cache, token, p)

    out_shape = jax.eval_shape(decode_fn, p_shapes, cache_shapes, tok, pos)
    logits_shard = NamedSharding(mesh, logical_to_pspec(
        out_shape[0].shape, ("batch", "vocab"), mesh))
    return StepBundle(
        name=f"{cfg.name}:{shape.name}", fn=decode_fn,
        args=(p_shapes, cache_shapes, tok, pos),
        in_shardings=(p_shard, cache_shard, tok_shard, pos_shard),
        out_shardings=(logits_shard, cache_shard),
        donate=(1,),
        meta=meta)


# ==========================================================================
# GNN family
# ==========================================================================

GNN_N_CLASSES = {"full_graph_sm": 16, "minibatch_lg": 41,
                 "ogb_products": 47, "molecule": 8}


def _pad512(n: int) -> int:
    """Graph inputs are padded (masks carry validity) so node/edge axes
    shard exactly on the 16x16 / 2x16x16 meshes."""
    return -(-n // 512) * 512


def _gnn_batch_specs(cfg: GNNConfig, shape: ShapeSpec):
    d = shape.dims
    if cfg.kind == "mace":
        if shape.name == "molecule":
            G, Nn, Ne = d["batch"], d["n_nodes"], d["n_edges"]
        elif shape.name == "minibatch_lg":
            # sampled-training shape: the step consumes the sampled
            # subgraph (as for the other GNNs), not the full 115M-edge graph
            from repro.data.sampler import subgraph_sizes

            Nn, Ne = subgraph_sizes(d["batch_nodes"], d["fanout"])
            G = 1
        else:
            # full-batch point-cloud interpretation of the big graph shapes
            G, Nn, Ne = 1, d["n_nodes"], d["n_edges"]
        N, E = _pad512(G * Nn), _pad512(G * Ne)
        shapes = {
            "positions": _sds((N, 3), jnp.float32),
            "species": _sds((N,), jnp.int32),
            "edge_src": _sds((E,), jnp.int32),
            "edge_dst": _sds((E,), jnp.int32),
            "edge_mask": _sds((E,), jnp.bool_),
            "node_mask": _sds((N,), jnp.bool_),
            "graph_ids": _sds((N,), jnp.int32),
            "energies": _sds((G,), jnp.float32),
        }
        axes = {
            "positions": ("nodes", None), "species": ("nodes",),
            "edge_src": ("edges",), "edge_dst": ("edges",),
            "edge_mask": ("edges",), "node_mask": ("nodes",),
            "graph_ids": ("nodes",), "energies": ("batch",),
        }
        return shapes, axes, 1

    if shape.name == "minibatch_lg":
        from repro.data.sampler import subgraph_sizes

        N, E = subgraph_sizes(d["batch_nodes"], d["fanout"])
        d_feat = d["d_feat"]
    elif shape.name == "molecule":
        N = d["batch"] * d["n_nodes"]
        E = d["batch"] * d["n_edges"]
        d_feat = 16
    else:
        N, E, d_feat = d["n_nodes"], d["n_edges"], d["d_feat"]
    N, E = _pad512(N), _pad512(E)
    n_classes = GNN_N_CLASSES[shape.name]
    shapes = {
        "node_feat": _sds((N, d_feat), jnp.float32),
        "edge_src": _sds((E,), jnp.int32),
        "edge_dst": _sds((E,), jnp.int32),
        "edge_mask": _sds((E,), jnp.bool_),
        "node_mask": _sds((N,), jnp.bool_),
    }
    axes = {
        "node_feat": ("nodes", None), "edge_src": ("edges",),
        "edge_dst": ("edges",), "edge_mask": ("edges",),
        "node_mask": ("nodes",),
    }
    if shape.name == "molecule":
        shapes["graph_ids"] = _sds((N,), jnp.int32)
        shapes["labels"] = _sds((d["batch"],), jnp.int32)
        axes["graph_ids"] = ("nodes",)
        axes["labels"] = ("batch",)
    else:
        shapes["labels"] = _sds((N,), jnp.int32)
        axes["labels"] = ("nodes",)
        if shape.name == "minibatch_lg":
            shapes["seed_mask"] = _sds((N,), jnp.bool_)
            axes["seed_mask"] = ("nodes",)
    return shapes, axes, n_classes


def build_gnn_bundle(cfg: GNNConfig, shape: ShapeSpec,
                     mesh: Mesh) -> StepBundle:
    batch_shapes, batch_axes, n_classes = _gnn_batch_specs(cfg, shape)
    if cfg.kind == "mace":
        schema = mace_lib.schema(cfg)
        loss = lambda p, b: mace_lib.loss_fn(p, cfg, b)
    else:
        d_feat = batch_shapes["node_feat"].shape[1]
        schema = gnn_lib.schema(cfg, d_feat, n_classes)
        loss = lambda p, b: gnn_lib.loss_fn(p, cfg, b)

    p_shapes = schema_shapes(schema)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           schema_pspecs(schema, mesh),
                           is_leaf=lambda x: isinstance(x, P))
    opt = make_optimizer(OptimizerConfig(name="adamw", lr=1e-3))
    o_shapes = jax.eval_shape(opt.init, p_shapes)
    o_shard = jax.tree.map(lambda p: NamedSharding(mesh, p),
                           opt_pspecs(schema, opt, mesh),
                           is_leaf=lambda x: isinstance(x, P))
    batch_shard = {
        k: NamedSharding(mesh, logical_to_pspec(batch_shapes[k].shape,
                                                batch_axes[k], mesh))
        for k in batch_shapes}
    step = make_train_step(loss, opt)
    metrics_shard = jax.tree.map(
        lambda _: NamedSharding(mesh, P()),
        jax.eval_shape(step, p_shapes, o_shapes, batch_shapes)[2])
    return StepBundle(
        name=f"{cfg.name}:{shape.name}", fn=step,
        args=(p_shapes, o_shapes, batch_shapes),
        in_shardings=(p_shard, o_shard, batch_shard),
        out_shardings=(p_shard, o_shard, metrics_shard),
        donate=(0, 1),
        meta={"n_nodes": batch_shapes[
            "node_feat" if cfg.kind != "mace" else "positions"].shape[0],
            "n_edges": batch_shapes["edge_src"].shape[0]})


# ==========================================================================
# recsys family
# ==========================================================================

def build_recsys_bundle(cfg: RecsysConfig, shape: ShapeSpec,
                        mesh: Mesh) -> StepBundle:
    schema = recsys_lib.schema(cfg)
    p_shapes = schema_shapes(schema)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           schema_pspecs(schema, mesh),
                           is_leaf=lambda x: isinstance(x, P))
    B = shape.dims["batch"]
    n_multi = len(cfg.multi_hot_fields)
    batch_shapes = {
        "sparse_ids": _sds((B, cfg.n_sparse), jnp.int32),
        "bags": _sds((B, n_multi, cfg.bag_size), jnp.int32),
        "dense": _sds((B, cfg.n_dense), jnp.float32),
    }
    batch_axes = {
        "sparse_ids": ("batch", None), "bags": ("batch", None, None),
        "dense": ("batch", None),
    }
    if shape.kind == "train":
        batch_shapes["labels"] = _sds((B,), jnp.float32)
        batch_axes["labels"] = ("batch",)
    batch_shard = {
        k: NamedSharding(mesh, logical_to_pspec(batch_shapes[k].shape,
                                                batch_axes[k], mesh))
        for k in batch_shapes}
    meta = {"n_params": sum(v * cfg.embed_dim for v in cfg.vocab_sizes)}

    if shape.kind == "train":
        opt = make_optimizer(OptimizerConfig(name="adamw", lr=1e-3))
        o_shapes = jax.eval_shape(opt.init, p_shapes)
        o_shard = jax.tree.map(lambda p: NamedSharding(mesh, p),
                               opt_pspecs(schema, opt, mesh),
                               is_leaf=lambda x: isinstance(x, P))
        step = make_train_step(
            lambda p, b: recsys_lib.loss_fn(p, cfg, b), opt)
        metrics_shard = jax.tree.map(
            lambda _: NamedSharding(mesh, P()),
            jax.eval_shape(step, p_shapes, o_shapes, batch_shapes)[2])
        return StepBundle(
            name=f"{cfg.name}:{shape.name}", fn=step,
            args=(p_shapes, o_shapes, batch_shapes),
            in_shardings=(p_shard, o_shard, batch_shard),
            out_shardings=(p_shard, o_shard, metrics_shard),
            donate=(0, 1), meta=meta)

    if shape.kind == "serve":
        def serve_fn(params, batch):
            return recsys_lib.serve_step(params, cfg, batch)

        out_shard = NamedSharding(mesh, logical_to_pspec(
            (B,), ("batch",), mesh))
        return StepBundle(
            name=f"{cfg.name}:{shape.name}", fn=serve_fn,
            args=(p_shapes, batch_shapes),
            in_shardings=(p_shard, batch_shard),
            out_shardings=out_shard, meta=meta)

    # retrieval: one user vs n_candidates item vectors
    n_cand = shape.dims["n_candidates"]
    batch_shapes["item_vectors"] = _sds((n_cand, recsys_lib.RETRIEVAL_DIM),
                                        jnp.float32)
    batch_axes["item_vectors"] = ("db", None)
    batch_shard["item_vectors"] = NamedSharding(
        mesh, logical_to_pspec((n_cand, recsys_lib.RETRIEVAL_DIM),
                               ("db", None), mesh))

    def retrieval_fn(params, batch):
        return recsys_lib.retrieval_step(params, cfg, batch)

    out_shard = (NamedSharding(mesh, P()), NamedSharding(mesh, P()))
    return StepBundle(
        name=f"{cfg.name}:{shape.name}", fn=retrieval_fn,
        args=(p_shapes, batch_shapes),
        in_shardings=(p_shard, batch_shard),
        out_shardings=out_shard, meta=meta)


# ==========================================================================
# ANN family (the paper's own system)
# ==========================================================================

def build_ann_bundle(cfg: ANNConfig, shape: ShapeSpec,
                     mesh: Mesh, roofline: bool = False) -> StepBundle:
    from repro.core import distributed as dist

    if roofline:
        cfg = dataclasses.replace(cfg, unroll_scans=True)
    d = shape.dims
    N, dim = d["n"], d["d"]
    db_spec = logical_to_pspec((N, dim), ("db", None), mesh)
    X_sds = _sds((N, dim), jnp.float32)
    X_shard = NamedSharding(mesh, db_spec)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_db = sizes.get("pod", 1) * sizes.get("data", 1)
    meta = {"n": N, "d": dim, "db_shards": n_db}

    if shape.kind == "build":
        fn = dist.make_build_fn(mesh, cfg)
        # the jitted shard_map fn carries its own shardings
        return StepBundle(
            name=f"tsdg:{shape.name}", fn=fn, args=(X_sds,),
            in_shardings=None, out_shardings=None, meta=meta)

    B = d["batch"]
    kind = "small" if B * d.get("t0", 1) < cfg.small_batch_threshold * n_db \
        else "large"
    kind = "small" if shape.name == "search_small" else "large"
    fn = dist.make_search_fn(mesh, cfg, kind=kind, k=10)
    Mdeg = cfg.max_degree
    nbrs = _sds((N, Mdeg), jnp.int32)
    lams = _sds((N, Mdeg), jnp.int32)
    degs = _sds((N,), jnp.int32)
    n_hubs = min(cfg.bridge_hubs, (N // n_db) // 4) * n_db
    hubs = _sds((n_hubs,), jnp.int32)
    Q = _sds((B, dim), jnp.float32)
    meta["search_kind"] = kind
    return StepBundle(
        name=f"tsdg:{shape.name}", fn=fn,
        args=(X_sds, nbrs, lams, degs, hubs, Q),
        in_shardings=None, out_shardings=None, meta=meta)


# ==========================================================================
# entry point
# ==========================================================================

def get_bundle(arch_id: str, shape_name: str, mesh: Mesh,
               cfg=None, roofline: bool = False) -> StepBundle:
    cfg = cfg or get_arch(arch_id)
    shape = shapes_for(cfg)[shape_name]
    if cfg.family == "lm":
        return build_lm_bundle(cfg, shape, mesh, roofline=roofline)
    if cfg.family == "gnn":
        return build_gnn_bundle(cfg, shape, mesh)  # no scans in GNN steps
    if cfg.family == "recsys":
        return build_recsys_bundle(cfg, shape, mesh)
    if cfg.family == "ann":
        return build_ann_bundle(cfg, shape, mesh, roofline=roofline)
    raise ValueError(cfg.family)


def all_cells(include_ann: bool = True):
    """The assigned 40 cells (+ the paper's own 4)."""
    from repro.configs.base import _ARCH_MODULES

    cells = []
    for m in _ARCH_MODULES:
        arch = m.replace("_", "-")
        cfg = get_arch(arch)
        if cfg.family == "ann" and not include_ann:
            continue
        for shape in shapes_for(cfg):
            cells.append((arch, shape))
    return cells
