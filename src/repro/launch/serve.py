"""ANN serving launcher — build a TSDG index and serve query batches.

  PYTHONPATH=src python -m repro.launch.serve [--n 20000 --d 32] \
      [--data vectors.npy --queries queries.npy] [--batches 20] [--k 10]

With --data/--queries, serves real vectors; otherwise a synthetic clustered
corpus with exact ground truth (recall is then reported per batch).
"""
import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--data", help="npy [N, d] float32 corpus")
    ap.add_argument("--queries", help="npy [B, d] float32 queries")
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--d", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--batches", type=int, default=20)
    ap.add_argument("--metric", default="l2", choices=("l2", "ip", "cos"))
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "pallas", "xla"),
                    help="hot-path kernel backend (auto = pallas on TPU, "
                         "xla elsewhere)")
    ap.add_argument("--gather-fused", default="auto",
                    choices=("auto", "on", "off"),
                    help="Pallas in-kernel neighbor gather (auto = DMA "
                         "path on real TPU, gather-then-block elsewhere)")
    ap.add_argument("--paper-faithful", action="store_true",
                    help="disable every beyond-paper feature")
    args = ap.parse_args()

    import dataclasses

    from repro.configs import get_arch
    from repro.data.synthetic import make_clustered, recall_at_k
    from repro.serve.engine import ANNEngine

    cfg = dataclasses.replace(get_arch("tsdg-paper"), metric=args.metric,
                              kernel_backend=args.backend,
                              gather_fused=args.gather_fused)
    if args.paper_faithful:
        cfg = dataclasses.replace(cfg, bridge_hubs=0, large_n_seeds=32,
                                  db_bf16=False, gather_limit=0)

    gt = None
    if args.data:
        X = np.load(args.data).astype(np.float32)
        Q = np.load(args.queries).astype(np.float32)
    else:
        ds = make_clustered(n=args.n, d=args.d, n_queries=512,
                            n_clusters=64, noise=0.6, metric=args.metric)
        X, Q, gt = ds.X, ds.Q, ds.gt

    t0 = time.perf_counter()
    engine = ANNEngine(X, cfg, k=args.k)
    print(f"[serve] index: N={X.shape[0]} d={X.shape[1]} "
          f"avg_degree={engine.graph.avg_degree():.1f} "
          f"built in {time.perf_counter() - t0:.1f}s "
          f"(kernel backend: {engine.backend})")

    rng = np.random.default_rng(0)
    hits = total = 0
    for i in range(args.batches):
        B = int(rng.choice([1, 4, 16, 64, 256]))
        sel = rng.integers(0, len(Q), B)
        t1 = time.perf_counter()
        ids, dists = engine.query(Q[sel])
        dt = (time.perf_counter() - t1) * 1e3
        line = (f"[serve] batch {i:3d} B={B:4d} "
                f"regime={engine.regime(B):5s} {dt:7.1f} ms")
        if gt is not None:
            r = recall_at_k(ids, gt[sel], args.k)
            hits += r * B
            total += B
            line += f"  recall@{args.k}={r:.3f}"
        print(line, flush=True)
    s = engine.stats
    print(f"[serve] {s.n_queries} queries / {s.n_batches} batches "
          f"({s.small_batches} small, {s.large_batches} large), "
          f"{s.qps:.0f} QPS steady-state"
          + (f", weighted recall {hits / total:.3f}" if total else ""))
    print(f"[serve] compiles={s.compiles} "
          f"bucket_hit_rate={s.bucket_hit_rate:.2f} "
          f"padded_queries={s.padded_queries}")


if __name__ == "__main__":
    main()
