"""ANN serving launcher — build (or load) a TSDG index and serve batches.

  PYTHONPATH=src python -m repro.launch.serve [--n 20000 --d 32] \
      [--data vectors.npy --queries queries.npy] [--batches 20] [--k 10] \
      [--save-index DIR | --load-index DIR] \
      [--router replicated:N|sharded:N [--replica-endpoints a,b,...] \
       [--health-interval S] [--kill-replica IDX]]

Drives the :class:`repro.ann.Index` facade: staged build (or artifact
load), automatic regime dispatch, and the persistent AOT serving cache —
``--save-index`` after a run writes the versioned artifact,
``--load-index`` on the next run skips both the rebuild and the warmup
compile sweep (``aot_primed`` in the stats line shows the restored
executables).

With --data/--queries, serves real vectors; otherwise a synthetic clustered
corpus with exact ground truth (recall is then reported per batch).

``--router`` puts the DESIGN.md §9 request router in front: N replicated
endpoints sharing the index's plane + compile cache (QPS scale-out), or N
sharded sub-indexes fanned out and merged (capacity scale-out), with
health-checked eject/readmit and a final aggregated stats line
(``[router] compiles=... lost_futures=...`` — what the CI smoke greps).
``--kill-replica IDX`` is the chaos drill: the endpoint dies mid-stream and
replicated mode must finish with ``lost_futures=0``.
"""
import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--data", help="npy [N, d] float32 corpus")
    ap.add_argument("--queries", help="npy [B, d] float32 queries")
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--d", type=int, default=32)
    ap.add_argument("--k", type=int, default=None,
                    help="neighbors per query (default: 10, or the saved "
                         "index's k with --load-index)")
    ap.add_argument("--batches", type=int, default=20)
    ap.add_argument("--metric", default="l2", choices=("l2", "ip", "cos"))
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "pallas", "xla"),
                    help="hot-path kernel backend (auto = pallas on TPU, "
                         "xla elsewhere)")
    ap.add_argument("--gather-fused", default="auto",
                    choices=("auto", "on", "off"),
                    help="Pallas in-kernel neighbor gather (auto = DMA "
                         "path on real TPU, gather-then-block elsewhere)")
    ap.add_argument("--quantization", default="none",
                    choices=("none", "int8"),
                    help="int8 = compressed residency: score per-row "
                         "symmetric int8 codes in-kernel (~4x less DMA), "
                         "then re-rank the top rerank_mult*k survivors "
                         "against the exact fp32 rows")
    ap.add_argument("--mesh", metavar="DxM",
                    help="serve through the mesh execution plane: 'D' or "
                         "'DxM' device counts for the data (DB shards) and "
                         "model (query fan-out) axes, e.g. --mesh 4x2. "
                         "Needs D*M visible devices (on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N). "
                         "Combines with --save-index/--load-index: sharded "
                         "artifacts restore onto a compatible mesh with "
                         "zero rebuilds and zero compiles")
    ap.add_argument("--router", metavar="MODE:N",
                    help="serve through the request router (DESIGN.md §9): "
                         "'replicated:N' dispatches each batch to one of N "
                         "replicas of the index (shared plane + compile "
                         "cache, least-loaded policy); 'sharded:N' splits "
                         "the corpus into N contiguous sub-indexes and "
                         "fans every batch out, merging per-shard top-k "
                         "into global ids")
    ap.add_argument("--replica-endpoints", metavar="NAME,NAME,...",
                    help="comma-separated endpoint names for --router "
                         "(default r0..rN-1 / s0..sN-1); count must match N")
    ap.add_argument("--health-interval", type=float, default=1.0,
                    metavar="SECONDS",
                    help="router health-probe period; a replica whose probe "
                         "fails is ejected within one interval and "
                         "readmitted after recovering (0 disables probing)")
    ap.add_argument("--kill-replica", type=int, default=None, metavar="IDX",
                    help="chaos drill: kill endpoint IDX halfway through "
                         "the batch stream (replicated mode retries on a "
                         "healthy peer — zero lost futures)")
    ap.add_argument("--calibrate", action="store_true",
                    help="fit the regime-dispatch threshold from timed "
                         "probe batches at init (paper §4's per-device "
                         "fit) instead of the static config value; the "
                         "fit is cached in a saved artifact")
    ap.add_argument("--save-index", metavar="DIR",
                    help="write the versioned index artifact (graph + "
                         "config + AOT serving cache) after serving")
    ap.add_argument("--load-index", metavar="DIR",
                    help="load a saved artifact instead of building "
                         "(skips rebuild AND the warmup compile sweep)")
    ap.add_argument("--warmup", action="store_true",
                    help="pre-compile every reachable (regime, bucket) "
                         "executable before serving")
    ap.add_argument("--paper-faithful", action="store_true",
                    help="disable every beyond-paper feature")
    args = ap.parse_args()

    import dataclasses

    from repro.ann import Index
    from repro.configs import get_arch
    from repro.data.synthetic import make_clustered, recall_at_k

    # validate router flags before any expensive build (did-you-mean
    # messages come from parse_router_spec, consistent with get_arch)
    router_cfg = None
    if args.router:
        from repro.serve.router import parse_router_spec

        names = ()
        if args.replica_endpoints:
            names = tuple(x.strip()
                          for x in args.replica_endpoints.split(",")
                          if x.strip())
        try:
            router_cfg = parse_router_spec(
                args.router, health_interval_s=args.health_interval,
                endpoint_names=names)
        except ValueError as e:
            raise SystemExit(f"--router: {e}")
        if (args.kill_replica is not None
                and not 0 <= args.kill_replica < router_cfg.replicas):
            raise SystemExit(
                f"--kill-replica {args.kill_replica} out of range for "
                f"{router_cfg.replicas} replicas")
    elif args.replica_endpoints or args.kill_replica is not None:
        raise SystemExit(
            "--replica-endpoints/--kill-replica only apply with --router")

    mesh = None
    if args.mesh:
        import jax

        try:
            dims = tuple(int(x) for x in args.mesh.lower().split("x"))
        except ValueError:
            raise SystemExit(f"--mesh {args.mesh!r} must be 'D' or 'DxM' "
                             "integers, e.g. --mesh 4x2")
        need = 1
        for x in dims:
            need *= x
        if need > jax.device_count():
            raise SystemExit(
                f"--mesh {args.mesh} needs {need} devices, only "
                f"{jax.device_count()} visible; on CPU set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={need}")
        axes = ("data",) if len(dims) == 1 else ("data", "model")
        if len(dims) > 2:
            raise SystemExit("--mesh takes at most two axes (data[xmodel])")
        mesh = jax.make_mesh(dims, axes)
        print(f"[serve] mesh plane: {dict(zip(axes, dims))} "
              f"({need} devices)")

    gt = None
    if args.data:
        X = np.load(args.data).astype(np.float32)
        Q = np.load(args.queries).astype(np.float32)
    else:
        ds = make_clustered(n=args.n, d=args.d, n_queries=512,
                            n_clusters=64, noise=0.6, metric=args.metric)
        X, Q, gt = ds.X, ds.Q, ds.gt

    t0 = time.perf_counter()
    if args.load_index:
        # build-time knobs are baked into the artifact; flag any the
        # caller tried to override instead of silently dropping them
        ignored = [f"--{n.replace('_', '-')}" for n, default in
                   (("metric", "l2"), ("backend", "auto"),
                    ("gather_fused", "auto"), ("quantization", "none"),
                    ("paper_faithful", False), ("calibrate", False))
                   if getattr(args, n) != default]
        if ignored:
            print(f"[serve] note: {' '.join(ignored)} ignored with "
                  "--load-index (the artifact's saved config governs)")
        index = Index.load(args.load_index, mesh=mesh)
        print(f"[serve] index loaded from {args.load_index} in "
              f"{time.perf_counter() - t0:.1f}s "
              f"(plane={index.plane.name}, "
              f"aot_primed={index.stats.aot_primed}, no rebuild, "
              f"no warmup sweep)")
    else:
        cfg = dataclasses.replace(get_arch("tsdg-paper"),
                                  metric=args.metric,
                                  kernel_backend=args.backend,
                                  gather_fused=args.gather_fused,
                                  quantization=args.quantization,
                                  regime_calibration=("probe" if
                                                      args.calibrate
                                                      else "static"))
        if args.paper_faithful:
            cfg = dataclasses.replace(cfg, bridge_hubs=0, large_n_seeds=32,
                                      db_bf16=False, gather_limit=0)
        index = Index.build(X, cfg, k=args.k if args.k is not None else 10,
                            mesh=mesh)
        line = (f"[serve] index: N={X.shape[0]} d={X.shape[1]} "
                f"avg_degree={index.graph.avg_degree():.1f} "
                f"built in {time.perf_counter() - t0:.1f}s "
                f"(kernel backend: {index.backend}, "
                f"plane: {index.plane.name}"
                + (f", quantization: {args.quantization}"
                   if args.quantization != "none" else "") + ")")
        if index.calibration is not None:
            cal = index.calibration
            line += (f"\n[serve] calibrated regime threshold: "
                     f"{index.engine.threshold:.1f} "
                     f"(crossover B*={cal.crossover_batch:.1f}, "
                     f"degenerate={cal.degenerate})")
        print(line)
    # a --k differing from the saved index's k still works (the engine
    # compiles that (regime, bucket, k) on demand, it just isn't primed)
    k = args.k if args.k is not None else index.k
    if args.warmup:
        t0 = time.perf_counter()
        n = index.warmup(k=k)
        print(f"[serve] warmup: {n} compiles in "
              f"{time.perf_counter() - t0:.1f}s")

    router = None
    if router_cfg is not None:
        router = index.serve(router=router_cfg)
        print(f"[router] mode={router_cfg.mode} "
              f"endpoints={[e.name for e in router.endpoints]} "
              f"policy={router_cfg.policy} "
              f"health_interval={router_cfg.health_interval_s}s")

    rng = np.random.default_rng(0)
    hits = total = 0
    try:
        for i in range(args.batches):
            if (router is not None and args.kill_replica is not None
                    and i == args.batches // 2):
                victim = router.endpoints[args.kill_replica]
                victim.kill()
                print(f"[router] killed replica {victim.name!r} at batch "
                      f"{i} (chaos drill — in-flight and later requests "
                      "fail over)")
            B = int(rng.choice([1, 4, 16, 64, 256]))
            sel = rng.integers(0, len(Q), B)
            t1 = time.perf_counter()
            if router is not None:
                ids, dists = router.query(Q[sel], k=k)
            else:
                ids, dists = index.search(Q[sel], k=k)
            dt = (time.perf_counter() - t1) * 1e3
            line = (f"[serve] batch {i:3d} B={B:4d} "
                    f"regime={index.regime(B):5s} {dt:7.1f} ms")
            if gt is not None:
                r = recall_at_k(ids, gt[sel], k)
                hits += r * B
                total += B
                line += f"  recall@{k}={r:.3f}"
            print(line, flush=True)
    finally:
        if router is not None:
            snap = router.snapshot()
            router.close()
    if router is not None:
        agg, rt = snap["aggregate"], snap["router"]
        print(f"[router] {rt['n_requests']} requests / "
              f"{rt['n_dispatches']} dispatches over "
              f"{agg['n_replicas']} endpoints "
              f"({agg['healthy_replicas']} healthy), "
              f"{agg['n_queries']} queries "
              f"({agg['small_batches']} small, {agg['large_batches']} "
              f"large batches), {agg['qps']:.0f} QPS aggregate"
              + (f", weighted recall {hits / total:.3f}" if total else ""))
        print(f"[router] compiles={agg['compiles']} "
              f"aot_primed={agg['aot_primed']} "
              f"lost_futures={rt['lost_futures']} "
              f"retries={rt['retries']} ejects={rt['ejects']} "
              f"readmits={rt['readmits']} probes={rt['probes']} "
              f"expired={agg['expired']}")
    else:
        s = index.stats
        print(f"[serve] {s.n_queries} queries / {s.n_batches} batches "
              f"({s.small_batches} small, {s.large_batches} large), "
              f"{s.qps:.0f} QPS steady-state"
              + (f", weighted recall {hits / total:.3f}" if total else ""))
        print(f"[serve] compiles={s.compiles} aot_primed={s.aot_primed} "
              f"bucket_hit_rate={s.bucket_hit_rate:.2f} "
              f"padded_queries={s.padded_queries}")
    if args.save_index:
        t0 = time.perf_counter()
        index.save(args.save_index)
        print(f"[serve] artifact written to {args.save_index} in "
              f"{time.perf_counter() - t0:.1f}s — next run: "
              f"--load-index {args.save_index}")


if __name__ == "__main__":
    main()
