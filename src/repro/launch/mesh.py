"""Production mesh construction.

Functions (never module-level constants) so importing this module never
touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import; tests and benches see the 1 real CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh(model: int = 1):
    """Mesh over whatever devices exist (tests/examples on CPU)."""
    n = len(jax.devices())
    model = max(1, min(model, n))
    return jax.make_mesh((n // model, model), ("data", "model"))
