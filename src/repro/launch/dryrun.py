import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first
# init).  Everything below is ordinary code.

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory/cost/collective statistics.

  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi

Results land in benchmarks/artifacts/dryrun/<arch>__<shape>__<mesh>.json and
feed EXPERIMENTS.md §Dry-run / §Roofline.
"""
import argparse
import json
import time
import traceback

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "benchmarks", "artifacts", "dryrun")


def run_cell(arch: str, shape: str, mesh_kind: str,
             roofline: bool = False) -> dict:
    import jax

    from repro.analysis import roofline as rl
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import get_bundle

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
           "chips": int(n_chips), "roofline_mode": roofline}
    t0 = time.time()
    bundle = get_bundle(arch, shape, mesh, roofline=roofline)
    lowered = bundle.lower(mesh)
    rec["lower_s"] = round(time.time() - t0, 1)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 1)
    rec["memory"] = rl.memory_summary(compiled)
    print(f"[{arch}:{shape}:{mesh_kind}] memory_analysis:",
          rec["memory"], flush=True)
    roof = rl.analyze(compiled, n_chips)
    rec["roofline"] = roof.as_dict()
    print(f"[{arch}:{shape}:{mesh_kind}] cost_analysis: "
          f"flops={roof.flops:.3e} bytes={roof.bytes_accessed:.3e} "
          f"coll={roof.coll_bytes:.3e} dominant={roof.dominant}", flush=True)
    rec["meta"] = {k: (int(v) if isinstance(v, (int,)) else v)
                   for k, v in bundle.meta.items()}
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--roofline", action="store_true",
                    help="unroll scans so cost_analysis counts every trip")
    ap.add_argument("--out", default=ARTIFACTS)
    args = ap.parse_args()

    from repro.launch.steps import all_cells

    if args.all:
        cells = all_cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape in cells:
        for mk in meshes:
            tag = f"{arch}__{shape}__{mk}" \
                + ("__roofline" if args.roofline else "")
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[skip] {tag} (cached)", flush=True)
                continue
            try:
                rec = run_cell(arch, shape, mk, roofline=args.roofline)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"[ok] {tag} lower={rec['lower_s']}s "
                      f"compile={rec['compile_s']}s", flush=True)
            except Exception as e:  # noqa: BLE001
                failures.append((tag, repr(e)))
                traceback.print_exc()
                print(f"[FAIL] {tag}: {e}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(" ", tag, err)
        raise SystemExit(1)
    print("\nall requested cells passed")


if __name__ == "__main__":
    main()
