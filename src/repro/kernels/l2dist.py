"""Tiled distance-matrix Pallas kernel — the ANN hot spot (paper §4.1).

Computes D[i, j] = ||q_i - x_j||^2 (or -<q_i, x_j> for ip/cos) for a tile of
queries against a tile of database vectors with ONE MXU contraction per
(bq x bn) block plus rank-1 norm corrections.  This is the TPU mapping of
the paper's warp-per-distance scheme: the unit of work is a 128x128 MXU
block, not a 32-thread warp (DESIGN.md §2).

Grid: (Q/bq, N/bn).  Each block touches q-tile [bq, d] + x-tile [bn, d] in
VMEM and writes [bq, bn]; d is kept whole (d <= ~1024 fits VMEM: 128*1024*4B
= 512 KB per operand tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dist_kernel(q_ref, x_ref, o_ref, *, metric: str):
    q = q_ref[...].astype(jnp.float32)            # [bq, d]
    x = x_ref[...].astype(jnp.float32)            # [bn, d]
    dots = jax.lax.dot_general(q, x, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    if metric in ("ip", "cos"):
        o_ref[...] = -dots
    else:
        qn = jnp.sum(q * q, axis=1, keepdims=True)
        xn = jnp.sum(x * x, axis=1)
        o_ref[...] = qn + xn[None, :] - 2.0 * dots


@functools.partial(jax.jit,
                   static_argnames=("metric", "bq", "bn", "interpret"))
def distance_matrix_pallas(Q, X, *, metric: str = "l2", bq: int = 128,
                           bn: int = 128, interpret: bool = False):
    """[B, d] x [N, d] -> [B, N] float32 (smaller = closer)."""
    B, d = Q.shape
    N = X.shape[0]
    Bp = -(-B // bq) * bq
    Np = -(-N // bn) * bn
    Qp = jnp.pad(Q, ((0, Bp - B), (0, 0)))
    Xp = jnp.pad(X, ((0, Np - N), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_dist_kernel, metric=metric),
        grid=(Bp // bq, Np // bn),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Np), jnp.float32),
        interpret=interpret,
    )(Qp, Xp)
    return out[:B, :N]
