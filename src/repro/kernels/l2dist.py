"""Tiled distance-matrix Pallas kernel — the ANN hot spot (paper §4.1).

Computes D[i, j] = ||q_i - x_j||^2 (or -<q_i, x_j> for ip/cos) for a tile of
queries against a tile of database vectors with ONE MXU contraction per
(bq x bn) block plus rank-1 norm corrections.  This is the TPU mapping of
the paper's warp-per-distance scheme: the unit of work is a 128x128 MXU
block, not a 32-thread warp (DESIGN.md §2).

Grid: (Q/bq, N/bn).  Each block touches q-tile [bq, d] + x-tile [bn, d] in
VMEM and writes [bq, bn]; d is kept whole (d <= ~1024 fits VMEM: 128*1024*4B
= 512 KB per operand tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Per-block VMEM budget for operand + output tiles.  ~4 MB of the ~16 MB
# per core, leaving headroom for Pallas' own pipeline double-buffering.
VMEM_BUDGET = 4 << 20


def _dist_kernel(q_ref, x_ref, o_ref, *, metric: str):
    q = q_ref[...].astype(jnp.float32)            # [bq, d]
    x = x_ref[...].astype(jnp.float32)            # [bn, d]
    dots = jax.lax.dot_general(q, x, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    if metric in ("ip", "cos"):
        o_ref[...] = -dots
    else:
        qn = jnp.sum(q * q, axis=1, keepdims=True)
        xn = jnp.sum(x * x, axis=1)
        o_ref[...] = qn + xn[None, :] - 2.0 * dots


@functools.partial(jax.jit,
                   static_argnames=("metric", "bq", "bn", "interpret"))
def distance_matrix_pallas(Q, X, *, metric: str = "l2", bq: int = 128,
                           bn: int = 128, interpret: bool = False):
    """[B, d] x [N, d] -> [B, N] float32 (smaller = closer)."""
    B, d = Q.shape
    N = X.shape[0]
    Bp = -(-B // bq) * bq
    Np = -(-N // bn) * bn
    Qp = jnp.pad(Q, ((0, Bp - B), (0, 0)))
    Xp = jnp.pad(X, ((0, Np - N), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_dist_kernel, metric=metric),
        grid=(Bp // bq, Np // bn),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Np), jnp.float32),
        interpret=interpret,
    )(Qp, Xp)
    return out[:B, :N]


# --------------------------------------------------------------------------
# batched-rowwise block distances — the search hot path's [S, W, d] shape
# --------------------------------------------------------------------------

def _score_block(q, v, m, *, metric: str, pin: bool = False):
    """Shared scoring formulation: fp32 q [bs, Kq, d] x fp32 v [bs, C, d]
    x int8 mask [bs, C] -> [bs, Kq, C] (masked lanes -> INF).  Every
    distance the system computes — both kernel backends, quantized or not —
    funnels through this exact op sequence, which is what makes the
    bitwise-parity contract hold.

    ``pin`` (quantized path, interpret mode only) computes the norm terms
    as batched self-``dot_general`` contractions instead of
    multiply-then-``sum``.  A plain reduce's rounding depends on how the
    *surrounding* program gets scheduled — XLA picks linear vs vectorized
    accumulation per compiled program — so the full-array reference and
    the per-block kernel trace can round the same norm differently by
    1 ulp.  ``dot_general`` lowers to the same per-row contraction
    everywhere (the ``dots`` term below matches bitwise across backends
    for exactly this reason), so both sides route norms through it.  The
    combine is fma-safe as-is: ``2.0 * dots`` is exact (power-of-two
    scale), so fusing it into the subtract cannot change the rounding."""
    dots = jax.lax.dot_general(q, v, (((2,), (2,)), ((0,), (0,))),
                               preferred_element_type=jnp.float32)
    if metric in ("ip", "cos"):
        dist = -dots
    else:
        if pin:
            nd = (((2,), (2,)), ((0, 1), (0, 1)))
            qn = jax.lax.dot_general(q, q, nd,
                                     preferred_element_type=jnp.float32)
            vn = jax.lax.dot_general(v, v, nd,
                                     preferred_element_type=jnp.float32)
        else:
            qn = jnp.sum(q * q, axis=2)
            vn = jnp.sum(v * v, axis=2)
        dist = qn[:, :, None] + vn[:, None, :] - 2.0 * dots
    return jnp.where((m != 0)[:, None, :], dist,
                     jnp.asarray(3.4e38, dist.dtype))


def _block_kernel(q_ref, v_ref, m_ref, o_ref, *, metric: str):
    """Per-row distance block: q [bs, Kq, d] x v [bs, C, d] -> [bs, Kq, C],
    with the candidate keep-mask fused (masked lanes -> INF)."""
    q = q_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    o_ref[...] = _score_block(q, v, m_ref[...], metric=metric)


def _block_kernel_quant(q_ref, v_ref, s_ref, m_ref, o_ref, *, metric: str,
                        pin: bool = False):
    """Quantized variant: v is int8 codes, s [bs, C] the per-row fp32
    scales; dequantize in-register after the (4x cheaper) VMEM load.

    ``pin`` (set in interpret mode, where the kernel body is ordinary XLA)
    pins the dequantized rows behind an optimization barrier so XLA cannot
    fuse the scale multiply into the norm reduction — the 1-ulp fma drift
    that would break the cross-backend bitwise contract.  Mosaic (real
    TPU) has no such cross-op refusion, and no barrier lowering, so the
    flag stays off there."""
    q = q_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32) * s_ref[...][:, :, None]
    if pin:
        v = jax.lax.optimization_barrier(v)
    o_ref[...] = _score_block(q, v, m_ref[...], metric=metric, pin=pin)


def _block_bytes(bs: int, Kq: int, bc: int, d: int,
                 itemsize: int = 4) -> int:
    """Bytes of one (Q-tile, V-tile, [scale-tile,] mask-tile, out-tile)
    block set.  `itemsize` is the V operand's dtype width — int8 codes
    bill 1 byte/element (plus their fp32 scale row) instead of 4, which
    is exactly the residency win."""
    scales = 0 if itemsize == 4 else bs * bc * 4
    return (bs * Kq * d * 4 + bs * bc * d * itemsize + scales
            + bs * bc + bs * Kq * bc * 4)


def _pick_bs(Kq: int, C: int, d: int, budget: int = VMEM_BUDGET,
             itemsize: int = 4) -> tuple[int, int]:
    """(row tile, candidate tile) whose operand+output blocks fit the VMEM
    budget.  Halves the row tile all the way to 1; if a single row still
    doesn't fit (e.g. GIST d=960 with a wide candidate set), the candidate
    axis is split into a second grid dimension instead of silently
    overflowing VMEM."""
    bs = 128
    while bs > 1 and _block_bytes(bs, Kq, C, d, itemsize) > budget:
        bs //= 2
    if _block_bytes(bs, Kq, C, d, itemsize) <= budget:
        return bs, C
    bc = C
    while bc > 1 and _block_bytes(1, Kq, bc, d, itemsize) > budget:
        bc = -(-bc // 2)
    return 1, bc


@functools.partial(jax.jit,
                   static_argnames=("metric", "bs", "bc", "interpret"))
def block_distances_pallas(Q, V, mask, v_scales=None, *, metric: str = "l2",
                           bs: int | None = None, bc: int | None = None,
                           interpret: bool = False):
    """Q [S, Kq, d] x V [S, C, d] x mask [S, C] -> [S, Kq, C] float32.

    The hot primitive behind ``hotpath.neighbor_distances``: one fused
    tile per `bs` rows computes the MXU contraction, the rank-1 norm
    corrections, and the validity masking in a single VMEM-resident block.
    When even a one-row block exceeds the VMEM budget the candidate axis
    is tiled too (grid dim 2, `bc` columns per block) — padded candidate
    lanes carry mask 0 and come back INF, so the result is unchanged.

    With ``v_scales`` [S, C] float32, V is int8 codes (compressed
    residency, DESIGN.md §8): the tile is loaded at 1 byte/element and
    dequantized in-register as ``v * scale`` before the same contraction.
    """
    S, Kq, d = Q.shape
    C = V.shape[1]
    if bs is None or bc is None:
        pbs, pbc = _pick_bs(Kq, C, d, itemsize=V.dtype.itemsize)
        bs = pbs if bs is None else bs
        bc = pbc if bc is None else bc
    Sp = -(-S // bs) * bs
    Cp = -(-C // bc) * bc
    Qp = jnp.pad(Q, ((0, Sp - S), (0, 0), (0, 0)))
    Vp = jnp.pad(V, ((0, Sp - S), (0, Cp - C), (0, 0)))
    mp = jnp.pad(mask.astype(jnp.int8), ((0, Sp - S), (0, Cp - C)))
    if v_scales is None:
        kernel = functools.partial(_block_kernel, metric=metric)
        in_specs = [
            pl.BlockSpec((bs, Kq, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((bs, bc, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bs, bc), lambda i, j: (i, j)),
        ]
        args = (Qp, Vp, mp)
    else:
        sp = jnp.pad(v_scales.astype(jnp.float32),
                     ((0, Sp - S), (0, Cp - C)))
        kernel = functools.partial(_block_kernel_quant, metric=metric,
                                   pin=interpret)
        in_specs = [
            pl.BlockSpec((bs, Kq, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((bs, bc, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bs, bc), lambda i, j: (i, j)),
            pl.BlockSpec((bs, bc), lambda i, j: (i, j)),
        ]
        args = (Qp, Vp, sp, mp)
    out = pl.pallas_call(
        kernel,
        grid=(Sp // bs, Cp // bc),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bs, Kq, bc), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((Sp, Kq, Cp), jnp.float32),
        interpret=interpret,
    )(*args)
    return out[:S, :, :C]


# --------------------------------------------------------------------------
# gather-fused block distances — in-kernel neighbor gather (DESIGN.md §2)
# --------------------------------------------------------------------------
#
# The paper's throughput bound is how fast one node's neighborhood can be
# fetched and scored (§4.1); CAGRA/GGNN win on GPU by streaming neighbor
# vectors into shared memory.  This is the TPU analogue: the database X
# stays resident in HBM (memory_space=ANY), the neighbor ids arrive via
# scalar prefetch (available before the kernel body runs), and each row
# tile issues one async copy per needed neighbor row HBM->VMEM.  Copies
# for tile i+1 are issued before tile i's compute (double buffering), so
# the DMA stream hides behind the MXU contraction.  The [S, C, d]
# gathered-neighbor buffer of the gather-then-block path never exists.


def span_group(C: int, *, cap: int = 8) -> int:
    """Aligned-group width for span-coalesced gather DMA: the largest
    power of two <= ``cap`` dividing C.  Group g covers candidate lanes
    [g*G, (g+1)*G); when its prefetched ids are contiguous ascending the
    kernel issues ONE [G, d] copy instead of G row copies.  Static in C,
    so the kernel trace (and its issue/wait pairing) never depends on the
    data.  ``ann.layout.span_stats`` mirrors this rule host-side."""
    g = 1
    while g * 2 <= cap and C % (g * 2) == 0:
        g *= 2
    return g


def _gather_tile_bytes(Kq: int, C: int, d: int, *, self_q: bool,
                       itemsize: int = 4) -> int:
    """Bytes of one gather-fused block set per row of tile: Q tile (unless
    the query side is gathered from the same ids), the double-buffered
    neighbor scratch (at the database dtype's actual width — int8 codes
    DMA 1 byte/element and bill their fp32 scale row), mask, and output."""
    q = 0 if self_q else Kq * d * 4
    scales = 0 if itemsize == 4 else C * 4
    return q + 2 * C * d * itemsize + scales + C + Kq * C * 4


def gather_fused_fits(Kq: int, C: int, d: int, *, self_q: bool = False,
                      budget: int = VMEM_BUDGET, itemsize: int = 4) -> bool:
    """True when at least a one-row tile of the fused gather kernel fits
    the VMEM budget (the dispatch fallback check in hotpath)."""
    return _gather_tile_bytes(Kq, C, d, self_q=self_q,
                              itemsize=itemsize) <= budget


def _pick_bs_fused(S: int, Kq: int, C: int, d: int, *,
                   self_q: bool, budget: int = VMEM_BUDGET,
                   itemsize: int = 4) -> int:
    per_row = _gather_tile_bytes(Kq, C, d, self_q=self_q, itemsize=itemsize)
    bs = 128
    while bs > 1 and bs * per_row > budget:
        bs //= 2
    while bs // 2 >= S and bs > 1:  # don't pad tiny batches up to 128 rows
        bs //= 2
    return bs


def _gather_body(idx_ref, q_ref, s_ref, m_ref, x_hbm, o_ref, vbuf, sem, *,
                 metric: str, bs: int, C: int, pin: bool = False):
    """One grid step = one row tile.  idx_ref [Sp, C] is scalar-prefetched
    (SMEM), so the DMA targets are known before the body runs; x_hbm is the
    whole database in HBM/ANY; vbuf [2, bs, C, d] revolves across the grid.
    ``s_ref`` (quantized path only) carries the gathered per-row fp32
    scales; the int8 tile dequantizes in-register after the DMA.  ``pin``
    — see :func:`_block_kernel_quant` (interpret-mode fma-fusion guard).
    """
    i = pl.program_id(0)
    n = pl.num_programs(0)
    G = span_group(C)  # aligned-group width for span-coalesced copies

    def _dma(slot, tile, r):
        # r enumerates the bs*C neighbor rows of the tile
        s, c = r // C, jax.lax.rem(r, C)
        return pltpu.make_async_copy(
            x_hbm.at[idx_ref[tile * bs + s, c]],
            vbuf.at[slot, s, c],
            sem.at[slot])

    def _span(tile, g):
        """Group g of the tile: (row-in-tile, lane offset, base id, ok)
        where ok means the G prefetched ids form one contiguous ascending
        run — a single multi-row HBM slice.  Layout-packed graphs
        (DESIGN.md §10) make this the common case.  All-SMEM scalar
        reads, recomputed identically at issue and wait time so starts
        and waits pair up; contiguity also bounds the slice (the last id
        is pre-clipped < N, so base + G <= N)."""
        gpr = C // G
        s, c0 = g // gpr, jax.lax.rem(g, gpr) * G
        base = idx_ref[tile * bs + s, c0]
        ok = base >= 0
        for j in range(1, G):
            ok = jnp.logical_and(ok, idx_ref[tile * bs + s, c0 + j]
                                 == base + j)
        return s, c0, base, ok

    def _span_dma(slot, tile, g):
        s, c0, base, _ = _span(tile, g)
        return pltpu.make_async_copy(
            x_hbm.at[pl.ds(base, G)],
            vbuf.at[slot, s, pl.ds(c0, G)],
            sem.at[slot])

    def _sweep(slot, tile, act):
        """Drive every DMA of a tile through ``act`` (start or wait).
        G == 1: the original per-row enumeration.  Else per group: one
        coalesced copy when the span predicate holds, the G per-row
        copies otherwise — both phases traverse the same groups with the
        same predicates, so every started copy gets one matching wait."""
        if G == 1:
            def body(r, carry):
                act(_dma(slot, tile, r))
                return carry
            jax.lax.fori_loop(0, bs * C, body, 0)
            return

        def body(g, carry):
            s, c0, _, ok = _span(tile, g)

            @pl.when(ok)
            def _():
                act(_span_dma(slot, tile, g))

            @pl.when(jnp.logical_not(ok))
            def _():
                for j in range(G):
                    act(_dma(slot, tile, s * C + c0 + j))
            return carry
        jax.lax.fori_loop(0, bs * (C // G), body, 0)

    def _issue(slot, tile):
        _sweep(slot, tile, lambda cp: cp.start())

    def _wait(slot, tile):
        _sweep(slot, tile, lambda cp: cp.wait())

    @pl.when(i == 0)
    def _():
        _issue(0, 0)

    @pl.when(i + 1 < n)  # prefetch the next tile's rows behind this compute
    def _():
        _issue((i + 1) % 2, i + 1)

    slot = jax.lax.rem(i, 2)
    _wait(slot, i)

    v = vbuf[slot].astype(jnp.float32)             # [bs, C, d]
    if s_ref is not None:
        v = v * s_ref[...][:, :, None]
        if pin:
            v = jax.lax.optimization_barrier(v)
    q = v if q_ref is None else q_ref[...].astype(jnp.float32)
    o_ref[...] = _score_block(q, v, m_ref[...], metric=metric, pin=pin)


def _gather_block_kernel(idx_ref, q_ref, m_ref, x_hbm, o_ref, vbuf, sem, *,
                         metric: str, bs: int, C: int):
    _gather_body(idx_ref, q_ref, None, m_ref, x_hbm, o_ref, vbuf, sem,
                 metric=metric, bs=bs, C=C)


def _gather_block_kernel_quant(idx_ref, q_ref, s_ref, m_ref, x_hbm, o_ref,
                               vbuf, sem, *, metric: str, bs: int, C: int,
                               pin: bool = False):
    _gather_body(idx_ref, q_ref, s_ref, m_ref, x_hbm, o_ref, vbuf, sem,
                 metric=metric, bs=bs, C=C, pin=pin)


def _self_q_gather_kernel(idx_ref, m_ref, x_hbm, o_ref, vbuf, sem, *,
                          metric: str, bs: int, C: int):
    """self_q variant: the query rows ARE the gathered neighbor rows (the
    diversify tiles' [T, K, K] pairwise blocks), so no Q input at all."""
    _gather_body(idx_ref, None, None, m_ref, x_hbm, o_ref, vbuf, sem,
                 metric=metric, bs=bs, C=C)


@functools.partial(jax.jit,
                   static_argnames=("metric", "bs", "interpret", "self_q"))
def gather_block_distances_pallas(Q, X, idx, mask, scales=None, *,
                                  metric: str = "l2",
                                  bs: int | None = None,
                                  interpret: bool = False,
                                  self_q: bool = False):
    """In-kernel-gather distance block.

    Q [S, Kq, d] (ignored/None when ``self_q``) x X [N, d] resident in HBM
    x idx [S, C] int32 (pre-clipped to [0, N)) x mask [S, C] bool ->
    [S, Kq, C] float32 (Kq = C when ``self_q``).  Bitwise-identical to
    ``block_distances_pallas(Q, X[idx], mask)`` — same contraction, same
    rank-1 norm corrections, same mask — without ever materializing the
    [S, C, d] neighbor buffer.

    With ``scales`` [S, C] float32 (the per-row scales pre-gathered by the
    same idx), X is the int8 code matrix: the DMA streams 1-byte rows
    (~4x less HBM->VMEM traffic) and the tile dequantizes in-register
    before the contraction.
    """
    S, C = idx.shape
    d = X.shape[1]
    Kq = C if self_q else Q.shape[1]
    if bs is None:
        bs = _pick_bs_fused(S, Kq, C, d, self_q=self_q,
                            itemsize=X.dtype.itemsize)
    Sp = -(-S // bs) * bs
    ip = jnp.pad(idx, ((0, Sp - S), (0, 0)))
    mp = jnp.pad(mask.astype(jnp.int8), ((0, Sp - S), (0, 0)))
    scratch = [pltpu.VMEM((2, bs, C, d), X.dtype),
               pltpu.SemaphoreType.DMA((2,))]
    if self_q:
        kernel = functools.partial(_self_q_gather_kernel, metric=metric,
                                   bs=bs, C=C)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(Sp // bs,),
            in_specs=[
                pl.BlockSpec((bs, C), lambda i, idx_ref: (i, 0)),
                pl.BlockSpec(memory_space=pltpu.ANY),
            ],
            out_specs=pl.BlockSpec((bs, Kq, C), lambda i, idx_ref: (i, 0, 0)),
            scratch_shapes=scratch,
        )
        args = (ip, mp, X)
    elif scales is not None:
        kernel = functools.partial(_gather_block_kernel_quant, metric=metric,
                                   bs=bs, C=C, pin=interpret)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(Sp // bs,),
            in_specs=[
                pl.BlockSpec((bs, Kq, d), lambda i, idx_ref: (i, 0, 0)),
                pl.BlockSpec((bs, C), lambda i, idx_ref: (i, 0)),
                pl.BlockSpec((bs, C), lambda i, idx_ref: (i, 0)),
                pl.BlockSpec(memory_space=pltpu.ANY),
            ],
            out_specs=pl.BlockSpec((bs, Kq, C), lambda i, idx_ref: (i, 0, 0)),
            scratch_shapes=scratch,
        )
        Qp = jnp.pad(Q, ((0, Sp - S), (0, 0), (0, 0)))
        sp = jnp.pad(scales.astype(jnp.float32), ((0, Sp - S), (0, 0)))
        args = (ip, Qp, sp, mp, X)
    else:
        kernel = functools.partial(_gather_block_kernel, metric=metric,
                                   bs=bs, C=C)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(Sp // bs,),
            in_specs=[
                pl.BlockSpec((bs, Kq, d), lambda i, idx_ref: (i, 0, 0)),
                pl.BlockSpec((bs, C), lambda i, idx_ref: (i, 0)),
                pl.BlockSpec(memory_space=pltpu.ANY),
            ],
            out_specs=pl.BlockSpec((bs, Kq, C), lambda i, idx_ref: (i, 0, 0)),
            scratch_shapes=scratch,
        )
        Qp = jnp.pad(Q, ((0, Sp - S), (0, 0), (0, 0)))
        args = (ip, Qp, mp, X)
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Sp, Kq, C), jnp.float32),
        interpret=interpret,
    )(*args)
    return out[:S]
