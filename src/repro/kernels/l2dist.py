"""Tiled distance-matrix Pallas kernel — the ANN hot spot (paper §4.1).

Computes D[i, j] = ||q_i - x_j||^2 (or -<q_i, x_j> for ip/cos) for a tile of
queries against a tile of database vectors with ONE MXU contraction per
(bq x bn) block plus rank-1 norm corrections.  This is the TPU mapping of
the paper's warp-per-distance scheme: the unit of work is a 128x128 MXU
block, not a 32-thread warp (DESIGN.md §2).

Grid: (Q/bq, N/bn).  Each block touches q-tile [bq, d] + x-tile [bn, d] in
VMEM and writes [bq, bn]; d is kept whole (d <= ~1024 fits VMEM: 128*1024*4B
= 512 KB per operand tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dist_kernel(q_ref, x_ref, o_ref, *, metric: str):
    q = q_ref[...].astype(jnp.float32)            # [bq, d]
    x = x_ref[...].astype(jnp.float32)            # [bn, d]
    dots = jax.lax.dot_general(q, x, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    if metric in ("ip", "cos"):
        o_ref[...] = -dots
    else:
        qn = jnp.sum(q * q, axis=1, keepdims=True)
        xn = jnp.sum(x * x, axis=1)
        o_ref[...] = qn + xn[None, :] - 2.0 * dots


@functools.partial(jax.jit,
                   static_argnames=("metric", "bq", "bn", "interpret"))
def distance_matrix_pallas(Q, X, *, metric: str = "l2", bq: int = 128,
                           bn: int = 128, interpret: bool = False):
    """[B, d] x [N, d] -> [B, N] float32 (smaller = closer)."""
    B, d = Q.shape
    N = X.shape[0]
    Bp = -(-B // bq) * bq
    Np = -(-N // bn) * bn
    Qp = jnp.pad(Q, ((0, Bp - B), (0, 0)))
    Xp = jnp.pad(X, ((0, Np - N), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_dist_kernel, metric=metric),
        grid=(Bp // bq, Np // bn),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Np), jnp.float32),
        interpret=interpret,
    )(Qp, Xp)
    return out[:B, :N]


# --------------------------------------------------------------------------
# batched-rowwise block distances — the search hot path's [S, W, d] shape
# --------------------------------------------------------------------------

def _block_kernel(q_ref, v_ref, m_ref, o_ref, *, metric: str):
    """Per-row distance block: q [bs, Kq, d] x v [bs, C, d] -> [bs, Kq, C],
    with the candidate keep-mask fused (masked lanes -> INF)."""
    q = q_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    m = m_ref[...]                                 # [bs, C] int8
    dots = jax.lax.dot_general(q, v, (((2,), (2,)), ((0,), (0,))),
                               preferred_element_type=jnp.float32)
    if metric in ("ip", "cos"):
        dist = -dots
    else:
        qn = jnp.sum(q * q, axis=2)[:, :, None]
        vn = jnp.sum(v * v, axis=2)[:, None, :]
        dist = qn + vn - 2.0 * dots
    o_ref[...] = jnp.where((m != 0)[:, None, :], dist,
                           jnp.asarray(3.4e38, dist.dtype))


def _pick_bs(Kq: int, C: int, d: int) -> int:
    """Largest power-of-two row tile whose operand+output blocks fit a VMEM
    budget (~4 MB, leaving room for double buffering)."""
    bs = 128
    while bs > 8 and bs * (Kq * d + C * d + Kq * C) * 4 > (4 << 20):
        bs //= 2
    return bs


@functools.partial(jax.jit, static_argnames=("metric", "bs", "interpret"))
def block_distances_pallas(Q, V, mask, *, metric: str = "l2",
                           bs: int | None = None, interpret: bool = False):
    """Q [S, Kq, d] x V [S, C, d] x mask [S, C] -> [S, Kq, C] float32.

    The hot primitive behind ``hotpath.neighbor_distances``: one fused
    tile per `bs` rows computes the MXU contraction, the rank-1 norm
    corrections, and the validity masking in a single VMEM-resident block.
    """
    S, Kq, d = Q.shape
    C = V.shape[1]
    if bs is None:
        bs = _pick_bs(Kq, C, d)
    Sp = -(-S // bs) * bs
    Qp = jnp.pad(Q, ((0, Sp - S), (0, 0), (0, 0)))
    Vp = jnp.pad(V, ((0, Sp - S), (0, 0), (0, 0)))
    mp = jnp.pad(mask.astype(jnp.int8), ((0, Sp - S), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_block_kernel, metric=metric),
        grid=(Sp // bs,),
        in_specs=[
            pl.BlockSpec((bs, Kq, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bs, C, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bs, C), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bs, Kq, C), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Sp, Kq, C), jnp.float32),
        interpret=interpret,
    )(Qp, Vp, mp)
    return out[:S]
