"""Bucketed open-addressing visited filter (CAGRA-style, DESIGN.md §10).

A per-query hash SET of already-visited node ids, consulted before
neighbor rows enter the candidate pool: ``search_small``/``search_large``
with ``visited_filter="hash"`` replace their per-hop full-width
dedup-by-id membership scans (O(width²) id comparisons through the
bitonic rank-merge) with W probes per candidate lane.

Layout: ``table`` [B, W, S] int32 — S buckets (power of two, last axis so
the TPU lane dimension does the probing) × W ways per bucket, ``EMPTY``
= -1 (node ids are always >= 0).  An id hashes to one bucket
(Fibonacci/Knuth multiplicative hash on the HIGH bits via a logical right
shift); membership is "any way equals id"; insertion takes the first
empty way.  A full bucket treats the id as already visited — a safe
*drop* (the search may rarely skip a revisit it would have re-pruned
anyway) and never a duplicate, which is what the downstream merges rely
on.  Tables are sized by :func:`repro.core.hotpath.visited_table` at load
factor <= 1/2, so overflow drops are rare.

Bitwise contract: everything here is int32 compare/select arithmetic, so
the Pallas kernel and the XLA reference (both driven through
:func:`lane_step`, one lane at a time in the caller-canonicalized order)
agree exactly — the parity harness extends over the filter unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

VF_EMPTY = -1  # node ids are >= 0 (plain int: kernels must not capture it)
# int32 wrap of Knuth's 2654435761 — multiplicative hashing wants the
# high bits, hence the logical (unsigned) right shift in hash_bucket
_GOLD = -1640531527


def shift_for(n_buckets: int) -> int:
    """Right-shift amount mapping a 32-bit hash onto [0, n_buckets)."""
    if n_buckets < 2 or n_buckets & (n_buckets - 1):
        raise ValueError(
            f"visited-filter bucket count must be a power of two >= 2, "
            f"got {n_buckets}")
    return 32 - (n_buckets.bit_length() - 1)


def hash_bucket(ids, shift: int):
    """[*, ] int32 ids -> bucket indices in [0, 2**(32-shift))."""
    return jax.lax.shift_right_logical(ids * jnp.int32(_GOLD), shift)


def lane_step(tab, lid, lval, *, shift: int):
    """Probe-and-insert ONE lane across the row batch.

    ``tab`` [B, W, S] int32, ``lid`` [B] int32, ``lval`` [B] bool ->
    ``(tab', fresh [B] bool)`` where ``fresh`` means: valid, not already
    present, and inserted (bucket had a free way).  Pure int32
    compare/select — the single formulation both backends execute, so
    they agree bitwise by construction.
    """
    B, W, S = tab.shape
    iota_s = jax.lax.broadcasted_iota(jnp.int32, (B, S), 1)
    iota_w = jax.lax.broadcasted_iota(jnp.int32, (B, W), 1)
    sel = iota_s == hash_bucket(lid, shift)[:, None]            # [B, S]
    in_bucket = (tab == lid[:, None, None]) & sel[:, None, :]
    hit = jnp.any(jnp.any(in_bucket, axis=2), axis=1)           # [B]
    emptyw = jnp.any((tab == jnp.int32(VF_EMPTY)) & sel[:, None, :], axis=2)
    slot = jnp.min(jnp.where(emptyw, iota_w, W), axis=1)        # first free
    fresh = lval & (~hit) & (slot < W)
    wmask = sel[:, None, :] & (iota_w == slot[:, None])[:, :, None] \
        & fresh[:, None, None]
    return jnp.where(wmask, lid[:, None, None], tab), fresh


def visited_filter_xla(table, ids, valid):
    """Reference path: lanes applied sequentially with ``lax.scan``."""
    shift = shift_for(table.shape[2])

    def lane(tab, xs):
        lid, lval = xs
        return lane_step(tab, lid, lval, shift=shift)

    table2, fresh_t = jax.lax.scan(lane, table, (ids.T, valid.T))
    return table2, fresh_t.T


def _vf_kernel(ids_ref, val_ref, tab_ref, tab_out, fresh_ref, *, shift):
    """One row-block: table resident in VMEM, lanes statically unrolled
    (M is a trace constant; per-lane work is a handful of [bs, W, S]
    compare/selects)."""
    tab = tab_ref[...]
    n_lanes = ids_ref.shape[1]
    for lane in range(n_lanes):
        lid = ids_ref[:, lane]
        lval = val_ref[:, lane] != 0
        tab, fresh = lane_step(tab, lid, lval, shift=shift)
        fresh_ref[:, lane] = fresh.astype(jnp.int32)
    tab_out[...] = tab


def visited_filter_pallas(table, ids, valid, *, interpret: bool = False):
    """Pallas path: grid over row blocks, the [bs, W, S] table block stays
    VMEM-resident across all lanes of the call (the XLA path re-streams it
    per scan step).  Same :func:`lane_step` arithmetic — bitwise the
    reference."""
    B, W, S = table.shape
    M = ids.shape[1]
    shift = shift_for(S)
    # block small enough that table + ids + masks sit comfortably in VMEM
    bs = 1
    while bs * 2 <= min(B, 8) and (2 * bs) * W * S * 4 <= (1 << 20):
        bs *= 2
    Bp = -(-B // bs) * bs
    if Bp != B:
        pad = ((0, Bp - B),)
        table = jnp.pad(table, pad + ((0, 0), (0, 0)),
                        constant_values=int(VF_EMPTY))
        ids = jnp.pad(ids, pad + ((0, 0),))
        valid = jnp.pad(valid, pad + ((0, 0),))
    table2, fresh = pl.pallas_call(
        functools.partial(_vf_kernel, shift=shift),
        grid=(Bp // bs,),
        in_specs=[pl.BlockSpec((bs, M), lambda i: (i, 0)),
                  pl.BlockSpec((bs, M), lambda i: (i, 0)),
                  pl.BlockSpec((bs, W, S), lambda i: (i, 0, 0))],
        out_specs=[pl.BlockSpec((bs, W, S), lambda i: (i, 0, 0)),
                   pl.BlockSpec((bs, M), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((Bp, W, S), jnp.int32),
                   jax.ShapeDtypeStruct((Bp, M), jnp.int32)],
        interpret=interpret,
    )(ids, valid.astype(jnp.int32), table)
    return table2[:B], fresh[:B] != 0
