"""Public jit'd wrappers: Pallas on TPU, interpret-mode on CPU, jnp ref as
the always-available fallback.  Model code calls these, never pallas_call."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import embedding_bag as _eb
from repro.kernels import flash_attention as _fa
from repro.kernels import l2dist as _l2dist
from repro.kernels import ref as _ref
from repro.kernels import segment_matmul as _sm
from repro.kernels import topk as _topk


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def distance_matrix(Q, X, *, metric: str = "l2", use_pallas: bool = True,
                    interpret: bool | None = None):
    """[B, d] x [N, d] -> [B, N]; smaller = closer."""
    if not use_pallas:
        return _ref.distance_matrix_ref(Q, X, metric=metric)
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _l2dist.distance_matrix_pallas(Q, X, metric=metric,
                                          interpret=interpret)


def bitonic_sort(dists, ids, *, use_pallas: bool = True,
                 interpret: bool | None = None):
    if not use_pallas:
        return _ref.sort_ref(dists, ids)
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _topk.bitonic_sort_pallas(dists, ids, interpret=interpret)


def bitonic_topk(dists, ids, k: int, *, use_pallas: bool = True,
                 interpret: bool | None = None):
    if not use_pallas:
        return _ref.topk_ref(dists, ids, k)
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _topk.bitonic_topk_pallas(dists, ids, k, interpret=interpret)


def flash_attention(q, k, v, *, window: int = 0, q_offset: int = 0,
                    use_pallas: bool = True, interpret: bool | None = None):
    if not use_pallas:
        return _ref.attention_ref(q, k, v, window=window, q_offset=q_offset)
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _fa.flash_attention_pallas(q, k, v, window=window,
                                      q_offset=q_offset, interpret=interpret)


def embedding_bag(table, ids, *, combine: str = "mean",
                  use_pallas: bool = True, interpret: bool | None = None):
    if not use_pallas:
        return _ref.embedding_bag_ref(table, ids, combine=combine)
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _eb.embedding_bag_pallas(table, ids, combine=combine,
                                    interpret=interpret)


def packed_spmm(neighbors, feat, w, *, combine: str = "sum",
                use_pallas: bool = True, interpret: bool | None = None):
    if not use_pallas:
        import jax.numpy as _jnp

        Nf = feat.shape[0]
        ok = neighbors < Nf
        rows = feat[_jnp.clip(neighbors, 0, Nf - 1)]
        rows = _jnp.where(ok[..., None], rows, 0.0)
        agg = rows.sum(1)
        if combine == "mean":
            agg = agg / _jnp.maximum(ok.sum(1, keepdims=True), 1)
        return agg @ w
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _sm.packed_spmm_pallas(neighbors, feat, w, combine=combine,
                                  interpret=interpret)
