"""FlashAttention Pallas kernel: causal + sliding-window, GQA.

Grid: (batch, q_heads, Sq/bq).  The q tile [bq, hd] stays in VMEM; the
kernel streams KV in bkv-chunks with pl.ds loads, maintaining the running
(max, sum, acc) online-softmax state in fp32.  GQA is expressed in the KV
BlockSpec index map (kv head = q head // group), so no KV duplication ever
materializes.  Window/causal masking prunes whole KV chunks via the loop
bounds (the FLOP savings gemma3's 5:1 local layers rely on).

Oracle: repro.kernels.ref.attention_ref (== models.layers.chunked_attention).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bkv: int,
                  skv: int, window: int, q_offset: int, scale: float):
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale   # [bq, hd]
    hd = q.shape[-1]
    q_pos = q_offset + qi * bq + jax.lax.iota(jnp.int32, bq)

    # causal upper bound: last kv chunk any row of this q tile can see
    hi = jnp.minimum((q_offset + (qi + 1) * bq + bkv - 1) // bkv,
                     skv // bkv)
    lo = jnp.int32(0)
    if window > 0:  # static python check — window is a per-layer constant
        lo = jnp.maximum(lo, (q_offset + qi * bq - window + 1) // bkv)

    def body(c, carry):
        m_run, l_run, acc = carry
        start = c * bkv
        k = k_ref[0, 0, pl.ds(start, bkv), :]
        v = v_ref[0, 0, pl.ds(start, bkv), :]
        s = jax.lax.dot_general(q, k.astype(jnp.float32),
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        k_pos = start + jax.lax.iota(jnp.int32, bkv)
        mask = k_pos[None, :] <= q_pos[:, None]
        if window > 0:
            mask &= k_pos[None, :] > (q_pos[:, None] - window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=1)
        pv = jax.lax.dot_general(p, v.astype(jnp.float32),
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc = acc * corr[:, None] + pv
        return m_new, l_new, acc

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "q_offset", "bq", "bkv",
                                    "interpret"))
def flash_attention_pallas(q, k, v, *, window: int = 0, q_offset: int = 0,
                           bq: int = 128, bkv: int = 128,
                           interpret: bool = False):
    """q [B, Sq, H, hd]; k/v [B, Skv, KV, hd]; H = KV * G. Causal.

    Returns [B, Sq, H, hd] in q.dtype.
    """
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = hd ** -0.5
    bq = min(bq, Sq)
    bkv = min(bkv, Skv)
    assert Sq % bq == 0 and Skv % bkv == 0, (Sq, bq, Skv, bkv)

    qt = jnp.moveaxis(q, 2, 1)                    # [B, H, Sq, hd]
    kt = jnp.moveaxis(k, 2, 1)                    # [B, KV, Skv, hd]
    vt = jnp.moveaxis(v, 2, 1)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bkv=bkv, skv=Skv,
                          window=window, q_offset=q_offset, scale=scale),
        grid=(B, H, Sq // bq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Skv, hd), lambda b, h, i: (b, h // G, 0, 0)),
            pl.BlockSpec((1, 1, Skv, hd), lambda b, h, i: (b, h // G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out, 1, 2)
