"""EmbeddingBag Pallas kernel — the recsys hot path (taxonomy §RecSys).

JAX has no nn.EmbeddingBag; the jnp path is take + mean (ref.py).  This
kernel keeps the table in HBM (`pl.ANY` memory space — 10^6-10^9 rows never
fit VMEM) and DMA-gathers the `bag` rows of each lookup into VMEM, reducing
on the fly.  Grid: one bag-tile per step; ids tile is VMEM-resident.

TPU-target note: production TBE kernels double-buffer the row DMAs
(async_copy + semaphores) to hide HBM latency behind the reduce; the
sequential fori_loop here is the portable core validated in interpret mode,
with the DMA schedule left to Mosaic's automatic pipelining.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bag_kernel(ids_ref, table_ref, o_ref, *, bag: int, rows: int,
                combine: str):
    E = o_ref.shape[-1]

    def one_row(r, _):
        acc0 = jnp.zeros((E,), jnp.float32)

        def body(t, acc):
            rid = ids_ref[r, t]
            row = table_ref[pl.ds(rid, 1), :]
            return acc + row[0].astype(jnp.float32)

        acc = jax.lax.fori_loop(0, bag, body, acc0)
        if combine == "mean":
            acc = acc / bag
        o_ref[r, :] = acc.astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, rows, one_row, 0)


@functools.partial(jax.jit,
                   static_argnames=("combine", "br", "interpret"))
def embedding_bag_pallas(table, ids, *, combine: str = "mean", br: int = 8,
                         interpret: bool = False):
    """table [V, E]; ids [B, bag] -> [B, E]."""
    B, bag = ids.shape
    V, E = table.shape
    Bp = -(-B // br) * br
    idp = jnp.pad(ids, ((0, Bp - B), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_bag_kernel, bag=bag, rows=br, combine=combine),
        grid=(Bp // br,),
        in_specs=[
            pl.BlockSpec((br, bag), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),    # table stays in HBM
        ],
        out_specs=pl.BlockSpec((br, E), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, E), table.dtype),
        interpret=interpret,
    )(idp, table)
    return out[:B]
