"""Pallas TPU kernels (validated in interpret mode on CPU; ops.py wrappers
fall back to the jnp ref path off-TPU)."""
from repro.kernels import ops, ref  # noqa: F401
