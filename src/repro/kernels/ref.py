"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import metrics as M


def distance_matrix_ref(Q, X, *, metric: str = "l2"):
    return M.pairwise(Q.astype(jnp.float32), X.astype(jnp.float32), metric)


def sort_ref(dists, ids):
    """Row-wise ascending (dist, id) lexicographic sort."""
    order = jnp.lexsort((ids, dists), axis=1)
    return (jnp.take_along_axis(dists, order, axis=1),
            jnp.take_along_axis(ids, order, axis=1))


def topk_ref(dists, ids, k: int):
    sd, si = sort_ref(dists, ids)
    return sd[:, :k], si[:, :k]


def attention_ref(q, k, v, *, window: int = 0, q_offset: int = 0):
    """Exact softmax attention (fp32), causal + optional window, GQA."""
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = hd ** -0.5
    qf = q.astype(jnp.float32).reshape(B, Sq, KV, G, hd) * scale
    s = jnp.einsum("bqkgh,bskh->bkgqs", qf, k.astype(jnp.float32))
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Skv)
    mask = k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= k_pos[None, :] > (q_pos[:, None] - window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def embedding_bag_ref(table, ids, *, combine: str = "mean"):
    emb = jnp.take(table, ids, axis=0)
    return emb.sum(-2) if combine == "sum" else emb.mean(-2)


def segment_matmul_ref(feat, src, dst, w, n_nodes: int):
    """GNN gather-GEMM-scatter: sum_{e: dst=i} (feat[src_e] @ w)."""
    msg = feat[src] @ w
    return jax.ops.segment_sum(msg, dst, n_nodes)
