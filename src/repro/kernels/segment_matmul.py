"""Fixed-degree SpMM Pallas kernel — GNN message passing over the packed
adjacency (taxonomy §GNN, GE-SpMM-style gather-GEMM-scatter).

Exploits the same [N, M] fixed-degree neighbor layout the ANN core uses: the
scatter disappears (each output row owns its gather list), so the kernel is
gather -> masked reduce -> MXU GEMM per node tile.  Features live in HBM
(`pl.ANY`) and rows are DMA-gathered; the weight tile is VMEM-resident.

out[i] = (Σ_{j < M, nbrs[i,j] < N} feat[nbrs[i, j]]) @ W   (sum | mean)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmm_kernel(nbr_ref, feat_ref, w_ref, o_ref, *, deg: int, rows: int,
                 n_valid: int, combine: str):
    d = feat_ref.shape[-1]

    def one_row(r, _):
        acc0 = jnp.zeros((d,), jnp.float32)
        cnt0 = jnp.zeros((), jnp.float32)

        def body(t, carry):
            acc, cnt = carry
            rid = nbr_ref[r, t]
            ok = rid < n_valid
            safe = jnp.where(ok, rid, 0)
            row = feat_ref[pl.ds(safe, 1), :][0].astype(jnp.float32)
            row = jnp.where(ok, row, 0.0)
            return acc + row, cnt + ok.astype(jnp.float32)

        acc, cnt = jax.lax.fori_loop(0, deg, body, (acc0, cnt0))
        if combine == "mean":
            acc = acc / jnp.maximum(cnt, 1.0)
        o_ref[r, :] = jax.lax.dot_general(
            acc[None, :], w_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[0].astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, rows, one_row, 0)


@functools.partial(jax.jit,
                   static_argnames=("combine", "br", "interpret"))
def packed_spmm_pallas(neighbors, feat, w, *, combine: str = "sum",
                       br: int = 8, interpret: bool = False):
    """neighbors [N, M] (sentinel >= n_feat rows); feat [Nf, d]; w [d, f]."""
    N, M = neighbors.shape
    Nf, d = feat.shape
    f = w.shape[1]
    Np = -(-N // br) * br
    nb = jnp.pad(neighbors, ((0, Np - N), (0, 0)),
                 constant_values=Nf)
    out = pl.pallas_call(
        functools.partial(_spmm_kernel, deg=M, rows=br, n_valid=Nf,
                          combine=combine),
        grid=(Np // br,),
        in_specs=[
            pl.BlockSpec((br, M), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),    # features stay in HBM
            pl.BlockSpec((d, f), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, f), feat.dtype),
        interpret=interpret,
    )(nb, feat, w)
    return out[:N]
