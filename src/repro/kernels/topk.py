"""Bitonic sort / top-k Pallas kernel (paper §4.1 R_ij merge).

Batcher's bitonic network [3] is data-oblivious: every compare-exchange
stage is a fixed permutation + vectorized select, which maps 1:1 onto TPU
vector lanes (the paper runs the same network on a warp).  We sort a fixed
power-of-two window per row, carrying ids alongside distances.

Grid: (rows/br,).  Block [br, W]; the full network is log2(W)(log2(W)+1)/2
unrolled stages, all in VMEM/registers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

INF = jnp.float32(3.4e38)
# column-padding id sentinel: sorts after every real id (incl. the graph's
# own sentinel N) among equal-INF entries, so padded lanes never displace
# real entries within the kept prefix
PAD_ID = np.int32(2**31 - 1)


def _bitonic_network(d, ids, width: int):
    """Bitonic network via reshape compare-exchange (no gathers, no captured
    constants — Pallas/Mosaic-safe: reshapes, iota, selects only).  Sorts
    rows ascending by (dist, id) — the same total order as
    ``lexsort((ids, dists))``, which is what keeps the XLA backend of
    :mod:`repro.core.hotpath` bit-identical to this kernel."""
    br = d.shape[0]
    k = 2
    while k <= width:
        j = k // 2
        while j >= 1:
            nblk = width // (2 * j)
            d2 = d.reshape(br, nblk, 2, j)
            i2 = ids.reshape(br, nblk, 2, j)
            a_d, b_d = d2[:, :, 0], d2[:, :, 1]   # partner pairs (xor j)
            a_i, b_i = i2[:, :, 0], i2[:, :, 1]
            # direction: ascending iff (position & k) == 0; constant across a
            # 2j-block because 2j <= k
            blk = jax.lax.iota(jnp.int32, nblk)
            asc = ((blk * (2 * j)) & k) == 0      # [nblk]
            asc = asc[None, :, None]
            a_smaller = (a_d < b_d) | ((a_d == b_d) & (a_i < b_i))
            a_first = jnp.where(asc, a_smaller, ~a_smaller)
            new_a_d = jnp.where(a_first, a_d, b_d)
            new_b_d = jnp.where(a_first, b_d, a_d)
            new_a_i = jnp.where(a_first, a_i, b_i)
            new_b_i = jnp.where(a_first, b_i, a_i)
            d = jnp.stack([new_a_d, new_b_d], axis=2).reshape(br, width)
            ids = jnp.stack([new_a_i, new_b_i], axis=2).reshape(br, width)
            j //= 2
        k *= 2
    return d, ids


def _sort_kernel(d_ref, i_ref, od_ref, oi_ref, *, width: int):
    od_ref[...], oi_ref[...] = _bitonic_network(d_ref[...], i_ref[...],
                                                width)


def _masked_sort_kernel(d_ref, i_ref, m_ref, od_ref, oi_ref, *, width: int):
    """Keep-mask fused into the sort: dropped lanes get INF distance (their
    ids are kept, matching the XLA reference path exactly)."""
    d = d_ref[...]
    d = jnp.where(m_ref[...] != 0, d, jnp.asarray(3.4e38, d.dtype))
    od_ref[...], oi_ref[...] = _bitonic_network(d, i_ref[...], width)


@functools.partial(jax.jit, static_argnames=("br", "interpret"))
def bitonic_sort_pallas(dists, ids, *, br: int = 64,
                        interpret: bool = False):
    """Row-wise ascending sort of (dists [R, W], ids [R, W]); W power of 2."""
    R, W = dists.shape
    assert W & (W - 1) == 0, f"width {W} must be a power of two"
    Rp = -(-R // br) * br
    dp = jnp.pad(dists, ((0, Rp - R), (0, 0)), constant_values=INF)
    ip = jnp.pad(ids, ((0, Rp - R), (0, 0)))
    od, oi = pl.pallas_call(
        functools.partial(_sort_kernel, width=W),
        grid=(Rp // br,),
        in_specs=[pl.BlockSpec((br, W), lambda i: (i, 0)),
                  pl.BlockSpec((br, W), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((br, W), lambda i: (i, 0)),
                   pl.BlockSpec((br, W), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((Rp, W), dists.dtype),
                   jax.ShapeDtypeStruct((Rp, W), ids.dtype)],
        interpret=interpret,
    )(dp, ip)
    return od[:R], oi[:R]


def bitonic_topk_pallas(dists, ids, k: int, **kw):
    od, oi = bitonic_sort_pallas(dists, ids, **kw)
    return od[:, :k], oi[:, :k]


@functools.partial(jax.jit, static_argnames=("keep", "br", "interpret"))
def rank_merge_pallas(dists, ids, mask=None, *, keep: int, br: int = 64,
                      interpret: bool = False):
    """Row-wise (dist, id)-ascending merge: sort [R, W] carrying ids, keep
    the `keep` smallest per row.  Generalizes :func:`bitonic_sort_pallas` to
    arbitrary widths (column-padded to the next power of two with
    (INF, PAD_ID) lanes) and an optional keep-mask (masked lanes -> INF
    distance, fused into the kernel)."""
    R, W = dists.shape
    if not 0 < keep <= W:
        raise ValueError(f"keep={keep} must be in (0, {W}]")
    Wp = 1 << max(W - 1, 0).bit_length()
    Rp = -(-R // br) * br
    dp = jnp.pad(dists, ((0, Rp - R), (0, Wp - W)), constant_values=INF)
    ip = jnp.pad(ids, ((0, Rp - R), (0, Wp - W)), constant_values=PAD_ID)
    spec = pl.BlockSpec((br, Wp), lambda i: (i, 0))
    out_shape = [jax.ShapeDtypeStruct((Rp, Wp), dists.dtype),
                 jax.ShapeDtypeStruct((Rp, Wp), ids.dtype)]
    if mask is None:
        od, oi = pl.pallas_call(
            functools.partial(_sort_kernel, width=Wp),
            grid=(Rp // br,), in_specs=[spec, spec],
            out_specs=[spec, spec], out_shape=out_shape,
            interpret=interpret)(dp, ip)
    else:
        mp = jnp.pad(mask.astype(jnp.int8), ((0, Rp - R), (0, Wp - W)))
        od, oi = pl.pallas_call(
            functools.partial(_masked_sort_kernel, width=Wp),
            grid=(Rp // br,), in_specs=[spec, spec, spec],
            out_specs=[spec, spec], out_shape=out_shape,
            interpret=interpret)(dp, ip, mp)
    return od[:R, :keep], oi[:R, :keep]
