"""Versioned on-disk index artifact with a persistent AOT serving cache.

Layout (a directory)::

    <path>/
      manifest.json       magic, format version, ANNConfig, k, fingerprint,
                          sha256 integrity hashes for every payload file
      arrays.npz          X + packed graph (neighbors/lambdas/degrees[/hubs])
      aot/<regime>_b<bucket>_k<k>.jaxexp
                          jax.export-serialized serving modules, one per
                          warmup-reachable (regime, bucket, k) cache entry

The AOT blobs are exported with the database and graph as *runtime
arguments* (never embedded constants), so each is a few tens of KB
regardless of index size.  :func:`load_index` closes the deserialized
modules back over the restored device arrays, compiles them once, and
primes the engine's compile cache — a restarted process skips both the
graph rebuild *and* the warmup compile sweep, and `ServeStats.compiles`
stays 0 (ROADMAP "AOT cache persistence").

Safety gates:

* ``magic`` / ``format_version`` mismatch  -> :class:`ArtifactError`;
* any sha256 mismatch (corruption)         -> :class:`ArtifactError`;
* runtime fingerprint mismatch (different jax version, platform, device
  kind, kernel backend, or gather mode) -> the index still loads, but the
  AOT cache is *skipped* with a warning and the engine recompiles on
  demand — stale executables are never served.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import warnings
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ANNConfig
from repro.core.diversify import PackedGraph

FORMAT_VERSION = 1
MAGIC = "repro-ann-index"
_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"
# fields that must match for persisted executables to be trusted
_FP_KEYS = ("jax", "platform", "device_kind", "kernel_backend",
            "gather_fused")


class ArtifactError(RuntimeError):
    """Unusable index artifact (bad magic/version, corruption)."""


def _sha256(path: Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def runtime_fingerprint(engine) -> dict:
    """What the AOT executables were lowered against.  Compared on load;
    any `_FP_KEYS` difference falls back to on-demand recompilation."""
    dev = jax.devices()[0]
    return {
        "jax": jax.__version__,
        "platform": jax.default_backend(),
        "device_kind": dev.device_kind,
        "n_devices": jax.device_count(),
        "kernel_backend": engine.backend,
        "gather_fused": engine.gather_fused,
    }


def _config_to_dict(cfg: ANNConfig) -> dict:
    return dataclasses.asdict(cfg)


def _config_from_dict(d: dict) -> ANNConfig:
    """Rebuild ANNConfig from manifest JSON; tuple fields arrive as lists.
    Unknown keys (written by a newer minor revision) are dropped with a
    warning rather than rejected — the format version gates real breaks."""
    fields = {f.name: f for f in dataclasses.fields(ANNConfig)}
    kwargs, unknown = {}, []
    for name, val in d.items():
        if name not in fields:
            unknown.append(name)
            continue
        kwargs[name] = tuple(val) if isinstance(val, list) else val
    if unknown:
        warnings.warn(f"index artifact config has unknown fields {unknown}; "
                      "ignored", stacklevel=3)
    return ANNConfig(**kwargs)


# --------------------------------------------------------------------------
# save
# --------------------------------------------------------------------------

def save_index(index, path, *, aot: bool = True) -> Path:
    """Write ``index`` to ``path`` (a directory, created if needed).

    With ``aot=True`` every warmup-reachable (regime, bucket, k) serving
    executable is exported alongside the graph, so :func:`load_index` can
    skip the warmup compile sweep entirely.  Entries whose export fails
    (e.g. an interpret-mode Pallas backend that cannot serialize) are
    skipped with a warning — the artifact stays loadable, load just
    recompiles those on demand.
    """
    eng = index.engine
    if eng.mesh is not None:
        raise ArtifactError(
            "mesh-sharded indexes cannot be saved yet (the sharded "
            "sub-index layout has no serialized form)")
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)

    g = eng.graph
    arrays = {"X": np.asarray(eng.X), "neighbors": np.asarray(g.neighbors),
              "lambdas": np.asarray(g.lambdas),
              "degrees": np.asarray(g.degrees)}
    if g.hubs is not None:
        arrays["hubs"] = np.asarray(g.hubs)
    np.savez(path / _ARRAYS, **arrays)

    aot_entries = []
    if aot:
        (path / "aot").mkdir(exist_ok=True)
        # warmup_probes() already dedups (regime, bucket); mesh rounding
        # can't perturb the bucket here because mesh saves are rejected
        for kind, bucket, _ in eng.warmup_probes():
            try:
                blob = eng.export_executable(kind, bucket, k=index.k)
            except Exception as e:  # noqa: BLE001 — degrade, don't fail save
                warnings.warn(
                    f"AOT export skipped for {kind}/b{bucket}/k{index.k}: "
                    f"{e!r} (load will recompile this entry)", stacklevel=2)
                continue
            fname = f"aot/{kind}_b{bucket}_k{index.k}.jaxexp"
            (path / fname).write_bytes(blob)
            aot_entries.append({
                "kind": kind, "bucket": bucket, "k": index.k,
                "file": fname, "sha256": _sha256(path / fname)})

    manifest = {
        "magic": MAGIC,
        "format_version": FORMAT_VERSION,
        "config": _config_to_dict(eng.cfg),
        "k": index.k,
        "fingerprint": runtime_fingerprint(eng),
        "arrays": {"file": _ARRAYS, "sha256": _sha256(path / _ARRAYS)},
        "aot": aot_entries,
    }
    (path / _MANIFEST).write_text(json.dumps(manifest, indent=2))
    return path


# --------------------------------------------------------------------------
# load
# --------------------------------------------------------------------------

def _compile_exported(eng, exported, bucket: int):
    """Close a deserialized module over the engine's device arrays and
    compile it back into the single-donated-argument executable form the
    compile cache expects."""
    parts = eng.aot_operands()
    Qspec = jax.ShapeDtypeStruct((bucket, eng.X.shape[1]), jnp.float32)
    donate = (0,) if eng._donate else ()
    fn = jax.jit(lambda Qb: exported.call(*parts, Qb),
                 donate_argnums=donate)
    return fn.lower(Qspec).compile()


def load_index(index_cls, path):
    """Restore an `Index` saved by :func:`save_index`.  See the module
    docstring for the verification/fallback contract."""
    path = Path(path)
    mpath = path / _MANIFEST
    if not mpath.is_file():
        raise ArtifactError(f"{path} is not an index artifact "
                            f"(missing {_MANIFEST})")
    try:
        manifest = json.loads(mpath.read_text())
    except ValueError as e:
        raise ArtifactError(f"corrupt manifest in {path}: {e}") from e
    if manifest.get("magic") != MAGIC:
        raise ArtifactError(f"{path} is not a {MAGIC} artifact")
    ver = manifest.get("format_version")
    if ver != FORMAT_VERSION:
        raise ArtifactError(
            f"unsupported index artifact version {ver!r} "
            f"(this build reads version {FORMAT_VERSION})")

    apath = path / manifest["arrays"]["file"]
    if not apath.is_file():
        raise ArtifactError(f"missing payload {apath.name}")
    if _sha256(apath) != manifest["arrays"]["sha256"]:
        raise ArtifactError(f"corrupt artifact: checksum mismatch in "
                            f"{apath.name}")
    with np.load(apath) as arrs:
        X = arrs["X"]
        graph = PackedGraph(
            neighbors=jnp.asarray(arrs["neighbors"]),
            lambdas=jnp.asarray(arrs["lambdas"]),
            degrees=jnp.asarray(arrs["degrees"]),
            hubs=jnp.asarray(arrs["hubs"]) if "hubs" in arrs else None)

    cfg = _config_from_dict(manifest["config"])
    index = index_cls(X, cfg, k=manifest["k"], graph=graph)

    entries = manifest.get("aot", ())
    if not entries:
        return index
    eng = index.engine
    saved_fp = manifest.get("fingerprint", {})
    now_fp = runtime_fingerprint(eng)
    stale = [f for f in _FP_KEYS if saved_fp.get(f) != now_fp.get(f)]
    if stale:
        warnings.warn(
            "AOT serving cache skipped — fingerprint mismatch on "
            + ", ".join(f"{f} ({saved_fp.get(f)!r} -> {now_fp.get(f)!r})"
                        for f in stale)
            + "; the engine will recompile on demand", stacklevel=3)
        return index

    from jax import export as jax_export
    for e in entries:
        bpath = path / e["file"]
        if not bpath.is_file():
            raise ArtifactError(f"missing AOT payload {e['file']}")
        if _sha256(bpath) != e["sha256"]:
            raise ArtifactError(
                f"corrupt artifact: checksum mismatch in {e['file']}")
        exported = jax_export.deserialize(bpath.read_bytes())
        exe = _compile_exported(eng, exported, e["bucket"])
        eng.prime_executable(e["kind"], e["bucket"], e["k"], exe)
    return index
