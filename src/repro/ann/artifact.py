"""Versioned on-disk index artifact with a persistent AOT serving cache.

Layout (a directory)::

    <path>/
      manifest.json       magic, format version, execution plane, ANNConfig,
                          k, runtime fingerprint, mesh topology (sharded),
                          calibrated regime threshold, sha256 per payload
      arrays.npz          single plane: X + packed graph
                          (neighbors/lambdas/degrees[/hubs])
      arrays/<i>.npz      mesh plane: shard-major layout — DB shard i's
                          slice of X and its OWN sub-index, one file +
                          checksum per shard (shards stream/verify
                          independently at pod scale)
      streaming.npz       format v3, only when un-compacted mutations exist:
                          tombstone bitmap (np.packbits of the base alive
                          mask) + the delta shard's assigned rows/alive
                          flags (DESIGN.md §7) — capacity padding is NOT
                          persisted, load re-pads
      aot/<regime>_b<bucket>_k<k>.jaxexp
                          jax.export-serialized serving modules, one per
                          saved (regime, bucket, k) cache entry

Format v3 adds the ``generation`` manifest field (completed compactions)
and the optional ``streaming`` payload; v1/v2 artifacts still load (they
are simply frozen indexes at generation 0).  AOT blobs persist only the
frozen serving form — streaming executables are cheap shape-variants
recompiled on demand after a load restores the mutation state.

Format v4 persists the compressed-residency payload (DESIGN.md §8): when
the index was built with ``cfg.quantization="int8"``, the arrays carry the
per-row int8 ``codes`` + fp32 ``scales`` alongside the fp32 database, so
``load`` re-binds them directly instead of re-quantizing.  v1–v3 artifacts
(or a v4 artifact saved with quantization off) simply lack the keys; a
quantized config loading one derives the codes at plane install.

Format v5 persists the locality layout (DESIGN.md §10): when the index was
built with the "layout" pipeline stage, the database rows (and any int8
codes) are stored in PACKED order with the ``perm`` array (per shard-local
on a mesh artifact) alongside, so ``load`` re-binds the packed operands
directly.  The rebuild fallbacks (reshard, gather) un-permute back to
external row order first so saved external ids — including streaming
tombstones — stay valid.  v1–v4 artifacts simply lack the key.

The AOT blobs are exported with the database and graph as *runtime
arguments* (never embedded constants), so each is a few tens of KB
regardless of index size.  :func:`load_index` closes the deserialized
modules back over the restored device arrays, compiles them once, and
primes the engine's compile cache — a restarted process skips both the
graph rebuild *and* the warmup compile sweep, and `ServeStats.compiles`
stays 0 (ROADMAP "AOT cache persistence").  Both planes persist: a mesh
artifact's modules record the operand shardings and logical device count,
and re-bind onto a mesh of identical topology.

Safety gates:

* ``magic`` / unknown ``format_version``    -> :class:`ArtifactError`;
* any sha256 mismatch (corruption)          -> :class:`ArtifactError`;
* runtime fingerprint mismatch (different jax version, platform, device
  kind, kernel backend, gather mode, or execution plane) -> the index
  still loads, but the AOT cache is *skipped* with a warning and the
  engine recompiles on demand — stale executables are never served;
* topology mismatch (sharded artifact onto a mesh with a different DB
  shard count, a mesh artifact without ``mesh=``, or a single-device
  artifact onto a mesh) -> gather-and-reshard fallback with a warning:
  the database is gathered from the shards and the sub-indexes are
  REBUILT for the requested layout (per-shard sub-indexes are only valid
  for the shard cut they were built on), AOT cache skipped.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import warnings
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ANNConfig
from repro.core.diversify import PackedGraph

FORMAT_VERSION = 5
# still-readable older revisions (1 = pre-plane single-device layout,
# 2 = pre-streaming: no generation counter / streaming payload,
# 3 = pre-quantization: no persisted int8 codes/scales,
# 4 = pre-layout: no locality permutation — rows are in external order)
READ_VERSIONS = (1, 2, 3, 4, 5)
MAGIC = "repro-ann-index"
_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"
_STREAMING = "streaming.npz"
_GRAPH_KEYS = ("neighbors", "lambdas", "degrees")
# fields that must match for persisted executables to be trusted
_FP_KEYS = ("jax", "platform", "device_kind", "kernel_backend",
            "gather_fused", "plane", "quantization", "layout",
            "visited_filter")


class ArtifactError(RuntimeError):
    """Unusable index artifact (bad magic/version, corruption)."""


def _sha256(path: Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def runtime_fingerprint(engine) -> dict:
    """What the AOT executables were lowered against (owned by the
    execution plane; kept as a wrapper for older callers).  Compared on
    load; any `_FP_KEYS` difference falls back to on-demand
    recompilation."""
    return engine.plane.fingerprint()


def _config_to_dict(cfg: ANNConfig) -> dict:
    return dataclasses.asdict(cfg)


def _config_from_dict(d: dict) -> ANNConfig:
    """Rebuild ANNConfig from manifest JSON; tuple fields arrive as lists.
    Unknown keys (written by a newer minor revision) are dropped with a
    warning rather than rejected — the format version gates real breaks."""
    fields = {f.name: f for f in dataclasses.fields(ANNConfig)}
    kwargs, unknown = {}, []
    for name, val in d.items():
        if name not in fields:
            unknown.append(name)
            continue
        kwargs[name] = tuple(val) if isinstance(val, list) else val
    if unknown:
        warnings.warn(f"index artifact config has unknown fields {unknown}; "
                      "ignored", stacklevel=3)
    return ANNConfig(**kwargs)


# --------------------------------------------------------------------------
# save
# --------------------------------------------------------------------------

def _to_host(a) -> np.ndarray:
    """Host copy of an operand.  A pod plane's row-sharded operands span
    devices other processes own; gather them with an all-gather collective
    (every process ends up with the full array — save runs SPMD)."""
    if isinstance(a, jax.Array) and not a.is_fully_addressable:
        from jax.experimental import multihost_utils
        return np.asarray(multihost_utils.process_allgather(a, tiled=True))
    return np.asarray(a)


def _shard_arrays(eng) -> list:
    """Gather the mesh/pod plane's operands to host and cut them
    shard-major: one dict per DB shard holding its X slice and its own
    sub-index.  The build laid every row-sharded operand out as the
    concatenation of the shard-local results (shard_map out_specs), so
    equal row slices ARE the per-shard arrays."""
    plane = eng.plane
    n_shards = plane.n_db_shards
    full = {"X": _to_host(plane.X)}
    g = plane.graph
    full["neighbors"] = _to_host(g.neighbors)
    full["lambdas"] = _to_host(g.lambdas)
    full["degrees"] = _to_host(g.degrees)
    full["hubs"] = (_to_host(g.hubs) if g.hubs is not None
                    else np.zeros((0,), np.int32))
    if getattr(plane, "quantized", False):
        # operand order is (X, nbrs, lams, degs, hubs, codes, scales[, perm])
        ops = plane.operands()
        full["codes"] = _to_host(ops[5])
        full["scales"] = _to_host(ops[6])
    if getattr(g, "perm", None) is not None:
        # v5 locality layout: rows are stored in PACKED order, with the
        # per-shard-local permutation alongside so load can re-bind (or
        # un-permute for a reshard fallback) without re-running the BFS
        full["perm"] = _to_host(g.perm)
    shards = []
    for i in range(n_shards):
        shard = {}
        for name, arr in full.items():
            n_local = arr.shape[0] // n_shards
            shard[name] = arr[i * n_local:(i + 1) * n_local]
        shards.append(shard)
    return shards


def save_index(index, path, *, aot: bool = True, extra_ks=()) -> Path:
    """Write ``index`` to ``path`` (a directory, created if needed).

    With ``aot=True`` every warmup-reachable (regime, bucket) serving
    executable is exported alongside the graph — for the index's default
    ``k`` and for every ``k`` in ``extra_ks`` — so :func:`load_index` can
    skip the warmup compile sweep entirely and additionally serve those
    extra ``k`` values steady-state from the first request.  Entries whose
    export fails (e.g. an interpret-mode Pallas backend that cannot
    serialize) are skipped with a warning — the artifact stays loadable,
    load just recompiles those on demand.
    """
    eng = index.engine
    plane = eng.plane
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)

    ks = sorted({index.k, *extra_ks})
    probes = eng.warmup_probes()
    for k in ks:  # fail fast, before any bytes hit disk
        for kind in {p[0] for p in probes}:
            eng._validate_k(k, kind)

    manifest = {
        "magic": MAGIC,
        "format_version": FORMAT_VERSION,
        "plane": plane.name,
        "config": _config_to_dict(eng.cfg),
        "k": index.k,
        "fingerprint": plane.fingerprint(),
        "calibrated_threshold": eng.threshold,
        "generation": int(eng.stats.generation),
    }

    # un-compacted mutations (DESIGN.md §7): tombstone bitmap + the delta
    # shard's assigned rows.  Saved OUTSIDE arrays.npz so the base payload
    # stays byte-stable across pure-streaming saves of one generation.
    # On a multi-process pod every process runs save_index SPMD (the shard
    # gather below is a collective), but only process 0 touches the disk —
    # the others rendezvous at the barrier before returning.
    pid = jax.process_index()

    stream = getattr(eng, "stream", None)
    if stream is not None and stream.dirty and pid == 0:
        count = stream.delta.count
        np.savez(path / _STREAMING,
                 alive_bits=np.packbits(stream.base_alive),
                 n_base=np.int64(stream.n_base),
                 delta_X=stream.delta.X[:count],
                 delta_alive=stream.delta.alive[:count])
        manifest["streaming"] = {"file": _STREAMING,
                                 "sha256": _sha256(path / _STREAMING)}

    if plane.name in ("mesh", "pod"):
        manifest["topology"] = plane.topology()
        shards = _shard_arrays(eng)  # collective on pod: run on ALL processes
        if pid == 0:
            (path / "arrays").mkdir(exist_ok=True)
            entries = []
            for i, shard in enumerate(shards):
                fname = f"arrays/{i}.npz"
                np.savez(path / fname, **shard)
                entries.append({"file": fname,
                                "sha256": _sha256(path / fname)})
            manifest["arrays"] = entries
    elif pid == 0:
        g = eng.graph
        arrays = {"X": np.asarray(eng.X),
                  "neighbors": np.asarray(g.neighbors),
                  "lambdas": np.asarray(g.lambdas),
                  "degrees": np.asarray(g.degrees)}
        if g.hubs is not None:
            arrays["hubs"] = np.asarray(g.hubs)
        if getattr(plane, "quantized", False):
            arrays["codes"] = np.asarray(plane.codes)
            arrays["scales"] = np.asarray(plane.scales)
        if getattr(g, "perm", None) is not None:
            # v5: X/codes rows are in packed order; perm restores external
            arrays["perm"] = np.asarray(g.perm)
        np.savez(path / _ARRAYS, **arrays)
        manifest["arrays"] = {"file": _ARRAYS,
                              "sha256": _sha256(path / _ARRAYS)}

    aot_entries = []
    if aot and pid == 0:
        (path / "aot").mkdir(exist_ok=True)
        # warmup_probes() already dedups (regime, bucket) after the plane's
        # batch-multiple rounding, so entry names cannot collide
        for kind, bucket, _ in probes:
            for k in ks:
                try:
                    blob = eng.export_executable(kind, bucket, k=k)
                except Exception as e:  # noqa: BLE001 — degrade, not fail
                    warnings.warn(
                        f"AOT export skipped for {kind}/b{bucket}/k{k}: "
                        f"{e!r} (load will recompile this entry)",
                        stacklevel=2)
                    continue
                fname = f"aot/{kind}_b{bucket}_k{k}.jaxexp"
                (path / fname).write_bytes(blob)
                aot_entries.append({
                    "kind": kind, "bucket": bucket, "k": k,
                    "file": fname, "sha256": _sha256(path / fname)})
    if pid == 0:
        manifest["aot"] = aot_entries
        (path / _MANIFEST).write_text(json.dumps(manifest, indent=2))
    if jax.process_count() > 1:
        from jax.experimental import multihost_utils
        multihost_utils.sync_global_devices("repro-save-index")
    return path


# --------------------------------------------------------------------------
# load
# --------------------------------------------------------------------------

def _verified_npz(root: Path, entry: dict) -> dict:
    fpath = root / entry["file"]
    if not fpath.is_file():
        raise ArtifactError(f"missing payload {entry['file']}")
    if _sha256(fpath) != entry["sha256"]:
        raise ArtifactError(f"corrupt artifact: checksum mismatch in "
                            f"{entry['file']}")
    with np.load(fpath) as arrs:
        return {k: arrs[k] for k in arrs.files}


def _prime_aot(index, path: Path, manifest: dict) -> None:
    """Verify fingerprint (+ mesh topology, via the mesh_axes fingerprint
    field) and prime the engine's compile cache from the persisted modules;
    on any mismatch, warn and leave the engine to recompile on demand."""
    entries = manifest.get("aot", ())
    if not entries:
        return
    eng = index.engine
    saved_fp = manifest.get("fingerprint", {})
    now_fp = eng.plane.fingerprint()
    # version-1 artifacts predate the plane field; they were all single
    saved_fp.setdefault("plane", "single")
    # pre-v4 artifacts predate compressed residency; all unquantized
    saved_fp.setdefault("quantization", "none")
    # pre-v5 artifacts predate layout packing + the visited filter
    saved_fp.setdefault("layout", False)
    saved_fp.setdefault("visited_filter", "none")
    stale = [f for f in _FP_KEYS if saved_fp.get(f) != now_fp.get(f)]
    if eng.plane.name in ("mesh", "pod"):
        # exported mesh/pod modules are pinned to the device count and the
        # operand shardings — the full axis map must match exactly (and for
        # a pod, the process count: collectives bake in the runtime layout)
        if saved_fp.get("n_devices") != now_fp.get("n_devices"):
            stale.append("n_devices")
        if saved_fp.get("mesh_axes") != now_fp.get("mesh_axes"):
            stale.append("mesh_axes")
        if saved_fp.get("n_processes") != now_fp.get("n_processes"):
            stale.append("n_processes")
    if stale:
        warnings.warn(
            "AOT serving cache skipped — fingerprint mismatch on "
            + ", ".join(f"{f} ({saved_fp.get(f)!r} -> {now_fp.get(f)!r})"
                        for f in stale)
            + "; the engine will recompile on demand", stacklevel=3)
        return

    from jax import export as jax_export
    for e in entries:
        bpath = path / e["file"]
        if not bpath.is_file():
            raise ArtifactError(f"missing AOT payload {e['file']}")
        if _sha256(bpath) != e["sha256"]:
            raise ArtifactError(
                f"corrupt artifact: checksum mismatch in {e['file']}")
        exported = jax_export.deserialize(bpath.read_bytes())
        exe = eng.plane.prime(exported, e["kind"], e["bucket"], e["k"])
        eng.prime_executable(e["kind"], e["bucket"], e["k"], exe)


def _finish_load(index, path: Path, manifest: dict):
    """Apply the format-v3 streaming state to a restored index: the saved
    generation counter and (when the artifact was saved mid-epoch) the
    tombstone bitmap + delta shard.  v1/v2 manifests carry neither — they
    load as frozen generation-0 indexes.  Runs on EVERY load path,
    including the gather/reshard fallbacks: those rebuild over the same
    base corpus in the same row order, so the saved ids stay valid."""
    eng = index.engine
    eng.stats.generation = int(manifest.get("generation", 0))
    entry = manifest.get("streaming")
    if entry:
        arrs = _verified_npz(path, entry)
        n_base = int(arrs["n_base"])
        base_alive = np.unpackbits(
            arrs["alive_bits"], count=n_base).astype(bool)
        eng.restore_stream(base_alive, arrs["delta_X"], arrs["delta_alive"])
    return index


def load_index(index_cls, path, *, mesh=None):
    """Restore an `Index` saved by :func:`save_index`; pass ``mesh=`` to
    restore a sharded artifact onto a compatible mesh.  See the module
    docstring for the verification/fallback contract."""
    path = Path(path)
    mpath = path / _MANIFEST
    if not mpath.is_file():
        raise ArtifactError(f"{path} is not an index artifact "
                            f"(missing {_MANIFEST})")
    try:
        manifest = json.loads(mpath.read_text())
    except ValueError as e:
        raise ArtifactError(f"corrupt manifest in {path}: {e}") from e
    if manifest.get("magic") != MAGIC:
        raise ArtifactError(f"{path} is not a {MAGIC} artifact")
    ver = manifest.get("format_version")
    if ver not in READ_VERSIONS:
        raise ArtifactError(
            f"unsupported index artifact version {ver!r} "
            f"(this build reads versions {READ_VERSIONS})")

    cfg = _config_from_dict(manifest["config"])
    k = manifest["k"]
    threshold = manifest.get("calibrated_threshold")
    saved_plane = manifest.get("plane", "single")

    if saved_plane == "single":
        arrs = _verified_npz(path, manifest["arrays"])
        X = arrs["X"]
        has_perm = "perm" in arrs  # v5 locality layout: X is packed
        graph = PackedGraph(
            neighbors=jnp.asarray(arrs["neighbors"]),
            lambdas=jnp.asarray(arrs["lambdas"]),
            degrees=jnp.asarray(arrs["degrees"]),
            hubs=jnp.asarray(arrs["hubs"]) if "hubs" in arrs else None,
            perm=jnp.asarray(arrs["perm"]) if has_perm else None)
        # v4 compressed-residency payload: re-bind the saved codes instead
        # of re-quantizing (pre-v4 quantized configs derive them at install)
        quant = ((arrs["codes"], arrs["scales"])
                 if "codes" in arrs else None)
        if mesh is not None:
            warnings.warn(
                "single-device artifact loaded with mesh=: resharding — "
                "the database is re-laid over the mesh and shard-local "
                "sub-indexes are REBUILT (the saved graph spans the whole "
                "database); AOT cache skipped", stacklevel=3)
            if has_perm:
                # rebuild wants the corpus back in external row order so
                # saved external ids (streaming state) stay valid
                from repro.ann.layout import unpack_rows
                X = unpack_rows(X, arrs["perm"])
            return _finish_load(
                index_cls(X, cfg, k=k, mesh=mesh, threshold=threshold),
                path, manifest)
        index = index_cls(X, cfg, k=k, graph=graph, threshold=threshold,
                          quant=quant, packed=True)
        _prime_aot(index, path, manifest)
        return _finish_load(index, path, manifest)

    # ---- sharded (mesh) artifact -----------------------------------------
    shard_entries = manifest["arrays"]
    shards = [_verified_npz(path, e) for e in shard_entries]
    names = ("X", *_GRAPH_KEYS, "hubs")
    if "codes" in shards[0]:  # v4 compressed-residency payload
        names = names + ("codes", "scales")
    if "perm" in shards[0]:  # v5 locality layout: rows are shard-packed
        names = names + ("perm",)
    full = {name: np.concatenate([s[name] for s in shards], axis=0)
            for name in names}
    topo = manifest.get("topology", {})

    def _external_X():
        """Corpus in external row order, for the rebuild fallbacks: a v5
        layout artifact stores rows shard-packed, and the rebuild paths
        must preserve the saved external ids (streaming state)."""
        if "perm" not in full:
            return full["X"]
        from repro.ann.layout import unpack_rows
        return unpack_rows(full["X"], full["perm"], n_shards=len(shards))

    if mesh is None:
        warnings.warn(
            f"sharded artifact ({topo.get('n_db_shards')} DB shards) "
            "loaded without mesh=: gathering shards and REBUILDING a "
            "single-device index (per-shard sub-indexes only search their "
            "own slice); pass mesh= to restore the sharded layout",
            stacklevel=3)
        return _finish_load(
            index_cls(_external_X(), cfg, k=k, threshold=threshold),
            path, manifest)

    from repro.core import distributed as D
    from repro.serve.plane import MeshPlane

    if D.n_db_shards(mesh) != topo.get("n_db_shards"):
        warnings.warn(
            f"mesh topology mismatch: artifact has "
            f"{topo.get('n_db_shards')} DB shards, requested mesh has "
            f"{D.n_db_shards(mesh)} — gathering and resharding (sub-"
            "indexes REBUILT for the new shard cut); AOT cache skipped",
            stacklevel=3)
        return _finish_load(
            index_cls(_external_X(), cfg, k=k, mesh=mesh,
                      threshold=threshold),
            path, manifest)

    # compatible shard cut: re-bind the saved sub-indexes, no rebuild.
    # concatenated row slices are exactly the shard_map build layout, so a
    # sharded placement reproduces the original layout bit-for-bit.  When
    # this process is part of a jax.distributed pod, restore onto a pod
    # plane — its assembly path can place rows on other processes' devices,
    # which a plain device_put cannot.
    if jax.process_count() > 1:
        from repro.serve.pod import PodPlane
        plane_cls = PodPlane

        def _put(a, sharding):
            a = np.asarray(a)
            return jax.make_array_from_callback(a.shape, sharding,
                                                lambda idx: a[idx])
    else:
        plane_cls = MeshPlane

        def _put(a, sharding):
            return jax.device_put(jnp.asarray(a), sharding)

    sh = _mesh_shardings(mesh)
    parts = (
        _put(full["X"], sh["row2"]),
        _put(full["neighbors"], sh["row2"]),
        _put(full["lambdas"], sh["row2"]),
        _put(full["degrees"], sh["row1"]),
        _put(full["hubs"], sh["row1"]),
    )
    if "codes" in full:  # v4: re-bind saved codes, skip re-quantization
        parts = parts + (
            _put(full["codes"], sh["row2"]),
            _put(full["scales"], sh["row1"]),
        )
    if "perm" in full:  # v5: shard-local locality perm rides last
        parts = parts + (_put(full["perm"], sh["row1"]),)
    plane = plane_cls(None, cfg, mesh, parts=parts)
    index = index_cls(None, cfg, k=k, plane=plane, threshold=threshold)
    _prime_aot(index, path, manifest)
    return _finish_load(index, path, manifest)


def _mesh_shardings(mesh) -> dict:
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import distributed as D
    d_ax = D.db_axes(mesh)
    return {"row2": NamedSharding(mesh, P(d_ax, None)),
            "row1": NamedSharding(mesh, P(d_ax))}
