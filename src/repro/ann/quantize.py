"""Symmetric int8 row quantization for compressed residency (DESIGN.md §8).

The database rows are quantized **per row**: each row gets its own fp32
scale ``max|x| / 127`` and an int8 code vector, so a single outlier row
cannot crush the resolution of every other row (the per-tensor scheme in
:mod:`repro.optim.compression` is fine for gradients, where error feedback
absorbs the residual, but not for distances, where the error is paid every
query).  Zero rows get scale 1.0 so they round-trip to exact zeros.

Scoring dequantizes **in-kernel** — the HBM->VMEM DMA moves int8 bytes
(~4x less traffic per row than fp32), then the block kernel widens to
fp32 and applies the scale before the MXU dot, so both kernel backends
share one arithmetic formulation and stay bitwise-identical.

The per-tensor helpers (``quantize`` / ``dequantize``) used by the
gradient-compression path live here too; ``repro.optim.compression``
re-exports them through a warn-once shim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x: jax.Array):
    """Symmetric per-tensor int8 quantization -> (q, scale)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x32)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array):
    return q.astype(jnp.float32) * scale


def quantize_rows(X: jax.Array):
    """Per-row symmetric int8 quantization of a [N, d] database.

    Returns ``(codes [N, d] int8, scales [N] float32)`` with
    ``codes[i] * scales[i] ~= X[i]`` to within ``scales[i] / 2`` per
    component.  All-zero rows get scale 1.0 (not an epsilon) so they
    dequantize to exact zeros.
    """
    x32 = jnp.asarray(X).astype(jnp.float32)
    raw = jnp.max(jnp.abs(x32), axis=1) / 127.0
    scales = jnp.where(raw > 0.0, raw, 1.0)
    codes = jnp.clip(jnp.round(x32 / scales[:, None]),
                     -127, 127).astype(jnp.int8)
    return codes, scales


def dequantize_rows(codes: jax.Array, scales: jax.Array):
    """Inverse of :func:`quantize_rows` -> [N, d] float32."""
    return codes.astype(jnp.float32) * scales[:, None]
