"""Locality-packed graph layout (CAGRA/GGNN-style, DESIGN.md §10).

The gather-fused Pallas path (DESIGN.md §2) issues one HBM->VMEM DMA per
neighbor row at whatever addresses the build left them.  CAGRA
(arXiv:2308.15136) and GGNN (arXiv:1912.01059) show that most of the
remaining headroom is *layout*: store the database in an order where a
node's neighbors live next to each other, and the per-row DMA descriptors
collapse into multi-row contiguous copies.

This module is the host-side half of that optimization:

  * :func:`locality_order` — a max-fresh-first greedy traversal (a
    coalescing-aware cousin of Cuthill–McKee): each pop numbers one
    node's still-unnumbered neighbors as ONE consecutive id run, and pops
    are ordered by how many fresh ids they can still mint, so the big
    runs are minted before sibling pops fragment them;
  * :func:`apply_layout` — relabel every structure into the packed order:
    ``X[perm]`` rows, neighbor values through ``inv``, each row re-sorted
    ascending by new id (sentinel ``N`` sinks to the end) so runs become
    *detectable spans* for the kernel's grouped DMA;
  * :func:`span_stats` — the measurement: how many of the kernel's
    aligned G-row groups are contiguous spans (one ``make_async_copy``
    instead of G), reported as mean DMA rows-per-copy.  The layout
    benchmark tier and the CI gate consume this.

The permutation is carried on the returned graph (``PackedGraph.perm``,
new->old) and persisted in artifact format v5; the search procedures keep
every externally-visible contract in the ORIGINAL id space (seeds, hash
placements, tombstone masks, returned ids), so a packed index answers
bitwise-identically to an unpacked one — see DESIGN.md §10 for the
equivariance argument.

Everything here is plain numpy on host: the traversal is inherently
sequential and runs once per build (the "layout" stage), never on the
serving path.
"""
from __future__ import annotations

import numpy as np


def locality_order(neighbors: np.ndarray, *, starts=None) -> np.ndarray:
    """Max-fresh-first traversal order of the packed adjacency.

    ``neighbors`` [N, M] int32 with sentinel ``N`` for absent edges.  Each
    pop of a node ``u`` numbers ``u`` itself (if still unnumbered) and
    then every still-unnumbered neighbor of ``u``, in stored lane order,
    as one consecutive block of new ids — after relabeling, row ``u``
    therefore holds a consecutive run the kernel's span detector can
    coalesce.  A plain FIFO BFS wastes the runs: sibling pops inside an
    exploding frontier have mostly-numbered neighborhoods and mint runs
    of length ~1.  Popping by *fresh count* (how many unnumbered
    neighbors a node still has, maintained exactly via the reverse
    adjacency) mints the long runs first, before overlap can fragment
    them.

    ``starts`` (optional int sequence, e.g. the hub set) is popped first
    in the given order; ties and leftovers resolve by smallest node id,
    so the order is deterministic.  Returns ``perm`` [N] int32, new->old:
    the node stored at packed row ``i`` is original node ``perm[i]``.
    """
    import heapq

    nb = np.asarray(neighbors)
    N = nb.shape[0]
    # per-row deduped valid neighbor lists + reverse adjacency (dedup so
    # a doubled lane cannot over-decrement the fresh counts)
    rows: list[list[int]] = []
    rev: list[list[int]] = [[] for _ in range(N)]
    for u in range(N):
        seen: set = set()
        row = []
        for v in nb[u]:
            v = int(v)
            if v < N and v not in seen:
                seen.add(v)
                row.append(v)
                rev[v].append(u)
        rows.append(row)
    cnt = [len(r) for r in rows]
    numbered = np.zeros(N, dtype=bool)
    perm = np.empty(N, dtype=np.int32)
    pos = 0

    def pop(u: int) -> None:
        nonlocal pos
        fresh = []
        if not numbered[u]:
            numbered[u] = True
            perm[pos] = u
            pos += 1
            fresh.append(u)
        for v in rows[u]:
            if not numbered[v]:
                numbered[v] = True
                perm[pos] = v
                pos += 1
                fresh.append(v)
        for v in fresh:
            for w in rev[v]:
                cnt[w] -= 1

    for s in (starts if starts is not None else []):
        s = int(s)
        if 0 <= s < N:
            pop(s)
    heap = [(-cnt[u], u) for u in range(N) if cnt[u] > 0]
    heapq.heapify(heap)
    while heap:
        c, u = heapq.heappop(heap)
        if -c != cnt[u]:
            if cnt[u] > 0:
                heapq.heappush(heap, (-cnt[u], u))  # lazy re-key
            continue
        pop(u)
    for u in range(N):  # isolated leftovers, ascending
        if not numbered[u]:
            numbered[u] = True
            perm[pos] = u
            pos += 1
    return perm


def inverse_permutation(perm: np.ndarray) -> np.ndarray:
    """old->new from new->old (``inv[perm[i]] == i``)."""
    perm = np.asarray(perm)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.shape[0], dtype=perm.dtype)
    return inv


def apply_layout(perm, X, neighbors, lambdas, degrees, hubs=None):
    """Relabel every build artifact into packed (new-id) order.

    Returns ``(X2, neighbors2, lambdas2, degrees2, hubs2)`` where

      * ``X2[i] == X[perm[i]]`` (bitwise row gather — the parity contract
        needs the packed rows to be the SAME fp32 bits);
      * ``neighbors2[i]`` is ``inv[neighbors[perm[i]]]`` re-laid per row so
        consecutive-id runs sit at ``span_group``-aligned lane boundaries
        (λ carried along, sentinel ``N`` last) — the aligned runs are
        exactly what the kernel's span detector coalesces;
      * ``hubs2[j] == inv[hubs[j]]`` — POSITIONS are kept so the search's
        hub draws pick the same vectors as the unpacked graph.

    Lane order within a row is otherwise free: search results go through
    the (dist, id)-total-order rank merge, so any lane permutation is
    bitwise-invisible.  The one casualty is the λ-ascending invariant (λ
    becomes a plain per-lane attribute); the λ-prefix ``gather_limit``
    knob is therefore rejected for packed graphs at config validation.
    """
    perm = np.asarray(perm)
    X = np.asarray(X)
    nb = np.asarray(neighbors)
    lam = np.asarray(lambdas)
    N, M = nb.shape
    inv = inverse_permutation(perm)
    nb_p = nb[perm]
    valid = nb_p < N
    nb_new = np.where(valid, inv[np.clip(nb_p, 0, N - 1)], np.int32(N))
    order = _run_aligned_order(nb_new, N, span_group(M))
    neighbors2 = np.take_along_axis(nb_new, order, axis=1).astype(np.int32)
    lambdas2 = np.take_along_axis(lam[perm], order, axis=1)
    degrees2 = np.asarray(degrees)[perm]
    hubs2 = None if hubs is None \
        else inv[np.asarray(hubs)].astype(np.int32)
    return X[perm], neighbors2, lambdas2, degrees2, hubs2


def _run_aligned_order(nb_new: np.ndarray, N: int, G: int) -> np.ndarray:
    """Per-row lane order packing consecutive-id runs onto aligned groups.

    Ascending sort alone wastes most runs: a row's older (already-visited)
    neighbor ids sort BEFORE its fresh BFS run and shift it off the
    G-aligned boundaries the kernel inspects.  Instead, cut the sorted row
    into maximal consecutive runs and emit each run's G-multiple prefix
    first (the emitted prefix lengths are all multiples of G, so every
    chunk lands on an aligned boundary and every G-chunk of a run is
    itself consecutive), then the leftovers, then the sentinels.  Rows are
    ``M`` lanes, so concatenated row gathers keep the alignment whenever
    ``G | M`` — which ``span_group`` guarantees.

    Returns ``order`` [N, M] int32 lane indices into the sorted-id view's
    source row (``take_along_axis``-ready).
    """
    M = nb_new.shape[1]
    sort_ord = np.argsort(nb_new, axis=1, kind="stable").astype(np.int32)
    if G <= 1:
        return sort_ord
    s = np.take_along_axis(nb_new, sort_ord, axis=1).astype(np.int64)
    # run ids: a lane starts a new run when it does not continue id+1
    starts = np.ones_like(s, dtype=bool)
    starts[:, 1:] = s[:, 1:] != s[:, :-1] + 1
    starts |= s >= N                      # sentinels never join a run
    run_id = np.cumsum(starts, axis=1) - 1           # [N, M]
    # position within the run, and the run's total length, per lane
    lane = np.arange(M)
    run_start_lane = np.where(starts, lane, 0)
    run_start_lane = np.maximum.accumulate(run_start_lane, axis=1)
    pos = lane - run_start_lane
    run_len = np.zeros_like(run_id)
    np.add.at(run_len, (np.arange(s.shape[0])[:, None], run_id), 1)
    run_len = np.take_along_axis(run_len, run_id, axis=1)
    head = (pos < (run_len // G) * G) & (s < N)      # aligned-group lanes
    # stable three-way partition: head lanes (in sorted order), spill, pad
    klass = np.where(head, 0, np.where(s < N, 1, 2))
    part = np.argsort(klass, axis=1, kind="stable").astype(np.int32)
    return np.take_along_axis(sort_ord, part, axis=1)


def unpack_rows(X: np.ndarray, perm: np.ndarray, *,
                n_shards: int = 1) -> np.ndarray:
    """Invert the packed row order back to external ids: packed row ``j``
    holds original row ``perm[j]``, so ``out[perm[j]] = X[j]``.  With
    ``n_shards > 1`` the inversion is per equal row slice (the mesh plane
    packs each shard's LOCAL ids independently)."""
    X = np.asarray(X)
    perm = np.asarray(perm, np.int64)
    N = X.shape[0]
    if N % n_shards:
        raise ValueError(f"{N} rows not divisible into {n_shards} shards")
    n_local = N // n_shards
    off = (np.arange(N, dtype=np.int64) // n_local) * n_local
    out = np.empty_like(X)
    out[off + perm] = X
    return out


def span_group(C: int, *, cap: int = 8) -> int:
    """The kernel's static DMA group width for a C-lane gather: the
    largest power of two <= ``cap`` dividing C (1 = no grouping).  Groups
    must tile the candidate axis exactly so a group never straddles two
    gather rows."""
    g = 1
    while g * 2 <= cap and C % (g * 2) == 0:
        g *= 2
    return g


def span_stats(neighbors: np.ndarray, *, group: int | None = None) -> dict:
    """Coalescing yield of a (packed or unpacked) adjacency.

    Mirrors the kernel's span rule exactly: the [*, C] index array is cut
    into aligned groups of ``group`` lanes; a group whose ids are one
    ascending contiguous run (``idx[c+i] == idx[c] + i``) moves as ONE
    multi-row ``make_async_copy``, every other group pays one copy per
    lane.  Returns the group/copy accounting (pass ``group=`` to probe
    sub-kernel span widths, e.g. the benchmark's G=2/4 histogram row).
    """
    nb = np.asarray(neighbors)
    N, C = nb.shape
    G = span_group(C) if group is None else group
    if G <= 1 or C % G:
        total = N * C
        return {"group": 1, "n_groups": total, "n_coalesced": 0,
                "dma_copies": total, "rows": total,
                "rows_per_copy": 1.0, "frac_coalesced": 0.0}
    g3 = nb.reshape(N, C // G, G).astype(np.int64)
    expect = g3[:, :, :1] + np.arange(G, dtype=np.int64)
    contig = np.all(g3 == expect, axis=2) & np.all(g3 < N, axis=2)
    n_groups = N * (C // G)
    n_coal = int(contig.sum())
    copies = n_coal + (n_groups - n_coal) * G
    rows = n_groups * G
    return {"group": G, "n_groups": n_groups, "n_coalesced": n_coal,
            "dma_copies": copies, "rows": rows,
            "rows_per_copy": rows / copies,
            "frac_coalesced": n_coal / n_groups}
