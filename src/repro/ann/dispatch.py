"""Batch-regime dispatch — the facade's ownership of the paper's §4 split.

The paper fits an empirical division threshold ``(a·SMs + b) / d`` per GPU
and routes each batch to the small- or large-batch procedure.  Our TPU
analogue compares the batch's *search population* (``B·t0`` for the small
procedure, which runs ``t0`` independent greedy searches per query) against
the device's matmul occupancy target, ``cfg.small_batch_threshold`` (per DB
shard).  This module is the single home of that rule: the serving engine,
the :class:`repro.ann.Index` facade, and the benchmarks all call
:func:`regime_for` so the threshold can never drift between layers.

**Calibration** (the paper's per-device fit, ``cfg.regime_calibration =
"probe"``): instead of trusting the static config value,
:func:`calibrate` times both procedures through the engine's execution
plane at two probe batch sizes, fits a linear latency model per regime,
and solves for the crossover batch B* where the large procedure starts
winning — exactly the paper's §4 methodology, with the plane substituted
for the bare GPU so a mesh engine calibrates against its *sharded*
procedures.  The fitted threshold is overridable (``ANNEngine(...,
threshold=)``) and cached in the index artifact manifest so a restarted
process skips the probe sweep.
"""
from __future__ import annotations

import dataclasses
import os
import time


def regime_for(cfg, batch: int, *, threshold: float | None = None,
               n_delta: int = 0) -> str:
    """``"small"`` or ``"large"`` for a batch of ``batch`` queries.

    Paper §4: small-batch search wins while the search population
    ``batch * t0`` undershoots the device saturation point; past it the
    best-first large-batch procedure amortizes better.  ``threshold``
    (a calibrated or caller-supplied value) replaces
    ``cfg.small_batch_threshold`` under the same rule.

    ``n_delta`` (beyond-paper, streaming indexes only — DESIGN.md §7):
    live rows in the brute-force delta shard.  Every query scores every
    delta row regardless of regime, so the shard contributes
    ``n_delta / hop_width`` hop-equivalents of extra population per query;
    counting it nudges borderline batches into the large regime as the
    un-compacted shard grows.  0 (a frozen index) reduces to the paper's
    rule exactly.
    """
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    thr = cfg.small_batch_threshold if threshold is None else threshold
    pop = batch * cfg.small_t0
    if n_delta > 0:
        pop += batch * (n_delta // max(1, cfg.hop_width))
    return "small" if pop < thr * 4 else "large"


@dataclasses.dataclass(frozen=True)
class Calibration:
    """A fitted regime split (see :func:`calibrate`).

    ``threshold`` drops into the ``B·t0 < 4·threshold`` rule of
    :func:`regime_for`.  ``a``/``b``/``cores``/``d`` express the same
    division point in the paper's ``(a·cores + b) / d`` form — with probes
    from a single device the fit is degenerate (``b = 0``,
    ``a = B*·d/cores``); fitting ``a`` and ``b`` separately needs probes
    from devices with different core counts, which is exactly how the
    paper presents it (§4, one fit per GPU model).
    """

    threshold: float
    crossover_batch: float     # B*: the batch where the procedures tie
    a: float
    b: float
    cores: int
    d: int
    degenerate: bool           # probes could not order the procedures
    probes: dict               # {regime: [(batch, seconds_per_call), ...]}

    def to_manifest(self) -> dict:
        out = dataclasses.asdict(self)
        out["probes"] = {kind: [[int(B), float(t)] for B, t in rows]
                         for kind, rows in self.probes.items()}
        return out

    @classmethod
    def from_manifest(cls, d: dict) -> "Calibration":
        d = dict(d)
        d["probes"] = {kind: [(int(B), float(t)) for B, t in rows]
                       for kind, rows in d.get("probes", {}).items()}
        return cls(**d)


def _device_cores() -> int:
    import jax

    dev = jax.devices()[0]
    cores = getattr(dev, "core_count", None) \
        or getattr(dev, "num_cores", None)
    if not cores and jax.default_backend() == "cpu":
        cores = os.cpu_count()
    return int(cores or 1)


def calibrate(plane, cfg, *, k: int = 10, probe_batches=(4, 32),
              repeats: int = 3) -> Calibration:
    """Fit the regime threshold from timed probe batches on ``plane``.

    Both procedures are compiled (through the plane, so a mesh plane
    probes its shard-mapped form) at each probe batch size and timed
    steady-state (best of ``repeats``, compile excluded).  Per-regime
    latency is modelled as ``t(B) = α + β·B``; the crossover
    ``B* = (α_large − α_small) / (β_small − β_large)`` becomes the
    threshold via the population rule ``threshold = B*·t0 / 4``.

    Degenerate fits (the small procedure never loses, or the probes are
    too noisy to order the slopes) fall back to the static config
    threshold with ``degenerate=True`` — calibration never makes dispatch
    *worse* than the shipped default.
    """
    import numpy as np

    d = int(plane.X.shape[1])
    mult = plane.batch_multiple()
    times: dict = {"small": [], "large": []}
    for kind in ("small", "large"):
        for B in probe_batches:
            Br = -(-int(B) // mult) * mult
            exe = plane.compile(kind, Br, k)
            Q = np.zeros((Br, d), np.float32)
            out = exe(np.array(Q))         # warm dispatch (compile done)
            out[0].block_until_ready()
            best = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                out = exe(np.array(Q))     # fresh buffer: exe may donate
                out[0].block_until_ready()
                best = min(best, time.perf_counter() - t0)
            times[kind].append((Br, best))

    def _fit(rows):
        (B1, t1), (B2, t2) = rows[0], rows[-1]
        if B2 == B1:
            return t1, 0.0
        beta = (t2 - t1) / (B2 - B1)
        return t1 - beta * B1, beta

    a_s, b_s = _fit(times["small"])
    a_l, b_l = _fit(times["large"])
    cores = _device_cores()
    if b_s <= b_l:  # small never loses per-query on these probes
        return Calibration(
            threshold=float(cfg.small_batch_threshold),
            crossover_batch=float("inf"), a=0.0, b=0.0, cores=cores, d=d,
            degenerate=True, probes=times)
    b_star = (a_l - a_s) / (b_s - b_l)
    b_star = min(max(b_star, 1.0), 1e7)
    threshold = b_star * cfg.small_t0 / 4.0
    return Calibration(
        threshold=float(threshold), crossover_batch=float(b_star),
        a=float(b_star * d / cores), b=0.0, cores=cores, d=d,
        degenerate=False, probes=times)
