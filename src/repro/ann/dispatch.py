"""Batch-regime dispatch — the facade's ownership of the paper's §4 split.

The paper fits an empirical division threshold ``(a·SMs + b) / d`` per GPU
and routes each batch to the small- or large-batch procedure.  Our TPU
analogue compares the batch's *search population* (``B·t0`` for the small
procedure, which runs ``t0`` independent greedy searches per query) against
the device's matmul occupancy target, ``cfg.small_batch_threshold`` (per DB
shard).  This module is the single home of that rule: the serving engine,
the :class:`repro.ann.Index` facade, and the benchmarks all call
:func:`regime_for` so the threshold can never drift between layers.
"""
from __future__ import annotations


def regime_for(cfg, batch: int) -> str:
    """``"small"`` or ``"large"`` for a batch of ``batch`` queries.

    Paper §4: small-batch search wins while the search population
    ``batch * t0`` undershoots the device saturation point; past it the
    best-first large-batch procedure amortizes better.
    """
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    return ("small" if batch * cfg.small_t0
            < cfg.small_batch_threshold * 4 else "large")
