"""`Index` — the single public object of the ANN system (DESIGN.md §5).

The paper describes a *serving system*: a diversified graph built once and
then searched under wildly varying batch regimes.  `Index` is that system's
one handle — CAGRA-shaped (build / search / save / load, PAPERS.md) with the
serving layers of this repo behind it:

    from repro.ann import Index

    index = Index.build(X, cfg, k=10)        # staged pipeline (pipeline.py)
    ids, dists = index.search(Q)             # automatic regime dispatch
    new_ids = index.add(V)                   # streaming insert (delta shard)
    index.delete(new_ids[:2])                # tombstone (base or delta ids)
    id_map = index.compact()                 # fold into a new generation
    index.save("/models/tsdg-1m")            # graph + config + AOT cache
    ...
    index = Index.load("/models/tsdg-1m")    # restart: no rebuild, and the
    ids, dists = index.search(Q)             #   warmup compile sweep is
                                             #   skipped (primed executables)
    with index.serve(max_wait_ms=2.0) as mb: # micro-batching queue + QoS
        fut = mb.submit(q, deadline_ms=15.0)

Sharded serving is the same four verbs (DESIGN.md §6): ``Index.build(X,
cfg, mesh=mesh)`` lays the database + one sub-index per DB shard over the
mesh, ``save`` writes the shard-major artifact, and ``Index.load(path,
mesh=mesh)`` restores it onto a compatible mesh with zero rebuilds and
zero compiles — the mesh is a first-class execution plane
(:mod:`repro.serve.plane`), not a separate API.

Everything underneath — the build stages, the shape-bucketed compile cache,
the kernel-backend seam, the micro-batcher — stays reachable for power
users, but this facade is the supported surface.
"""
from __future__ import annotations

from repro.ann.pipeline import build_graph
from repro.configs.base import ANNConfig


class Index:
    """A built TSDG index plus its serving engine.

    Construct with :meth:`build` (or :meth:`load`); the constructor accepts
    a prebuilt :class:`~repro.core.diversify.PackedGraph` via ``graph=`` to
    skip the pipeline (how :meth:`load` restores a single-device artifact).
    Pass ``mesh=`` to build shard-local sub-indices over a device mesh
    (DESIGN.md §6) behind the same ``search()`` API, or ``plane=`` to
    inject any prebuilt :class:`~repro.serve.plane.ExecutionPlane` (how
    :meth:`load` restores a sharded artifact without rebuilding).

    ``threshold=`` overrides the §4 regime split; with
    ``cfg.regime_calibration="probe"`` the engine fits it from timed probe
    batches instead (:func:`repro.ann.dispatch.calibrate`).
    """

    def __init__(self, X, cfg: ANNConfig | None = None, *, k: int = 10,
                 graph=None, mesh=None, plane=None, stages=None,
                 tile: int = 2048, threshold: float | None = None,
                 quant: tuple | None = None, packed: bool = False):
        from repro.serve.engine import ANNEngine

        cfg = cfg or ANNConfig()
        if plane is not None:
            if stages is not None or graph is not None or mesh is not None:
                raise ValueError("plane= already fixes the layout and "
                                 "graph; stages=/graph=/mesh= do not apply")
        elif mesh is None and graph is None:
            graph = build_graph(X, cfg, stages=stages, tile=tile)
        elif stages is not None:
            raise ValueError("stages= only applies when the pipeline runs "
                             "(not with graph= or mesh=)")
        self.engine = ANNEngine(X, cfg, k=k, graph=graph, mesh=mesh,
                                plane=plane, threshold=threshold,
                                quant=quant, packed=packed)

    @classmethod
    def build(cls, X, cfg: ANNConfig | None = None, *, k: int = 10,
              mesh=None, stages=None, tile: int = 2048,
              threshold: float | None = None) -> "Index":
        """Run the staged build pipeline (``cfg.build_pipeline``, default
        knn -> diversify -> bridges) and wrap the result in an `Index`.

        ``stages`` overrides the pipeline per call; names resolve through
        :func:`repro.ann.pipeline.register_stage`'s registry.  With
        ``mesh=`` each DB shard builds its own sub-index shard-locally
        (zero cross-shard traffic) and serving goes through the mesh
        execution plane.
        """
        return cls(X, cfg, k=k, mesh=mesh, stages=stages, tile=tile,
                   threshold=threshold)

    # -- search / serve -----------------------------------------------------

    def search(self, Q, *, k: int | None = None):
        """Answer one batch: (ids [B, k], dists [B, k]) numpy arrays.

        Dispatches to the paper's small- or large-batch procedure by the
        §4 regime threshold (:func:`repro.ann.dispatch.regime_for`), pads
        to the engine's shape-bucket ladder, and serves from the AOT
        compile cache — bitwise-identical to calling the raw procedures.
        """
        return self.engine.query(Q, k=k)

    def regime(self, batch: int) -> str:
        """Which procedure a batch of this size takes ("small"/"large").
        Delegates to the engine so a live delta shard's extra brute-force
        population counts (DESIGN.md §7); a frozen index reduces to the
        paper's static rule."""
        return self.engine.regime(batch)

    # -- streaming mutability (DESIGN.md §7) --------------------------------

    def add(self, V):
        """Append vectors without rebuilding: they land in a brute-force
        delta shard searched alongside the graph (results fused by
        ``merge_topk``, recall-equivalent to a brute-force oracle over the
        effective corpus).  Returns the new global ids (``n_base + slot``),
        stable until :meth:`compact`."""
        return self.engine.add(V)

    def delete(self, ids) -> int:
        """Tombstone ids (base or delta).  Deleted rows are still routed
        *through* during graph traversal (connectivity is preserved) but
        can never be returned.  All-or-nothing: unknown, duplicate, or
        already-deleted ids raise KeyError without mutating anything."""
        return self.engine.delete(ids)

    def compact(self, *, tile: int = 2048):
        """Fold adds/deletes into a fresh generation: re-runs the staged
        build pipeline over the effective corpus and hot-swaps it into the
        serving plane without dropping in-flight requests — post-compaction
        searches are bitwise-identical to a cold :meth:`build` over the
        same vectors.  Returns the old->new id map (int64, -1 = deleted)."""
        return self.engine.compact(tile=tile)

    @property
    def generation(self) -> int:
        """Completed compactions since this index was built/loaded."""
        return self.engine.stats.generation

    @property
    def n_active(self) -> int:
        """Rows a search can currently return (base + delta − tombstones)."""
        return self.engine.n_active()

    def warmup(self, k: int | None = None) -> int:
        """Pre-compile every reachable (regime, bucket) executable; returns
        the number of fresh compiles (0 after a fingerprint-matched
        :meth:`load`)."""
        return self.engine.warmup(k=k)

    def serve(self, *, router=None, **qos):
        """The concurrent-caller serving front over this index.

        By default: a running :class:`~repro.serve.queue.MicroBatcher`.
        QoS knobs pass through: ``max_wait_ms`` (coalescing window),
        ``max_batch`` (dispatch cap; submits at or above it take the
        bypass lane instead of queueing behind latency traffic).  Per
        request, ``submit(..., deadline_ms=)`` bounds the queue wait.

        With ``router=`` (a :class:`~repro.serve.router.RouterConfig` or a
        spec string like ``"replicated:3"`` / ``"sharded:2"``): a running
        :class:`~repro.serve.router.Router` instead — N replica endpoints
        (each its own micro-batching queue) with health-checked dispatch.
        Replicated endpoints share this index's plane and compile cache;
        sharded endpoints re-cut the corpus into equal slices.  The QoS
        knobs then apply to every endpoint's queue.
        """
        if router is not None:
            from repro.serve.router import Router, parse_router_spec

            if isinstance(router, str):
                router = parse_router_spec(router)
            return Router.for_index(self, router, **qos)
        from repro.serve.queue import MicroBatcher

        return MicroBatcher(self.engine, **qos)

    # -- persistence --------------------------------------------------------

    def save(self, path, *, aot: bool = True, extra_ks=()):
        """Write the versioned index artifact: packed graph + database +
        config + fingerprint (+ the AOT-exported serving executables unless
        ``aot=False``).  Sharded indexes write the shard-major layout
        (one ``arrays/<i>.npz`` per DB shard + mesh topology).

        ``extra_ks`` exports the warmup-reachable executables for those
        additional ``k`` values too, so a loaded index serves them
        steady-state from the first request (they are primed on load like
        the default ``k``).  See :mod:`repro.ann.artifact` for the format.
        """
        from repro.ann.artifact import save_index

        return save_index(self, path, aot=aot, extra_ks=extra_ks)

    @classmethod
    def load(cls, path, *, mesh=None) -> "Index":
        """Restore a saved index: no rebuild, and — when the saved
        fingerprint (and, for sharded artifacts, mesh topology) matches
        this process — no warmup compile sweep either (the persisted
        executables are primed straight into the serving cache).  Pass
        ``mesh=`` to restore a sharded artifact onto a compatible mesh.
        On fingerprint mismatch the index still loads and falls back to
        on-demand recompilation; on topology mismatch it gathers the
        shards and rebuilds for the requested layout (with a warning)."""
        from repro.ann.artifact import load_index

        return load_index(cls, path, mesh=mesh)

    # -- introspection ------------------------------------------------------

    @property
    def X(self):
        return self.engine.X

    @property
    def graph(self):
        return self.engine.graph

    @property
    def cfg(self) -> ANNConfig:
        return self.engine.cfg

    @property
    def k(self) -> int:
        return self.engine.k

    @property
    def stats(self):
        return self.engine.stats

    @property
    def backend(self) -> str:
        return self.engine.backend

    @property
    def plane(self):
        """The engine's execution plane (single-device or mesh)."""
        return self.engine.plane

    @property
    def mesh(self):
        return self.engine.mesh

    @property
    def calibration(self):
        """The fitted regime split, when ``regime_calibration="probe"``."""
        return self.engine.calibration

    def __repr__(self) -> str:
        g = self.graph
        return (f"Index(n={g.n}, d={self.X.shape[1]}, "
                f"max_degree={g.max_degree}, metric={self.cfg.metric!r}, "
                f"backend={self.backend!r}, plane={self.plane.name!r}, "
                f"k={self.k})")
