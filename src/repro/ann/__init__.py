"""`repro.ann` — the public facade of the ANN system (DESIGN.md §5).

One object, four verbs, CAGRA-shaped::

    from repro.ann import Index

    index = Index.build(X, cfg, k=10)     # staged pipeline (register_stage)
    ids, dists = index.search(Q)          # automatic regime dispatch
    index.save(path)                      # versioned artifact + AOT cache
    index = Index.load(path)              # no rebuild, no warmup sweep
    mb = index.serve(max_wait_ms=2.0)     # micro-batching queue + QoS

The modules behind it stay importable (``repro.core`` is the internal
layer; the old entry points remain as thin deprecation shims), but new
code should consume the system through this package.

Submodule imports are lazy: :mod:`repro.serve.engine` imports
``repro.ann.dispatch`` (the regime rule lives here now), so an eager
``from repro.ann.index import Index`` at package-init time would cycle.
"""
from __future__ import annotations

from repro.ann.dispatch import regime_for  # noqa: F401  (dependency-light)

_LAZY = {
    "Index": ("repro.ann.index", "Index"),
    "build_graph": ("repro.ann.pipeline", "build_graph"),
    "register_stage": ("repro.ann.pipeline", "register_stage"),
    "build_stages": ("repro.ann.pipeline", "build_stages"),
    "BuildState": ("repro.ann.pipeline", "BuildState"),
    "ArtifactError": ("repro.ann.artifact", "ArtifactError"),
    "FORMAT_VERSION": ("repro.ann.artifact", "FORMAT_VERSION"),
    "save_index": ("repro.ann.artifact", "save_index"),
    "load_index": ("repro.ann.artifact", "load_index"),
    "StreamState": ("repro.ann.delta", "StreamState"),
    "DeltaShard": ("repro.ann.delta", "DeltaShard"),
    "compact": ("repro.ann.compaction", "compact"),
    "effective_corpus": ("repro.ann.compaction", "effective_corpus"),
}

__all__ = ["regime_for", *_LAZY]


def __getattr__(name: str):
    try:
        mod_name, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}") from None
    import importlib

    return getattr(importlib.import_module(mod_name), attr)


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
