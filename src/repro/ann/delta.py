"""Host-side streaming mutation state: tombstones + delta shard.

DESIGN.md §7.  A built index is frozen *device* state — the packed graph
never mutates in place.  Streaming writes instead accumulate in a small
host-side :class:`StreamState` owned by the serving engine:

* ``base_alive`` — a persistent bool mask over the base corpus; ``delete``
  of a base id flips its bit, and the mask threads into the search kernels'
  keep-masks (``alive=`` on both procedures) so tombstoned nodes are still
  *routed through* (the graph keeps its connectivity) but can never be
  ranked, seeded from, or returned.
* :class:`DeltaShard` — an append-only capacity-padded buffer of added
  vectors, brute-force scanned by every query (``hotpath.scan_distances``)
  and fused with the graph results by ``distributed.merge_topk``.  Delta
  rows answer at global ids ``n_base + slot``, disjoint from every base id
  and stable until compaction renumbers the corpus.

The capacity doubles geometrically from ``cfg.delta_min_cap``, so the
streaming executables (whose shapes include the capacity) recompile
O(log adds) times, and dead slots ride along as masked lanes until
:func:`repro.ann.compaction.compact` folds everything into a fresh
generation.

All methods are plain numpy and NOT thread-safe on their own — the engine
serializes every mutation under its ``_mutlock`` and publishes immutable
device snapshots to the plane.
"""
from __future__ import annotations

import numpy as np

# floor on the first allocated capacity (overridable per-config via
# cfg.delta_min_cap); tiny shards would churn recompiles for nothing
MIN_CAP = 256


class DeltaShard:
    """Append-only capacity-padded vector buffer.

    ``X [cap, d] float32`` / ``alive [cap] bool``; slots ``[count:]`` are
    unfilled (alive=False), slots below ``count`` may be tombstoned.  The
    device view is the FULL capacity-padded pair — masked lanes cost one
    fused multiply each, and a stable shape keeps the streaming executable
    cached between adds.
    """

    def __init__(self, d: int, *, min_cap: int = MIN_CAP):
        self.d = int(d)
        self.cap = max(1, int(min_cap))
        self.count = 0
        self.X = np.zeros((self.cap, self.d), np.float32)
        self.alive = np.zeros((self.cap,), bool)

    def append(self, V: np.ndarray) -> np.ndarray:
        """Copy rows of ``V [m, d]`` into the next free slots, doubling the
        capacity as needed; returns the slot indices [m] int64."""
        m = V.shape[0]
        need = self.count + m
        if need > self.cap:
            new_cap = self.cap
            while new_cap < need:
                new_cap *= 2
            X = np.zeros((new_cap, self.d), np.float32)
            alive = np.zeros((new_cap,), bool)
            X[:self.count] = self.X[:self.count]
            alive[:self.count] = self.alive[:self.count]
            self.X, self.alive, self.cap = X, alive, new_cap
        slots = np.arange(self.count, need, dtype=np.int64)
        self.X[self.count:need] = V
        self.alive[self.count:need] = True
        self.count = need
        return slots

    def n_alive(self) -> int:
        return int(self.alive[:self.count].sum())


class StreamState:
    """The whole mutation log for one index generation (see module doc)."""

    def __init__(self, n_base: int, d: int, *, min_cap: int = MIN_CAP):
        self.n_base = int(n_base)
        self.base_alive = np.ones((self.n_base,), bool)
        self.delta = DeltaShard(d, min_cap=min_cap)

    # -- state ---------------------------------------------------------------

    @property
    def dirty(self) -> bool:
        """Any mutation recorded since this generation was built?"""
        return self.delta.count > 0 or not self.base_alive.all()

    def n_active(self) -> int:
        """Rows a search can return: live base rows + live delta rows."""
        return int(self.base_alive.sum()) + self.delta.n_alive()

    def n_total(self) -> int:
        """The id space: base rows + assigned delta slots (dead included)."""
        return self.n_base + self.delta.count

    # -- mutations -----------------------------------------------------------

    def add(self, V: np.ndarray) -> np.ndarray:
        """Append [m, d] float32 rows; returns their global ids [m]."""
        return self.n_base + self.delta.append(V)

    def delete(self, ids) -> int:
        """Tombstone global ids.  All-or-nothing: every id is validated
        (known, in range, not already tombstoned, no duplicates within the
        request) before any bit flips, so a rejected request leaves the
        index untouched.  Returns the number of ids tombstoned."""
        arr = np.asarray(ids)
        if arr.ndim == 0:
            arr = arr[None]
        if arr.size == 0:
            return 0
        if arr.dtype.kind not in "iu":
            raise KeyError(
                f"ids must be integers, got dtype {arr.dtype!r}")
        arr = arr.astype(np.int64).ravel()
        n_total = self.n_total()
        seen: set = set()
        for i in arr.tolist():
            if i < 0 or i >= n_total:
                raise KeyError(
                    f"id {i} out of range [0, {n_total}) "
                    f"({self.n_base} base rows + {self.delta.count} delta "
                    "rows)")
            if i in seen:
                raise KeyError(f"duplicate id {i} in delete request")
            seen.add(i)
            alive = (self.base_alive[i] if i < self.n_base
                     else self.delta.alive[i - self.n_base])
            if not alive:
                raise KeyError(f"id {i} already deleted")
        for i in arr.tolist():
            if i < self.n_base:
                self.base_alive[i] = False
            else:
                self.delta.alive[i - self.n_base] = False
        return int(arr.size)

    # -- views ---------------------------------------------------------------

    def device_view(self) -> tuple:
        """(base_alive [n_base] bool, delta_X [cap, d] f32, delta_alive
        [cap] bool) — copies, so the plane's device snapshot is immune to
        later host-side mutation."""
        return (self.base_alive.copy(), self.delta.X.copy(),
                self.delta.alive.copy())

    @classmethod
    def restore(cls, base_alive, delta_X, delta_alive, *,
                min_cap: int = MIN_CAP) -> "StreamState":
        """Rebuild from persisted arrays (artifact format v3): delta arrays
        hold only the ``count`` assigned slots; capacity re-pads here."""
        base_alive = np.asarray(base_alive, bool)
        delta_X = np.asarray(delta_X, np.float32)
        delta_alive = np.asarray(delta_alive, bool)
        st = cls(base_alive.shape[0], delta_X.shape[1], min_cap=min_cap)
        st.base_alive[:] = base_alive
        count = delta_X.shape[0]
        if count:
            st.delta.append(delta_X)
            st.delta.alive[:count] = delta_alive
        return st
