"""Compaction: fold streamed mutations back into a fresh index generation.

DESIGN.md §7.  Streaming writes (:mod:`repro.ann.delta`) keep the built
graph frozen and accumulate in a tombstone mask + brute-force delta shard;
:func:`compact` ends an epoch by re-running the staged build pipeline over
the *effective corpus* — live base rows followed by live delta rows — and
hot-swapping the new generation into the serving plane:

* **Bitwise parity.**  The new generation is produced by exactly the code
  path a fresh ``Index.build`` runs (``build_graph`` on the single plane,
  the shard-mapped build on the mesh plane), on exactly the fresh-build
  array shapes (live rows only, no capacity padding), so post-compaction
  searches are bitwise-identical to a cold build over the same vectors —
  the correctness bar ``tests/test_streaming.py`` pins.
* **Hot swap.**  ``plane.rebind`` replaces the operand snapshot atomically
  between micro-batches: in-flight calls finish on the old (immutable)
  arrays, and cached executables whose operand shapes survive the swap
  keep serving with ZERO recompiles (``ServeStats.compiles == 0`` across a
  same-shape generation swap).  Shape-changing swaps surface as
  ``StaleGeneration`` and the engine re-dispatches.
* **Renumbering.**  Compaction densifies ids.  The returned ``id_map``
  (int64 [n_base + n_delta_slots], old global id -> new id, ``-1`` for
  tombstoned/unassigned rows) is the caller's bridge for external id
  bookkeeping.
"""
from __future__ import annotations

import numpy as np


def effective_corpus(stream, base_X: np.ndarray):
    """(X_eff, id_map) for a mutation log over ``base_X``.

    ``X_eff [n_active, d]`` is live base rows (original order) followed by
    live delta rows (slot order) — the corpus a fresh build over the
    mutated index covers.  ``id_map [n_total] int64`` maps every old global
    id to its post-compaction row, -1 where tombstoned."""
    base_X = np.asarray(base_X, np.float32)
    n_base = stream.n_base
    count = stream.delta.count
    base_alive = stream.base_alive
    delta_alive = stream.delta.alive[:count]
    parts = [base_X[base_alive]]
    if count:
        parts.append(stream.delta.X[:count][delta_alive])
    X_eff = np.concatenate(parts, axis=0) if len(parts) > 1 else parts[0]
    id_map = np.full((n_base + count,), -1, np.int64)
    id_map[:n_base][base_alive] = np.arange(int(base_alive.sum()))
    if count:
        id_map[n_base:][delta_alive] = int(base_alive.sum()) \
            + np.arange(int(delta_alive.sum()))
    return X_eff, id_map


def compact(engine, *, tile: int = 2048) -> np.ndarray:
    """Rebuild ``engine``'s index over its effective corpus and hot-swap
    the new generation in (see module docstring).  Returns the old->new
    ``id_map``.  A clean index (no mutations since the last generation) is
    a no-op returning the identity map."""
    with engine._mutlock:
        stream = engine.stream
        n_base = int(engine.X.shape[0])
        if stream is None or not stream.dirty:
            engine.stream = None
            engine.plane.clear_stream()
            return np.arange(n_base, dtype=np.int64)
        if stream.n_active() == 0:
            raise ValueError(
                "cannot compact to an empty index: every row is "
                "tombstoned; add vectors or rebuild")
        base_X = np.asarray(engine.X)
        perm = getattr(engine.graph, "perm", None)
        if perm is not None:
            # locality-packed plane (DESIGN.md §10): device rows are in
            # packed order, but the mutation log (and id_map semantics)
            # live in external ids — un-permute before cutting the corpus
            from repro.ann.layout import unpack_rows
            nsh = getattr(engine.plane, "n_db_shards", 1)
            base_X = unpack_rows(base_X, np.asarray(perm), n_shards=nsh)
        X_eff, id_map = effective_corpus(stream, base_X)
        plane = engine.plane
        if plane.name == "mesh":
            shards = plane.n_db_shards
            if X_eff.shape[0] % shards:
                raise ValueError(
                    f"effective corpus has {X_eff.shape[0]} rows, not "
                    f"divisible over {shards} DB shards; add/delete "
                    "vectors to a multiple or compact on a single plane")
            # the same device_put + shard-mapped build a fresh MeshPlane
            # runs -> bitwise a cold build of X_eff
            plane.rebind(X_eff)
        else:
            from repro.ann.pipeline import build_graph
            import jax.numpy as jnp
            Xe = jnp.asarray(X_eff)
            graph = build_graph(Xe, engine.cfg, tile=tile)
            plane.rebind(Xe, graph)
        engine.stream = None
        engine._prune_stale_entries()
        with engine._lock:
            engine.stats.compactions += 1
            engine.stats.generation += 1
        return id_map
