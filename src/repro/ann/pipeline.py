"""Staged, pluggable index-build pipeline (knn -> diversify -> bridges).

:func:`build_graph` is the facade's build path: it runs the named stages of
``cfg.build_pipeline`` over a shared :class:`BuildState` and returns the
:class:`~repro.core.diversify.PackedGraph`.  The default stages reproduce
the paper's build exactly — bit-for-bit the same graph the old
``build_tsdg`` entry point produced (that function is now a thin shim over
this pipeline):

  * ``"knn"``       — NN-expansion k-NN graph (skipped when the caller
    supplies a precomputed ``knn_ids``/``knn_dists`` pair);
  * ``"diversify"`` — the paper's §3 two-stage diversification: relaxed GD
    (Eq. 2) -> symmetrize (reverse edges) -> soft GD occlusion factors,
    λ-sorted and truncated to ``max_degree``;
  * ``"bridges"``   — beyond-paper hub cross-links (no-op when
    ``cfg.bridge_hubs == 0``).

Third-party stages plug in with :func:`register_stage`, mirroring the
kernel-backend registry in :mod:`repro.core.hotpath`: a stage is a callable
``stage(state) -> None`` mutating the :class:`BuildState` in place, and a
config selects it by name via ``cfg.build_pipeline``.
"""
from __future__ import annotations

import dataclasses
import difflib

import jax
import jax.numpy as jnp

from repro.core import metrics as M
from repro.core.diversify import (PackedGraph, add_bridges, append_reverse,
                                  relaxed_gd, soft_gd)
from repro.core.knn_build import nn_descent


@dataclasses.dataclass
class BuildState:
    """Mutable scratch shared by the stages of one build.

    ``X`` is metric-preprocessed; stages communicate through the optional
    fields (``knn`` fills ``knn_ids``/``knn_dists``, ``diversify`` fills the
    packed arrays, later stages may rewrite them).
    """

    X: jax.Array
    cfg: object
    tile: int = 2048
    unroll: bool = False
    backend: str = "auto"
    gather_fused: str | None = None
    knn_ids: jax.Array | None = None
    knn_dists: jax.Array | None = None
    neighbors: jax.Array | None = None
    lambdas: jax.Array | None = None
    degrees: jax.Array | None = None
    hubs: jax.Array | None = None
    perm: jax.Array | None = None  # [N] int32 new->old ("layout" stage)


# --------------------------------------------------------------------------
# stage registry (mirrors hotpath.register_backend)
# --------------------------------------------------------------------------

_STAGES: dict = {}


def register_stage(name: str, fn=None):
    """Register a build stage; usable directly or as a decorator.

    A stage is ``fn(state: BuildState) -> None`` and becomes selectable by
    name in ``cfg.build_pipeline`` / ``Index.build(stages=...)``.
    """
    if fn is None:
        def deco(f):
            _STAGES[name] = f
            return f
        return deco
    _STAGES[name] = fn
    return fn


def build_stages() -> tuple:
    """Registered stage names, sorted."""
    return tuple(sorted(_STAGES))


def get_stage(name: str):
    """Stage callable for ``name``; unknown names suggest close matches."""
    try:
        return _STAGES[name]
    except KeyError:
        close = difflib.get_close_matches(name, _STAGES, n=3, cutoff=0.5)
        hint = f"; did you mean {', '.join(close)}?" if close else ""
        raise KeyError(f"unknown build stage {name!r}{hint}; "
                       f"registered: {build_stages()}") from None


# --------------------------------------------------------------------------
# default stages — the paper's build, factored
# --------------------------------------------------------------------------

@register_stage("knn")
def _stage_knn(s: BuildState) -> None:
    """NN-expansion k-NN graph; respects caller-precomputed lists."""
    if s.knn_ids is None:
        s.knn_ids, s.knn_dists = nn_descent(
            s.X, s.cfg.k_graph, metric=s.cfg.metric, unroll=s.unroll,
            backend=s.backend, gather_fused=s.gather_fused)


@register_stage("diversify")
def _stage_diversify(s: BuildState) -> None:
    """Paper §3: relaxed GD -> symmetrize -> soft GD (λ-sorted, truncated)."""
    cfg = s.cfg
    keep = relaxed_gd(s.X, s.knn_ids, s.knn_dists, alpha=cfg.alpha,
                      metric=cfg.metric, tile=s.tile, unroll=s.unroll,
                      backend=s.backend, gather_fused=s.gather_fused)
    adj_ids, adj_d = append_reverse(s.X, s.knn_ids, s.knn_dists, keep,
                                    rev_cap=cfg.k_graph, metric=cfg.metric,
                                    backend=s.backend,
                                    gather_fused=s.gather_fused)
    s.neighbors, s.lambdas, s.degrees = soft_gd(
        s.X, adj_ids, adj_d, lambda0=cfg.lambda0,
        max_degree=cfg.max_degree, metric=cfg.metric, tile=s.tile,
        unroll=s.unroll, backend=s.backend, gather_fused=s.gather_fused)


@register_stage("bridges")
def _stage_bridges(s: BuildState) -> None:
    """Beyond-paper hub cross-links; no-op when ``cfg.bridge_hubs == 0``."""
    cfg = s.cfg
    n_hubs = getattr(cfg, "bridge_hubs", 0)
    if not n_hubs:
        return
    N = s.X.shape[0]
    n_hubs = min(n_hubs, N // 4)
    hub_k = min(getattr(cfg, "bridge_k", 8), cfg.max_degree // 2)
    s.neighbors, s.lambdas, s.hubs = add_bridges(
        s.X, s.neighbors, s.lambdas, n_hubs=n_hubs, hub_k=hub_k,
        metric=cfg.metric)
    s.degrees = jnp.sum(s.neighbors < N, axis=1).astype(jnp.int32)


@register_stage("layout")
def _stage_layout(s: BuildState) -> None:
    """Locality-packed layout (DESIGN.md §10): BFS-reorder node ids so
    neighbor rows land contiguous in HBM and the gather kernel's grouped
    DMA coalesces.  Host-side numpy — the traversal is sequential and runs
    once per build, so this stage cannot appear inside a traced (mesh
    shard_map) build; the mesh plane applies it per shard after the traced
    stages instead."""
    import numpy as np

    from repro.ann import layout as L

    if isinstance(s.X, jax.core.Tracer):
        raise ValueError(
            "the 'layout' build stage runs on host and cannot be traced; "
            "mesh builds must strip it from the in-map pipeline and apply "
            "the layout per shard afterwards (distributed.make_build_fn "
            "does this automatically)")
    if s.neighbors is None:
        raise ValueError("'layout' must come after a graph-producing stage "
                         "(e.g. 'diversify')")
    nbrs = np.asarray(jax.device_get(s.neighbors))
    hubs_np = None if s.hubs is None else np.asarray(jax.device_get(s.hubs))
    perm = L.locality_order(nbrs, starts=hubs_np)
    X2, nb2, lam2, deg2, hubs2 = L.apply_layout(
        perm, jax.device_get(s.X), nbrs, jax.device_get(s.lambdas),
        jax.device_get(s.degrees), hubs_np)
    s.X = jnp.asarray(X2)
    s.neighbors = jnp.asarray(nb2)
    s.lambdas = jnp.asarray(lam2)
    s.degrees = jnp.asarray(deg2)
    s.hubs = None if hubs2 is None else jnp.asarray(hubs2)
    s.perm = jnp.asarray(perm)


# --------------------------------------------------------------------------
# runner
# --------------------------------------------------------------------------

def build_graph(X, cfg, *, stages=None, tile: int = 2048,
                knn_ids=None, knn_dists=None) -> PackedGraph:
    """Run the staged build pipeline and return the packed graph.

    ``stages`` overrides ``cfg.build_pipeline`` (default
    ``("knn", "diversify", "bridges")``).  Stage names resolve through the
    registry, so configs can select third-party stages registered with
    :func:`register_stage`.
    """
    names = tuple(stages if stages is not None
                  else getattr(cfg, "build_pipeline",
                               ("knn", "diversify", "bridges")))
    fns = [(n, get_stage(n)) for n in names]  # resolve before any compute
    state = BuildState(
        X=M.preprocess(jnp.asarray(X), cfg.metric), cfg=cfg, tile=tile,
        unroll=getattr(cfg, "unroll_scans", False),
        backend=getattr(cfg, "kernel_backend", "auto"),
        gather_fused=getattr(cfg, "gather_fused", None),
        knn_ids=knn_ids, knn_dists=knn_dists)
    for name, fn in fns:
        fn(state)
    if state.neighbors is None:
        raise ValueError(
            f"build pipeline {names} produced no graph — it must include a "
            "stage that sets state.neighbors/lambdas/degrees "
            "(e.g. 'diversify')")
    return PackedGraph(neighbors=state.neighbors, lambdas=state.lambdas,
                       degrees=state.degrees, hubs=state.hubs,
                       perm=state.perm)
