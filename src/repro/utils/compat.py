"""JAX version-compatibility shims.

The codebase targets the modern ``jax.shard_map`` API (jax >= 0.6) but must
also run on the 0.4.x line, where the function lives in
``jax.experimental.shard_map`` and the replication-check kwarg is spelled
``check_rep`` instead of ``check_vma``.  Everything that shard-maps goes
through :func:`shard_map` below so the version split lives in exactly one
place.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.6: top-level export, kwarg `check_vma`
    _shard_map = jax.shard_map
    _CHECK_KWARG = "check_vma"
except AttributeError:  # jax 0.4.x: experimental module, kwarg `check_rep`
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KWARG = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """`jax.shard_map` resolved across JAX versions.

    `check_vma` follows the modern spelling; on 0.4.x it is forwarded as
    `check_rep` (same semantics: verify per-axis replication of outputs).
    """
    kwargs = {} if check_vma is None else {_CHECK_KWARG: check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)
