"""Warn-once deprecation helper for the pre-facade entry points.

The old public seams (``build_tsdg``, the two ``*_batch_search`` functions)
keep working as thin shims over the internal layer, but steer callers to
the :mod:`repro.ann` facade (DESIGN.md §5).  Each seam warns at most once
per process so hot loops and test suites are not flooded.
"""
from __future__ import annotations

import warnings

_seen: set = set()


def warn_once(old: str, new: str) -> None:
    if old in _seen:
        return
    _seen.add(old)
    warnings.warn(
        f"{old} is a deprecated entry point; use {new} (DESIGN.md §5). "
        "It remains a thin shim over the same internal implementation.",
        DeprecationWarning, stacklevel=3)
