"""SO(3) machinery for MACE: real spherical harmonics (l <= 3) and real
Clebsch-Gordan (Wigner-3j-style) coupling coefficients.

Complex CG coefficients come from the Racah closed form; real-basis
coefficients are obtained by conjugating with the standard complex->real
spherical-harmonic unitary.  For integer l the result is purely real (or
purely imaginary, fixed by an i^{l1+l2-l3} phase); we verify numerically at
import-test time that the imaginary residue is ~0 (see tests/test_so3.py,
which also checks rotation equivariance end-to-end).
"""
from __future__ import annotations

import functools
import math

import numpy as np

# --------------------------------------------------------------------------
# complex Clebsch-Gordan (Racah formula)
# --------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _fact(n: int) -> float:
    return math.factorial(n)


def cg_complex(j1, m1, j2, m2, j3, m3) -> float:
    """<j1 m1 j2 m2 | j3 m3> (Condon-Shortley)."""
    if m3 != m1 + m2:
        return 0.0
    if not (abs(j1 - j2) <= j3 <= j1 + j2):
        return 0.0
    if abs(m1) > j1 or abs(m2) > j2 or abs(m3) > j3:
        return 0.0
    pref = math.sqrt(
        (2 * j3 + 1) * _fact(j3 + j1 - j2) * _fact(j3 - j1 + j2)
        * _fact(j1 + j2 - j3) / _fact(j1 + j2 + j3 + 1))
    pref *= math.sqrt(
        _fact(j3 + m3) * _fact(j3 - m3) * _fact(j1 - m1) * _fact(j1 + m1)
        * _fact(j2 - m2) * _fact(j2 + m2))
    s = 0.0
    for k in range(0, j1 + j2 - j3 + 1):
        d1 = j1 + j2 - j3 - k
        d2 = j1 - m1 - k
        d3 = j2 + m2 - k
        d4 = j3 - j2 + m1 + k
        d5 = j3 - j1 - m2 + k
        if min(d1, d2, d3, d4, d5) < 0:
            continue
        s += (-1) ** k / (_fact(k) * _fact(d1) * _fact(d2) * _fact(d3)
                          * _fact(d4) * _fact(d5))
    return pref * s


# --------------------------------------------------------------------------
# complex -> real spherical-harmonic change of basis
# --------------------------------------------------------------------------


def real_basis_matrix(l: int) -> np.ndarray:
    """U[l] with  Y_real = U @ Y_complex  (rows: m_real = -l..l)."""
    dim = 2 * l + 1
    U = np.zeros((dim, dim), np.complex128)
    s2 = 1.0 / math.sqrt(2.0)
    for m in range(-l, l + 1):
        r = m + l  # row index for real m
        if m < 0:
            U[r, l + m] = 1j * s2
            U[r, l - m] = -1j * s2 * (-1) ** m
        elif m == 0:
            U[r, l] = 1.0
        else:
            U[r, l - m] = s2
            U[r, l + m] = s2 * (-1) ** m
    return U


@functools.lru_cache(maxsize=None)
def real_cg(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real-basis coupling tensor C[m1, m2, m3] (float64).

    Satisfies:  (Y_{l1} outer Y_{l2}) : C  transforms as Y_{l3}.
    """
    d1, d2, d3 = 2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1
    Cc = np.zeros((d1, d2, d3), np.complex128)
    for m1 in range(-l1, l1 + 1):
        for m2 in range(-l2, l2 + 1):
            m3 = m1 + m2
            if abs(m3) <= l3:
                Cc[m1 + l1, m2 + l2, m3 + l3] = cg_complex(
                    l1, m1, l2, m2, l3, m3)
    U1, U2, U3 = (real_basis_matrix(l) for l in (l1, l2, l3))
    # C_real = U1* x U2* x U3 applied to C_complex
    C = np.einsum("ai,bj,ijk,ck->abc", np.conj(U1), np.conj(U2), Cc, U3)
    # integer-l coupling is real up to a global i^{l1+l2+l3} phase
    if np.abs(C.imag).max() > np.abs(C.real).max():
        C = (C / 1j)
    assert np.abs(C.imag).max() < 1e-10, (l1, l2, l3, np.abs(C.imag).max())
    return np.ascontiguousarray(C.real)


# --------------------------------------------------------------------------
# real spherical harmonics of unit vectors (l <= 3, racah normalization)
# --------------------------------------------------------------------------


def spherical_harmonics(vec: np.ndarray, l_max: int):
    """vec [..., 3] (unit vectors) -> [..., (l_max+1)^2].

    Racah normalization (Y_0 = 1), matching e3nn's 'integral'-free convention
    used by MACE: components are polynomials in (x, y, z).
    Works with numpy or jax.numpy arrays.
    """
    xp = _xp(vec)
    x, y, z = vec[..., 0], vec[..., 1], vec[..., 2]
    out = [xp.ones_like(x)]  # l = 0
    if l_max >= 1:
        out += [y, z, x]     # l = 1 (e3nn component order)
    if l_max >= 2:
        s3 = math.sqrt(3.0)
        out += [
            s3 * x * y,
            s3 * y * z,
            0.5 * (3 * z * z - 1.0),
            s3 * x * z,
            0.5 * s3 * (x * x - y * y),
        ]
    if l_max >= 3:
        s = math.sqrt
        out += [
            s(5.0 / 8.0) * y * (3 * x * x - y * y),
            s(15.0) * x * y * z,
            s(3.0 / 8.0) * y * (5 * z * z - 1),
            0.5 * z * (5 * z * z - 3),
            s(3.0 / 8.0) * x * (5 * z * z - 1),
            0.5 * s(15.0) * z * (x * x - y * y),
            s(5.0 / 8.0) * x * (x * x - 3 * y * y),
        ]
    return xp.stack(out, axis=-1)


def _xp(a):
    if isinstance(a, np.ndarray):
        return np
    import jax.numpy as jnp

    return jnp


def irrep_slices(l_max: int):
    """[(l, start, stop)] into the flattened (l_max+1)^2 axis."""
    out, off = [], 0
    for l in range(l_max + 1):
        out.append((l, off, off + 2 * l + 1))
        off += 2 * l + 1
    return out
