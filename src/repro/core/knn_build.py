"""k-NN graph construction: exact tiled brute force + NN-Descent.

The paper builds its k-NN graphs with GPU NN-Descent [31].  TPU adaptation
(DESIGN.md §2): NN-Descent's *local join* trades distance computations for
scatter traffic — the right trade on CUDA cores, the wrong one on an MXU
where batched gather+GEMM distance evaluation is nearly free.  We therefore
run NN-*expansion* with reverse edges: per iteration each node evaluates its
neighbors-of-neighbors + reverse neighbors with one batched GEMM and merges
top-k.  Same fixpoint, TPU-shaped inner loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import hotpath as HP
from repro.core import metrics as M

INF = jnp.float32(3.4e38)


def tiled_map(fn, n: int, unroll: bool = False):
    """lax.map over range(n); python-unrolled when `unroll` (so the dry-run's
    cost_analysis counts every tile — XLA costs a while body exactly once)."""
    if unroll:
        outs = [fn(i) for i in range(n)]
        return jax.tree.map(lambda *a: jnp.stack(a), *outs)
    return jax.lax.map(fn, jnp.arange(n))


# --------------------------------------------------------------------------
# exact (tiled brute force)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "metric", "tile", "unroll"))
def exact_knn(X, k: int, metric: str = "l2", tile: int = 1024,
              unroll: bool = False):
    """[N, d] -> (ids [N, k], dists [N, k]); excludes self."""
    N = X.shape[0]
    n_tiles = -(-N // tile)
    Xp = jnp.pad(X, ((0, n_tiles * tile - N), (0, 0)))

    def one_tile(i):
        q = jax.lax.dynamic_slice_in_dim(Xp, i * tile, tile, axis=0)
        dist = M.pairwise(q, X, metric)                        # [tile, N]
        rows = i * tile + jnp.arange(tile)
        dist = jnp.where(rows[:, None] == jnp.arange(N)[None, :], INF, dist)
        dist = jnp.where(rows[:, None] >= N, INF, dist)
        neg, ids = jax.lax.top_k(-dist, k)
        return ids.astype(jnp.int32), -neg

    ids, dists = tiled_map(one_tile, n_tiles, unroll)
    return ids.reshape(-1, k)[:N], dists.reshape(-1, k)[:N]


# --------------------------------------------------------------------------
# reverse adjacency with fixed cap (sort-based scatter; shared with MoE trick)
# --------------------------------------------------------------------------

def reverse_neighbors(ids, valid, cap: int):
    """ids [N, K] (+valid mask) -> reverse lists [N, cap] (sentinel = N)."""
    N, K = ids.shape
    src = jnp.repeat(jnp.arange(N, dtype=jnp.int32), K)
    dst = ids.reshape(-1)
    dst = jnp.where(valid.reshape(-1), dst, N)                 # invalid -> trash
    order = jnp.argsort(dst, stable=True)
    sdst, ssrc = dst[order], src[order]
    counts = jnp.bincount(dst, length=N + 1)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(N * K) - starts[sdst]
    keep = (rank < cap) & (sdst < N)
    slot = jnp.where(keep, sdst * cap + rank, N * cap)
    rev = jnp.full((N * cap + 1,), N, jnp.int32).at[slot].set(ssrc)
    return rev[: N * cap].reshape(N, cap)


# --------------------------------------------------------------------------
# NN-expansion (TPU-shaped NN-Descent)
# --------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("k", "metric", "iters", "sample",
                                    "unroll", "backend", "gather_fused"))
def nn_descent(X, k: int, metric: str = "l2", iters: int = 8,
               sample: int = 8, seed: int = 0, unroll: bool = False,
               backend: str = "auto", gather_fused: str | None = None):
    """Approximate k-NN graph. Returns (ids [N, k], dists [N, k]) sorted asc.

    Per iteration, candidates(u) = reverse(u) ++ B[B[u]][:, :sample] — one
    gather + one batched GEMM per node, merged by (dedup, top-k).
    """
    N, d = X.shape
    key = jax.random.key(seed)
    ids = jax.random.randint(key, (N, k), 0, N, jnp.int32)
    # avoid self at init
    ids = jnp.where(ids == jnp.arange(N)[:, None], (ids + 1) % N, ids)
    dists = HP.neighbor_distances(X, X, ids, metric=metric,
                                  backend=backend,
                                  gather_fused=gather_fused)
    dists, ids = HP.rank_merge(dists, ids, keep=k, backend=backend)

    def body(state, _):
        ids, dists = state
        rev = reverse_neighbors(ids, ids < N, cap=k)           # [N, k]
        hop2 = ids[jnp.clip(ids, 0, N - 1)][:, :, :sample]     # [N, k, sample]
        cand = jnp.concatenate([rev, hop2.reshape(N, k * sample)], axis=1)
        cand = jnp.where(cand == jnp.arange(N)[:, None], N, cand)  # drop self
        # one fused gather+GEMM evaluation; cand >= N masked in-kernel
        cdist = HP.neighbor_distances(X, X, cand, metric=metric,
                                      backend=backend,
                                      gather_fused=gather_fused)
        all_ids = jnp.concatenate([ids, cand], axis=1)
        all_d = jnp.concatenate([dists, cdist], axis=1)
        # dedup by id then keep k smallest
        order = jnp.argsort(all_ids, axis=1)
        sid = jnp.take_along_axis(all_ids, order, axis=1)
        sd = jnp.take_along_axis(all_d, order, axis=1)
        dup = jnp.concatenate(
            [jnp.zeros((N, 1), bool), sid[:, 1:] == sid[:, :-1]], axis=1)
        new_d, new_ids = HP.rank_merge(sd, sid, keep=k,
                                       mask=~dup & (sid < N),
                                       backend=backend)
        return (new_ids.astype(jnp.int32), new_d), None

    (ids, dists), _ = jax.lax.scan(body, (ids, dists), None, length=iters,
                                   unroll=unroll)
    return ids, dists

