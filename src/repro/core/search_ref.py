"""Reference CPU best-first search (numpy) — the NSG-style procedure the
paper uses for its CPU evaluation (§5.3), with 32 random starting seeds.

Serves three roles: (a) the paper's CPU search for Fig. 4-style benchmarks,
(b) a correctness oracle for the TPU search procedures, (c) an unbounded
upper bound on what a given graph can reach (no hashed-structure losses).
"""
from __future__ import annotations

import heapq

import numpy as np


def _dist(q, x, metric):
    if metric in ("ip", "cos"):
        return -float(np.dot(q, x))
    diff = q - x
    return float(np.dot(diff, diff))


def best_first_search(X: np.ndarray, neighbors: np.ndarray,
                      lambdas: np.ndarray, q: np.ndarray, *, k: int = 10,
                      ef: int = 64, lambda_limit: int = 10,
                      metric: str = "l2", n_seeds: int = 32,
                      rng: np.random.Generator | None = None):
    """Single-query best-first search. Returns (ids [k], dists [k])."""
    N = X.shape[0]
    rng = rng or np.random.default_rng(0)
    seeds = rng.integers(0, N, size=n_seeds)
    visited = set()
    cand: list = []   # min-heap of (dist, id)
    top: list = []    # max-heap of (-dist, id), size <= ef
    for s in set(seeds.tolist()):
        d = _dist(q, X[s], metric)
        heapq.heappush(cand, (d, s))
        heapq.heappush(top, (-d, s))
        visited.add(s)
    while len(top) > ef:
        heapq.heappop(top)

    while cand:
        d_u, u = heapq.heappop(cand)
        if len(top) == ef and d_u > -top[0][0]:
            break
        for e, lam in zip(neighbors[u], lambdas[u]):
            e = int(e)
            if e >= N or lam >= lambda_limit or e in visited:
                continue
            visited.add(e)
            d_e = _dist(q, X[e], metric)
            if len(top) < ef or d_e < -top[0][0]:
                heapq.heappush(cand, (d_e, e))
                heapq.heappush(top, (-d_e, e))
                if len(top) > ef:
                    heapq.heappop(top)
    out = sorted([(-nd, i) for nd, i in top])[:k]
    ids = np.array([i for _, i in out], np.int32)
    ds = np.array([d for d, _ in out], np.float32)
    return ids, ds


def search_batch(X, graph, Q, *, k=10, ef=64, lambda_limit=10, metric="l2",
                 seed=0):
    """Batch wrapper; graph is a PackedGraph (device or numpy arrays)."""
    nbrs = np.asarray(graph.neighbors)
    lams = np.asarray(graph.lambdas)
    Xn = np.asarray(X)
    rng = np.random.default_rng(seed)
    ids, ds = [], []
    for q in np.asarray(Q):
        i, d = best_first_search(Xn, nbrs, lams, q, k=k, ef=ef,
                                 lambda_limit=lambda_limit, metric=metric,
                                 rng=rng)
        ids.append(i)
        ds.append(d)
    return np.stack(ids), np.stack(ds)
