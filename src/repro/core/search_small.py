"""Small-batch NN search — paper Algorithm 1, TPU adaptation.

Per query, `t0` independent cheap greedy searches run in parallel; quality
comes from the *number* of searches, not per-search care (paper §4.1).  The
whole (B x t0) search population advances in lock-step: each hop is

  gather neighbor ids -> gather vectors -> one batched GEMM of distances
  -> lane-paired R_temp update -> half-merge into R_ij -> pick next u

which is exactly the paper's warp schedule with the 32-lane warp replaced by
vector lanes and the per-warp distance loop replaced by an MXU contraction.
The hop's distance evaluation and ranking merges go through the
``repro.core.hotpath`` primitives, so the Pallas and XLA kernel backends
share this file bit-for-bit (DESIGN.md §3).

Faithful details preserved:
  * 32 random seeds, best becomes the start node (no hierarchy needed);
  * R_temp lane-paired approximate update — candidate i only compares with
    cell i (cheap, deliberately lossy);
  * half-merge: best 16 of R_temp replace the worst 16 of R_ij (bitonic
    half-cleaner semantics), then R_ij is fully re-sorted; all merges dedup
    by id — a node reached through two edges (duplicate graph lanes, bridge
    splices) never occupies two ranking slots (explicit-set semantics,
    enforced by tests/test_search_dedup.py);
  * no expansion queue, no visited set; termination on no-improvement or T;
  * λ-prefix dynamic degree: only edges with λ < λ_limit are visited (the
    graph rows are λ-sorted, so this is a prefix mask).
`exact_merge=True` (beyond-paper toggle) replaces the lossy half-merge with
an exact top-32 merge — measured in benchmarks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import hotpath as HP
from repro.core.diversify import PackedGraph

INF = jnp.float32(3.4e38)


@functools.partial(
    jax.jit,
    static_argnames=("k", "t0", "hops", "hop_width", "n_seeds",
                     "lambda_limit", "metric", "exact_merge", "width",
                     "unroll", "backend", "gather_fused", "t0_total",
                     "rerank_mult", "visited"))
def _small_batch_search(X, graph: PackedGraph, Q, *, k: int = 10,
                       t0: int = 32, hops: int = 6, hop_width: int = 32,
                       n_seeds: int = 32, lambda_limit: int = 10,
                       metric: str = "l2", exact_merge: bool = False,
                       width: int = 32, seed: int = 0,
                       unroll: bool = False, seed_offset=0,
                       t0_offset=0, t0_total: int | None = None,
                       alive=None,
                       backend: str = "auto",
                       gather_fused: str | None = None,
                       codes=None, scales=None, rerank_mult: int = 0,
                       visited: str = "none"):
    """Returns (ids [B, k], dists [B, k]).  `seed_offset` may be traced
    (it perturbs the base key — a cheap way to decorrelate restarts).

    `alive` (optional traced [N] bool) is the streaming tombstone mask
    (DESIGN.md §7): dead rows are excluded from seed selection, from every
    hop's neighbor evaluation, and from the final merge, so a tombstoned
    vector can never surface in the results.  Tombstoned nodes are fully
    invisible (not routed *through* either) — adequate at serving-window
    deletion rates; heavier churn is folded back by compaction.  ``None``
    (the default) traces exactly the frozen-index computation.

    Random seeds are derived per search row (`fold_in` by global row index),
    so row i's draws depend only on (seed, seed_offset, i) — never on the
    batch size.  Appending padding queries (the serving engine's shape
    buckets) therefore leaves the real rows bitwise-identical to an unpadded
    call.

    `t0_offset` / `t0_total` place this call's searches inside a LARGER
    t0 population: query b's search j here is globally search
    ``b * t0_total + t0_offset + j`` (defaults: ``t0_total = t0``,
    ``t0_offset = 0`` — the whole population, bit-identical to older
    revisions).  The mesh execution plane splits the paper's t0 searches
    over the `model` axis with ``t0_offset = column * t0_local``, so the
    union of the columns' searches IS the single-device search population —
    the sharded small regime is bitwise-identical to the single-device one
    (DESIGN.md §6).  `t0_offset` may be traced (it is an `axis_index`
    product inside shard_map).

    ``codes`` [N, d] int8 + ``scales`` [N] f32 (compressed residency,
    DESIGN.md §8): seed selection and every hop score against the
    quantized rows in-kernel; the final merge keeps the best
    ``rerank_mult * k`` distinct survivors, re-scores them exactly
    against the fp32 ``X``, and only then takes top-k — returned
    distances are exact.  ``codes=None`` traces the frozen fp32
    computation bit-for-bit.

    ``graph.perm`` (locality-packed layout, DESIGN.md §10): when present,
    X/codes rows and graph ids are in packed (internal) order, but every
    externally-meaningful quantity stays in ORIGINAL id space — random
    seeds are drawn externally and mapped in, the ``alive`` mask is
    external, the visited filter hashes external ids, and candidate ids
    are mapped back external *before* the final (id, dist) dedup merge —
    so a packed index returns bitwise-identical results to the unpacked
    baseline.

    ``visited="hash"`` (DESIGN.md §10) consults a per-search bucketed
    hash set (:func:`repro.core.hotpath.visited_filter`) before
    candidates enter R_temp: already-seen ids drop to (INF, N) sentinels
    up front, so the hop skips the O(width²) dedup-by-id scans and the
    extra re-rank merge the paper path needs.  ``"none"`` traces the
    frozen computation bit-for-bit.
    """
    N, d = X.shape
    B = Q.shape[0]
    S = B * t0
    if k > t0 * width:
        raise ValueError(
            f"k={k} exceeds the candidate pool t0*width={t0 * width}; "
            "raise t0/width or lower k")
    if visited not in ("none", "hash"):
        raise ValueError(f"visited={visited!r} must be 'none' or 'hash'")
    perm = graph.perm
    if perm is not None:
        # old->new, in-trace (one [N] scatter per call — negligible vs the
        # search itself); maps external draws/ids into packed space
        inv = jnp.zeros((N,), jnp.int32).at[perm].set(
            jnp.arange(N, dtype=jnp.int32))
        alive_int = None if alive is None else alive[perm]
    else:
        inv = None
        alive_int = alive
    half = width // 2
    key = jax.random.fold_in(jax.random.key(seed), seed_offset)
    t0_total = t0 if t0_total is None else t0_total
    flat = jnp.arange(S)
    row_ids = (flat // t0) * t0_total + t0_offset + flat % t0
    row_keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(row_ids)

    Qs = jnp.repeat(Q, t0, axis=0)                            # [S, d]

    # --- seeds: best of n_seeds randoms (paper: as good as hierarchies);
    # half are drawn from the hub set when bridges are enabled ---------------
    seeds = jax.vmap(
        lambda rk: jax.random.randint(rk, (n_seeds,), 0, N, jnp.int32))(
        row_keys)                                             # [S, n_seeds]
    if perm is not None:  # draws are EXTERNAL ids (seed parity) -> map in
        seeds = inv[seeds]
    if graph.hubs is not None:
        nh = graph.hubs.shape[0]
        hub_pick = jax.vmap(
            lambda rk: jax.random.randint(jax.random.fold_in(rk, 1),
                                          (n_seeds // 2,), 0, nh))(row_keys)
        # hubs hold internal ids at layout-invariant POSITIONS, so the
        # same draw picks the same vector packed or not
        seeds = seeds.at[:, : n_seeds // 2].set(graph.hubs[hub_pick])
    seed_mask = alive_int[seeds] if alive is not None else None
    X_score = X if codes is None else codes  # int8 codes when quantized
    sd1, si1 = HP.seed_select(Qs, X_score, seeds, metric=metric, k=1,
                              mask=seed_mask, backend=backend,
                              gather_fused=gather_fused,
                              scales=scales)                  # [S, 1] each
    u, u_d = si1[:, 0], sd1[:, 0]

    rij_ids = jnp.full((S, width), N, jnp.int32)
    rij_d = jnp.full((S, width), INF)
    rij_ids = rij_ids.at[:, 0].set(u)
    rij_d = rij_d.at[:, 0].set(u_d)

    nbrs_all = graph.neighbors
    lams_all = graph.lambdas
    M_deg = nbrs_all.shape[1]
    n_chunks = max(1, -(-M_deg // hop_width))
    pad_m = n_chunks * hop_width - M_deg  # short NN lists -> one padded chunk
    tril_w = jnp.tril(jnp.ones((width, width), bool), k=-1)
    if perm is not None and n_chunks > 1:
        raise ValueError(
            f"packed layout requires hop_width >= max_degree (got "
            f"{hop_width} < {M_deg}): the chunked R_temp argmin pairs "
            "lanes positionally, which is only permutation-equivariant "
            "when a hop is a single chunk")

    def _ext(ids):  # internal -> external (hash keys, output ids)
        if perm is None:
            return ids
        return jnp.where(ids < N, perm[jnp.clip(ids, 0, N - 1)], ids)

    if visited == "hash":
        # <= M_deg fresh inserts per hop + the start node, per search row
        vtab = HP.visited_table(S, hops * M_deg + 1)
        vtab, _ = HP.visited_filter(vtab, _ext(u)[:, None],
                                    valid=(u < N)[:, None], backend=backend)

    def hop(state, _):
        if visited == "hash":
            u, rij_ids, rij_d, active, vtab = state
        else:
            u, rij_ids, rij_d, active = state
        nbrs = nbrs_all[u]                                    # [S, M]
        lams = lams_all[u]
        visit = lams < lambda_limit  # idx >= N masked by the primitive
        if alive is not None:  # tombstoned neighbors never enter a ranking
            visit = visit & alive_int[jnp.clip(nbrs, 0, N - 1)]
        if visited == "hash":
            # already-seen ids drop to (INF, N) sentinels BEFORE scoring:
            # the hop then needs no dedup scans and no re-rank merge.
            # External-id keys + the filter's canonical probe order make
            # the drop set layout-invariant (graph.perm docstring above).
            vtab_new, fresh = HP.visited_filter(
                vtab, _ext(nbrs), valid=visit & (nbrs < N) & active[:, None],
                backend=backend)
            visit = fresh
            nbrs = jnp.where(fresh, nbrs, N)
        dists = HP.neighbor_distances(Qs, X_score, nbrs, metric=metric,
                                      mask=visit, backend=backend,
                                      gather_fused=gather_fused,
                                      scales=scales)
        if pad_m:
            dists = jnp.concatenate(
                [dists, jnp.full((S, pad_m), INF)], axis=1)
            nbrs = jnp.concatenate(
                [nbrs, jnp.full((S, pad_m), N, jnp.int32)], axis=1)

        # R_temp: lane-paired min across chunks of `hop_width` (the warp trick)
        cd = dists.reshape(S, n_chunks, hop_width)
        ci = nbrs.reshape(S, n_chunks, hop_width)
        lane_arg = jnp.argmin(cd, axis=1)                     # [S, hop_width]
        rt_d = jnp.take_along_axis(cd, lane_arg[:, None, :], axis=1)[:, 0]
        rt_ids = jnp.take_along_axis(ci, lane_arg[:, None, :], axis=1)[:, 0]
        if hop_width < width:  # pad R_temp to R width
            pad = width - hop_width
            rt_d = jnp.concatenate([rt_d, jnp.full((S, pad), INF)], axis=1)
            rt_ids = jnp.concatenate(
                [rt_ids, jnp.full((S, pad), N, jnp.int32)], axis=1)

        rt_d_s, rt_ids_s = HP.rank_merge(rt_d, rt_ids, keep=width,
                                         backend=backend)
        if visited == "hash":
            # the filter already guarantees R_temp ids are distinct AND
            # absent from R_ij (every id enters a ranking at most once per
            # search), so the paper path's O(width²) dup scans and its
            # re-rank of the deduped half collapse into plain merges
            if exact_merge:
                new_d, new_ids = HP.rank_merge(
                    jnp.concatenate([rij_d, rt_d_s], axis=1),
                    jnp.concatenate([rij_ids, rt_ids_s], axis=1),
                    keep=width, backend=backend)
                improved = jnp.any(new_d < rij_d, axis=1)
            else:
                improved = jnp.any(rt_d_s[:, :half] < rij_d[:, half:],
                                   axis=1)
                new_d, new_ids = HP.rank_merge(
                    jnp.concatenate([rij_d[:, :half], rt_d_s[:, :half]],
                                    axis=1),
                    jnp.concatenate([rij_ids[:, :half], rt_ids_s[:, :half]],
                                    axis=1),
                    keep=width, backend=backend)
            new_u = rt_ids_s[:, 0]
            rij_d = jnp.where(active[:, None], new_d, rij_d)
            rij_ids = jnp.where(active[:, None], new_ids, rij_ids)
            u = jnp.where(active, new_u, u)
            active = active & improved
            return (u, rij_ids, rij_d, active, vtab_new), None
        # dedup R_temp by id: a node reached through two edges (duplicate
        # graph lanes, bridge splices) must not occupy two ranking slots.
        # The (dist, id) sort puts equal-id copies first-is-best, so "equal
        # to some earlier entry" keeps the best copy; dropped lanes become
        # (INF, N) sentinels instead of keep-masked (INF, id) lanes that
        # could shadow a real entry in the final id-dedup merge.
        dup_rt = jnp.any((rt_ids_s[:, :, None] == rt_ids_s[:, None, :])
                         & tril_w[None], axis=2) & (rt_ids_s < N)

        if exact_merge:  # beyond-paper: exact top-`width` of the union
            in_rij = jnp.any((rt_ids_s[:, :, None] == rij_ids[:, None, :])
                             & (rij_d[:, None, :] < INF), axis=2)
            drop = dup_rt | in_rij
            cat_d = jnp.concatenate(
                [rij_d, jnp.where(drop, INF, rt_d_s)], axis=1)
            cat_i = jnp.concatenate(
                [rij_ids, jnp.where(drop, N, rt_ids_s)], axis=1)
            new_d, new_ids = HP.rank_merge(cat_d, cat_i, keep=width,
                                           backend=backend)
            improved = jnp.any(new_d < rij_d, axis=1)
        else:  # paper: best half of R_temp replaces worst half of R_ij
            # also drop candidates already present in the kept R_ij half
            # (they'd double up after the concat below), then re-rank so
            # the best `half` *distinct new* candidates fill the slots
            in_keep = jnp.any(
                (rt_ids_s[:, :, None] == rij_ids[:, None, :half])
                & (rij_d[:, None, :half] < INF), axis=2)
            drop = dup_rt | in_keep
            rt_u_d, rt_u_i = HP.rank_merge(
                jnp.where(drop, INF, rt_d_s), jnp.where(drop, N, rt_ids_s),
                keep=width, backend=backend)
            improved = jnp.any(rt_u_d[:, :half] < rij_d[:, half:], axis=1)
            merged_d = jnp.concatenate(
                [rij_d[:, :half], rt_u_d[:, :half]], axis=1)
            merged_i = jnp.concatenate(
                [rij_ids[:, :half], rt_u_i[:, :half]], axis=1)
            new_d, new_ids = HP.rank_merge(merged_d, merged_i, keep=width,
                                           backend=backend)

        new_u = rt_ids_s[:, 0]                                # closest in R_temp
        # frozen searches keep their state
        rij_d = jnp.where(active[:, None], new_d, rij_d)
        rij_ids = jnp.where(active[:, None], new_ids, rij_ids)
        u = jnp.where(active, new_u, u)
        active = active & improved
        return (u, rij_ids, rij_d, active), None

    if visited == "hash":
        state = (u, rij_ids, rij_d, jnp.ones((S,), bool), vtab)
        (u, rij_ids, rij_d, _, _), _ = jax.lax.scan(
            hop, state, None, length=hops, unroll=unroll)
    else:
        state = (u, rij_ids, rij_d, jnp.ones((S,), bool))
        (u, rij_ids, rij_d, _), _ = jax.lax.scan(
            hop, state, None, length=hops, unroll=unroll)

    # --- merge the t0 searches of each query (dedup + top-k) ---------------
    # (id, dist)-lexsorted so the dedup keeps the BEST copy of each id: a
    # plain stable id-sort keeps the first *column*, which can be an
    # INF-distance copy (λ-masked lane that entered a ranking array),
    # shadowing the real entry
    # packed layout: back to EXTERNAL ids BEFORE the dedup sort, so the
    # (id, dist) order — and hence which duplicate survives — matches the
    # unpacked baseline exactly
    cand_ids = _ext(rij_ids.reshape(B, t0 * width))
    cand_d = rij_d.reshape(B, t0 * width)
    o = jnp.lexsort((cand_d, cand_ids), axis=1)
    sid = jnp.take_along_axis(cand_ids, o, axis=1)
    sd2 = jnp.take_along_axis(cand_d, o, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros((B, 1), bool), sid[:, 1:] == sid[:, :-1]], axis=1)
    keep_lane = ~dup & (sid < N)
    if alive is not None:  # a dead best-seed id can linger in slot 0
        keep_lane = keep_lane & alive[jnp.clip(sid, 0, N - 1)]
    if codes is None:
        out_d, out_ids = HP.rank_merge(sd2, sid, keep=k,
                                       mask=keep_lane, backend=backend)
        return out_ids.astype(jnp.int32), out_d
    # exact fp32 re-rank: keep the best rerank_mult*k distinct survivors
    # of the approximate search, re-score them against the fp32 rows
    # (one narrow gather — the only fp32 row traffic of the whole query),
    # then take the true top-k.  Keep-masked lanes come back INF from the
    # merge, so they stay masked through the re-score and can't resurface.
    rerank = min(max(rerank_mult, 1) * k, sd2.shape[1])
    rr_d, rr_ids = HP.rank_merge(sd2, sid, keep=rerank,
                                 mask=keep_lane, backend=backend)
    # rr_ids are external; the packed fp32 rows want internal indices
    # (INF-masked lanes gather a garbage row harmlessly)
    gi = rr_ids if perm is None else \
        jnp.where(rr_ids < N, inv[jnp.clip(rr_ids, 0, N - 1)], rr_ids)
    ed = HP.neighbor_distances(Q, X, gi, metric=metric,
                               mask=rr_d < INF, backend=backend,
                               gather_fused=gather_fused)
    out_d, out_ids = HP.rank_merge(ed, rr_ids, keep=k, backend=backend)
    return out_ids.astype(jnp.int32), out_d


def small_batch_search(*args, **kwargs):
    """Deprecated public seam — prefer ``repro.ann.Index.search`` (DESIGN.md
    §5), which dispatches to this procedure automatically for small batches.
    Thin shim over :func:`_small_batch_search`; identical results."""
    from repro.utils.deprecation import warn_once
    warn_once("repro.core.search_small.small_batch_search",
              "repro.ann.Index.search")
    return _small_batch_search(*args, **kwargs)
