"""Large-batch NN search — paper Algorithm 2, TPU adaptation.

One best-first search per query, vmapped over the batch (the TPU analogue of
one-thread-block-per-query).  The paper's three data structures are kept with
their exact hashed-segment layouts:

  R — top-`ef` ranking array, fixed size, Δ-relaxed termination
      ``m(u,q) > m(f,q) + Δ`` (f = furthest element of a full R);
  C — expansion queue: `m` segments (segment = id % m) of fixed width;
      insertion evicts the most distant entry of the segment, pop takes the
      global min over segment heads;
  V — visited table: `mv` circular unsorted segments (id % mv), lossy by
      design — only expanded nodes are recorded (paper: "only the nodes used
      in the expansion are pushed into V").

TPU adaptation (DESIGN.md §2): the CUDA motivation for *sorted* segments was
O(1) warp-wide pops; on TPU an [m x seg] masked argmin is a single vector op,
so segments are stored unsorted with validity masks — same behaviour (hash
placement, per-segment eviction), one less sort per hop.  R-merges dedup by
id (strictly better than the paper under a lossy V; noted in EXPERIMENTS).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import metrics as M
from repro.core.diversify import PackedGraph

INF = jnp.float32(3.4e38)


@functools.partial(
    jax.jit,
    static_argnames=("k", "ef", "hops", "lambda_limit", "metric",
                     "n_seeds", "m_seg", "seg", "mv_seg", "segv",
                     "push_all_seeds", "unroll", "gather_limit",
                     "exact_visited"))
def large_batch_search(X, graph: PackedGraph, Q, *, k: int = 10,
                       ef: int = 64, hops: int = 128, lambda_limit: int = 5,
                       metric: str = "l2", n_seeds: int = 32,
                       m_seg: int = 8, seg: int = 32, mv_seg: int = 8,
                       segv: int = 32, delta: float = 0.0, seed: int = 0,
                       push_all_seeds: bool = True, unroll: bool = False,
                       gather_limit: int = 0, exact_visited: bool = False):
    """Returns (ids [B, k], dists [B, k]).

    `gather_limit` > 0 fetches only that many λ-sorted columns per row (the
    rows are λ-ascending, so this is the paper's dynamic-degree prefix
    pushed down into the gather itself — beyond-paper, see EXPERIMENTS §Perf).

    `exact_visited=True` (beyond-paper, EXPERIMENTS §Perf cell 3) replaces
    the paper's lossy circular V with an exact per-query byte table in HBM:
    every *evaluated* node is marked, so the per-hop membership tests
    collapse from three structure scans (V rows, C rows, R array) to one
    [M]-byte gather — the CUDA shared-memory capacity constraint that
    forced the lossy V does not exist on TPU.
    """
    N, d = X.shape
    B = Q.shape[0]
    if k > ef:
        raise ValueError(f"k={k} exceeds the ranking array size ef={ef}; "
                         "raise ef or lower k")
    key = jax.random.key(seed)
    # per-row keys: row i's seeds depend only on (seed, i), never on B, so
    # padded batches (serving shape buckets) match unpadded calls bitwise
    row_keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(B))
    seeds = jax.vmap(
        lambda rk: jax.random.randint(rk, (n_seeds,), 0, N, jnp.int32))(
        row_keys)                                             # [B, n_seeds]
    if graph.hubs is not None:
        nh = graph.hubs.shape[0]
        hub_pick = jax.vmap(
            lambda rk: jax.random.randint(jax.random.fold_in(rk, 1),
                                          (n_seeds // 2,), 0, nh))(row_keys)
        seeds = seeds.at[:, : n_seeds // 2].set(graph.hubs[hub_pick])

    nbrs_all, lams_all = graph.neighbors, graph.lambdas
    if gather_limit and gather_limit < nbrs_all.shape[1]:
        nbrs_all = nbrs_all[:, :gather_limit]
        lams_all = lams_all[:, :gather_limit]
    Mdeg = nbrs_all.shape[1]

    def one_query(q, seed_ids):
        # ---- init: best of 32 random seeds -> R = C = {u}  (paper), or
        # push every *already evaluated* seed (beyond-paper, free) ----------
        sd = M.batched_rowwise(q[None], X[seed_ids][None], metric)[0]
        # dedup repeated seed ids so they can't occupy several R slots
        so = jnp.argsort(seed_ids)
        ss_ids, ss_d = seed_ids[so], sd[so]
        dupm = jnp.concatenate([jnp.zeros((1,), bool),
                                ss_ids[1:] == ss_ids[:-1]])
        ss_d = jnp.where(dupm, INF, ss_d)
        if not push_all_seeds:
            b = jnp.argmin(ss_d)
            keep1 = jnp.arange(n_seeds) == b
            ss_d = jnp.where(keep1, ss_d, INF)
        o = jnp.argsort(ss_d)
        init_ids = jnp.where(ss_d[o] < INF, ss_ids[o], N)
        init_d = ss_d[o]

        R_ids = jnp.full((ef,), N, jnp.int32)
        R_d = jnp.full((ef,), INF)
        n_init = min(ef, n_seeds)
        R_ids = R_ids.at[:n_init].set(init_ids[:n_init])
        R_d = R_d.at[:n_init].set(init_d[:n_init])
        # C: hashed-segment batch insert of the seeds
        C_ids = jnp.full((m_seg, seg), N, jnp.int32)
        C_d = jnp.full((m_seg, seg), INF)
        seg_of = jnp.clip(init_ids, 0, N - 1) % m_seg
        smask = (init_d < INF)[None, :] \
            & (seg_of[None, :] == jnp.arange(m_seg)[:, None])
        cd = jnp.where(smask, init_d[None, :], INF)
        ci = jnp.where(smask, init_ids[None, :], N)
        alld = jnp.concatenate([C_d, cd], axis=1)
        alli = jnp.concatenate([C_ids, ci], axis=1)
        os_ = jnp.argsort(alld, axis=1)
        C_d = jnp.take_along_axis(alld, os_, axis=1)[:, :seg]
        C_ids = jnp.take_along_axis(alli, os_, axis=1)[:, :seg]
        if exact_visited:
            # mark the evaluated seeds; V_ptr is unused in this mode
            V = jnp.zeros((N,), jnp.uint8).at[
                jnp.clip(init_ids, 0, N - 1)].set(
                jnp.where(init_d < INF, 1, 0).astype(jnp.uint8))
            V_ptr = jnp.zeros((1,), jnp.int32)
        else:
            V = jnp.full((mv_seg, segv), N, jnp.int32)
            V_ptr = jnp.zeros((mv_seg,), jnp.int32)

        def step(state, _):
            R_ids, R_d, C_ids, C_d, V, V_ptr, done = state

            # ---- pop global min from C (argmin over m x seg lanes) -------
            flat = C_d.reshape(-1)
            pidx = jnp.argmin(flat)
            u_d = flat[pidx]
            u = C_ids.reshape(-1)[pidx]
            empty = u_d >= INF
            C_d2 = C_d.reshape(-1).at[pidx].set(INF).reshape(m_seg, seg)
            C_ids2 = C_ids.reshape(-1).at[pidx].set(N).reshape(m_seg, seg)

            # ---- Δ-relaxed termination (only once R is full) -------------
            r_full = R_d[ef - 1] < INF
            worst = jnp.where(r_full, R_d[ef - 1], INF)
            terminate = empty | (r_full & (u_d > worst + delta))
            now_done = done | terminate
            u_safe = jnp.clip(u, 0, N - 1)

            # ---- neighbors of u, λ-prefix masked --------------------------
            e = nbrs_all[u_safe]                               # [M]
            lam = lams_all[u_safe]
            ok = (lam < lambda_limit) & (e < N) & ~now_done
            e_safe = jnp.clip(e, 0, N - 1)
            # drop repeats within this neighbor list (bridge splicing can
            # duplicate an existing edge) — keep the first occurrence
            dup_here = jnp.any(
                jnp.tril(e_safe[:, None] == e_safe[None, :], k=-1), axis=1)

            if exact_visited:
                # one byte-gather replaces all three membership scans;
                # evaluated nodes are marked immediately below
                in_any = V[e_safe] == 1
                new = ok & ~in_any & ~dup_here
                V2 = V.at[e_safe].set(
                    jnp.where(new & ~now_done, 1, V[e_safe])
                    .astype(jnp.uint8))
                V_ptr2 = V_ptr
            else:
                # ---- V.add(u) (circular segment insert, paper Alg.2) -----
                vs = u_safe % mv_seg
                V2 = V.at[vs, V_ptr[vs] % segv].set(u_safe)
                V_ptr2 = V_ptr.at[vs].add(1)
                V2 = jnp.where(now_done, V, V2)
                V_ptr2 = jnp.where(now_done, V_ptr, V_ptr2)
                # membership tests: e ∉ V and e ∉ C (paper line 15)
                in_V = jnp.any(V2[e_safe % mv_seg] == e_safe[:, None],
                               axis=1)
                c_rows_ids = C_ids2[e_safe % m_seg]            # [M, seg]
                c_rows_d = C_d2[e_safe % m_seg]
                in_C = jnp.any((c_rows_ids == e_safe[:, None])
                               & (c_rows_d < INF), axis=1)
                in_R = jnp.any((R_ids[None, :] == e_safe[:, None])
                               & (R_d[None, :] < INF), axis=1)
                new = ok & ~in_V & ~in_C & ~in_R & ~dup_here

            # ---- distances for new candidates (gather + matvec) ----------
            ev = X[e_safe]                                     # [M, d]
            ed = M.batched_rowwise(q[None], ev[None], metric)[0]
            ed = jnp.where(new, ed, INF)
            admit = (ed < worst) | ~r_full                     # paper line 17
            ed = jnp.where(admit, ed, INF)

            # ---- push into R: dedup merge-sort, keep ef smallest ----------
            cat_d = jnp.concatenate([R_d, ed])
            cat_i = jnp.concatenate([R_ids, jnp.where(ed < INF, e, N)])
            o = jnp.argsort(cat_d)
            R_d3 = cat_d[o][:ef]
            R_ids3 = cat_i[o][:ef]

            # ---- push into C: per-segment insert, evict most distant ------
            seg_of_e = e_safe % m_seg
            cand_mask = (ed < INF)[None, :] \
                & (seg_of_e[None, :] == jnp.arange(m_seg)[:, None])
            cand_d = jnp.where(cand_mask, ed[None, :], INF)    # [m, M]
            cand_i = jnp.where(cand_mask, e[None, :], N)
            all_d = jnp.concatenate([C_d2, cand_d], axis=1)    # [m, seg+M]
            all_i = jnp.concatenate([C_ids2, cand_i], axis=1)
            oseg = jnp.argsort(all_d, axis=1)
            C_d3 = jnp.take_along_axis(all_d, oseg, axis=1)[:, :seg]
            C_ids3 = jnp.take_along_axis(all_i, oseg, axis=1)[:, :seg]

            R_d4 = jnp.where(now_done, R_d, R_d3)
            R_ids4 = jnp.where(now_done, R_ids, R_ids3)
            C_d4 = jnp.where(now_done, C_d, C_d3)
            C_ids4 = jnp.where(now_done, C_ids, C_ids3)
            return (R_ids4, R_d4, C_ids4, C_d4, V2, V_ptr2, now_done), None

        state = (R_ids, R_d, C_ids, C_d, V, V_ptr, jnp.zeros((), bool))
        (R_ids, R_d, *_), _ = jax.lax.scan(step, state, None, length=hops,
                                           unroll=unroll)
        return R_ids[:k], R_d[:k]

    ids, dists = jax.vmap(one_query)(Q, seeds)
    return ids.astype(jnp.int32), dists
