"""Large-batch NN search — paper Algorithm 2, TPU adaptation.

One best-first search per query, advanced in lock-step across the batch
(the TPU analogue of one-thread-block-per-query).  The paper's three data
structures are kept with their exact hashed-segment layouts:

  R — top-`ef` ranking array, fixed size, Δ-relaxed termination
      ``m(u,q) > m(f,q) + Δ`` (f = furthest element of a full R);
  C — expansion queue: `m` segments (segment = id % m) of fixed width;
      insertion evicts the most distant entry of the segment, pop takes the
      global min over segment heads;
  V — visited table: `mv` circular unsorted segments (id % mv), lossy by
      design — only expanded nodes are recorded (paper: "only the nodes used
      in the expansion are pushed into V").

TPU adaptation (DESIGN.md §2): the CUDA motivation for *sorted* segments was
O(1) warp-wide pops; on TPU an [m x seg] masked argmin is a single vector op,
so segments are stored unsorted with validity masks — same behaviour (hash
placement, per-segment eviction), one less sort per hop.  R-merges dedup by
id (strictly better than the paper under a lossy V; noted in EXPERIMENTS).

The whole batch advances as one [B, ...] state (no vmap): the per-hop
neighbor evaluation is a single fused ``hotpath.neighbor_distances`` call
and every ranking update is a ``hotpath.rank_merge`` — the kernel-backend
seam (DESIGN.md §3) that lets the Pallas and XLA paths share this file.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import hotpath as HP
from repro.core.diversify import PackedGraph

INF = jnp.float32(3.4e38)


def _seg_merge(d3, i3, keep: int, backend: str):
    """Per-segment eviction merge: [B, m, W] -> keep smallest `keep` per
    segment (one rank_merge over the flattened segment rows)."""
    B, m, W = d3.shape
    dd, ii = HP.rank_merge(d3.reshape(B * m, W), i3.reshape(B * m, W),
                           keep=keep, backend=backend)
    return dd.reshape(B, m, keep), ii.reshape(B, m, keep)


@functools.partial(
    jax.jit,
    static_argnames=("k", "ef", "hops", "lambda_limit", "metric",
                     "n_seeds", "m_seg", "seg", "mv_seg", "segv",
                     "push_all_seeds", "unroll", "gather_limit",
                     "exact_visited", "backend", "gather_fused",
                     "rerank_mult", "visited"))
def _large_batch_search(X, graph: PackedGraph, Q, *, k: int = 10,
                       ef: int = 64, hops: int = 128, lambda_limit: int = 5,
                       metric: str = "l2", n_seeds: int = 32,
                       m_seg: int = 8, seg: int = 32, mv_seg: int = 8,
                       segv: int = 32, delta: float = 0.0, seed: int = 0,
                       seed_offset=0,
                       push_all_seeds: bool = True, unroll: bool = False,
                       gather_limit: int = 0, exact_visited: bool = False,
                       alive=None,
                       backend: str = "auto",
                       gather_fused: str | None = None,
                       codes=None, scales=None, rerank_mult: int = 0,
                       visited: str = "none"):
    """Returns (ids [B, k], dists [B, k]).

    `alive` (optional traced [N] bool) is the streaming tombstone mask
    (DESIGN.md §7): dead rows are dropped from the seed pool and from every
    expansion's neighbor admission, so they can never enter R or C.  ``None``
    (the default) traces exactly the frozen-index computation.

    `gather_limit` > 0 fetches only that many λ-sorted columns per row (the
    rows are λ-ascending, so this is the paper's dynamic-degree prefix
    pushed down into the gather itself — beyond-paper, see EXPERIMENTS §Perf).

    `exact_visited=True` (beyond-paper, EXPERIMENTS §Perf cell 3) replaces
    the paper's lossy circular V with an exact per-query byte table in HBM:
    every *evaluated* node is marked, so the per-hop membership tests
    collapse from three structure scans (V rows, C rows, R array) to one
    [M]-byte gather — the CUDA shared-memory capacity constraint that
    forced the lossy V does not exist on TPU.

    ``codes`` [N, d] int8 + ``scales`` [N] f32 (compressed residency,
    DESIGN.md §8): seed selection and every expansion score against the
    quantized rows in-kernel; the top ``rerank_mult * k`` of the final R
    are re-scored exactly against the fp32 ``X`` before the returned
    top-k — returned distances are exact.  ``codes=None`` traces the
    frozen fp32 computation bit-for-bit.

    ``graph.perm`` (locality-packed layout, DESIGN.md §10): X/codes rows
    and graph ids are then in packed (internal) order; seeds are drawn in
    EXTERNAL id space and mapped in, every id-hash placement (C segments,
    circular-V segments, the visited filter) keys on the external id, the
    ``alive`` mask is external, and R's ids are mapped back external
    before they leave — a packed index answers bitwise-identically to the
    unpacked baseline.

    ``visited="hash"`` (DESIGN.md §10) replaces the lossy circular V AND
    the three per-hop membership scans (V rows, C rows, R array) with one
    bucketed hash-set probe per neighbor lane
    (:func:`repro.core.hotpath.visited_filter`) — exact up to rare
    bucket-overflow *drops* (never duplicates).  Mutually exclusive with
    ``exact_visited``; ``"none"`` traces the frozen computation
    bit-for-bit.
    """
    N, d = X.shape
    B = Q.shape[0]
    if k > ef:
        raise ValueError(f"k={k} exceeds the ranking array size ef={ef}; "
                         "raise ef or lower k")
    if visited not in ("none", "hash"):
        raise ValueError(f"visited={visited!r} must be 'none' or 'hash'")
    if visited == "hash" and exact_visited:
        raise ValueError("visited='hash' replaces the visited structures; "
                         "it cannot combine with exact_visited=True")
    perm = graph.perm
    if perm is not None:
        if gather_limit:
            raise ValueError(
                "packed layouts re-sort neighbor rows by id, destroying "
                f"the λ-ascending prefix gather_limit={gather_limit} "
                "relies on")
        inv = jnp.zeros((N,), jnp.int32).at[perm].set(
            jnp.arange(N, dtype=jnp.int32))
        alive_int = None if alive is None else alive[perm]
    else:
        inv = None
        alive_int = alive

    def _ext(ids):  # internal -> external (hash keys, output ids)
        if perm is None:
            return ids
        return jnp.where(ids < N, perm[jnp.clip(ids, 0, N - 1)], ids)

    def _ext_hash(ids):  # hash key for CLIPPED ids (always < N)
        return ids if perm is None else perm[ids]
    key = jax.random.key(seed)
    # per-row keys: row i's seeds depend only on (seed, seed_offset + i),
    # never on B, so padded batches (serving shape buckets) match unpadded
    # calls bitwise.  `seed_offset` may be traced — the mesh execution plane
    # passes each model column's global row offset so a query's search is
    # seeded by its GLOBAL batch row, making model-sharded execution
    # bitwise-identical to the single-device plane (DESIGN.md §6).
    row_keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(
        jnp.arange(B) + seed_offset)
    seeds = jax.vmap(
        lambda rk: jax.random.randint(rk, (n_seeds,), 0, N, jnp.int32))(
        row_keys)                                             # [B, n_seeds]
    if perm is not None:  # draws are EXTERNAL ids (seed parity) -> map in
        seeds = inv[seeds]
    if graph.hubs is not None:
        nh = graph.hubs.shape[0]
        hub_pick = jax.vmap(
            lambda rk: jax.random.randint(jax.random.fold_in(rk, 1),
                                          (n_seeds // 2,), 0, nh))(row_keys)
        # hubs hold internal ids at layout-invariant POSITIONS
        seeds = seeds.at[:, : n_seeds // 2].set(graph.hubs[hub_pick])

    nbrs_all, lams_all = graph.neighbors, graph.lambdas
    if gather_limit and gather_limit < nbrs_all.shape[1]:
        nbrs_all = nbrs_all[:, :gather_limit]
        lams_all = lams_all[:, :gather_limit]
    Mdeg = nbrs_all.shape[1]
    rows = jnp.arange(B)

    # ---- init: distance + masked top-k over the seeds (one fused call);
    # repeated seed ids are deduped via the keep-mask so they can't occupy
    # several R slots ------------------------------------------------------
    so = jnp.argsort(seeds, axis=1)
    ss_ids = jnp.take_along_axis(seeds, so, axis=1)
    dupm = jnp.concatenate([jnp.zeros((B, 1), bool),
                            ss_ids[:, 1:] == ss_ids[:, :-1]], axis=1)
    seed_keep = ~dupm if alive is None else ~dupm & alive_int[ss_ids]
    X_score = X if codes is None else codes  # int8 codes when quantized
    init_d, sids = HP.seed_select(Q, X_score, ss_ids, metric=metric,
                                  k=n_seeds, mask=seed_keep, backend=backend,
                                  gather_fused=gather_fused, scales=scales)
    if not push_all_seeds:
        # keep only the best seed (paper: R = C = {u}); sorted, so column 0
        first = jnp.arange(n_seeds)[None, :] == 0
        init_d = jnp.where(first, init_d, INF)
    init_ids = jnp.where(init_d < INF, sids, N)

    R_ids = jnp.full((B, ef), N, jnp.int32)
    R_d = jnp.full((B, ef), INF)
    n_init = min(ef, n_seeds)
    R_ids = R_ids.at[:, :n_init].set(init_ids[:, :n_init])
    R_d = R_d.at[:, :n_init].set(init_d[:, :n_init])
    # C: hashed-segment batch insert of the seeds
    C_ids = jnp.full((B, m_seg, seg), N, jnp.int32)
    C_d = jnp.full((B, m_seg, seg), INF)
    # hash placements key on the EXTERNAL id so packed/unpacked layouts
    # fill identical structures (sentinel lanes are masked via smask)
    seg_of = _ext_hash(jnp.clip(init_ids, 0, N - 1)) % m_seg
    smask = (init_d < INF)[:, None, :] \
        & (seg_of[:, None, :] == jnp.arange(m_seg)[None, :, None])
    cd = jnp.where(smask, init_d[:, None, :], INF)
    ci = jnp.where(smask, init_ids[:, None, :], N)
    C_d, C_ids = _seg_merge(jnp.concatenate([C_d, cd], axis=2),
                            jnp.concatenate([C_ids, ci], axis=2),
                            seg, backend)
    if visited == "hash":
        # the hash set subsumes V *and* the per-hop C/R membership scans;
        # V_ptr is unused.  Seeds are inserted up front (they are already
        # in R and C, so a neighbor lane hitting a seed must not be fresh).
        V, _ = HP.visited_filter(
            HP.visited_table(B, n_seeds + hops * Mdeg),
            _ext(init_ids), valid=init_d < INF, backend=backend)
        V_ptr = jnp.zeros((B, 1), jnp.int32)
    elif exact_visited:
        # mark the evaluated seeds; V_ptr is unused in this mode.  Marks are
        # monotone (never unset), so `.max` keeps duplicate-index scatters
        # (INF lanes clip onto node N-1) deterministic
        V = jnp.zeros((B, N), jnp.uint8).at[
            rows[:, None], jnp.clip(init_ids, 0, N - 1)].max(
            jnp.where(init_d < INF, 1, 0).astype(jnp.uint8))
        V_ptr = jnp.zeros((B, 1), jnp.int32)
    else:
        V = jnp.full((B, mv_seg, segv), N, jnp.int32)
        V_ptr = jnp.zeros((B, mv_seg), jnp.int32)

    tril = jnp.tril(jnp.ones((Mdeg, Mdeg), bool), k=-1)

    def step(state, _):
        R_ids, R_d, C_ids, C_d, V, V_ptr, done = state

        # ---- pop global min from C (argmin over m x seg lanes) -------
        flat_d = C_d.reshape(B, -1)
        flat_i = C_ids.reshape(B, -1)
        pidx = jnp.argmin(flat_d, axis=1)
        u_d = jnp.take_along_axis(flat_d, pidx[:, None], axis=1)[:, 0]
        u = jnp.take_along_axis(flat_i, pidx[:, None], axis=1)[:, 0]
        empty = u_d >= INF
        C_d2 = flat_d.at[rows, pidx].set(INF).reshape(B, m_seg, seg)
        C_ids2 = flat_i.at[rows, pidx].set(N).reshape(B, m_seg, seg)

        # ---- Δ-relaxed termination (only once R is full) -------------
        r_full = R_d[:, ef - 1] < INF
        worst = jnp.where(r_full, R_d[:, ef - 1], INF)
        terminate = empty | (r_full & (u_d > worst + delta))
        now_done = done | terminate
        u_safe = jnp.clip(u, 0, N - 1)

        # ---- neighbors of u, λ-prefix masked --------------------------
        e = nbrs_all[u_safe]                               # [B, M]
        lam = lams_all[u_safe]
        ok = (lam < lambda_limit) & (e < N) & ~now_done[:, None]
        e_safe = jnp.clip(e, 0, N - 1)
        if alive is not None:  # tombstoned neighbors never enter R or C
            ok = ok & alive_int[e_safe]
        # drop repeats within this neighbor list (bridge splicing can
        # duplicate an existing edge) — keep the first occurrence
        dup_here = jnp.any(
            (e_safe[:, :, None] == e_safe[:, None, :]) & tril[None],
            axis=2)

        if visited == "hash":
            # one probe-and-insert answers "seen before?" for V, C, and R
            # at once (every id that ever entered a ranking structure went
            # through the filter first) and subsumes dup_here: duplicate
            # lanes of one hop can't both be fresh.  `ok` already excludes
            # done rows, so frozen rows never mutate their table.
            V2, fresh = HP.visited_filter(V, _ext(e), valid=ok,
                                          backend=backend)
            new = fresh
            V_ptr2 = V_ptr
        elif exact_visited:
            # one byte-gather replaces all three membership scans;
            # evaluated nodes are marked immediately below (`.max` so a
            # duplicate edge's no-op lane can't erase its twin's fresh mark)
            v_here = jnp.take_along_axis(V, e_safe, axis=1)
            in_any = v_here == 1
            new = ok & ~in_any & ~dup_here
            V2 = V.at[rows[:, None], e_safe].max(
                jnp.where(new, 1, 0).astype(jnp.uint8))
            V_ptr2 = V_ptr
        else:
            # ---- V.add(u) (circular segment insert, paper Alg.2) -----
            vs = _ext_hash(u_safe) % mv_seg
            slot = jnp.take_along_axis(V_ptr, vs[:, None], axis=1)[:, 0] \
                % segv
            V2 = V.at[rows, vs, slot].set(u_safe)
            V_ptr2 = V_ptr.at[rows, vs].add(1)
            V2 = jnp.where(now_done[:, None, None], V, V2)
            V_ptr2 = jnp.where(now_done[:, None], V_ptr, V_ptr2)
            # membership tests: e ∉ V and e ∉ C (paper line 15)
            in_V = jnp.any(V2[rows[:, None], _ext_hash(e_safe) % mv_seg]
                           == e_safe[:, :, None], axis=2)
            c_seg = _ext_hash(e_safe) % m_seg
            c_rows_ids = C_ids2[rows[:, None], c_seg]           # [B, M, seg]
            c_rows_d = C_d2[rows[:, None], c_seg]
            in_C = jnp.any((c_rows_ids == e_safe[:, :, None])
                           & (c_rows_d < INF), axis=2)
            in_R = jnp.any((R_ids[:, None, :] == e_safe[:, :, None])
                           & (R_d[:, None, :] < INF), axis=2)
            new = ok & ~in_V & ~in_C & ~in_R & ~dup_here

        # ---- distances for new candidates: ONE fused gather+GEMM+mask
        # block for the whole batch (the per-hop hot spot) --------------
        ed = HP.neighbor_distances(Q, X_score, e_safe, metric=metric,
                                   mask=new, backend=backend,
                                   gather_fused=gather_fused, scales=scales)
        admit = (ed < worst[:, None]) | ~r_full[:, None]   # paper line 17
        ed = jnp.where(admit, ed, INF)

        # ---- push into R: merge candidates, keep ef smallest ----------
        cat_d = jnp.concatenate([R_d, ed], axis=1)
        cat_i = jnp.concatenate([R_ids, jnp.where(ed < INF, e, N)], axis=1)
        R_d3, R_ids3 = HP.rank_merge(cat_d, cat_i, keep=ef, backend=backend)

        # ---- push into C: per-segment insert, evict most distant ------
        seg_of_e = _ext_hash(e_safe) % m_seg
        cand_mask = (ed < INF)[:, None, :] \
            & (seg_of_e[:, None, :] == jnp.arange(m_seg)[None, :, None])
        cand_d = jnp.where(cand_mask, ed[:, None, :], INF)  # [B, m, M]
        cand_i = jnp.where(cand_mask, e[:, None, :], N)
        C_d3, C_ids3 = _seg_merge(
            jnp.concatenate([C_d2, cand_d], axis=2),
            jnp.concatenate([C_ids2, cand_i], axis=2), seg, backend)

        R_d4 = jnp.where(now_done[:, None], R_d, R_d3)
        R_ids4 = jnp.where(now_done[:, None], R_ids, R_ids3)
        C_d4 = jnp.where(now_done[:, None, None], C_d, C_d3)
        C_ids4 = jnp.where(now_done[:, None, None], C_ids, C_ids3)
        return (R_ids4, R_d4, C_ids4, C_d4, V2, V_ptr2, now_done), None

    state = (R_ids, R_d, C_ids, C_d, V, V_ptr, jnp.zeros((B,), bool))
    (R_ids, R_d, *_), _ = jax.lax.scan(step, state, None, length=hops,
                                       unroll=unroll)
    if codes is None:
        return _ext(R_ids[:, :k]).astype(jnp.int32), R_d[:, :k]
    # exact fp32 re-rank of the best rerank_mult*k survivors (R is already
    # (dist, id)-sorted and id-deduped, so a prefix slice is the top pool).
    # INF lanes (unfilled R slots carrying sentinel id N) stay masked
    # through the re-score, so they cannot displace real survivors.
    rerank = min(max(rerank_mult, 1) * k, ef)
    rr_ids = R_ids[:, :rerank]       # internal: indexes the packed fp32 rows
    rr_d = R_d[:, :rerank]
    ed = HP.neighbor_distances(Q, X, rr_ids, metric=metric,
                               mask=rr_d < INF, backend=backend,
                               gather_fused=gather_fused)
    # external BEFORE the merge so its (dist, id) tie order matches the
    # unpacked baseline
    out_d, out_ids = HP.rank_merge(ed, _ext(rr_ids), keep=k,
                                   backend=backend)
    return out_ids.astype(jnp.int32), out_d


def large_batch_search(*args, **kwargs):
    """Deprecated public seam — prefer ``repro.ann.Index.search`` (DESIGN.md
    §5), which dispatches to this procedure automatically for large batches.
    Thin shim over :func:`_large_batch_search`; identical results."""
    from repro.utils.deprecation import warn_once
    warn_once("repro.core.search_large.large_batch_search",
              "repro.ann.Index.search")
    return _large_batch_search(*args, **kwargs)
