"""Distance metrics for the ANN core (paper Table 1: L2 / Cosine / IP).

Smaller = closer, uniformly: inner-product and cosine are negated so a single
ascending comparison serves all three (the paper's footnote 1 convention).
"""
from __future__ import annotations

import jax.numpy as jnp


def preprocess(X, metric: str):
    """Dataset-side preprocessing (cosine -> unit norm)."""
    if metric == "cos":
        return X / jnp.maximum(jnp.linalg.norm(X, axis=-1, keepdims=True),
                               1e-12)
    return X


def pairwise(Q, X, metric: str):
    """[B, d] x [N, d] -> [B, N] (smaller = closer)."""
    if metric in ("ip", "cos"):
        return -jnp.matmul(Q, X.T, preferred_element_type=jnp.float32)
    # squared L2 via the Gram trick (one GEMM; the MXU hot path)
    qn = jnp.sum(Q * Q, axis=-1, keepdims=True)
    xn = jnp.sum(X * X, axis=-1)
    return qn + xn[None, :] - 2.0 * jnp.matmul(
        Q, X.T, preferred_element_type=jnp.float32)


def batched_rowwise(Q, V, metric: str):
    """Q [S, d] against per-row candidate vecs V [S, C, d] -> [S, C]."""
    dots = jnp.einsum("scd,sd->sc", V, Q,
                      preferred_element_type=jnp.float32)
    if metric in ("ip", "cos"):
        return -dots
    qn = jnp.sum((Q * Q).astype(jnp.float32), axis=-1)[:, None]
    vn = jnp.sum((V * V).astype(jnp.float32), axis=-1)
    return qn + vn - 2.0 * dots


def point_pairs(A, B, metric: str):
    """Rowwise distance between A [.., d] and B [.., d] -> [..]."""
    dots = jnp.sum(A * B, axis=-1)
    if metric in ("ip", "cos"):
        return -dots
    return jnp.sum(jnp.square(A - B), axis=-1)
