"""Distributed TSDG: sharded index build + 2-D parallel search (shard_map).

Production layout (DESIGN.md §2): the database (vectors + packed graph) is
sharded over the ``data`` axis (and ``pod`` when multi-pod) — each shard owns
an independent TSDG sub-index over its slice, built with zero cross-shard
traffic (the paper's batched-GPU build, pod-scaled).  Queries are sharded
over the ``model`` axis.  A query visits every DB shard's sub-index in
parallel and the per-shard top-k are merged with one all-gather over the DB
axes — k·shards ids/dists per query, the only collective in the hot path.

This is the standard sharded-ANN serving architecture (sub-linear per-shard
search, embarrassingly parallel scale-out); the paper is single-GPU, so this
layer is our extension for the 1000+-node deployment target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ANNConfig
from repro.core import metrics as M
from repro.core.diversify import PackedGraph
from repro.core.search_large import _large_batch_search
from repro.core.search_small import _small_batch_search
from repro.utils.compat import shard_map


def db_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def query_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("model",) if a in mesh.axis_names)


def graph_pspec(mesh: Mesh):
    d = db_axes(mesh)
    return PackedGraph(
        neighbors=P(d, None), lambdas=P(d, None), degrees=P(d),
        hubs=P(None))


def make_build_fn(mesh: Mesh, cfg: ANNConfig):
    """shard_map'd index build: each DB shard builds its own TSDG."""
    d_ax = db_axes(mesh)

    def local_build(X_shard):
        from repro.ann.pipeline import build_graph
        g = build_graph(X_shard, cfg)
        return g.neighbors, g.lambdas, g.degrees, \
            (g.hubs if g.hubs is not None else jnp.zeros((0,), jnp.int32))

    fn = shard_map(
        local_build, mesh=mesh,
        in_specs=(P(d_ax, None),),
        out_specs=(P(d_ax, None), P(d_ax, None), P(d_ax), P(d_ax)),
        check_vma=False)
    return jax.jit(fn)


def make_search_fn(mesh: Mesh, cfg: ANNConfig, *, kind: str = "large",
                   k: int = 10, batch: int | None = None):
    """Returns jit(search)(X, neighbors, lambdas, degrees, hubs, Q) ->
    (global ids [B, k], dists [B, k]).

    Layouts mirror the paper's two regimes:
      * large batch — queries sharded over `model` (one best-first search
        per query, thousands in flight), DB sharded over `data`(+`pod`);
      * small batch — queries REPLICATED; the paper's `t0` independent
        greedy searches are split across the `model` axis (that is the
        small-batch parallelism unit, §4.1), results merged with the same
        dedup-top-k that merges the DB shards.
    """
    d_ax = db_axes(mesh)
    q_ax = query_axes(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_db_shards = 1
    for a in d_ax:
        n_db_shards *= sizes[a]
    n_q_shards = 1
    for a in q_ax:
        n_q_shards *= sizes[a]
    unroll = getattr(cfg, "unroll_scans", False)
    backend = getattr(cfg, "kernel_backend", "auto")
    gather_fused = getattr(cfg, "gather_fused", None)

    def local_search(X_s, nbrs_s, lams_s, degs_s, hubs_s, Q_s):
        n_local = X_s.shape[0]
        if getattr(cfg, "db_bf16", False):  # beyond-paper: bf16 database
            X_s = X_s.astype(jnp.bfloat16)
        graph = PackedGraph(neighbors=nbrs_s, lambdas=lams_s,
                            degrees=degs_s,
                            hubs=hubs_s if hubs_s.shape[0] else None)
        # shard index along the DB axes -> global id offset
        idx = 0
        for a in d_ax:
            idx = idx * sizes[a] + jax.lax.axis_index(a)
        offset = (idx * n_local).astype(jnp.int32)
        if kind == "small":
            # this model-column runs its slice of the t0 searches
            q_idx = jax.lax.axis_index(q_ax[0]) if q_ax else 0
            t0_local = max(1, cfg.small_t0 // max(1, n_q_shards))
            ids, dist = _small_batch_search(
                X_s, graph, Q_s, k=k, t0=t0_local, hops=cfg.small_hops,
                hop_width=cfg.hop_width, n_seeds=cfg.n_seeds,
                lambda_limit=10, metric=cfg.metric, unroll=unroll,
                seed_offset=q_idx, backend=backend,
                gather_fused=gather_fused)
        else:
            ids, dist = _large_batch_search(
                X_s, graph, Q_s, k=k, ef=cfg.large_ef, hops=cfg.large_hops,
                lambda_limit=5, metric=cfg.metric,
                n_seeds=getattr(cfg, "large_n_seeds", cfg.n_seeds),
                m_seg=cfg.queue_segments, seg=cfg.segment_size,
                mv_seg=cfg.visited_segments, delta=cfg.delta,
                unroll=unroll,
                gather_limit=getattr(cfg, "gather_limit", 0),
                exact_visited=getattr(cfg, "exact_visited", False),
                backend=backend, gather_fused=gather_fused)
        gids = jnp.where(ids < n_local, ids + offset, jnp.int32(-1))
        dist = jnp.where(ids < n_local, dist, jnp.float32(3.4e38))
        # merge across DB shards (and search shards in the small regime)
        merge_ax = d_ax + q_ax if kind == "small" else d_ax
        n_merge = n_db_shards * (n_q_shards if kind == "small" else 1)
        all_ids = jax.lax.all_gather(gids, merge_ax, tiled=False)
        all_d = jax.lax.all_gather(dist, merge_ax, tiled=False)
        all_ids = jnp.moveaxis(all_ids.reshape(n_merge, *gids.shape),
                               0, 1).reshape(gids.shape[0], -1)
        all_d = jnp.moveaxis(all_d.reshape(n_merge, *dist.shape),
                             0, 1).reshape(dist.shape[0], -1)
        # dedup by id (different searches may find the same neighbor)
        o = jnp.argsort(all_ids, axis=1)
        sid = jnp.take_along_axis(all_ids, o, axis=1)
        sd = jnp.take_along_axis(all_d, o, axis=1)
        dup = jnp.concatenate(
            [jnp.zeros((sid.shape[0], 1), bool),
             sid[:, 1:] == sid[:, :-1]], axis=1)
        sd = jnp.where(dup, jnp.float32(3.4e38), sd)
        neg, pos = jax.lax.top_k(-sd, k)
        return jnp.take_along_axis(sid, pos, axis=1), -neg

    q_spec = P(None, None) if kind == "small" else P(q_ax, None)
    out_spec = P(None, None) if kind == "small" else P(q_ax, None)
    fn = shard_map(
        local_search, mesh=mesh,
        in_specs=(P(d_ax, None), P(d_ax, None), P(d_ax, None), P(d_ax),
                  P(d_ax), q_spec),
        out_specs=(out_spec, out_spec),
        check_vma=False)
    return jax.jit(fn)
