"""Distributed TSDG: sharded index build + 2-D parallel search (shard_map).

Production layout (DESIGN.md §6): the database (vectors + packed graph) is
sharded over the ``data`` axis (and ``pod`` when multi-pod) — each shard owns
an independent TSDG sub-index over its slice, built with zero cross-shard
traffic (the paper's batched-GPU build, pod-scaled).  Queries are sharded
over the ``model`` axis.  A query visits every DB shard's sub-index in
parallel and the per-shard top-k are merged with one all-gather over the DB
axes — k·shards ids/dists per query, the only collective in the hot path.

This is the standard sharded-ANN serving architecture (sub-linear per-shard
search, embarrassingly parallel scale-out); the paper is single-GPU, so this
layer is our extension for the 1000+-node deployment target.

Determinism contract (new with the execution-plane refactor): every search
row is seeded by its GLOBAL index — the large regime passes each model
column's row offset as ``seed_offset``, the small regime places each
column's slice of the t0 population with ``t0_offset``/``t0_total``.  On a
mesh with a single DB shard the union of the columns' searches is therefore
*exactly* the single-device search population, and the merged answers are
bitwise-identical to the single-device plane (asserted in
``tests/test_mesh_plane.py``).  With several DB shards the per-shard
sub-indexes genuinely differ from a global index, so only recall — not
bitwise identity — is comparable.

The callable returned by :func:`make_search_fn` is consumed by
:class:`repro.serve.plane.MeshPlane`, which owns the mesh, the operand
shardings, and the serving engine integration (AOT cache, donation, stats).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ANNConfig
from repro.core.diversify import PackedGraph
from repro.core.search_large import _large_batch_search
from repro.core.search_small import _small_batch_search
from repro.utils.compat import shard_map

PAD_ID = jnp.int32(-1)
INF = jnp.float32(3.4e38)


def db_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def query_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("model",) if a in mesh.axis_names)


def axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def n_db_shards(mesh: Mesh) -> int:
    sizes = axis_sizes(mesh)
    out = 1
    for a in db_axes(mesh):
        out *= sizes[a]
    return out


def n_query_shards(mesh: Mesh) -> int:
    sizes = axis_sizes(mesh)
    out = 1
    for a in query_axes(mesh):
        out *= sizes[a]
    return out


def graph_pspec(mesh: Mesh):
    d = db_axes(mesh)
    return PackedGraph(
        neighbors=P(d, None), lambdas=P(d, None), degrees=P(d),
        hubs=P(None))


def make_build_fn(mesh: Mesh, cfg: ANNConfig):
    """shard_map'd index build: each DB shard builds its own TSDG.

    The "layout" stage (DESIGN.md §10) is a host-side BFS and cannot run
    under the shard_map trace; it is stripped here and applied per shard
    afterwards by :meth:`repro.serve.plane.MeshPlane._host_layout`."""
    d_ax = db_axes(mesh)
    pipeline = tuple(getattr(cfg, "build_pipeline", ()) or ())
    if "layout" in pipeline:
        import dataclasses
        cfg = dataclasses.replace(
            cfg, build_pipeline=tuple(p for p in pipeline if p != "layout"))

    def local_build(X_shard):
        from repro.ann.pipeline import build_graph
        g = build_graph(X_shard, cfg)
        return g.neighbors, g.lambdas, g.degrees, \
            (g.hubs if g.hubs is not None else jnp.zeros((0,), jnp.int32))

    fn = shard_map(
        local_build, mesh=mesh,
        in_specs=(P(d_ax, None),),
        out_specs=(P(d_ax, None), P(d_ax, None), P(d_ax), P(d_ax)),
        check_vma=False)
    return jax.jit(fn)


def merge_topk(all_ids, all_d, k: int):
    """Dedup-top-k merge of per-shard candidate lists — THE cross-shard
    collective's reduction, extracted so it is testable against an
    explicit-set oracle (``tests/test_mesh_plane.py``).

    ``all_ids`` [B, n_cand] carries *global* ids with ``PAD_ID`` (-1) for
    invalid lanes, ``all_d`` the matching distances (PAD lanes hold INF).
    Different searches (other shards, other t0 columns) may surface the same
    global id; duplicates must occupy exactly ONE output slot, keeping the
    best (equal-valued — same query, same vector, same arithmetic) copy.

    Returns (ids [B, k], dists [B, k]) ascending by distance; rows with
    fewer than k distinct valid candidates are padded with (PAD_ID, INF).
    This is also the streaming base+delta fuse (DESIGN.md §7), where the
    edge cases are routine rather than exotic: pools narrower than ``k``
    (tiny delta shard), rows whose candidates are ALL invalid (every shard
    tombstoned), and the same id surfacing from several pools.  Any
    negative id — not just ``PAD_ID`` — counts as invalid, and invalid
    lanes are INF-demoted *before* the top-k so they can never shadow a
    real candidate (oracle-fuzzed in ``tests/test_streaming.py``).
    """
    if k <= 0:
        raise ValueError(f"k must be >= 1, got {k}")
    if k > all_ids.shape[1]:  # fewer candidates than k: pad the pool
        pad = k - all_ids.shape[1]
        all_ids = jnp.pad(all_ids, ((0, 0), (0, pad)),
                          constant_values=PAD_ID)
        all_d = jnp.pad(all_d, ((0, 0), (0, pad)), constant_values=INF)
    # (id, dist)-lexsorted so the dedup keeps the BEST copy of each id
    # (mirrors the single-device t0-merge in search_small; a plain stable
    # id-sort would keep whichever copy arrived first)
    o = jnp.lexsort((all_d, all_ids), axis=1)
    sid = jnp.take_along_axis(all_ids, o, axis=1)
    sd = jnp.take_along_axis(all_d, o, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros((sid.shape[0], 1), bool),
         sid[:, 1:] == sid[:, :-1]], axis=1)
    sd = jnp.where(dup | (sid < 0), INF, sd)
    neg, pos = jax.lax.top_k(-sd, k)
    out_ids = jnp.take_along_axis(sid, pos, axis=1)
    return jnp.where(-neg < INF, out_ids, PAD_ID), -neg


def _pool_merge(ids, dists, offsets, n_rows, k: int):
    """jit body of :func:`merge_shard_results`: stacked per-shard pools
    [P, B, k] -> merged global (ids, dists) [B, k]."""
    valid = (ids >= 0) & (ids < n_rows[:, None, None]) & (dists < INF)
    gids = jnp.where(valid, ids + offsets[:, None, None], PAD_ID)
    gd = jnp.where(valid, dists, INF)
    # shard-major column order, exactly the mesh plane's all_gather layout
    all_ids = jnp.moveaxis(gids, 0, 1).reshape(gids.shape[1], -1)
    all_d = jnp.moveaxis(gd, 0, 1).reshape(gd.shape[1], -1)
    return merge_topk(all_ids, all_d, k)


def merge_shard_results(results, offsets, n_rows, *, k: int,
                        batch: int | None = None):
    """Host-side counterpart of the mesh plane's cross-shard merge, used by
    the request router's sharded mode (:mod:`repro.serve.router`).

    ``results`` is one (ids [B, k'], dists [B, k']) pair per surviving
    shard — shard-LOCAL ids from independent single-device engines.  Each
    shard's ids are offset by its global row start (``offsets``) after
    masking invalid lanes (negative / ``>= n_rows[i]`` sentinel ids, INF
    distances — the same validity rule the streaming fuse applies), then
    the pools are concatenated shard-major and reduced with
    :func:`merge_topk` — so a router over P equal row slices answers
    bitwise-identically to a P-DB-shard mesh plane.

    ``batch`` sizes the all-PAD answer when ``results`` is empty (every
    shard failed); otherwise it is inferred.  Returns numpy arrays.
    """
    import numpy as np
    if not results:
        if batch is None:
            raise ValueError("batch= is required when no shard survived")
        return (np.full((batch, k), int(PAD_ID), np.int32),
                np.full((batch, k), float(INF), np.float32))
    ids = jnp.stack([jnp.asarray(i) for i, _ in results])
    dists = jnp.stack([jnp.asarray(d) for _, d in results])
    gi, gd = jax.jit(_pool_merge, static_argnums=(4,))(
        ids, dists,
        jnp.asarray(list(offsets), jnp.int32),
        jnp.asarray(list(n_rows), jnp.int32), k)
    return np.asarray(gi), np.asarray(gd)


def make_search_fn(mesh: Mesh, cfg: ANNConfig, *, kind: str = "large",
                   k: int = 10, batch: int | None = None,
                   stream: bool = False):
    """Returns jit(search)(X, neighbors, lambdas, degrees, hubs, Q) ->
    (global ids [B, k], dists [B, k]).

    Layouts mirror the paper's two regimes:
      * large batch — queries sharded over `model` (one best-first search
        per query, thousands in flight), DB sharded over `data`(+`pod`);
        each column seeds its rows by GLOBAL batch index (`seed_offset`),
        so column placement is bit-invisible;
      * small batch — queries REPLICATED; the paper's `t0` independent
        greedy searches are split across the `model` axis (that is the
        small-batch parallelism unit, §4.1) via `t0_offset`/`t0_total`
        global placement, results merged with the same dedup-top-k that
        merges the DB shards.

    ``stream=True`` is the mutable-index form (DESIGN.md §7): the callable
    takes three extra operands before Q — ``alive`` ([N] bool, row-sharded
    like ``degrees``: the tombstone mask over the base corpus, threaded
    into each shard's in-kernel keep-mask) and the replicated delta shard
    ``delta_X`` [cap, d] / ``delta_alive`` [cap].  Every shard scores the
    delta brute-force (``hotpath.scan_distances``) against its own query
    slice and splices the candidates — at global ids ``N_total + slot`` —
    into the same dedup-top-k that merges the DB shards, so base+delta
    fusion is bitwise the single-device streaming path's merge.
    """
    d_ax = db_axes(mesh)
    q_ax = query_axes(mesh)
    sizes = axis_sizes(mesh)
    n_db = n_db_shards(mesh)
    n_q = n_query_shards(mesh)
    unroll = getattr(cfg, "unroll_scans", False)
    backend = getattr(cfg, "kernel_backend", "auto")
    gather_fused = getattr(cfg, "gather_fused", None)
    quantized = getattr(cfg, "quantization", "none") == "int8"
    rerank_mult = getattr(cfg, "rerank_mult", 4)
    visited = getattr(cfg, "visited_filter", "none")
    has_layout = "layout" in tuple(getattr(cfg, "build_pipeline", ()) or ())

    def local_search(X_s, nbrs_s, lams_s, degs_s, hubs_s, *rest):
        rest = list(rest)
        codes_s = scales_s = None
        if quantized:  # row-sharded codes ride right after the fp32 parts
            codes_s, scales_s = rest[0], rest[1]
            rest = rest[2:]
        perm_s = None
        if has_layout:  # shard-local locality perm rides after the codes
            perm_s = rest[0]
            rest = rest[1:]
        d_codes = d_scales = None
        if stream:
            alive_s, delta_X, delta_alive = rest[0], rest[1], rest[2]
            rest = rest[3:]
            if quantized:
                d_codes, d_scales = rest[0], rest[1]
                rest = rest[2:]
        else:
            alive_s, delta_X, delta_alive = None, None, None
        (Q_s,) = rest
        n_local = X_s.shape[0]
        quant_kw = dict(codes=codes_s, scales=scales_s,
                        rerank_mult=rerank_mult) if quantized else {}
        if getattr(cfg, "db_bf16", False):  # beyond-paper: bf16 database
            X_s = X_s.astype(jnp.bfloat16)
        graph = PackedGraph(neighbors=nbrs_s, lambdas=lams_s,
                            degrees=degs_s,
                            hubs=hubs_s if hubs_s.shape[0] else None,
                            perm=perm_s)
        # shard index along the DB axes -> global id offset
        idx = 0
        for a in d_ax:
            idx = idx * sizes[a] + jax.lax.axis_index(a)
        offset = (idx * n_local).astype(jnp.int32)
        # query-shard index along the model axes -> global row / t0 offset
        q_idx = 0
        for a in q_ax:
            q_idx = q_idx * sizes[a] + jax.lax.axis_index(a)
        if kind == "small":
            # this model-column runs its slice of the t0 searches, placed at
            # its GLOBAL position inside the population so the union over
            # columns reproduces the single-device searches exactly
            t0_local = max(1, cfg.small_t0 // max(1, n_q))
            ids, dist = _small_batch_search(
                X_s, graph, Q_s, k=k, t0=t0_local, hops=cfg.small_hops,
                hop_width=cfg.hop_width, n_seeds=cfg.n_seeds,
                lambda_limit=10, metric=cfg.metric, unroll=unroll,
                t0_offset=q_idx * t0_local, t0_total=t0_local * n_q,
                alive=alive_s, visited=visited,
                backend=backend, gather_fused=gather_fused, **quant_kw)
        else:
            ids, dist = _large_batch_search(
                X_s, graph, Q_s, k=k, ef=cfg.large_ef, hops=cfg.large_hops,
                lambda_limit=5, metric=cfg.metric,
                n_seeds=getattr(cfg, "large_n_seeds", cfg.n_seeds),
                m_seg=cfg.queue_segments, seg=cfg.segment_size,
                mv_seg=cfg.visited_segments, delta=cfg.delta,
                seed_offset=q_idx * Q_s.shape[0],
                unroll=unroll,
                gather_limit=getattr(cfg, "gather_limit", 0),
                exact_visited=getattr(cfg, "exact_visited", False),
                alive=alive_s, visited=visited,
                backend=backend, gather_fused=gather_fused, **quant_kw)
        gids = jnp.where(ids < n_local, ids + offset, PAD_ID)
        dist = jnp.where(ids < n_local, dist, INF)
        # merge across DB shards (and search shards in the small regime)
        merge_ax = d_ax + q_ax if kind == "small" else d_ax
        n_merge = n_db * (n_q if kind == "small" else 1)
        all_ids = jax.lax.all_gather(gids, merge_ax, tiled=False)
        all_d = jax.lax.all_gather(dist, merge_ax, tiled=False)
        all_ids = jnp.moveaxis(all_ids.reshape(n_merge, *gids.shape),
                               0, 1).reshape(gids.shape[0], -1)
        all_d = jnp.moveaxis(all_d.reshape(n_merge, *dist.shape),
                             0, 1).reshape(dist.shape[0], -1)
        if stream:
            # delta shard: replicated, scored once per shard against this
            # shard's own query slice; global ids start past the base rows
            from repro.core import hotpath as HP
            cap = delta_X.shape[0]
            n_total = n_local * n_db
            if quantized:
                # approx scan over int8 delta codes, then exact fp32
                # re-rank of the surviving slots — bitwise the single
                # plane's quantized delta pipeline (replicated operands,
                # so every shard computes identical candidates)
                dd = HP.scan_distances(Q_s, d_codes, metric=cfg.metric,
                                       mask=delta_alive, backend=backend,
                                       scales=d_scales)
                r = min(max(rerank_mult, 1) * k, cap)
                slots = jnp.broadcast_to(
                    jnp.arange(cap, dtype=jnp.int32)[None], dd.shape)
                sd, ss = HP.rank_merge(dd, slots, keep=r, backend=backend)
                ed = HP.neighbor_distances(
                    Q_s, delta_X, ss, metric=cfg.metric, mask=sd < INF,
                    backend=backend, gather_fused=gather_fused)
                d_gids = jnp.where(ed < INF, n_total + ss, PAD_ID)
                all_ids = jnp.concatenate([all_ids, d_gids], axis=1)
                all_d = jnp.concatenate([all_d, ed], axis=1)
            else:
                dd = HP.scan_distances(Q_s, delta_X, metric=cfg.metric,
                                       mask=delta_alive, backend=backend)
                d_gids = jnp.where(
                    delta_alive,
                    n_total + jnp.arange(cap, dtype=jnp.int32), PAD_ID)
                all_ids = jnp.concatenate(
                    [all_ids, jnp.broadcast_to(d_gids[None], dd.shape)],
                    axis=1)
                all_d = jnp.concatenate(
                    [all_d, jnp.where(delta_alive[None], dd, INF)], axis=1)
        return merge_topk(all_ids, all_d, k)

    q_spec = P(None, None) if kind == "small" else P(q_ax, None)
    out_spec = P(None, None) if kind == "small" else P(q_ax, None)
    in_specs = (P(d_ax, None), P(d_ax, None), P(d_ax, None), P(d_ax),
                P(d_ax))
    if quantized:  # row-sharded int8 codes + per-row scales
        in_specs = in_specs + (P(d_ax, None), P(d_ax))
    if has_layout:  # shard-local locality perm, row-sharded
        in_specs = in_specs + (P(d_ax),)
    if stream:
        in_specs = in_specs + (P(d_ax), P(None, None), P(None))
        if quantized:  # replicated delta codes + scales
            in_specs = in_specs + (P(None, None), P(None))
    fn = shard_map(
        local_search, mesh=mesh,
        in_specs=in_specs + (q_spec,),
        out_specs=(out_spec, out_spec),
        check_vma=False)
    return jax.jit(fn)
