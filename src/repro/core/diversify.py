"""Two-stage graph diversification (the paper's §3).

Stage 1 — *relaxed GD* (Eq. 2): greedy occlusion pruning of each k-NN list
with relaxation α > 1.  Edge ⟨x0,xj⟩ is dropped iff some already-kept closer
neighbor xi satisfies  α·m(x0,xi) < m(x0,xj)  ∧  α·m(xi,xj) < m(x0,xj).
α = 1 recovers plain GD/HNSW pruning (a tested invariant).

Symmetrize — reverse edges of surviving lists are appended (capped), turning
the graph undirected before stage 2 (paper §3.3 ¶1).

Stage 2 — *soft GD*: every surviving edge gets an occlusion factor
λ_j = #{ i ≠ j kept : m(x0,xi) < m(x0,xj) ∧ m(xi,xj) < m(x0,xj) }  (Eq. 1).
Edges are sorted per node by (λ asc, dist asc); λ > λ0 dropped.  The stored
λ-sorted order is what lets the search pick a *prefix* of each list at
query time — one graph, every batch regime (the paper's key flexibility).

All stages are batched over node tiles: the inner objects are [T, K, K]
pairwise-distance blocks computed by one GEMM per tile — the GPU
parallelization of §3.3 mapped onto the MXU.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hotpath as HP
from repro.core import metrics as M
from repro.core.knn_build import reverse_neighbors

INF = jnp.float32(3.4e38)


# --------------------------------------------------------------------------
# stage 1: relaxed GD
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("metric", "alpha", "backend",
                                             "gather_fused"))
def relaxed_gd_tile(X, node_ids, nbr_ids, nbr_dists, *, alpha: float,
                    metric: str, backend: str = "auto",
                    gather_fused: str | None = None):
    """Greedy occlusion pruning for a tile of nodes.

    node_ids [T]; nbr_ids/nbr_dists [T, K] sorted ascending by distance.
    Returns keep mask [T, K].
    """
    T, K = nbr_ids.shape
    N = X.shape[0]
    valid = nbr_ids < N
    # pairwise distances among the K neighbors: one fused [T, K, K] block
    # per tile (invalid columns -> INF, which Eq. 2 treats as non-occluding);
    # q_idx=nbr_ids lets the fused Pallas path gather BOTH sides in-kernel
    pair = HP.neighbor_distances(None, X, nbr_ids, metric=metric,
                                 backend=backend, gather_fused=gather_fused,
                                 q_idx=nbr_ids)
    # occ[t, i, j]: (kept) edge i occludes candidate j   (Eq. 2)
    # ip/cos distances are negative (-<x,y>): a plain α-multiply would make
    # the occluder condition *easier* (α·m more negative), inverting the
    # relaxation.  Sign-aware scaling keeps Eq. 2's semantics ("xi must be
    # α-times closer") in every metric encoding.
    def _relax(m):
        return jnp.where(m >= 0, alpha * m, m / alpha)

    occ = (_relax(nbr_dists[:, :, None]) < nbr_dists[:, None, :]) \
        & (_relax(pair) < nbr_dists[:, None, :])

    def body(keep, j):
        occluded = jnp.any(keep & occ[:, :, j], axis=1)
        keep = keep.at[:, j].set(~occluded & valid[:, j])
        return keep, None

    keep0 = jnp.zeros((T, K), bool).at[:, 0].set(valid[:, 0])
    keep, _ = jax.lax.scan(body, keep0, jnp.arange(1, K))
    return keep


def relaxed_gd(X, ids, dists, *, alpha: float, metric: str,
               tile: int = 2048, unroll: bool = False,
               backend: str = "auto", gather_fused: str | None = None):
    """Stage 1 over the whole graph (tiled). Returns keep mask [N, K]."""
    from repro.core.knn_build import tiled_map

    N, K = ids.shape
    n_tiles = -(-N // tile)
    pad = n_tiles * tile - N
    ids_p = jnp.pad(ids, ((0, pad), (0, 0)), constant_values=N)
    d_p = jnp.pad(dists, ((0, pad), (0, 0)), constant_values=INF)

    def one(i):
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, i * tile, tile, 0)
        rows = i * tile + jnp.arange(tile)
        return relaxed_gd_tile(X, rows, sl(ids_p), sl(d_p),
                               alpha=alpha, metric=metric, backend=backend,
                               gather_fused=gather_fused)

    keep = tiled_map(one, n_tiles, unroll)
    return keep.reshape(-1, K)[:N]


# --------------------------------------------------------------------------
# symmetrize: append reverse edges of the stage-1 graph
# --------------------------------------------------------------------------

def append_reverse(X, ids, dists, keep, *, rev_cap: int, metric: str,
                   backend: str = "auto", gather_fused: str | None = None):
    """Undirected candidate lists: kept forward edges ++ reverse edges.

    Returns (adj_ids [N, K+rev_cap], adj_dists) with sentinel N / INF, each
    row deduplicated.
    """
    N, K = ids.shape
    fwd_ids = jnp.where(keep, ids, N)
    fwd_d = jnp.where(keep, dists, INF)
    rev = reverse_neighbors(fwd_ids, fwd_ids < N, cap=rev_cap)  # [N, rev_cap]
    rd = HP.neighbor_distances(X, X, rev, metric=metric, backend=backend,
                               gather_fused=gather_fused)
    all_ids = jnp.concatenate([fwd_ids, rev], axis=1)
    all_d = jnp.concatenate([fwd_d, rd], axis=1)
    # dedup by id (duplicates -> sentinel)
    order = jnp.argsort(all_ids, axis=1)
    sid = jnp.take_along_axis(all_ids, order, axis=1)
    sd = jnp.take_along_axis(all_d, order, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros((N, 1), bool), sid[:, 1:] == sid[:, :-1]], axis=1)
    sid = jnp.where(dup, N, sid)
    sd = jnp.where(dup, INF, sd)
    # re-sort by distance so stage 2 sees ascending lists
    order2 = jnp.argsort(sd, axis=1)
    return (jnp.take_along_axis(sid, order2, axis=1),
            jnp.take_along_axis(sd, order2, axis=1))


# --------------------------------------------------------------------------
# stage 2: soft GD (occlusion factors)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("metric", "backend",
                                             "gather_fused"))
def occlusion_factors_tile(X, nbr_ids, nbr_dists, *, metric: str,
                           backend: str = "auto",
                           gather_fused: str | None = None):
    """λ_j = #occluders of edge j within its node's list (Eq. 1, α = 1)."""
    T, K = nbr_ids.shape
    N = X.shape[0]
    valid = nbr_ids < N
    pair = HP.neighbor_distances(None, X, nbr_ids, metric=metric,
                                 backend=backend, gather_fused=gather_fused,
                                 q_idx=nbr_ids)
    occ = (nbr_dists[:, :, None] < nbr_dists[:, None, :]) \
        & (pair < nbr_dists[:, None, :]) \
        & valid[:, :, None] & valid[:, None, :]
    lam = jnp.sum(occ, axis=1).astype(jnp.int32)              # [T, K]
    return jnp.where(valid, lam, jnp.int32(2 ** 30))


def soft_gd(X, adj_ids, adj_dists, *, lambda0: int, max_degree: int,
            metric: str, tile: int = 2048, unroll: bool = False,
            backend: str = "auto", gather_fused: str | None = None):
    """Stage 2: λ per edge, sort by (λ, dist), threshold λ0, truncate to M.

    Returns (neighbors [N, M], lambdas [N, M], degrees [N]).
    """
    N, K = adj_ids.shape
    n_tiles = -(-N // tile)
    pad = n_tiles * tile - N
    ids_p = jnp.pad(adj_ids, ((0, pad), (0, 0)), constant_values=N)
    d_p = jnp.pad(adj_dists, ((0, pad), (0, 0)), constant_values=INF)

    from repro.core.knn_build import tiled_map

    def one(i):
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, i * tile, tile, 0)
        return occlusion_factors_tile(X, sl(ids_p), sl(d_p), metric=metric,
                                      backend=backend,
                                      gather_fused=gather_fused)

    lam = tiled_map(one, n_tiles, unroll).reshape(-1, K)[:N]

    # sort by (λ asc, dist asc) — lexsort via two stable argsorts
    order_d = jnp.argsort(adj_dists, axis=1, stable=True)
    lam_d = jnp.take_along_axis(lam, order_d, axis=1)
    order_l = jnp.argsort(lam_d, axis=1, stable=True)
    order = jnp.take_along_axis(order_d, order_l, axis=1)

    sid = jnp.take_along_axis(adj_ids, order, axis=1)
    slam = jnp.take_along_axis(lam, order, axis=1)
    ok = (slam <= lambda0) & (sid < N)
    sid = jnp.where(ok, sid, N)
    slam = jnp.where(ok, slam, jnp.int32(2 ** 30))
    degrees = jnp.sum(ok[:, :max_degree], axis=1).astype(jnp.int32)
    return (sid[:, :max_degree].astype(jnp.int32),
            slam[:, :max_degree], degrees)


# --------------------------------------------------------------------------
# packed graph + end-to-end build
# --------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedGraph:
    """λ-sorted fixed-width adjacency (sentinel id = N).

    `hubs` (optional) — beyond-paper connectivity augmentation: a random
    sample of nodes cross-linked by an exact hub-k-NN graph, also offered to
    the search procedures as seed candidates.  k-NN graphs of strongly
    clustered data are disconnected (no amount of diversification fixes
    that); HNSW solves it with its hierarchy, NSG with a spanning tree — the
    hub graph is the flat, TPU-friendly equivalent.  Disabled
    (bridge_hubs=0) for paper-faithful runs.

    `perm` (optional) — locality layout permutation (DESIGN.md §10),
    new->old: set by the "layout" build stage when the graph rows (and the
    corpus rows alongside it) were reordered into BFS neighborhood order.
    Node ids INSIDE `neighbors`/`hubs` are then internal (packed) ids;
    everything at the facade stays in original-id space, translated
    in-trace by the searches.  When `perm` is present the per-row λ
    ordering gives way to ascending-id ordering (spans for the kernel's
    coalesced DMA); λ remains a per-lane attribute.
    """

    neighbors: jax.Array  # [N, M] int32
    lambdas: jax.Array    # [N, M] int32 (ascending per row unless perm)
    degrees: jax.Array    # [N] int32
    hubs: jax.Array | None = None  # [n_hubs] int32
    perm: jax.Array | None = None  # [N] int32, new->old

    @property
    def n(self) -> int:
        return self.neighbors.shape[0]

    @property
    def max_degree(self) -> int:
        return self.neighbors.shape[1]

    def avg_degree(self) -> float:
        return float(jnp.mean(self.degrees.astype(jnp.float32)))

    def degree_at(self, lambda_limit: int) -> jax.Array:
        """Per-node prefix length visiting only edges with λ < limit."""
        return jnp.sum(self.lambdas < lambda_limit, axis=1).astype(jnp.int32)

    def tree_flatten(self):
        return (self.neighbors, self.lambdas, self.degrees, self.hubs,
                self.perm), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def add_bridges(X, nbrs, lams, *, n_hubs: int, hub_k: int, metric: str,
                seed: int = 0):
    """Beyond-paper: cross-link a random hub sample with its exact hub-k-NN
    graph (symmetric), splicing hub edges into the packed rows with λ = 1.
    Returns (neighbors, lambdas, hubs)."""
    N, Mdeg = nbrs.shape
    key = jax.random.key(seed)
    hubs = jax.random.choice(key, N, (n_hubs,), replace=False).astype(jnp.int32)
    hd = M.pairwise(X[hubs], X[hubs], metric)
    hd = jnp.where(jnp.eye(n_hubs, dtype=bool), INF, hd)
    near_k = max(1, hub_k // 2)
    rand_k = hub_k - near_k
    _, hnn = jax.lax.top_k(-hd, near_k)                       # nearest hubs
    hub_edges = hubs[hnn]
    if rand_k:  # Kleinberg-style long links make the hub graph an expander
        rnd = jax.random.randint(jax.random.fold_in(key, 7),
                                 (n_hubs, rand_k), 0, n_hubs)
        hub_edges = jnp.concatenate([hub_edges, hubs[rnd]], axis=1)
    # no self-loops: a random link may hit its own hub -> sentinel it out
    hub_edges = jnp.where(hub_edges == hubs[:, None], N, hub_edges)
    # symmetric: each hub row gets fwd + rev hub edges (rev of an exact
    # symmetric-ish kNN is approximated by the fwd list of the other side)
    # splice: overwrite the tail (highest-λ) columns of each hub row
    tail = jnp.arange(Mdeg - hub_k, Mdeg)
    new_nbrs = nbrs.at[hubs[:, None], tail[None, :]].set(hub_edges)
    new_lams = lams.at[hubs[:, None], tail[None, :]].set(1)
    # restore (λ, ·) sort order per touched row
    order = jnp.argsort(new_lams[hubs], axis=1, stable=True)
    new_nbrs = new_nbrs.at[hubs].set(
        jnp.take_along_axis(new_nbrs[hubs], order, axis=1))
    new_lams = new_lams.at[hubs].set(
        jnp.take_along_axis(new_lams[hubs], order, axis=1))
    return new_nbrs, new_lams, hubs


def build_tsdg(X, cfg, knn_ids=None, knn_dists=None, *,
               tile: int = 2048) -> PackedGraph:
    """Full paper pipeline: k-NN graph -> stage 1 -> reverse -> stage 2
    (-> optional hub bridges).

    Deprecated public seam — prefer ``repro.ann.Index.build`` (DESIGN.md
    §5).  Thin shim over the staged build pipeline
    (:func:`repro.ann.pipeline.build_graph`), which runs the same stages
    with the same arguments; the produced graph is bit-identical.
    """
    from repro.ann.pipeline import build_graph
    from repro.utils.deprecation import warn_once

    warn_once("repro.core.diversify.build_tsdg", "repro.ann.Index.build")
    return build_graph(X, cfg, tile=tile, knn_ids=knn_ids,
                       knn_dists=knn_dists)


def build_gd_baseline(X, cfg, knn_ids=None, knn_dists=None, *,
                      tile: int = 2048) -> PackedGraph:
    """Plain GD (α=1, no soft stage) — the paper's GD [36] baseline.

    Honors `tile`/`cfg.unroll_scans` exactly like :func:`build_tsdg`, so
    the dry-run cost analysis counts the baseline's tiles too.
    """
    from repro.core.knn_build import nn_descent

    unroll = getattr(cfg, "unroll_scans", False)
    backend = getattr(cfg, "kernel_backend", "auto")
    gather_fused = getattr(cfg, "gather_fused", None)
    X = M.preprocess(jnp.asarray(X), cfg.metric)
    if knn_ids is None:
        knn_ids, knn_dists = nn_descent(X, cfg.k_graph, metric=cfg.metric,
                                        unroll=unroll, backend=backend,
                                        gather_fused=gather_fused)
    keep = relaxed_gd(X, knn_ids, knn_dists, alpha=1.0, metric=cfg.metric,
                      tile=tile, unroll=unroll, backend=backend,
                      gather_fused=gather_fused)
    adj_ids, adj_d = append_reverse(X, knn_ids, knn_dists, keep,
                                    rev_cap=cfg.k_graph, metric=cfg.metric,
                                    backend=backend,
                                    gather_fused=gather_fused)
    N, K = adj_ids.shape
    order = jnp.argsort(adj_d, axis=1)
    sid = jnp.take_along_axis(adj_ids, order, axis=1)[:, :cfg.max_degree]
    degs = jnp.sum(sid < N, axis=1).astype(jnp.int32)
    lams = jnp.where(sid < N, 0, 2 ** 30).astype(jnp.int32)
    return PackedGraph(neighbors=sid.astype(jnp.int32), lambdas=lams,
                       degrees=degs)
