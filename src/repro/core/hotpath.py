"""Kernel-dispatch seam for the search hot path (DESIGN.md §3).

Every hot loop in the ANN stack — ``search_small`` hops, ``search_large``
expansions, ``nn_descent`` candidate evaluation, the ``diversify`` pairwise
tiles — reduces to three primitives:

  * :func:`neighbor_distances` — fused gather-of-neighbor-vectors -> tiled
    distance block ([S, W, d] batched-rowwise generalization of
    ``kernels/l2dist.py``), with the validity keep-mask applied in-kernel;
  * :func:`rank_merge` — (dist, id)-ascending merge of a candidate block
    into a ranking array, keeping the best ``keep`` per row (the id-carrying,
    keep-masked generalization of ``kernels/topk.py``);
  * :func:`seed_select` — distance + masked top-k over seed candidates
    (composition of the two, sharing one backend).

Two registered backends compute them:

  * ``"pallas"`` — the Pallas TPU kernels (interpret mode off-TPU, so CPU
    tests exercise the real kernel bodies);
  * ``"xla"`` — plain jnp with the *same* arithmetic formulation and the
    same (dist, id) total order, so the two backends are bit-identical —
    the parity contract ``tests/test_hotpath.py`` enforces end-to-end.

Selection comes from ``ANNConfig.kernel_backend``; the default ``"auto"``
resolves to ``"pallas"`` on TPU and falls back to ``"xla"`` elsewhere.
Third-party backends can be plugged in with :func:`register_backend` —
this seam is where every future kernel optimization lands.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import l2dist as _l2
from repro.kernels import topk as _topk

INF = jnp.float32(3.4e38)


def _dist_block(Q3, V3, mask, metric: str):
    """The shared arithmetic formulation (XLA reference). Mirrors
    ``l2dist._block_kernel`` op-for-op so both backends agree bitwise."""
    Q3 = Q3.astype(jnp.float32)
    V3 = V3.astype(jnp.float32)
    dots = jax.lax.dot_general(Q3, V3, (((2,), (2,)), ((0,), (0,))),
                               preferred_element_type=jnp.float32)
    if metric in ("ip", "cos"):
        dist = -dots
    else:
        qn = jnp.sum(Q3 * Q3, axis=2)[:, :, None]
        vn = jnp.sum(V3 * V3, axis=2)[:, None, :]
        dist = qn + vn - 2.0 * dots
    return jnp.where(mask[:, None, :], dist, INF)


def _gather_and_mask(X, idx, mask):
    N = X.shape[0]
    valid = (idx >= 0) & (idx < N)
    if mask is not None:
        valid = valid & mask
    return X[jnp.clip(idx, 0, N - 1)], valid


def _interp(interpret):
    return (jax.default_backend() != "tpu") if interpret is None else interpret


class _XlaBackend:
    """Pure-jnp reference path — always available, always the oracle."""

    name = "xla"

    @staticmethod
    def neighbor_distances(Q, X, idx, *, metric, mask=None, interpret=None):
        V, m = _gather_and_mask(X, idx, mask)
        squeeze = Q.ndim == 2
        Q3 = Q[:, None, :] if squeeze else Q
        out = _dist_block(Q3, V, m, metric)
        return out[:, 0] if squeeze else out

    @staticmethod
    def rank_merge(dists, ids, *, keep, mask=None, interpret=None):
        if not 0 < keep <= dists.shape[1]:
            raise ValueError(f"keep={keep} must be in (0, {dists.shape[1]}]")
        if mask is not None:
            dists = jnp.where(mask, dists, INF)
        # lexsort((ids, dists)) = ascending (dist, id) — exactly the bitonic
        # network's compare-exchange order, so backends agree on ties
        order = jnp.lexsort((ids, dists), axis=1)
        return (jnp.take_along_axis(dists, order, axis=1)[:, :keep],
                jnp.take_along_axis(ids, order, axis=1)[:, :keep])


class _PallasBackend:
    """Fused device kernels (interpret mode when not on TPU)."""

    name = "pallas"

    @staticmethod
    def neighbor_distances(Q, X, idx, *, metric, mask=None, interpret=None):
        V, m = _gather_and_mask(X, idx, mask)
        squeeze = Q.ndim == 2
        Q3 = Q[:, None, :] if squeeze else Q
        out = _l2.block_distances_pallas(Q3, V, m, metric=metric,
                                         interpret=_interp(interpret))
        return out[:, 0] if squeeze else out

    @staticmethod
    def rank_merge(dists, ids, *, keep, mask=None, interpret=None):
        return _topk.rank_merge_pallas(dists, ids, mask, keep=keep,
                                       interpret=_interp(interpret))


_REGISTRY = {"xla": _XlaBackend, "pallas": _PallasBackend}


def register_backend(name: str, impl) -> None:
    """Register a kernel backend (must provide ``neighbor_distances`` and
    ``rank_merge`` with the signatures above)."""
    _REGISTRY[name] = impl


def backends() -> tuple:
    return tuple(sorted(_REGISTRY))


def resolve_backend(name: str | None = None) -> str:
    """``"auto"``/None -> "pallas" on TPU, "xla" everywhere else (the
    auto-fallback that keeps CPU runs on the compiled-XLA path instead of
    slow interpret-mode kernels).  Explicit names are validated."""
    name = name or "auto"
    if name == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown kernel backend {name!r}; registered: {backends()}")
    return name


# --------------------------------------------------------------------------
# the three public primitives
# --------------------------------------------------------------------------

def neighbor_distances(Q, X, idx, *, metric: str = "l2", mask=None,
                       backend: str | None = None, interpret=None):
    """Fused gather + distance block, smaller = closer.

    Q [S, d] (or [S, Kq, d]), X [N, d], idx [S, C] -> [S, C] (or
    [S, Kq, C]) float32.  Rows of ``idx`` outside [0, N) and lanes where
    ``mask`` (optional [S, C] bool) is False come back as INF.
    """
    b = resolve_backend(backend)
    return _REGISTRY[b].neighbor_distances(Q, X, idx, metric=metric,
                                           mask=mask, interpret=interpret)


def rank_merge(dists, ids, *, keep: int, mask=None,
               backend: str | None = None, interpret=None):
    """Row-wise ascending (dist, id) sort carrying ids; returns the best
    ``keep`` per row as (dists [S, keep], ids [S, keep]).  ``mask`` lanes
    that are False are demoted to INF distance (ids untouched)."""
    b = resolve_backend(backend)
    return _REGISTRY[b].rank_merge(dists, ids, keep=keep, mask=mask,
                                   interpret=interpret)


def seed_select(Q, X, seeds, *, metric: str = "l2", k: int = 1, mask=None,
                backend: str | None = None, interpret=None):
    """Distance + masked top-k over seed candidates: returns
    (dists [S, k], ids [S, k]) of the k closest valid seeds per row."""
    b = resolve_backend(backend)
    d = _REGISTRY[b].neighbor_distances(Q, X, seeds, metric=metric,
                                        mask=mask, interpret=interpret)
    return _REGISTRY[b].rank_merge(d, seeds, keep=k, interpret=interpret)
