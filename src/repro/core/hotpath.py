"""Kernel-dispatch seam for the search hot path (DESIGN.md §3).

Every hot loop in the ANN stack — ``search_small`` hops, ``search_large``
expansions, ``nn_descent`` candidate evaluation, the ``diversify`` pairwise
tiles — reduces to three primitives:

  * :func:`neighbor_distances` — fused gather-of-neighbor-vectors -> tiled
    distance block ([S, W, d] batched-rowwise generalization of
    ``kernels/l2dist.py``), with the validity keep-mask applied in-kernel.
    On the Pallas backend the gather itself moves in-kernel when
    ``gather_fused`` allows it: neighbor rows stream HBM->VMEM via
    scalar-prefetch DMAs instead of materializing ``X[idx]`` (DESIGN.md
    §2);
  * :func:`rank_merge` — (dist, id)-ascending merge of a candidate block
    into a ranking array, keeping the best ``keep`` per row (the id-carrying,
    keep-masked generalization of ``kernels/topk.py``);
  * :func:`seed_select` — distance + masked top-k over seed candidates
    (composition of the two, sharing one backend);
  * :func:`scan_distances` — whole-shard brute-force distance block (the
    streaming delta shard's scoring, DESIGN.md §7): no gather, one GEMM
    of the query batch against a small append-only array.

Two registered backends compute them:

  * ``"pallas"`` — the Pallas TPU kernels (interpret mode off-TPU, so CPU
    tests exercise the real kernel bodies);
  * ``"xla"`` — plain jnp with the *same* arithmetic formulation and the
    same (dist, id) total order, so the two backends are bit-identical —
    the parity contract ``tests/test_hotpath.py`` enforces end-to-end.

Selection comes from ``ANNConfig.kernel_backend``; the default ``"auto"``
resolves to ``"pallas"`` on TPU and falls back to ``"xla"`` elsewhere.
Third-party backends can be plugged in with :func:`register_backend` —
this seam is where every future kernel optimization lands.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import l2dist as _l2
from repro.kernels import topk as _topk
from repro.kernels import visited as _vf

INF = jnp.float32(3.4e38)


def gather_dispatch(mode: str, interp: bool, fits: bool) -> bool:
    """The gather-fused placement decision, named so tests can pin it.

    ``"on"`` forces the in-kernel DMA gather (the parity tests);
    ``"off"`` never fuses; ``"auto"`` fuses only where it wins — on real
    TPU (BENCH_hotpath.json measured the fused path at 0.59x under
    interpret-mode DMA emulation, so ``interp`` opts out) and only when
    the tile fits the VMEM budget (``fits``).
    """
    if mode not in ("auto", "on", "off"):
        raise ValueError(
            f"gather_fused={mode!r} must be 'auto', 'on', or 'off'")
    return mode == "on" or (mode == "auto" and not interp and fits)


def _dist_block(Q3, V3, mask, metric: str, v_scale=None):
    """The shared arithmetic formulation (XLA reference). Mirrors
    ``l2dist._block_kernel`` op-for-op so both backends agree bitwise.
    ``v_scale`` [S, C] dequantizes int8 candidate rows exactly as the
    Pallas kernels do: widen to fp32, then scale, then contract.  On the
    quantized path the dequantized rows sit behind an optimization
    barrier (XLA would otherwise hoist the per-row scale out of the dot)
    and the norm terms are batched self-``dot_general`` contractions
    rather than multiply-then-``sum`` — a plain reduce's accumulation
    order varies with the surrounding program (1-ulp drift between the
    two backends' traces), a ``dot_general`` contraction does not.  The
    kernels mirror both choices under their ``pin`` flag."""
    Q3 = Q3.astype(jnp.float32)
    V3 = V3.astype(jnp.float32)
    pin = v_scale is not None
    if pin:
        V3 = jax.lax.optimization_barrier(V3 * v_scale[:, :, None])
    dots = jax.lax.dot_general(Q3, V3, (((2,), (2,)), ((0,), (0,))),
                               preferred_element_type=jnp.float32)
    if metric in ("ip", "cos"):
        dist = -dots
    else:
        if pin:
            nd = (((2,), (2,)), ((0, 1), (0, 1)))
            qn = jax.lax.dot_general(Q3, Q3, nd,
                                     preferred_element_type=jnp.float32)
            vn = jax.lax.dot_general(V3, V3, nd,
                                     preferred_element_type=jnp.float32)
        else:
            qn = jnp.sum(Q3 * Q3, axis=2)
            vn = jnp.sum(V3 * V3, axis=2)
        dist = qn[:, :, None] + vn[:, None, :] - 2.0 * dots
    return jnp.where(mask[:, None, :], dist, INF)


def _valid_mask(X, idx, mask):
    valid = (idx >= 0) & (idx < X.shape[0])
    if mask is not None:
        valid = valid & mask
    return valid


def _gather_and_mask(X, idx, mask):
    return X[jnp.clip(idx, 0, X.shape[0] - 1)], _valid_mask(X, idx, mask)


def _q3_of(Q, X, q_idx):
    """Resolve the query block: explicit Q ([S, d] squeezed or [S, Kq, d]),
    or rows of X named by ``q_idx`` [S, Kq] (the diversify tiles — lets the
    fused kernel gather the query side in-kernel too)."""
    if q_idx is not None:
        return X[jnp.clip(q_idx, 0, X.shape[0] - 1)], False
    squeeze = Q.ndim == 2
    return (Q[:, None, :] if squeeze else Q), squeeze


def _interp(interpret):
    return (jax.default_backend() != "tpu") if interpret is None else interpret


class _XlaBackend:
    """Pure-jnp reference path — always available, always the oracle."""

    name = "xla"

    @staticmethod
    def neighbor_distances(Q, X, idx, *, metric, mask=None, interpret=None,
                           gather_fused=None, q_idx=None, scales=None):
        V, m = _gather_and_mask(X, idx, mask)
        Q3, squeeze = _q3_of(Q, X, q_idx)
        sc = None if scales is None \
            else scales[jnp.clip(idx, 0, X.shape[0] - 1)]
        out = _dist_block(Q3, V, m, metric, v_scale=sc)
        return out[:, 0] if squeeze else out

    @staticmethod
    def rank_merge(dists, ids, *, keep, mask=None, interpret=None):
        if not 0 < keep <= dists.shape[1]:
            raise ValueError(f"keep={keep} must be in (0, {dists.shape[1]}]")
        if mask is not None:
            dists = jnp.where(mask, dists, INF)
        # lexsort((ids, dists)) = ascending (dist, id) — exactly the bitonic
        # network's compare-exchange order, so backends agree on ties
        order = jnp.lexsort((ids, dists), axis=1)
        return (jnp.take_along_axis(dists, order, axis=1)[:, :keep],
                jnp.take_along_axis(ids, order, axis=1)[:, :keep])

    @staticmethod
    def scan_distances(Q, Xd, *, metric, mask=None, interpret=None,
                       scales=None):
        m = jnp.ones((Xd.shape[0],), bool) if mask is None else mask
        sc = None if scales is None else scales[None]
        return _dist_block(Q[None], Xd[None], m[None], metric,
                           v_scale=sc)[0]

    @staticmethod
    def visited_filter(table, ids, valid, *, interpret=None):
        return _vf.visited_filter_xla(table, ids, valid)


class _PallasBackend:
    """Fused device kernels (interpret mode when not on TPU)."""

    name = "pallas"

    @staticmethod
    def neighbor_distances(Q, X, idx, *, metric, mask=None, interpret=None,
                           gather_fused=None, q_idx=None, scales=None):
        interp = _interp(interpret)
        mode = gather_fused or "auto"
        if mode not in ("auto", "on", "off"):
            raise ValueError(
                f"gather_fused={mode!r} must be 'auto', 'on', or 'off'")
        C = idx.shape[-1]
        d = X.shape[1]
        # the in-kernel query gather only pays off when the query rows are
        # the candidate rows (the diversify tiles pass the same id array)
        self_q = q_idx is idx and q_idx is not None
        if self_q and scales is not None:
            raise ValueError("self_q tiles (build-time diversify) score "
                             "fp32 rows; scales= is a search-time knob")
        Kq = C if self_q else (
            1 if (q_idx is None and Q.ndim == 2) else
            (q_idx.shape[-1] if q_idx is not None else Q.shape[1]))
        # int8 codes DMA 1 byte/element — the fused window widens ~4x
        fits = _l2.gather_fused_fits(Kq, C, d, self_q=self_q,
                                     itemsize=X.dtype.itemsize)
        use_fused = gather_dispatch(mode, interp, fits)
        idx_c = jnp.clip(idx, 0, X.shape[0] - 1)
        sc = None if scales is None else scales[idx_c]
        if not use_fused:
            V, m = _gather_and_mask(X, idx, mask)
            Q3, squeeze = _q3_of(Q, X, q_idx)
            out = _l2.block_distances_pallas(Q3, V, m, sc, metric=metric,
                                             interpret=interp)
            return out[:, 0] if squeeze else out
        m = _valid_mask(X, idx, mask)
        if self_q:
            out = _l2.gather_block_distances_pallas(
                None, X, idx_c, m, metric=metric, interpret=interp,
                self_q=True)
            return out
        Q3, squeeze = _q3_of(Q, X, q_idx)
        out = _l2.gather_block_distances_pallas(
            Q3, X, idx_c, m, sc, metric=metric, interpret=interp)
        return out[:, 0] if squeeze else out

    @staticmethod
    def rank_merge(dists, ids, *, keep, mask=None, interpret=None):
        return _topk.rank_merge_pallas(dists, ids, mask, keep=keep,
                                       interpret=_interp(interpret))

    @staticmethod
    def scan_distances(Q, Xd, *, metric, mask=None, interpret=None,
                       scales=None):
        # bs=1: the whole scan is ONE [1, B, cap] block — the same operand
        # shapes as the XLA reference's single contraction, so the backends
        # keep their bitwise-parity contract (row tiling would change the
        # gemm's accumulation grouping)
        m = jnp.ones((Xd.shape[0],), bool) if mask is None else mask
        sc = None if scales is None else scales[None]
        out = _l2.block_distances_pallas(Q[None], Xd[None], m[None], sc,
                                         metric=metric, bs=1,
                                         interpret=_interp(interpret))
        return out[0]

    @staticmethod
    def visited_filter(table, ids, valid, *, interpret=None):
        return _vf.visited_filter_pallas(table, ids, valid,
                                         interpret=_interp(interpret))


_REGISTRY = {"xla": _XlaBackend, "pallas": _PallasBackend}


def register_backend(name: str, impl) -> None:
    """Register a kernel backend (must provide ``neighbor_distances`` and
    ``rank_merge`` with the signatures above)."""
    _REGISTRY[name] = impl


def backends() -> tuple:
    return tuple(sorted(_REGISTRY))


def resolve_backend(name: str | None = None) -> str:
    """``"auto"``/None -> "pallas" on TPU, "xla" everywhere else (the
    auto-fallback that keeps CPU runs on the compiled-XLA path instead of
    slow interpret-mode kernels).  Explicit names are validated."""
    name = name or "auto"
    if name == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown kernel backend {name!r}; registered: {backends()}")
    return name


# --------------------------------------------------------------------------
# the three public primitives
# --------------------------------------------------------------------------

def neighbor_distances(Q, X, idx, *, metric: str = "l2", mask=None,
                       backend: str | None = None, interpret=None,
                       gather_fused: str | None = None, q_idx=None,
                       scales=None):
    """Fused gather + distance block, smaller = closer.

    Q [S, d] (or [S, Kq, d]), X [N, d], idx [S, C] -> [S, C] (or
    [S, Kq, C]) float32.  Rows of ``idx`` outside [0, N) and lanes where
    ``mask`` (optional [S, C] bool) is False come back as INF.

    ``q_idx`` [S, Kq] replaces ``Q`` (pass Q=None) with rows of ``X`` —
    the diversify tiles' pairwise blocks.  When ``q_idx`` is the SAME
    array object as ``idx`` (Python ``is`` — value equality cannot be
    detected at trace time) the fused Pallas path gathers the query side
    in-kernel too; a distinct-but-equal array still computes correctly
    but pays an XLA-level gather for the query block.

    ``gather_fused`` selects the Pallas backend's gather placement:
    ``"auto"`` (in-kernel scalar-prefetch DMA gather on real TPU, the
    XLA-gather-then-block path in interpret mode or when the tile exceeds
    the VMEM budget), ``"on"`` (force the DMA path — the parity tests),
    ``"off"`` (always gather at the XLA level).  The XLA backend ignores
    it: that path stays the bitwise oracle.

    ``scales`` [N] float32 switches on compressed residency (DESIGN.md
    §8): X is then the per-row int8 code matrix and every candidate row
    is dequantized in-kernel as ``code * scale`` before the contraction —
    approximate distances whose survivors the search re-ranks exactly.
    """
    b = resolve_backend(backend)
    return _REGISTRY[b].neighbor_distances(
        Q, X, idx, metric=metric, mask=mask, interpret=interpret,
        gather_fused=gather_fused, q_idx=q_idx, scales=scales)


def rank_merge(dists, ids, *, keep: int, mask=None,
               backend: str | None = None, interpret=None):
    """Row-wise ascending (dist, id) sort carrying ids; returns the best
    ``keep`` per row as (dists [S, keep], ids [S, keep]).  ``mask`` lanes
    that are False are demoted to INF distance (ids untouched)."""
    b = resolve_backend(backend)
    return _REGISTRY[b].rank_merge(dists, ids, keep=keep, mask=mask,
                                   interpret=interpret)


def scan_distances(Q, Xd, *, metric: str = "l2", mask=None,
                   backend: str | None = None, interpret=None,
                   scales=None):
    """Brute-force distance block of a whole (delta) shard against a query
    batch: Q [B, d], Xd [cap, d] -> [B, cap] float32, smaller = closer.

    The streaming delta shard's scoring primitive (DESIGN.md §7): freshly
    added vectors live in a small append-only array searched exhaustively —
    one [B, cap] GEMM per call, no graph — and merged with the base graph's
    candidates by ``distributed.merge_topk``.  ``mask`` (optional [cap]
    bool) demotes unfilled / tombstoned delta slots to INF in-kernel, the
    same keep-mask semantics as :func:`neighbor_distances`.  Both backends
    share the :func:`_dist_block` arithmetic, so they agree bitwise (the
    parity contract of ``tests/test_hotpath.py``).  ``scales`` [cap]
    float32 marks Xd as int8 codes (compressed delta shard) and
    dequantizes in-kernel, same as :func:`neighbor_distances`."""
    b = resolve_backend(backend)
    impl = _REGISTRY[b]
    fn = getattr(impl, "scan_distances", None)
    if fn is None:  # third-party backend: synthesize from the gather form
        idx = jnp.broadcast_to(
            jnp.arange(Xd.shape[0], dtype=jnp.int32),
            (Q.shape[0], Xd.shape[0]))
        m = None if mask is None else jnp.broadcast_to(mask, idx.shape)
        return impl.neighbor_distances(Q, Xd, idx, metric=metric, mask=m,
                                       interpret=interpret, scales=scales)
    return fn(Q, Xd, metric=metric, mask=mask, interpret=interpret,
              scales=scales)


def visited_table(rows: int, bound: int, *, ways: int = 8) -> jax.Array:
    """Empty visited-filter table for ``rows`` independent searches, sized
    for at most ``bound`` distinct insertions each at load factor <= 1/2
    (buckets are a power of two >= 64, so bucket-overflow drops stay
    rare).  Shape [rows, ways, n_buckets] int32, all ``EMPTY``."""
    n_buckets = 64
    need = -(-2 * bound // ways)
    while n_buckets < need:
        n_buckets *= 2
    return jnp.full((rows, ways, n_buckets), _vf.VF_EMPTY, jnp.int32)


def visited_filter(table, ids, *, valid, backend: str | None = None,
                   interpret=None):
    """Probe-and-insert a lane block into per-row visited hash sets.

    ``table`` [B, W, S] int32 (from :func:`visited_table`), ``ids``
    [B, M] int32 node ids, ``valid`` [B, M] bool -> ``(table', fresh)``
    with ``fresh`` [B, M] bool marking lanes that are valid, were NOT
    already in the row's set, and were inserted now.  A full bucket
    reports not-fresh (safe drop, never a duplicate) — see
    ``kernels/visited.py`` for the structure.

    Lanes are processed in a canonical order (ascending id, invalid lanes
    last; ties are bitwise-duplicate inserts, so their order cannot
    matter) rather than lane order: within one call every id is probed
    against the SAME table state regardless of how the caller's lanes are
    arranged, which is what makes the packed-layout searches — whose hops
    present the same multiset of ids in a permuted lane order — bitwise
    equal to the unpacked baseline.  Backends share one int32 formulation
    (``kernels/visited.lane_step``), so the parity contract holds here
    too.
    """
    B, M = ids.shape
    key = jnp.where(valid, ids, jnp.int32(2147483647))
    order = jnp.argsort(key, axis=1, stable=True)
    s_ids = jnp.take_along_axis(ids, order, axis=1)
    s_valid = jnp.take_along_axis(valid, order, axis=1)
    b = resolve_backend(backend)
    impl = _REGISTRY[b]
    fn = getattr(impl, "visited_filter", None)
    if fn is None:  # third-party backend: the reference path is always legal
        fn = _XlaBackend.visited_filter
    table2, s_fresh = fn(table, s_ids, s_valid, interpret=interpret)
    unsort = jnp.argsort(order, axis=1)
    return table2, jnp.take_along_axis(s_fresh, unsort, axis=1)


def seed_select(Q, X, seeds, *, metric: str = "l2", k: int = 1, mask=None,
                backend: str | None = None, interpret=None,
                gather_fused: str | None = None, scales=None):
    """Distance + masked top-k over seed candidates: returns
    (dists [S, k], ids [S, k]) of the k closest valid seeds per row.
    ``scales`` as in :func:`neighbor_distances` (int8 codes in X)."""
    b = resolve_backend(backend)
    d = _REGISTRY[b].neighbor_distances(Q, X, seeds, metric=metric,
                                        mask=mask, interpret=interpret,
                                        gather_fused=gather_fused,
                                        scales=scales)
    return _REGISTRY[b].rank_merge(d, seeds, keep=k, interpret=interpret)
