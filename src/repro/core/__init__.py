"""The paper's contribution: TSDG build + the two search procedures."""
from repro.core.diversify import PackedGraph, build_gd_baseline, build_tsdg  # noqa: F401
from repro.core.knn_build import exact_knn, nn_descent  # noqa: F401
from repro.core.search_large import large_batch_search  # noqa: F401
from repro.core.search_small import small_batch_search  # noqa: F401
