"""Execution planes — the one seam between the serving engine and devices.

The serving engine (:mod:`repro.serve.engine`) owns everything that is
*traffic-shaped*: the shape-bucket ladder, the (regime, bucket, k) compile
cache, warmup enumeration, micro-batching, stats.  Everything that is
*device-shaped* — where the database lives, how a search computation is
lowered, what a persisted executable must be fingerprinted against — lives
behind the :class:`ExecutionPlane` protocol defined here, with two
registered implementations:

* :class:`SingleDevicePlane` — the default: database + packed graph resident
  on one device, searches lowered from the raw procedures.
* :class:`MeshPlane` — the sharded peer: database + per-shard sub-indexes
  laid out over a device mesh (DESIGN.md §6), searches lowered from the
  shard-mapped procedures of :mod:`repro.core.distributed`.  The mesh, the
  DB/query PartitionSpecs, and the global-id offset logic are owned here,
  so the engine above it is mesh-agnostic: a mesh engine gets per-(regime,
  bucket, k) cached executables, padded-batch donation, AOT persistence and
  percentile stats for free.

Both planes expose the same surface::

    compile(regime, bucket, k) -> executable     # padded Q -> (ids, dists)
    compile_stream(regime, bucket, k) -> executable  # + tombstones & delta
    operands() -> tuple                          # flat AOT runtime args
    fingerprint() -> dict                        # what executables bind to
    shardings() -> dict                          # operand placements
    export(regime, bucket, k) -> bytes           # jax.export serialization
    prime(exported, regime, bucket, k) -> executable   # deserialize + bind

plus ``X``, ``graph``, ``cfg``, ``backend``, ``gather_fused``, ``donate``,
``batch_multiple()`` (bucket divisibility constraint) and ``topology()``
(mesh shape; ``None`` on the single-device plane).  `register_plane()`
accepts third-party planes by name, mirroring the kernel-backend registry
(DESIGN.md §3): the `jax.distributed` pod plane (:mod:`repro.serve.pod`,
DESIGN.md §9) slots in through exactly this seam — registered lazily on
first ``get_plane("pod")`` so single-process imports never touch it.

**Generations & streaming (DESIGN.md §7).**  Every serving computation is
lowered with the database and graph as *runtime arguments* (never closed
over as compile-time constants) and the compiled module is wrapped in a
thin binding that reads the plane's current operand snapshot at call time.
The snapshot — ``(shape token, operand tuple, stream operands or None)`` —
is replaced atomically by :meth:`rebind` (compaction's generation hot-swap)
and :meth:`set_stream` (mutation pushes), so:

* a generation swap that preserves operand shapes re-binds every cached
  executable to the new arrays with ZERO recompiles (the acceptance bar
  ``ServeStats.compiles == 0`` across a swap);
* in-flight calls that already grabbed the old snapshot finish on the old
  immutable arrays — nothing is dropped;
* a swap that *changes* shapes makes stale executables raise
  :class:`StaleGeneration`, which the engine turns into a re-dispatch
  against the new shape token (lazy recompile, never a wrong answer).

``compile_stream`` lowers the mutable-index form: the frozen computation
plus the tombstone ``alive`` mask threaded into the in-kernel keep-masks
and the brute-force delta shard fused by ``distributed.merge_topk``.
Frozen and streaming executables coexist in the engine cache; AOT artifacts
persist only the frozen form (the streaming operands are serving state, not
index payload).
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.configs.base import ANNConfig
from repro.core import hotpath
from repro.core.diversify import PackedGraph


class StaleGeneration(RuntimeError):
    """A bound executable's operand shapes no longer match the plane's
    current generation (compaction swapped in a different-shaped corpus, or
    the delta shard grew); the engine re-dispatches against the new token."""


@runtime_checkable
class ExecutionPlane(Protocol):
    """Structural protocol for execution planes (see module docstring)."""

    name: str
    cfg: ANNConfig
    X: jax.Array
    graph: PackedGraph
    backend: str
    gather_fused: str
    donate: bool

    def compile(self, regime: str, bucket: int, k: int):
        """Compiled executable for one (regime, bucket, k): takes the
        bucket-padded query batch as its ONLY argument (donated when
        ``donate``) and returns (ids [bucket, k], dists [bucket, k])."""
        ...

    def operands(self) -> tuple:
        """Flat device-resident runtime arguments of exported modules, in
        order: (X, neighbors, lambdas, degrees[, hubs][, codes, scales]
        [, perm])."""
        ...

    def fingerprint(self) -> dict:
        """What persisted executables were lowered against; compared on
        artifact load (any mismatch -> recompile on demand)."""
        ...

    def shardings(self) -> dict:
        """Operand-name -> sharding placements ({} on a single device)."""
        ...


_PLANES: dict = {}


def register_plane(name: str, factory) -> None:
    """Register a plane factory ``(X, cfg, **kw) -> plane`` under ``name``."""
    _PLANES[name] = factory


def planes() -> tuple:
    return tuple(sorted(_PLANES))


def get_plane(name: str):
    if name == "pod" and name not in _PLANES:
        # the multi-process plane lives in its own module (it must not be
        # imported before jax.distributed is initialized); registering on
        # first lookup keeps single-process imports free of it
        import repro.serve.pod as _pod
        _pod.PodPlane  # noqa: B018 — lazy class build registers "pod"
    try:
        return _PLANES[name]
    except KeyError:
        raise KeyError(f"unknown execution plane {name!r}; "
                       f"registered: {planes()}") from None


def _runtime_fingerprint(plane) -> dict:
    dev = jax.devices()[0]
    return {
        "jax": jax.__version__,
        "platform": jax.default_backend(),
        "device_kind": dev.device_kind,
        "n_devices": jax.device_count(),
        "kernel_backend": plane.backend,
        "gather_fused": plane.gather_fused,
        "plane": plane.name,
        "quantization": getattr(plane.cfg, "quantization", "none"),
        # locality-packed layout + visited filter (DESIGN.md §10): both
        # change the lowered search trace, so persisted executables must
        # not be reused across a flip of either
        "layout": getattr(plane.graph, "perm", None) is not None,
        "visited_filter": getattr(plane.cfg, "visited_filter", "none"),
    }


def _token_of(ops) -> tuple:
    """Shape/dtype token of an operand tuple: equality means a compiled
    module lowered against one tuple can run against the other."""
    return tuple((tuple(a.shape), str(a.dtype)) for a in ops)


class _SnapshotPlane:
    """Shared generation-snapshot machinery for both planes.

    ``self._snap = (token, operands, stream_or_None)`` is the plane's whole
    mutable state, replaced wholesale (one attribute store — atomic under
    the GIL) so concurrent queries always read a coherent generation.
    """

    _snap: tuple

    # -- snapshot accessors -------------------------------------------------

    @property
    def quantized(self) -> bool:
        """Compressed residency on (DESIGN.md §8): the operand tuple and
        the stream tuple carry int8 codes + fp32 scales after the fp32
        arrays, and searches score/re-rank through them."""
        return getattr(self.cfg, "quantization", "none") == "int8"

    def operands(self) -> tuple:
        return self._snap[1]

    def shape_token(self) -> tuple:
        return self._snap[0]

    def stream_token(self):
        """Delta-shard capacity of the attached stream state (None when the
        index is frozen) — part of the engine's streaming cache key."""
        stream = self._snap[2]
        return None if stream is None else (int(stream[1].shape[0]),)

    @property
    def stream_active(self) -> bool:
        return self._snap[2] is not None

    def clear_stream(self) -> None:
        token, ops, _ = self._snap
        self._snap = (token, ops, None)

    # -- executable binding -------------------------------------------------

    def _place_query(self, Qb):
        """Hook: place the engine's (process-local) padded query batch where
        the compiled module expects it.  Identity for in-process planes; the
        multi-process pod plane lifts it into a global replicated array."""
        return Qb

    def _bind(self, raw, token, *, stream_cap=None):
        """Wrap a compiled module (over flat operand args + Q) into the
        engine-facing single-argument form.  The wrapper reads the CURRENT
        snapshot per call, so a same-shape generation swap re-binds every
        cached executable for free; shape drift raises StaleGeneration."""
        def call(Qb):
            tok, ops, stream = self._snap
            if tok != token:
                raise StaleGeneration(
                    "executable lowered for a previous generation's operand "
                    "shapes; re-dispatch against the new shape token")
            Qb = self._place_query(Qb)
            if stream_cap is None:
                return raw(*ops, Qb)
            if stream is None or int(stream[1].shape[0]) != stream_cap:
                raise StaleGeneration(
                    "stream operands detached or delta capacity changed; "
                    "re-dispatch")
            return raw(*ops, *stream, Qb)
        return call

    def _op_specs(self) -> tuple:
        return tuple(jax.ShapeDtypeStruct(a.shape, a.dtype)
                     for a in self.operands())

    def _stream_specs(self) -> tuple:
        stream = self._snap[2]
        return tuple(jax.ShapeDtypeStruct(a.shape, a.dtype) for a in stream)

    def _require_stream(self):
        stream = self._snap[2]
        if stream is None:
            raise RuntimeError(
                "no stream state attached (set_stream() installs the "
                "tombstone mask + delta shard before compile_stream)")
        return stream


# ==========================================================================
# single-device plane
# ==========================================================================

# small_batch_search's compiled-in ranking width (its `width` kwarg
# default): the per-query candidate pool is t0 * width entries
SMALL_WIDTH = 32


class SingleDevicePlane(_SnapshotPlane):
    """Database + graph on one device; searches lowered from the raw
    procedures.  Mutation state (tombstones + delta shard) and generation
    swaps ride on the snapshot machinery of :class:`_SnapshotPlane`."""

    name = "single"

    def __init__(self, X, cfg: ANNConfig, *, graph: PackedGraph | None = None,
                 quant: tuple | None = None, packed: bool = False):
        self.cfg = cfg
        # reusable pinned-host H2D staging routes (see stage_query)
        self._stage_puts = {}
        self.stage_reuses = 0
        # kernel backend resolved once per plane; part of the engine's AOT
        # cache key so an engine rebuilt with a different backend never
        # aliases entries
        self.backend = hotpath.resolve_backend(
            getattr(cfg, "kernel_backend", "auto"))
        self.gather_fused = getattr(cfg, "gather_fused", "auto")
        # donate the bucket-padded query buffer into each dispatch so steady
        # state reuses its HBM instead of re-allocating per call; skipped on
        # CPU where XLA cannot alias the input (it would warn every call)
        self.donate = jax.default_backend() != "cpu"
        X = jnp.asarray(X)
        if graph is None:
            from repro.ann.pipeline import build_graph
            graph = build_graph(X, cfg)
        self._install(X, graph, stream=None, quant=quant, packed=packed)

    def _install(self, X, graph, *, stream, quant=None,
                 packed: bool = False) -> None:
        """Swap in a generation.  ``X`` (and ``quant`` rows, if given)
        arrive in EXTERNAL row order and are packed here when the graph
        carries a locality permutation (DESIGN.md §10) — ``packed=True``
        (artifact load) says they are already in packed order."""
        perm = getattr(graph, "perm", None)
        if perm is not None and not packed:
            X = jnp.take(X, perm, axis=0)
            if quant is not None:
                quant = (jnp.take(jnp.asarray(quant[0]), perm, axis=0),
                         jnp.take(jnp.asarray(quant[1]), perm, axis=0))
        self.X = X
        self.graph = graph
        if self.quantized:
            if quant is None:  # build / compaction; artifact load passes it
                from repro.ann.quantize import quantize_rows
                quant = quantize_rows(X)
            self.codes, self.scales = (jnp.asarray(quant[0]),
                                       jnp.asarray(quant[1]))
        else:
            self.codes = self.scales = None
        ops = (X, graph.neighbors, graph.lambdas, graph.degrees)
        if graph.hubs is not None:
            ops = ops + (graph.hubs,)
        if self.quantized:
            ops = ops + (self.codes, self.scales)
        if perm is not None:
            ops = ops + (perm,)  # rides last; tokenized like any operand
        self._snap = (_token_of(ops), ops, stream)

    # -- generations & streaming -------------------------------------------

    def rebind(self, X, graph) -> None:
        """Hot-swap to a new generation's corpus + graph (compaction).
        Clears stream state; cached executables whose shapes still match
        keep serving against the new arrays with zero recompiles, and
        in-flight calls finish on the old (immutable) arrays.  A quantized
        plane re-quantizes the new generation's rows here."""
        self._install(jnp.asarray(X), graph, stream=None)

    def set_stream(self, alive, delta_X, delta_alive) -> None:
        """Attach/refresh the streaming operands: ``alive`` [N] bool
        (base-corpus tombstone mask), ``delta_X`` [cap, d] float32,
        ``delta_alive`` [cap] bool (unfilled/tombstoned delta slots).
        A quantized plane appends per-row int8 codes + scales of the delta
        shard (delta_X stays fp32 for the exact re-rank)."""
        token, ops, _ = self._snap
        stream = (jnp.asarray(alive), jnp.asarray(delta_X),
                  jnp.asarray(delta_alive))
        if self.quantized:
            from repro.ann.quantize import quantize_rows
            stream = stream + quantize_rows(stream[1])
        self._snap = (token, ops, stream)

    # -- engine-facing geometry --------------------------------------------

    def batch_multiple(self) -> int:
        return 1

    def topology(self) -> dict | None:
        return None

    def shardings(self) -> dict:
        return {}

    def fingerprint(self) -> dict:
        return _runtime_fingerprint(self)

    # -- H2D staging --------------------------------------------------------

    def _make_stage(self, shape, dtype):
        """Build the staging route for one (shape, dtype): host numpy ->
        pinned-host buffer -> one device DMA.  Falls back to a plain
        ``device_put`` where the runtime has no pinned-host memory space
        (CPU, interpret-mode test rigs)."""
        dev = jax.devices()[0]
        try:
            pin = jax.sharding.SingleDeviceSharding(
                dev, memory_kind="pinned_host")
            dst = jax.sharding.SingleDeviceSharding(dev)
            # probe: raises on runtimes without a pinned_host space
            jax.device_put(jnp.zeros((1,), dtype), pin).block_until_ready()

            def put(Qh):
                return jax.device_put(jax.device_put(Qh, pin), dst)
            return put
        except Exception:  # noqa: BLE001 — capability probe
            return lambda Qh: jax.device_put(jnp.asarray(Qh), dev)

    def stage_query(self, Qh):
        """Stage a host query batch onto the device through a reusable
        pinned-host bounce route (ROADMAP "H2D staging").  One route is
        kept per (shape, dtype) — the engine's bucket ladder makes repeats
        the steady state — and every re-hit increments ``stage_reuses``
        (surfaced as ``ServeStats.h2d_stage_reuses``, the proof that
        steady-state traffic reuses the staging buffer instead of setting
        up a fresh transfer path per call)."""
        key = (tuple(Qh.shape), str(Qh.dtype))
        put = self._stage_puts.get(key)
        if put is None:
            put = self._stage_puts[key] = self._make_stage(Qh.shape, Qh.dtype)
        else:
            self.stage_reuses += 1
        return put(Qh)

    # -- lowering -----------------------------------------------------------

    def _search_args(self, kind: str, k: int):
        """(procedure, static kwargs) for one regime at one k."""
        from repro.core.search_large import _large_batch_search
        from repro.core.search_small import _small_batch_search

        cfg = self.cfg
        visited = getattr(cfg, "visited_filter", "none")
        if kind == "small":
            kwargs = dict(k=k, t0=cfg.small_t0, hops=cfg.small_hops,
                          hop_width=cfg.hop_width, n_seeds=cfg.n_seeds,
                          lambda_limit=10, metric=cfg.metric,
                          visited=visited,
                          backend=self.backend,
                          gather_fused=self.gather_fused)
            return _small_batch_search, kwargs
        kwargs = dict(k=k, ef=cfg.large_ef, hops=cfg.large_hops,
                      lambda_limit=5, metric=cfg.metric,
                      n_seeds=getattr(cfg, "large_n_seeds", cfg.n_seeds),
                      m_seg=cfg.queue_segments, seg=cfg.segment_size,
                      mv_seg=cfg.visited_segments, delta=cfg.delta,
                      visited=visited,
                      backend=self.backend,
                      gather_fused=self.gather_fused)
        return _large_batch_search, kwargs

    def _qspec(self, bucket: int):
        return jax.ShapeDtypeStruct((bucket, self.X.shape[1]), jnp.float32)

    def _flat_search(self, kind: str, k: int):
        """The operand-parameterized serving computation: flat array args
        ``(X, neighbors, lambdas, degrees[, hubs][, codes, scales], Qb)``
        -> (ids, dists).  The same trace :meth:`export` serializes, so
        primed and locally compiled executables answer identically
        (bitwise contract)."""
        fn, kwargs = self._search_args(kind, k)
        has_hubs = self.graph.hubs is not None
        has_perm = self.graph.perm is not None
        n_base = 5 if has_hubs else 4
        quantized = self.quantized
        i_perm = n_base + (2 if quantized else 0)  # perm rides last
        rerank_mult = getattr(self.cfg, "rerank_mult", 4)

        def call(*args):
            Xa, nbrs, lams, degs = args[:4]
            g = PackedGraph(neighbors=nbrs, lambdas=lams, degrees=degs,
                            hubs=args[4] if has_hubs else None,
                            perm=args[i_perm] if has_perm else None)
            extra = dict(codes=args[n_base], scales=args[n_base + 1],
                         rerank_mult=rerank_mult) if quantized else {}
            return fn(Xa, g, args[-1], **kwargs, **extra)
        return call

    def compile(self, kind: str, bucket: int, k: int):
        """The database and graph are runtime ARGUMENTS of the compiled
        module (see module docstring: generation swaps re-bind, not
        recompile); only the bucket-padded query buffer is donated
        (ROADMAP "Donated buffers") so steady-state serving reuses its
        device memory instead of re-allocating per call."""
        specs = self._op_specs()
        wrapped = jax.jit(
            self._flat_search(kind, k),
            donate_argnums=(len(specs),) if self.donate else ())
        raw = wrapped.lower(*specs, self._qspec(bucket)).compile()
        return self._bind(raw, self.shape_token())

    def compile_stream(self, kind: str, bucket: int, k: int):
        """The mutable-index serving computation (DESIGN.md §7): the base
        graph search with the tombstone mask threaded into its in-kernel
        keep-masks, a brute-force scan of the delta shard, and one
        ``merge_topk`` fuse.  Delta rows answer at global ids
        ``N + slot``; rows with fewer than k live candidates pad with
        (PAD_ID, INF).  Keyed by delta capacity in the engine cache — the
        shard grows geometrically, so recompiles are logarithmic in the
        number of added vectors."""
        from repro.core.distributed import PAD_ID, merge_topk

        stream = self._require_stream()
        cap = int(stream[1].shape[0])
        fn, kwargs = self._search_args(kind, k)
        has_hubs = self.graph.hubs is not None
        has_perm = self.graph.perm is not None
        n_base = 5 if has_hubs else 4
        i_perm = n_base + (2 if self.quantized else 0)
        n_ops = len(self.operands())
        N = int(self.X.shape[0])
        metric = self.cfg.metric
        backend = self.backend
        gather_fused = self.gather_fused
        quantized = self.quantized
        rerank_mult = getattr(self.cfg, "rerank_mult", 4)
        INF = hotpath.INF

        def call(*args):
            Xa, nbrs, lams, degs = args[:4]
            g = PackedGraph(neighbors=nbrs, lambdas=lams, degrees=degs,
                            hubs=args[4] if has_hubs else None,
                            perm=args[i_perm] if has_perm else None)
            Qb = args[-1]
            extra = dict(codes=args[n_base], scales=args[n_base + 1],
                         rerank_mult=rerank_mult) if quantized else {}
            al, dX, dal = args[n_ops:n_ops + 3]
            bids, bd = fn(Xa, g, Qb, alive=al, **kwargs, **extra)
            valid = (bids < N) & (bd < INF)
            pool_i = jnp.where(valid, bids, PAD_ID)
            pool_d = jnp.where(valid, bd, INF)
            if quantized:
                # approximate scan of the int8 delta codes, then exact
                # fp32 re-score of the best rerank_mult*k slots — the
                # same approx->exact pipeline the base search runs
                dcodes, dscales = args[n_ops + 3:n_ops + 5]
                dd = hotpath.scan_distances(Qb, dcodes, metric=metric,
                                            mask=dal, backend=backend,
                                            scales=dscales)
                r = min(rerank_mult * k, cap)
                slots = jnp.broadcast_to(
                    jnp.arange(cap, dtype=jnp.int32)[None], dd.shape)
                # dead/unfilled lanes are already INF from the masked scan
                sd, ss = hotpath.rank_merge(dd, slots, keep=r,
                                            backend=backend)
                ed = hotpath.neighbor_distances(
                    Qb, dX, ss, metric=metric, mask=sd < INF,
                    backend=backend, gather_fused=gather_fused)
                d_ids = jnp.where(ed < INF, N + ss, PAD_ID)
                all_i = jnp.concatenate([pool_i, d_ids], axis=1)
                all_d = jnp.concatenate([pool_d, ed], axis=1)
                return merge_topk(all_i, all_d, k)
            dd = hotpath.scan_distances(Qb, dX, metric=metric, mask=dal,
                                        backend=backend)
            d_ids = jnp.where(dal, N + jnp.arange(cap, dtype=jnp.int32),
                              PAD_ID)
            all_i = jnp.concatenate(
                [pool_i, jnp.broadcast_to(d_ids[None], dd.shape)], axis=1)
            all_d = jnp.concatenate(
                [pool_d, jnp.where(dal[None], dd, INF)], axis=1)
            return merge_topk(all_i, all_d, k)

        specs = self._op_specs() + self._stream_specs()
        wrapped = jax.jit(
            call, donate_argnums=(len(specs),) if self.donate else ())
        raw = wrapped.lower(*specs, self._qspec(bucket)).compile()
        return self._bind(raw, self.shape_token(), stream_cap=cap)

    # -- AOT persistence ----------------------------------------------------

    def export(self, kind: str, bucket: int, k: int) -> bytes:
        """Serialize one (regime, bucket, k) serving computation with
        ``jax.export`` — the persistent form of a compile-cache entry.

        The database and packed graph are *arguments* of the exported
        module (not embedded constants), so blobs stay graph-independent
        small and one artifact can hold many entries.  Bitwise contract:
        the exported module is lowered from the same trace :meth:`compile`
        compiles, so a primed executable answers identically to a
        locally-compiled one.  Only the frozen form is exported — stream
        state is serving state, persisted separately by the artifact's
        ``streaming`` payload (format v3)."""
        from jax import export as jax_export
        specs = self._op_specs()
        exported = jax_export.export(jax.jit(self._flat_search(kind, k)))(
            *specs, self._qspec(bucket))
        return bytes(exported.serialize())

    def prime(self, exported, kind: str, bucket: int, k: int):
        """Compile a deserialized module back into the snapshot-bound
        single-argument executable form the engine's cache expects."""
        specs = self._op_specs()
        fn = jax.jit(lambda *args: exported.call(*args),
                     donate_argnums=(len(specs),) if self.donate else ())
        raw = fn.lower(*specs, self._qspec(bucket)).compile()
        return self._bind(raw, self.shape_token())


# ==========================================================================
# mesh plane
# ==========================================================================

class MeshPlane(_SnapshotPlane):
    """Database + per-shard sub-indexes over a device mesh; searches lowered
    from the shard-mapped procedures (:mod:`repro.core.distributed`).

    Owns the mesh, the DB/query PartitionSpecs, and (via the distributed
    search bodies) the global-id offset logic.  ``parts=`` accepts prebuilt
    device-resident ``(X, neighbors, lambdas, degrees, hubs)`` — how the
    artifact loader restores a sharded index without rebuilding.  Streaming
    operands place the tombstone mask row-sharded with the database and the
    delta shard replicated (every shard scores it; ``merge_topk``'s id
    dedup collapses the copies).
    """

    name = "mesh"

    def __init__(self, X, cfg: ANNConfig, mesh, *, parts: tuple | None = None):
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.core import distributed as D
        self._D = D
        self._P = P
        self._NamedSharding = NamedSharding
        self.cfg = cfg
        self.mesh = mesh
        self.backend = hotpath.resolve_backend(
            getattr(cfg, "kernel_backend", "auto"))
        self.gather_fused = getattr(cfg, "gather_fused", "auto")
        self.donate = jax.default_backend() != "cpu"
        d_ax = D.db_axes(mesh)
        if not d_ax:
            raise ValueError(
                f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} has "
                "no DB axis; name one of its axes 'data' (and optionally "
                "'pod'/'model')")
        self.n_db_shards = D.n_db_shards(mesh)
        self.n_q_shards = D.n_query_shards(mesh)
        self._db2 = NamedSharding(mesh, P(d_ax, None))   # [N, *] row-sharded
        self._db1 = NamedSharding(mesh, P(d_ax))         # [N] row-sharded
        self._repl = NamedSharding(mesh, P(None, None))
        self._repl1 = NamedSharding(mesh, P(None))
        self._qsharded = NamedSharding(mesh, P(D.query_axes(mesh) or None,
                                               None))
        if parts is None:
            Xs = self._put(X, self._db2)
            built = D.make_build_fn(mesh, cfg)(Xs)
            jax.block_until_ready(built[0])
            Xs, built = self._host_layout(Xs, built)
            parts = (Xs,) + tuple(built)
        self._install(parts[0], parts[1:], stream=None)

    def _put(self, a, sharding):
        """Hook: lay a host array out over the mesh.  ``device_put`` when
        every device is process-local; the pod plane overrides this with a
        per-process ``make_array_from_callback`` assembly (a device_put to
        non-addressable devices is illegal in multi-process jax)."""
        return jax.device_put(jnp.asarray(a), sharding)

    def _quantize_sharded(self, Xs):
        """Per-row codes + scales, row-sharded alongside the database (the
        quantization is row-local, so no cross-shard traffic)."""
        from repro.ann.quantize import quantize_rows
        return jax.jit(quantize_rows,
                       out_shardings=(self._db2, self._db1))(Xs)

    def _host_layout(self, Xs, built):
        """Per-shard locality packing (DESIGN.md §10).  The traced shard
        build cannot run the host-BFS "layout" stage (it is stripped from
        the shard_map pipeline by ``distributed.make_build_fn``), so a
        layout config packs here instead: pull each shard's sub-index to
        host, BFS-order its LOCAL ids, relabel, and lay the packed arrays
        (plus the [N] shard-local perm operand) back over the mesh.  The
        ``ids + offset`` global-id composition in the distributed search is
        untouched because the search procedures translate back to
        external-local ids before returning."""
        pipeline = tuple(getattr(self.cfg, "build_pipeline", ()) or ())
        if "layout" not in pipeline:
            return Xs, tuple(built)
        import numpy as np

        from repro.ann import layout as L

        def host(a):
            if isinstance(a, jax.Array) and not a.is_fully_addressable:
                from jax.experimental import multihost_utils
                return np.asarray(
                    multihost_utils.process_allgather(a, tiled=True))
            return np.asarray(jax.device_get(a))

        X_h = host(Xs)
        nbrs, lams, degs, hubs = (host(a) for a in built)
        nsh = self.n_db_shards
        n_local = X_h.shape[0] // nsh
        nh = hubs.shape[0] // nsh if hubs.shape[0] else 0
        outs = {"X": [], "nbrs": [], "lams": [], "degs": [], "hubs": [],
                "perm": []}
        for i in range(nsh):
            lo = i * n_local
            hub_i = hubs[i * nh:(i + 1) * nh] if nh else None
            nb_i = nbrs[lo:lo + n_local]
            perm_i = L.locality_order(nb_i, starts=hub_i)
            X2, nb2, lam2, deg2, hub2 = L.apply_layout(
                perm_i, X_h[lo:lo + n_local], nb_i,
                lams[lo:lo + n_local], degs[lo:lo + n_local], hubs=hub_i)
            outs["X"].append(X2)
            outs["nbrs"].append(nb2)
            outs["lams"].append(lam2)
            outs["degs"].append(deg2)
            outs["hubs"].append(hub2 if hub2 is not None
                                else np.zeros((0,), np.int32))
            outs["perm"].append(perm_i)
        cat = {k: np.concatenate(v, axis=0) for k, v in outs.items()}
        return (self._put(cat["X"], self._db2),
                (self._put(cat["nbrs"], self._db2),
                 self._put(cat["lams"], self._db2),
                 self._put(cat["degs"], self._db1),
                 self._put(cat["hubs"], self._db1),
                 self._put(cat["perm"].astype(np.int32), self._db1)))

    def _install(self, Xs, parts, *, stream) -> None:
        parts = tuple(parts)
        # perm (layout configs) rides LAST in the operand tuple, after any
        # quantization extras — same convention as the single plane
        has_layout = "layout" in tuple(
            getattr(self.cfg, "build_pipeline", ()) or ())
        perm = None
        if has_layout:
            perm = parts[-1]
            parts = parts[:-1]
        if self.quantized and len(parts) == 4:
            # built fresh / restored from a pre-v4 artifact: derive the
            # codes here (a v4 artifact restores them via parts directly).
            # Xs is already in packed order, so the row-local codes are too.
            parts = parts + self._quantize_sharded(Xs)
        if perm is not None:
            parts = parts + (perm,)
        nbrs, lams, degs, hubs = parts[:4]
        self.X = Xs
        self._parts = parts
        self.graph = PackedGraph(
            neighbors=nbrs, lambdas=lams, degrees=degs,
            hubs=hubs if hubs.shape[0] else None, perm=perm)
        ops = (Xs, *parts)
        self._snap = (_token_of(ops), ops, stream)

    # -- generations & streaming -------------------------------------------

    def rebind(self, X) -> None:
        """Hot-swap to a new generation: re-lay the corpus over the mesh
        and rebuild the shard-local sub-indexes — the same device_put +
        shard-mapped build a fresh mesh plane runs, so the swapped-in state
        is bitwise a fresh build's (compaction's parity bar)."""
        Xs = self._put(X, self._db2)
        built = self._D.make_build_fn(self.mesh, self.cfg)(Xs)
        jax.block_until_ready(built[0])
        Xs, built = self._host_layout(Xs, built)
        self._install(Xs, built, stream=None)

    def set_stream(self, alive, delta_X, delta_alive) -> None:
        """Tombstone mask row-sharded like ``degrees``; delta shard
        replicated across every DB shard (codes + scales too when
        quantized — every shard runs the identical delta selection, and
        ``merge_topk``'s id dedup collapses the copies)."""
        token, ops, _ = self._snap
        stream = (
            self._put(alive, self._db1),
            self._put(delta_X, self._repl),
            self._put(delta_alive, self._repl1))
        if self.quantized:
            from repro.ann.quantize import quantize_rows
            # quantize on host inputs so the codes can be laid out via
            # _put (works for both the single-process and pod planes)
            dcodes, dscales = quantize_rows(jnp.asarray(delta_X))
            stream = stream + (
                self._put(dcodes, self._repl),
                self._put(dscales, self._repl1))
        self._snap = (token, ops, stream)

    # -- engine-facing geometry --------------------------------------------

    def batch_multiple(self) -> int:
        """Sharded large-batch search splits B over the model axis, so
        buckets must divide evenly across the query shards."""
        return self.n_q_shards

    def topology(self) -> dict:
        """Mesh shape persisted in the artifact manifest and compared on
        load: ``n_db_shards`` gates sub-index reuse, the full axis map
        (+ device count, via the fingerprint) gates AOT executable reuse."""
        return {
            "axes": {name: int(size) for name, size in
                     zip(self.mesh.axis_names, self.mesh.devices.shape)},
            "n_db_shards": self.n_db_shards,
            "n_q_shards": self.n_q_shards,
        }

    def shardings(self) -> dict:
        return {"X": self._db2, "neighbors": self._db2, "lambdas": self._db2,
                "degrees": self._db1, "hubs": self._db1, "perm": self._db1,
                "codes": self._db2, "scales": self._db1,
                "alive": self._db1, "delta_X": self._repl,
                "delta_alive": self._repl1, "delta_codes": self._repl,
                "delta_scales": self._repl1,
                "query_small": self._repl, "query_large": self._qsharded}

    def fingerprint(self) -> dict:
        fp = _runtime_fingerprint(self)
        fp["mesh_axes"] = self.topology()["axes"]
        return fp

    def query_sharding(self, kind: str):
        """Small-regime queries are replicated (the t0 population splits
        over `model` instead); large-regime queries shard over `model`."""
        return self._repl if kind == "small" else self._qsharded

    # -- lowering -----------------------------------------------------------

    def _qspec(self, kind: str, bucket: int):
        return jax.ShapeDtypeStruct((bucket, self.X.shape[1]), jnp.float32,
                                    sharding=self.query_sharding(kind))

    def _sharded_specs(self, arrays, shardings) -> tuple:
        return tuple(jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s)
                     for a, s in zip(arrays, shardings))

    def compile(self, kind: str, bucket: int, k: int):
        fn = self._D.make_search_fn(self.mesh, self.cfg, kind=kind, k=k)
        specs = self._sharded_specs(self.operands(),
                                    self._operand_shardings())
        wrapped = jax.jit(
            fn, donate_argnums=(len(specs),) if self.donate else ())
        raw = wrapped.lower(*specs, self._qspec(kind, bucket)).compile()
        return self._bind(raw, self.shape_token())

    def compile_stream(self, kind: str, bucket: int, k: int):
        stream = self._require_stream()
        cap = int(stream[1].shape[0])
        fn = self._D.make_search_fn(self.mesh, self.cfg, kind=kind, k=k,
                                    stream=True)
        stream_sh = (self._db1, self._repl, self._repl1)
        if self.quantized:
            stream_sh = stream_sh + (self._repl, self._repl1)
        specs = self._sharded_specs(
            self.operands() + stream,
            self._operand_shardings() + stream_sh)
        wrapped = jax.jit(
            fn, donate_argnums=(len(specs),) if self.donate else ())
        raw = wrapped.lower(*specs, self._qspec(kind, bucket)).compile()
        return self._bind(raw, self.shape_token(), stream_cap=cap)

    # -- AOT persistence ----------------------------------------------------

    def export(self, kind: str, bucket: int, k: int) -> bytes:
        """jax.export of the shard-mapped computation.  The exported module
        records the input shardings and logical device count; it can only
        be re-bound on a mesh of identical shape (gated by the fingerprint
        + topology check at load)."""
        from jax import export as jax_export
        fn = self._D.make_search_fn(self.mesh, self.cfg, kind=kind, k=k)
        specs = self._sharded_specs(self.operands(),
                                    self._operand_shardings())
        exported = jax_export.export(jax.jit(fn))(
            *specs, self._qspec(kind, bucket))
        return bytes(exported.serialize())

    def prime(self, exported, kind: str, bucket: int, k: int):
        specs = self._sharded_specs(self.operands(),
                                    self._operand_shardings())
        fn = jax.jit(lambda *args: exported.call(*args),
                     donate_argnums=(len(specs),) if self.donate else ())
        raw = fn.lower(*specs, self._qspec(kind, bucket)).compile()
        return self._bind(raw, self.shape_token())

    def _operand_shardings(self) -> tuple:
        base = (self._db2, self._db2, self._db2, self._db1, self._db1)
        if self.quantized:
            base = base + (self._db2, self._db1)
        if self.graph.perm is not None:
            base = base + (self._db1,)  # shard-local perm, row-sharded
        return base


register_plane("single", lambda X, cfg, **kw: SingleDevicePlane(X, cfg, **kw))
register_plane("mesh", lambda X, cfg, **kw: MeshPlane(X, cfg, **kw))
