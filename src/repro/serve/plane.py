"""Execution planes — the one seam between the serving engine and devices.

The serving engine (:mod:`repro.serve.engine`) owns everything that is
*traffic-shaped*: the shape-bucket ladder, the (regime, bucket, k) compile
cache, warmup enumeration, micro-batching, stats.  Everything that is
*device-shaped* — where the database lives, how a search computation is
lowered, what a persisted executable must be fingerprinted against — lives
behind the :class:`ExecutionPlane` protocol defined here, with two
registered implementations:

* :class:`SingleDevicePlane` — the default: database + packed graph resident
  on one device, searches lowered from the raw procedures.  Extracted
  verbatim from the pre-plane ``ANNEngine`` internals; behavior-identical
  (same cache keys, same donation rule, same AOT export scheme).
* :class:`MeshPlane` — the sharded peer: database + per-shard sub-indexes
  laid out over a device mesh (DESIGN.md §6), searches lowered from the
  shard-mapped procedures of :mod:`repro.core.distributed`.  The mesh, the
  DB/query PartitionSpecs, and the global-id offset logic are owned here,
  so the engine above it is mesh-agnostic: a mesh engine gets per-(regime,
  bucket, k) cached executables, padded-batch donation, AOT persistence and
  percentile stats for free.

Both planes expose the same surface::

    compile(regime, bucket, k) -> executable     # padded Q -> (ids, dists)
    operands() -> tuple                          # flat AOT runtime args
    fingerprint() -> dict                        # what executables bind to
    shardings() -> dict                          # operand placements
    export(regime, bucket, k) -> bytes           # jax.export serialization
    prime(exported, regime, bucket, k) -> executable   # deserialize + bind

plus ``X``, ``graph``, ``cfg``, ``backend``, ``gather_fused``, ``donate``,
``batch_multiple()`` (bucket divisibility constraint) and ``topology()``
(mesh shape; ``None`` on the single-device plane).  `register_plane()`
accepts third-party planes by name, mirroring the kernel-backend registry
(DESIGN.md §3): a future `jax.distributed` pod plane slots in without
touching the engine.
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.configs.base import ANNConfig
from repro.core import hotpath
from repro.core.diversify import PackedGraph


@runtime_checkable
class ExecutionPlane(Protocol):
    """Structural protocol for execution planes (see module docstring)."""

    name: str
    cfg: ANNConfig
    X: jax.Array
    graph: PackedGraph
    backend: str
    gather_fused: str
    donate: bool

    def compile(self, regime: str, bucket: int, k: int):
        """Compiled executable for one (regime, bucket, k): takes the
        bucket-padded query batch as its ONLY argument (donated when
        ``donate``) and returns (ids [bucket, k], dists [bucket, k])."""
        ...

    def operands(self) -> tuple:
        """Flat device-resident runtime arguments of exported modules, in
        order: (X, neighbors, lambdas, degrees[, hubs])."""
        ...

    def fingerprint(self) -> dict:
        """What persisted executables were lowered against; compared on
        artifact load (any mismatch -> recompile on demand)."""
        ...

    def shardings(self) -> dict:
        """Operand-name -> sharding placements ({} on a single device)."""
        ...


_PLANES: dict = {}


def register_plane(name: str, factory) -> None:
    """Register a plane factory ``(X, cfg, **kw) -> plane`` under ``name``."""
    _PLANES[name] = factory


def planes() -> tuple:
    return tuple(sorted(_PLANES))


def get_plane(name: str):
    try:
        return _PLANES[name]
    except KeyError:
        raise KeyError(f"unknown execution plane {name!r}; "
                       f"registered: {planes()}") from None


def _runtime_fingerprint(plane) -> dict:
    dev = jax.devices()[0]
    return {
        "jax": jax.__version__,
        "platform": jax.default_backend(),
        "device_kind": dev.device_kind,
        "n_devices": jax.device_count(),
        "kernel_backend": plane.backend,
        "gather_fused": plane.gather_fused,
        "plane": plane.name,
    }


# ==========================================================================
# single-device plane
# ==========================================================================

# small_batch_search's compiled-in ranking width (its `width` kwarg
# default): the per-query candidate pool is t0 * width entries
SMALL_WIDTH = 32


class SingleDevicePlane:
    """Database + graph on one device; searches lowered from the raw
    procedures (extracted, behavior-identical, from the pre-plane engine)."""

    name = "single"

    def __init__(self, X, cfg: ANNConfig, *, graph: PackedGraph | None = None):
        self.cfg = cfg
        # kernel backend resolved once per plane; part of the engine's AOT
        # cache key so an engine rebuilt with a different backend never
        # aliases entries
        self.backend = hotpath.resolve_backend(
            getattr(cfg, "kernel_backend", "auto"))
        self.gather_fused = getattr(cfg, "gather_fused", "auto")
        # donate the bucket-padded query buffer into each dispatch so steady
        # state reuses its HBM instead of re-allocating per call; skipped on
        # CPU where XLA cannot alias the input (it would warn every call)
        self.donate = jax.default_backend() != "cpu"
        self.X = jnp.asarray(X)
        if graph is None:
            from repro.ann.pipeline import build_graph
            graph = build_graph(self.X, cfg)
        self.graph = graph

    # -- engine-facing geometry --------------------------------------------

    def batch_multiple(self) -> int:
        return 1

    def topology(self) -> dict | None:
        return None

    def shardings(self) -> dict:
        return {}

    def fingerprint(self) -> dict:
        return _runtime_fingerprint(self)

    # -- lowering -----------------------------------------------------------

    def _search_args(self, kind: str, k: int):
        """(procedure, static kwargs) for one regime at one k."""
        from repro.core.search_large import _large_batch_search
        from repro.core.search_small import _small_batch_search

        cfg = self.cfg
        if kind == "small":
            kwargs = dict(k=k, t0=cfg.small_t0, hops=cfg.small_hops,
                          hop_width=cfg.hop_width, n_seeds=cfg.n_seeds,
                          lambda_limit=10, metric=cfg.metric,
                          backend=self.backend,
                          gather_fused=self.gather_fused)
            return _small_batch_search, kwargs
        kwargs = dict(k=k, ef=cfg.large_ef, hops=cfg.large_hops,
                      lambda_limit=5, metric=cfg.metric,
                      n_seeds=getattr(cfg, "large_n_seeds", cfg.n_seeds),
                      m_seg=cfg.queue_segments, seg=cfg.segment_size,
                      mv_seg=cfg.visited_segments, delta=cfg.delta,
                      backend=self.backend,
                      gather_fused=self.gather_fused)
        return _large_batch_search, kwargs

    def _qspec(self, bucket: int):
        return jax.ShapeDtypeStruct((bucket, self.X.shape[1]), jnp.float32)

    def compile(self, kind: str, bucket: int, k: int):
        """The database, graph, and every search parameter are closed over
        so the padded query batch is the executable's ONLY argument — which
        is what lets its bucket-sized buffer be donated (ROADMAP "Donated
        buffers"): steady-state serving reuses the input's device memory
        instead of re-allocating per call."""
        fn, kwargs = self._search_args(kind, k)
        X, graph = self.X, self.graph
        wrapped = jax.jit(lambda Qb: fn(X, graph, Qb, **kwargs),
                          donate_argnums=(0,) if self.donate else ())
        return wrapped.lower(self._qspec(bucket)).compile()

    # -- AOT persistence ----------------------------------------------------

    def operands(self) -> tuple:
        g = self.graph
        parts = (self.X, g.neighbors, g.lambdas, g.degrees)
        return parts + ((g.hubs,) if g.hubs is not None else ())

    def export(self, kind: str, bucket: int, k: int) -> bytes:
        """Serialize one (regime, bucket, k) serving computation with
        ``jax.export`` — the persistent form of a compile-cache entry.

        The database and packed graph are *arguments* of the exported
        module (not embedded constants), so blobs stay graph-independent
        small and one artifact can hold many entries.  Bitwise contract:
        the exported module is lowered from the same trace :meth:`compile`
        compiles, so a primed executable answers identically to a
        locally-compiled one.
        """
        from jax import export as jax_export
        fn, kwargs = self._search_args(kind, k)
        # flat array args (jax.export cannot serialize the PackedGraph
        # pytree type); operands() is the shared flattening so the loader
        # feeds arguments in exactly this order
        parts = self.operands()
        has_hubs = self.graph.hubs is not None

        def _call(*args):
            Xa, nbrs, lams, degs = args[:4]
            g = PackedGraph(neighbors=nbrs, lambdas=lams, degrees=degs,
                            hubs=args[4] if has_hubs else None)
            return fn(Xa, g, args[-1], **kwargs)

        specs = tuple(jax.ShapeDtypeStruct(a.shape, a.dtype) for a in parts)
        exported = jax_export.export(jax.jit(_call))(
            *specs, self._qspec(bucket))
        return bytes(exported.serialize())

    def prime(self, exported, kind: str, bucket: int, k: int):
        """Close a deserialized module over the plane's device arrays and
        compile it back into the single-donated-argument executable form
        the engine's compile cache expects."""
        parts = self.operands()
        fn = jax.jit(lambda Qb: exported.call(*parts, Qb),
                     donate_argnums=(0,) if self.donate else ())
        return fn.lower(self._qspec(bucket)).compile()


# ==========================================================================
# mesh plane
# ==========================================================================

class MeshPlane:
    """Database + per-shard sub-indexes over a device mesh; searches lowered
    from the shard-mapped procedures (:mod:`repro.core.distributed`).

    Owns the mesh, the DB/query PartitionSpecs, and (via the distributed
    search bodies) the global-id offset logic.  ``parts=`` accepts prebuilt
    device-resident ``(X, neighbors, lambdas, degrees, hubs)`` — how the
    artifact loader restores a sharded index without rebuilding.
    """

    name = "mesh"

    def __init__(self, X, cfg: ANNConfig, mesh, *, parts: tuple | None = None):
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.core import distributed as D
        self._D = D
        self._P = P
        self._NamedSharding = NamedSharding
        self.cfg = cfg
        self.mesh = mesh
        self.backend = hotpath.resolve_backend(
            getattr(cfg, "kernel_backend", "auto"))
        self.gather_fused = getattr(cfg, "gather_fused", "auto")
        self.donate = jax.default_backend() != "cpu"
        d_ax = D.db_axes(mesh)
        if not d_ax:
            raise ValueError(
                f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} has "
                "no DB axis; name one of its axes 'data' (and optionally "
                "'pod'/'model')")
        self.n_db_shards = D.n_db_shards(mesh)
        self.n_q_shards = D.n_query_shards(mesh)
        self._db2 = NamedSharding(mesh, P(d_ax, None))   # [N, *] row-sharded
        self._db1 = NamedSharding(mesh, P(d_ax))         # [N] row-sharded
        self._repl = NamedSharding(mesh, P(None, None))
        self._qsharded = NamedSharding(mesh, P(D.query_axes(mesh) or None,
                                               None))
        if parts is None:
            Xs = jax.device_put(jnp.asarray(X), self._db2)
            nbrs, lams, degs, hubs = D.make_build_fn(mesh, cfg)(Xs)
            jax.block_until_ready(nbrs)
        else:
            Xs, nbrs, lams, degs, hubs = parts
        self.X = Xs
        self._parts = (nbrs, lams, degs, hubs)
        self.graph = PackedGraph(
            neighbors=nbrs, lambdas=lams, degrees=degs,
            hubs=hubs if hubs.shape[0] else None)

    # -- engine-facing geometry --------------------------------------------

    def batch_multiple(self) -> int:
        """Sharded large-batch search splits B over the model axis, so
        buckets must divide evenly across the query shards."""
        return self.n_q_shards

    def topology(self) -> dict:
        """Mesh shape persisted in the artifact manifest and compared on
        load: ``n_db_shards`` gates sub-index reuse, the full axis map
        (+ device count, via the fingerprint) gates AOT executable reuse."""
        return {
            "axes": {name: int(size) for name, size in
                     zip(self.mesh.axis_names, self.mesh.devices.shape)},
            "n_db_shards": self.n_db_shards,
            "n_q_shards": self.n_q_shards,
        }

    def shardings(self) -> dict:
        return {"X": self._db2, "neighbors": self._db2, "lambdas": self._db2,
                "degrees": self._db1, "hubs": self._db1,
                "query_small": self._repl, "query_large": self._qsharded}

    def fingerprint(self) -> dict:
        fp = _runtime_fingerprint(self)
        fp["mesh_axes"] = self.topology()["axes"]
        return fp

    def query_sharding(self, kind: str):
        """Small-regime queries are replicated (the t0 population splits
        over `model` instead); large-regime queries shard over `model`."""
        return self._repl if kind == "small" else self._qsharded

    # -- lowering -----------------------------------------------------------

    def _qspec(self, kind: str, bucket: int):
        return jax.ShapeDtypeStruct((bucket, self.X.shape[1]), jnp.float32,
                                    sharding=self.query_sharding(kind))

    def compile(self, kind: str, bucket: int, k: int):
        fn = self._D.make_search_fn(self.mesh, self.cfg, kind=kind, k=k)
        ops = (self.X, *self._parts)
        wrapped = jax.jit(lambda Qb: fn(*ops, Qb),
                          in_shardings=(self.query_sharding(kind),),
                          donate_argnums=(0,) if self.donate else ())
        return wrapped.lower(self._qspec(kind, bucket)).compile()

    # -- AOT persistence ----------------------------------------------------

    def operands(self) -> tuple:
        # hubs is always a dense array on the mesh plane (possibly empty) —
        # the shard-mapped search takes the flat 5-tuple unconditionally
        return (self.X, *self._parts)

    def export(self, kind: str, bucket: int, k: int) -> bytes:
        """jax.export of the shard-mapped computation.  The exported module
        records the input shardings and logical device count; it can only
        be re-bound on a mesh of identical shape (gated by the fingerprint
        + topology check at load)."""
        from jax import export as jax_export
        fn = self._D.make_search_fn(self.mesh, self.cfg, kind=kind, k=k)
        specs = tuple(
            jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s)
            for a, s in zip(self.operands(), self._operand_shardings()))
        exported = jax_export.export(jax.jit(fn))(
            *specs, self._qspec(kind, bucket))
        return bytes(exported.serialize())

    def prime(self, exported, kind: str, bucket: int, k: int):
        ops = self.operands()
        fn = jax.jit(lambda Qb: exported.call(*ops, Qb),
                     in_shardings=(self.query_sharding(kind),),
                     donate_argnums=(0,) if self.donate else ())
        return fn.lower(self._qspec(kind, bucket)).compile()

    def _operand_shardings(self) -> tuple:
        return (self._db2, self._db2, self._db2, self._db1, self._db1)


register_plane("single", lambda X, cfg, **kw: SingleDevicePlane(X, cfg, **kw))
register_plane("mesh", lambda X, cfg, **kw: MeshPlane(X, cfg, **kw))
