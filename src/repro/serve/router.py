"""Request router — pod-scale serving across replica endpoints (DESIGN.md §9).

The mesh/pod planes scale one *process tree*; serving "heavy traffic from
millions of users" (ROADMAP north star) additionally needs N independent
serving replicas behind one front door.  :class:`Router` is that front door,
sitting in front of the per-replica micro-batching queues:

* **replicated** mode — every endpoint holds the SAME index; each request is
  dispatched to one healthy replica (``least_loaded`` by in-flight count, or
  ``round_robin``) for QPS scale-out.  Replicas created by
  :func:`replicate_engine` share the donor engine's execution plane *and*
  compile cache, so a router over a loaded (AOT-primed) index serves its
  first request on every replica with ZERO compiles, and answers are
  bitwise-identical to querying the donor directly.
* **sharded** mode — one logical index split row-contiguously across the
  endpoints (:func:`shard_engines`); each request fans out to every shard,
  per-shard top-k are mapped to global ids and merged with
  :func:`repro.core.distributed.merge_shard_results` — the host-side
  counterpart of the mesh plane's in-collective ``merge_topk``, so a router
  over P equal shards answers bitwise-identically to a P-DB-shard mesh
  plane over the concatenated corpus (asserted in ``tests/test_router.py``).

**Robustness** (the eject/readmit state machine, DESIGN.md §9): a dispatch
failure — or a periodic health probe that errors or times out — ejects the
replica (``healthy=False``); in replicated mode the failed request retries
on a healthy peer with bounded exponential backoff (``max_retries``,
``backoff_s``) so a replica killed under live traffic loses ZERO futures.
In sharded mode a dead shard has no peer holding its rows: after bounded
same-shard retries the request fails with :class:`PartialResultError`
carrying the surviving shards' merged top-k.  An ejected replica is
readmitted after ``readmit_probes`` consecutive successful probes.

:class:`RouterStats` aggregates the per-replica
:class:`~repro.serve.engine.ServeStats` (compiles, regimes, latency
percentiles) and :class:`~repro.serve.queue.BatcherStats` (expired
deadlines) plus the router's own counters (dispatches, retries, ejects,
readmits, lost futures) into one ``Router.snapshot()`` dict.

Wire-up is the facade: ``Index.serve(router=RouterConfig(...))``; the
launcher exposes ``--router replicated:N|sharded:N``.  Endpoints are
in-process :class:`ANNEngine` instances here — the seam a real deployment
replaces with RPC stubs is exactly :class:`EngineEndpoint`'s four methods
(submit/stats/kill/close).
"""
from __future__ import annotations

import dataclasses
import difflib
import itertools
import threading
import time
from concurrent.futures import Future

import numpy as np

from repro.serve.queue import DeadlineExceeded, MicroBatcher

ROUTER_MODES = ("replicated", "sharded")
ROUTER_POLICIES = ("least_loaded", "round_robin")

# exceptions that mean the REQUEST is wrong (propagate to the caller, never
# retried) — everything else means the REPLICA failed (eject + fail over)
_USER_ERRORS = (ValueError, TypeError, KeyError, DeadlineExceeded)


class ReplicaDead(RuntimeError):
    """The endpoint is down (killed, or its queue is closed)."""


class NoHealthyReplicas(RuntimeError):
    """Every endpoint is ejected; nothing can serve the request."""


class PartialResultError(RuntimeError):
    """Sharded-mode request lost one or more shards after bounded retries.

    Carries the *surviving* shards' merged top-k (``ids``/``dists``, shaped
    like a successful answer, global ids with ``PAD_ID`` padding) so callers
    that prefer partial recall over an error can still use it, plus the
    names of the ``failed`` and ``survivors`` endpoints."""

    def __init__(self, msg, *, ids, dists, failed, survivors):
        super().__init__(msg)
        self.ids = ids
        self.dists = dists
        self.failed = tuple(failed)
        self.survivors = tuple(survivors)


def _unknown(value, known, what: str) -> str:
    """get_arch-style did-you-mean message for an unknown option value."""
    close = difflib.get_close_matches(str(value), known, n=3, cutoff=0.5)
    hint = ""
    if close:
        hint = "; did you mean " + " or ".join(repr(c) for c in close) + "?"
    return f"unknown {what} {value!r}{hint} (known: {', '.join(known)})"


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Topology + robustness knobs for :class:`Router` (and the facade's
    ``Index.serve(router=...)`` / the launcher's ``--router`` flag).

    ``replicas`` is the endpoint count N of ``replicated:N`` /
    ``sharded:N``; ``endpoint_names`` optionally names them (the launcher's
    ``--replica-endpoints``).  ``health_interval_s=0`` disables the probe
    thread (dispatch failures still eject)."""

    mode: str = "replicated"
    replicas: int = 2
    policy: str = "least_loaded"          # replicated dispatch policy
    health_interval_s: float = 1.0        # probe period; 0 disables probing
    probe_timeout_s: float = 30.0         # probe answer deadline -> eject
    max_retries: int = 2                  # failovers per request
    backoff_s: float = 0.02               # retry delay, scaled by attempt
    readmit_probes: int = 1               # consecutive OK probes to readmit
    endpoint_names: tuple = ()

    def __post_init__(self):
        if self.mode not in ROUTER_MODES:
            raise ValueError(_unknown(self.mode, ROUTER_MODES,
                                      "router mode"))
        if self.policy not in ROUTER_POLICIES:
            raise ValueError(_unknown(self.policy, ROUTER_POLICIES,
                                      "router policy"))
        if not isinstance(self.replicas, int) or self.replicas < 1:
            raise ValueError(f"replicas must be a positive int, "
                             f"got {self.replicas!r}")
        if self.max_retries < 0 or self.backoff_s < 0:
            raise ValueError("max_retries and backoff_s must be >= 0")
        if self.health_interval_s < 0 or self.probe_timeout_s <= 0:
            raise ValueError("health_interval_s must be >= 0 and "
                             "probe_timeout_s > 0")
        if self.readmit_probes < 1:
            raise ValueError("readmit_probes must be >= 1")
        if self.endpoint_names and len(self.endpoint_names) != self.replicas:
            raise ValueError(
                f"endpoint_names has {len(self.endpoint_names)} entries "
                f"for {self.replicas} replicas")


def parse_router_spec(spec: str, **overrides) -> RouterConfig:
    """``"replicated:3"`` / ``"sharded:2"`` -> :class:`RouterConfig` — the
    launcher's ``--router`` syntax, with get_arch-consistent did-you-mean
    validation on the mode."""
    mode, sep, n = spec.partition(":")
    if mode not in ROUTER_MODES:
        raise ValueError(_unknown(mode, ROUTER_MODES, "router mode")
                         + "; expected MODE:N, e.g. replicated:3")
    if not sep or not n.isdigit() or int(n) < 1:
        raise ValueError(f"--router {spec!r} must be MODE:N with N a "
                         "positive int, e.g. replicated:3 or sharded:2")
    return RouterConfig(mode=mode, replicas=int(n), **overrides)


# ==========================================================================
# endpoints
# ==========================================================================

class _EngineProxy:
    """The queue-facing view of a replica's engine, with a failure switch.

    :meth:`EngineEndpoint.kill` flips the switch, after which every
    dispatch — including requests already coalesced into the victim's
    queue — fails with the injected exception, exactly like a process
    dying mid-batch.  The micro-batcher only touches ``cfg``, ``X`` and
    ``query``, so this is the whole surface."""

    def __init__(self, engine, owner: "EngineEndpoint"):
        self._engine = engine
        self._owner = owner

    @property
    def cfg(self):
        return self._engine.cfg

    @property
    def X(self):
        return self._engine.X

    def query(self, Q, *, k=None):
        dead = self._owner._dead
        if dead is not None:
            raise dead
        return self._engine.query(Q, k=k)


class EngineEndpoint:
    """One replica: an :class:`ANNEngine` behind its own micro-batching
    queue.  ``id_offset``/``n_rows`` place a sharded endpoint's local ids in
    the global corpus (0/N for replicated endpoints).  This class is the
    RPC seam — a remote replica implements the same submit/stats/close."""

    def __init__(self, engine, *, name: str, id_offset: int = 0,
                 queue_kw: dict | None = None):
        self.engine = engine
        self.name = name
        self.id_offset = int(id_offset)
        self.n_rows = int(engine.X.shape[0])
        self._dead: Exception | None = None
        self.batcher = MicroBatcher(_EngineProxy(engine, self),
                                    **(queue_kw or {}))

    def submit(self, Q, *, k=None, deadline_ms=None) -> Future:
        """Enqueue one request; failures (including a killed endpoint)
        surface through the returned future, never synchronously — the
        router's retry path handles both uniformly."""
        dead = self._dead
        if dead is None:
            try:
                return self.batcher.submit(Q, k=k, deadline_ms=deadline_ms)
            except _USER_ERRORS:
                raise                     # malformed request: caller's bug
            except Exception as e:        # closed queue etc: replica fault
                dead = ReplicaDead(f"replica {self.name!r}: {e}")
        fut: Future = Future()
        fut.set_exception(dead)
        return fut

    # -- simulated failure (tests, CI, chaos drills) -------------------------

    def kill(self, exc: Exception | None = None) -> None:
        """Simulate the replica dying: every subsequent dispatch — even
        requests already sitting in its queue — fails until :meth:`revive`."""
        self._dead = exc or ReplicaDead(f"replica {self.name!r} killed")

    def revive(self) -> None:
        self._dead = None

    @property
    def alive(self) -> bool:
        return self._dead is None

    def stats(self) -> dict:
        """Engine + queue counters for this replica (one consistent view
        of each; the router's :meth:`Router.snapshot` aggregates these)."""
        with self.engine._lock:
            engine = self.engine.stats.snapshot()
        return {"engine": engine, "queue": self.batcher.stats.snapshot()}

    def close(self) -> None:
        self.batcher.close()


def replicate_engine(engine, n: int, *, names=(), queue_kw=None) -> list:
    """N serving replicas of one engine for the replicated router: each
    shares the donor's execution plane (same device arrays — no extra
    residency) AND its compile cache (an AOT-primed donor means every
    replica starts steady-state, aggregated ``compiles=0``), with its own
    ServeStats and micro-batcher.  Answers are bitwise the donor's."""
    from repro.serve.engine import ANNEngine

    if n < 1:
        raise ValueError(f"need at least one replica, got {n}")
    if names and len(names) != n:
        raise ValueError(f"{len(names)} names for {n} replicas")
    endpoints = []
    for i in range(n):
        rep = ANNEngine(None, engine.cfg, k=engine.k, plane=engine.plane,
                        threshold=engine.threshold, cache_from=engine)
        endpoints.append(EngineEndpoint(
            rep, name=names[i] if names else f"r{i}", queue_kw=queue_kw))
    return endpoints


def shard_engines(X, cfg, *, shards: int, k: int = 10, threshold=None,
                  names=(), queue_kw=None) -> list:
    """Split ``X`` into ``shards`` contiguous equal row slices and build one
    single-device engine per slice — the sharded router's endpoints.  The
    equal cut mirrors the mesh plane's row sharding, and each sub-index
    build is the same ``build_graph`` a mesh shard runs on the same rows,
    so the fanned-out + merged answers are bitwise a P-DB-shard mesh
    plane's (tests/test_router.py::test_sharded_router_matches_mesh)."""
    from repro.serve.engine import ANNEngine

    X = np.asarray(X, np.float32)
    n = X.shape[0]
    if shards < 1:
        raise ValueError(f"need at least one shard, got {shards}")
    if n % shards:
        raise ValueError(
            f"N={n} rows do not split evenly into {shards} shards (the "
            "sharded router mirrors the mesh plane's equal row cut)")
    if names and len(names) != shards:
        raise ValueError(f"{len(names)} names for {shards} shards")
    per = n // shards
    endpoints = []
    for i in range(shards):
        eng = ANNEngine(X[i * per:(i + 1) * per], cfg, k=k,
                        threshold=threshold)
        endpoints.append(EngineEndpoint(
            eng, name=names[i] if names else f"s{i}", id_offset=i * per,
            queue_kw=queue_kw))
    return endpoints


# ==========================================================================
# stats
# ==========================================================================

@dataclasses.dataclass
class RouterStats:
    """Router-level counters (one lock, same discipline as BatcherStats);
    :meth:`Router.snapshot` composes these with every replica's engine +
    queue stats into the aggregated view."""

    n_requests: int = 0
    n_dispatches: int = 0      # endpoint submits, retries included
    retries: int = 0           # failovers after a replica fault
    lost_futures: int = 0      # requests failed by replica faults (not
    #                            user errors / partials) after retries
    partial_results: int = 0   # sharded requests that lost >= 1 shard
    ejects: int = 0
    readmits: int = 0
    probes: int = 0
    probe_failures: int = 0
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)

    def bump(self, field: str, by: int = 1) -> None:
        with self._lock:
            setattr(self, field, getattr(self, field) + by)

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "n_requests": self.n_requests,
                "n_dispatches": self.n_dispatches,
                "retries": self.retries,
                "lost_futures": self.lost_futures,
                "partial_results": self.partial_results,
                "ejects": self.ejects,
                "readmits": self.readmits,
                "probes": self.probes,
                "probe_failures": self.probe_failures,
            }


class _Replica:
    """Router-side state for one endpoint (guarded by the router's lock)."""

    __slots__ = ("endpoint", "healthy", "inflight", "dispatches",
                 "failures", "ejects", "readmits", "ok_probes",
                 "last_error")

    def __init__(self, endpoint: EngineEndpoint):
        self.endpoint = endpoint
        self.healthy = True
        self.inflight = 0
        self.dispatches = 0
        self.failures = 0
        self.ejects = 0
        self.readmits = 0
        self.ok_probes = 0        # consecutive successes while ejected
        self.last_error = None

    @property
    def name(self) -> str:
        return self.endpoint.name


class _InFlight:
    """One routed request: the caller-facing future plus retry/fan-out
    bookkeeping.  ``lock`` guards the sharded accumulation; the ``done``
    flag makes completion idempotent (a user error can finish the request
    while other shards are still resolving)."""

    __slots__ = ("Q", "k", "deadline_ms", "single", "outer", "attempts",
                 "tried", "lock", "done", "results", "failed", "remaining")

    def __init__(self, Q, k, deadline_ms, single):
        self.Q = Q
        self.k = k
        self.deadline_ms = deadline_ms
        self.single = single
        self.outer: Future = Future()
        self.attempts = 0          # replicated failovers so far
        self.tried: set = set()    # replica names already failed
        self.lock = threading.Lock()
        self.done = False
        self.results: list = []    # sharded: per-shard (ids, dists) | None
        self.failed: dict = {}     # sharded: shard index -> exception
        self.remaining = 0


# ==========================================================================
# router
# ==========================================================================

class Router:
    """Dispatch queries across replica endpoints; see module docstring.

    ``submit()`` mirrors the micro-batcher's API (vector or batch, ``k=``,
    ``deadline_ms=``, a Future resolving to (ids, dists)); ``query()`` is
    the synchronous convenience.  Use as a context manager — ``close()``
    waits for in-flight requests, stops the prober, and drains every
    replica's queue."""

    def __init__(self, endpoints, cfg: RouterConfig | None = None):
        self.cfg = cfg or RouterConfig(replicas=len(endpoints))
        if not endpoints:
            raise ValueError("router needs at least one endpoint")
        if len(endpoints) != self.cfg.replicas:
            raise ValueError(f"RouterConfig.replicas={self.cfg.replicas} "
                             f"but {len(endpoints)} endpoints given")
        names = [e.name for e in endpoints]
        if len(set(names)) != len(names):
            raise ValueError(f"endpoint names must be unique, got {names}")
        dims = {int(e.engine.X.shape[1]) for e in endpoints}
        if len(dims) != 1:
            raise ValueError(f"endpoints disagree on vector dim: {dims}")
        self.d = dims.pop()
        self.k = endpoints[0].engine.k
        self._replicas = [_Replica(e) for e in endpoints]
        self.stats = RouterStats()
        self._lock = threading.Lock()
        self._rr = itertools.count()      # round-robin cursor
        self._closed = False
        self._close_done = threading.Event()
        # in-flight request tracking so close() can drain
        self._n_inflight = 0
        self._idle = threading.Event()
        self._idle.set()
        self._probe_Q = np.zeros((1, self.d), np.float32)
        self._stop = threading.Event()
        self._prober = None
        if self.cfg.health_interval_s > 0:
            self._prober = threading.Thread(
                target=self._probe_loop, daemon=True, name="repro-router-hc")
            self._prober.start()

    @classmethod
    def for_index(cls, index, cfg: RouterConfig, **queue_kw) -> "Router":
        """The facade constructor behind ``Index.serve(router=...)``:
        replicated mode replicates the index's engine (shared plane +
        compile cache); sharded mode splits the index's corpus into
        ``cfg.replicas`` contiguous slices and builds one sub-index per
        slice (a rebuild — capacity scaling, not a free view)."""
        qkw = queue_kw or None
        if cfg.mode == "replicated":
            eps = replicate_engine(index.engine, cfg.replicas,
                                   names=cfg.endpoint_names, queue_kw=qkw)
        else:
            eps = shard_engines(np.asarray(index.X), index.cfg,
                                shards=cfg.replicas, k=index.k,
                                threshold=index.engine.threshold,
                                names=cfg.endpoint_names, queue_kw=qkw)
        return cls(eps, cfg)

    @property
    def endpoints(self) -> tuple:
        return tuple(r.endpoint for r in self._replicas)

    def healthy_replicas(self) -> tuple:
        with self._lock:
            return tuple(r.name for r in self._replicas if r.healthy)

    # -- client side ---------------------------------------------------------

    def submit(self, Q, *, k: int | None = None,
               deadline_ms: float | None = None) -> Future:
        """Route one request; `Q` is a single vector [d] or a batch [b, d].
        Returns a Future resolving to (ids, dists) shaped to the input
        rank.  Replica faults are retried/failed over per the config;
        malformed requests raise here, synchronously."""
        Q = np.asarray(Q, np.float32)
        single = Q.ndim == 1
        if single:
            Q = Q[None]
        if Q.ndim != 2 or Q.shape[0] == 0 or Q.shape[1] != self.d:
            raise ValueError(f"Q must be [{self.d}] or [b, {self.d}], "
                             f"got {Q.shape}")
        with self._lock:
            if self._closed:
                raise RuntimeError("Router is closed")
            self._n_inflight += 1
            self._idle.clear()
        self.stats.bump("n_requests")
        st = _InFlight(Q, k, deadline_ms, single)
        if self.cfg.mode == "replicated":
            self._dispatch(st)
        else:
            self._dispatch_sharded(st)
        return st.outer

    def query(self, Q, *, k: int | None = None, timeout: float | None = 60):
        """Synchronous convenience: ``submit(...).result(timeout)``."""
        return self.submit(Q, k=k).result(timeout=timeout)

    def close(self, *, drain: bool = True) -> None:
        """Stop probing, wait for in-flight requests (``drain=True``), and
        close every replica's queue.  Idempotent: concurrent/second calls
        wait for the first to finish."""
        with self._lock:
            first = not self._closed
            self._closed = True
        if not first:
            self._close_done.wait()
            return
        try:
            self._stop.set()
            if self._prober is not None:
                self._prober.join(timeout=60)
            if drain:
                # every accepted request either resolves or fails over on a
                # bounded schedule, so this terminates
                self._idle.wait(timeout=600)
            for rep in self._replicas:
                rep.endpoint.close()
        finally:
            self._close_done.set()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- request completion ---------------------------------------------------

    def _finish(self, st: _InFlight, result=None, exc=None) -> None:
        with st.lock:
            if st.done:
                return
            st.done = True
        if exc is not None:
            st.outer.set_exception(exc)
        else:
            ids, dists = result
            if st.single:
                ids, dists = ids[0], dists[0]
            st.outer.set_result((ids, dists))
        with self._lock:
            self._n_inflight -= 1
            if self._n_inflight == 0:
                self._idle.set()

    # -- replicated dispatch ---------------------------------------------------

    def _pick(self, exclude: set):
        """A healthy replica not in ``exclude`` (falling back to any healthy
        one), per the configured policy; None when all are ejected."""
        with self._lock:
            healthy = [r for r in self._replicas if r.healthy]
            pool = [r for r in healthy if r.name not in exclude] or healthy
            if not pool:
                return None
            if self.cfg.policy == "round_robin":
                return pool[next(self._rr) % len(pool)]
            return min(pool, key=lambda r: r.inflight)

    def _dispatch(self, st: _InFlight) -> None:
        rep = self._pick(st.tried)
        if rep is None:
            self.stats.bump("lost_futures")
            self._finish(st, exc=NoHealthyReplicas(
                f"all {len(self._replicas)} replicas are ejected"))
            return
        with self._lock:
            rep.inflight += 1
            rep.dispatches += 1
        self.stats.bump("n_dispatches")
        fut = rep.endpoint.submit(st.Q, k=st.k, deadline_ms=st.deadline_ms)
        fut.add_done_callback(
            lambda f, rep=rep: self._on_replicated_done(st, rep, f))

    def _on_replicated_done(self, st: _InFlight, rep: _Replica, fut) -> None:
        with self._lock:
            rep.inflight -= 1
        exc = fut.exception()
        if exc is None:
            self._finish(st, result=fut.result())
            return
        if isinstance(exc, _USER_ERRORS):
            self._finish(st, exc=exc)      # the request's fault: no retry
            return
        self._eject(rep, exc)
        st.tried.add(rep.name)
        st.attempts += 1
        if st.attempts > self.cfg.max_retries:
            self.stats.bump("lost_futures")
            self._finish(st, exc=exc)
            return
        self.stats.bump("retries")
        self._later(self.cfg.backoff_s * st.attempts, self._dispatch, st)

    def _later(self, delay: float, fn, *args) -> None:
        if delay <= 0:
            fn(*args)
            return
        t = threading.Timer(delay, fn, args=args)
        t.daemon = True
        t.start()

    # -- sharded dispatch -------------------------------------------------------

    def _dispatch_sharded(self, st: _InFlight) -> None:
        reps = self._replicas
        st.results = [None] * len(reps)
        st.remaining = len(reps)
        for i, rep in enumerate(reps):
            self._submit_shard(st, i, rep, attempt=0)

    def _submit_shard(self, st: _InFlight, i: int, rep: _Replica,
                      attempt: int) -> None:
        with self._lock:
            healthy = rep.healthy
            if healthy:
                rep.inflight += 1
                rep.dispatches += 1
        if not healthy and attempt == 0:
            # known-dead shard: fail its slot immediately, don't burn the
            # whole retry budget discovering what the prober already knows
            self._shard_failed(st, i, rep, ReplicaDead(
                f"shard {rep.name!r} is ejected"), self.cfg.max_retries)
            return
        if not healthy:
            # mid-retry eject (e.g. by the prober): one attempt to come back
            with self._lock:
                rep.inflight += 1
                rep.dispatches += 1
        self.stats.bump("n_dispatches")
        fut = rep.endpoint.submit(st.Q, k=st.k, deadline_ms=st.deadline_ms)
        fut.add_done_callback(
            lambda f, i=i, rep=rep, attempt=attempt:
            self._on_shard_done(st, i, rep, attempt, f))

    def _on_shard_done(self, st: _InFlight, i: int, rep: _Replica,
                       attempt: int, fut) -> None:
        with self._lock:
            rep.inflight -= 1
        exc = fut.exception()
        if exc is None:
            with st.lock:
                st.results[i] = fut.result()
                st.remaining -= 1
                ready = st.remaining == 0
            if ready:
                self._merge_shards(st)
            return
        if isinstance(exc, _USER_ERRORS):
            self._finish(st, exc=exc)      # outer future fails once; other
            return                         # shards resolve into a done st
        self._eject(rep, exc)
        if attempt < self.cfg.max_retries:
            # a shard has no peer holding its rows: retry the SAME shard
            self.stats.bump("retries")
            self._later(self.cfg.backoff_s * (attempt + 1),
                        self._submit_shard, st, i, rep, attempt + 1)
            return
        self._shard_failed(st, i, rep, exc, attempt)

    def _shard_failed(self, st: _InFlight, i: int, rep: _Replica, exc,
                      attempt) -> None:
        with st.lock:
            st.failed[i] = exc
            st.remaining -= 1
            ready = st.remaining == 0
        if ready:
            self._merge_shards(st)

    def _merge_shards(self, st: _InFlight) -> None:
        from repro.core.distributed import merge_shard_results

        with st.lock:
            if st.done:
                return
            results = list(st.results)
            failed = dict(st.failed)
        k = st.k if st.k is not None else self.k
        reps = self._replicas
        survivors = [i for i in range(len(reps)) if results[i] is not None]
        pools = [results[i] for i in survivors]
        offsets = [reps[i].endpoint.id_offset for i in survivors]
        n_rows = [reps[i].endpoint.n_rows for i in survivors]
        B = st.Q.shape[0]
        try:
            ids, dists = merge_shard_results(pools, offsets, n_rows,
                                             k=k, batch=B)
        except Exception as e:  # noqa: BLE001 — deliver, don't die
            self._finish(st, exc=e)
            return
        if failed:
            self.stats.bump("partial_results")
            if st.single:
                ids, dists = ids[0], dists[0]
            names = lambda idx: tuple(reps[i].name for i in idx)  # noqa: E731
            self._finish(st, exc=PartialResultError(
                f"{len(failed)}/{len(reps)} shards failed after "
                f"{self.cfg.max_retries} retries "
                f"({', '.join(sorted(names(failed)))}); carrying the "
                "surviving shards' merged top-k",
                ids=ids, dists=dists,
                failed=names(sorted(failed)), survivors=names(survivors)))
            return
        self._finish(st, result=(ids, dists))

    # -- health: eject / probe / readmit -----------------------------------------

    def _eject(self, rep: _Replica, exc) -> None:
        with self._lock:
            rep.failures += 1
            rep.last_error = repr(exc)
            if not rep.healthy:
                return
            rep.healthy = False
            rep.ejects += 1
            rep.ok_probes = 0
        self.stats.bump("ejects")

    def _readmit(self, rep: _Replica) -> None:
        with self._lock:
            if rep.healthy:
                return
            rep.healthy = True
            rep.ok_probes = 0
        self.stats.bump("readmits")

    def _probe(self, rep: _Replica) -> bool:
        self.stats.bump("probes")
        try:
            fut = rep.endpoint.submit(self._probe_Q, k=self.k)
            fut.result(timeout=self.cfg.probe_timeout_s)
            return True
        except Exception as e:  # noqa: BLE001 — any failure ejects
            self.stats.bump("probe_failures")
            with self._lock:
                rep.last_error = repr(e)
            return False

    def _probe_loop(self) -> None:
        """Periodic health checks: a failed/timed-out probe ejects within
        one interval; ``readmit_probes`` consecutive successes readmit."""
        while not self._stop.wait(self.cfg.health_interval_s):
            for rep in self._replicas:
                if self._stop.is_set():
                    return
                ok = self._probe(rep)
                if rep.healthy:
                    if not ok:
                        self._eject(rep, ReplicaDead(
                            f"health probe failed for {rep.name!r}"))
                    continue
                with self._lock:
                    rep.ok_probes = rep.ok_probes + 1 if ok else 0
                    ready = rep.ok_probes >= self.cfg.readmit_probes
                if ready:
                    self._readmit(rep)

    # -- aggregated stats ----------------------------------------------------------

    def snapshot(self) -> dict:
        """One aggregated view: router counters, per-replica health +
        engine/queue stats, and cross-replica aggregates (summed counters;
        latency percentiles over the MERGED per-regime windows, not an
        average of per-replica percentiles)."""
        with self._lock:
            states = [(r, r.healthy, r.inflight, r.dispatches, r.failures,
                       r.ejects, r.readmits, r.last_error)
                      for r in self._replicas]
        replicas = {}
        agg = {"n_queries": 0, "n_batches": 0, "small_batches": 0,
               "large_batches": 0, "compiles": 0, "aot_primed": 0,
               "expired": 0, "qps": 0.0}
        windows = {"small": [], "large": []}
        for (rep, healthy, inflight, dispatches, failures, ejects,
             readmits, last_error) in states:
            eng = rep.endpoint.engine
            with eng._lock:
                e = eng.stats.snapshot()
                for regime, reg in eng.stats.per_regime.items():
                    windows[regime].extend(reg.latencies_s)
            q = rep.endpoint.batcher.stats.snapshot()
            replicas[rep.name] = {
                "healthy": healthy, "inflight": inflight,
                "dispatches": dispatches, "failures": failures,
                "ejects": ejects, "readmits": readmits,
                "last_error": last_error, "engine": e, "queue": q,
            }
            for key in ("n_queries", "n_batches", "small_batches",
                        "large_batches", "compiles", "aot_primed"):
                agg[key] += e[key]
            agg["qps"] += e["qps"]
            agg["expired"] += q["expired"]
        for regime, window in windows.items():
            arr = np.asarray(window) if window else np.asarray([np.nan])
            for p in (50, 90, 99):
                agg[f"{regime}_p{p}_ms"] = float(
                    np.nanpercentile(arr, p)) * 1e3 if window else float(
                    "nan")
        agg["healthy_replicas"] = sum(1 for s in states if s[1])
        agg["n_replicas"] = len(states)
        return {"mode": self.cfg.mode, "router": self.stats.snapshot(),
                "replicas": replicas, "aggregate": agg}
