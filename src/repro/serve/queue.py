"""Async micro-batching queue: coalesce concurrent requests into one dispatch.

The GPU serving systems the paper competes with (CAGRA, GGNN) get their
throughput from request coalescing — many concurrent callers, one device
launch.  :class:`MicroBatcher` is the thread-based TPU/JAX equivalent: a
single dispatcher thread drains a submission queue, concatenates requests
that share `k` into one batch (up to ``max_batch`` queries, waiting at most
``max_wait`` for co-riders), answers them with one ``engine.query()`` call,
and resolves each caller's :class:`~concurrent.futures.Future` with its own
rows.  Coalesced singles ride the engine's shape buckets, so steady-state
traffic stays on persistent compiled executables.

    engine = ANNEngine(X, cfg, k=10)
    with MicroBatcher(engine) as mb:
        futs = [mb.submit(q) for q in queries]       # from any thread(s)
        results = [f.result() for f in futs]         # (ids [k], dists [k])
"""
from __future__ import annotations

import collections
import dataclasses
import queue as _queue
import threading
import time
from concurrent.futures import Future

import numpy as np


class DeadlineExceeded(TimeoutError):
    """The request's ``deadline_ms`` elapsed before it was dispatched.

    Raised *through the future* (``Future.result()``), never out of
    ``submit``; the request consumed no bucket slot and no device time."""


@dataclasses.dataclass
class _Request:
    Q: np.ndarray          # [b, d] float32
    k: int | None
    single: bool           # caller passed a bare vector -> return [k] rows
    future: Future
    deadline: float | None = None   # absolute time.monotonic() cutoff


@dataclasses.dataclass
class BatcherStats:
    """Dispatch counters, mutated by the dispatcher thread and read by any
    caller thread — every access goes through ``_lock`` so readers never
    see a torn update (e.g. ``n_dispatches`` bumped before ``n_queries``).
    ``snapshot()`` returns one consistent view; the bare attributes remain
    readable for single-field checks."""

    n_requests: int = 0
    n_queries: int = 0
    n_dispatches: int = 0
    bypass: int = 0                 # dispatches that took the QoS bypass lane
    expired: int = 0                # requests failed with DeadlineExceeded
    # recent dispatch sizes only (bounded; the means use the counters)
    dispatch_sizes: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=8192))
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)

    def record_dispatch(self, n_requests: int, n_queries: int, *,
                        bypass: bool = False) -> None:
        with self._lock:
            self.n_requests += n_requests
            self.n_queries += n_queries
            self.n_dispatches += 1
            if bypass:
                self.bypass += 1
            self.dispatch_sizes.append(n_queries)

    def record_expired(self) -> None:
        with self._lock:
            self.expired += 1

    @property
    def mean_coalesced(self) -> float:
        with self._lock:
            return self.n_queries / max(self.n_dispatches, 1)

    def snapshot(self) -> dict:
        """One consistent view of every counter (all under one lock hold)."""
        with self._lock:
            return {
                "n_requests": self.n_requests,
                "n_queries": self.n_queries,
                "n_dispatches": self.n_dispatches,
                "bypass": self.bypass,
                "expired": self.expired,
                "mean_coalesced":
                    self.n_queries / max(self.n_dispatches, 1),
                "dispatch_sizes": tuple(self.dispatch_sizes),
            }


class MicroBatcher:
    """Coalesces concurrent `submit()`s into batched `engine.query()` calls.

    Requests with different `k` never share a dispatch (they need different
    compiled shapes); a `k` change flushes the in-flight group.  Errors from
    the engine propagate to every future of the failed dispatch.

    **QoS bypass lane** — a submit whose batch is already ``>= max_batch``
    gains nothing from coalescing (it fills a dispatch by itself) but, in
    the FIFO queue, would head-of-line block every latency-sensitive single
    behind a multi-second bulk search.  Such requests skip the queue
    entirely: they dispatch immediately on a dedicated thread while the
    FIFO lane keeps draining interactive traffic (the engine's compile
    cache and stats are thread-safe).  Counted in ``stats.bypass``.

    At most ``MAX_BYPASS_LANES`` bypass dispatches run concurrently; bulk
    submits beyond that fall back to the FIFO queue (bounded threads and
    bounded resident batches under bursty bulk traffic).

    **QoS deadlines** — ``submit(..., deadline_ms=)`` bounds how long a
    request may wait for dispatch; one that expires while queued fails
    with :class:`DeadlineExceeded` instead of occupying a slot in a
    coalesced batch (checked when the dispatcher pops it and again in the
    close-drain sweep; counted in ``stats.expired``).

    ``close(drain=True)`` (the default, also the context-manager exit)
    serves everything already enqueued — including submits that raced the
    shutdown sentinel — before returning; ``drain=False`` fails pending
    futures instead.  ``stats`` is safe to read from any thread; use
    ``stats.snapshot()`` for a consistent multi-field view.
    """

    MAX_BYPASS_LANES = 8

    def __init__(self, engine, *, max_wait_ms: float | None = None,
                 max_batch: int | None = None):
        cfg = engine.cfg
        self.engine = engine
        self.max_wait_s = (cfg.queue_max_wait_ms if max_wait_ms is None
                           else max_wait_ms) / 1e3
        self.max_batch = (cfg.queue_max_batch if max_batch is None
                          else max_batch)
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.stats = BatcherStats()
        self._q: _queue.Queue = _queue.Queue()
        self._carry: _Request | None = None
        self._bypass_threads: list = []
        self._closed = False
        self._close_done = threading.Event()  # set once a close() finishes
        # makes submit's closed-check + enqueue atomic against close()
        # setting the flag: every accepted request is enqueued BEFORE the
        # shutdown sentinel, so it is either served by the dispatcher or
        # swept up by close()'s drain — no Future can be silently dropped
        self._submit_lock = threading.Lock()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="repro-microbatcher")
        self._thread.start()

    # -- client side --------------------------------------------------------

    def submit(self, Q, *, k: int | None = None,
               deadline_ms: float | None = None) -> Future:
        """Enqueue one request; `Q` is a single vector [d] or a batch [b, d].

        Returns a Future resolving to (ids, dists) — shaped [k]/[b, k] to
        match the input rank.

        ``deadline_ms`` (QoS): if the request is still waiting for dispatch
        when the deadline elapses, its future fails with
        :class:`DeadlineExceeded` instead of occupying a slot in a
        coalesced batch — stale answers are never computed, and fresh
        traffic isn't padded out by requests nobody is waiting for anymore.
        The deadline gates *dispatch*, not completion: a request that makes
        it into a device batch before the cutoff is answered normally even
        if the answer lands after it.  Expired requests are counted in
        ``stats.expired``.
        """
        if deadline_ms is not None and deadline_ms <= 0:
            raise ValueError(f"deadline_ms must be > 0, got {deadline_ms}")
        Q = np.asarray(Q, np.float32)
        single = Q.ndim == 1
        if single:
            Q = Q[None]
        d = self.engine.X.shape[1]
        if Q.ndim != 2 or Q.shape[0] == 0 or Q.shape[1] != d:
            # reject here so a malformed request can't poison the group it
            # would be concatenated with in the dispatcher
            raise ValueError(f"Q must be [{d}] or [b, {d}], got {Q.shape}")
        fut: Future = Future()
        req = _Request(Q=Q, k=k, single=single, future=fut,
                       deadline=(None if deadline_ms is None
                                 else time.monotonic() + deadline_ms / 1e3))
        with self._submit_lock:
            if self._closed:
                raise RuntimeError(
                    "MicroBatcher is closed — close() was already called; "
                    "submits after close are rejected rather than queued "
                    "(they could never be dispatched)")
            self._bypass_threads = [x for x in self._bypass_threads
                                    if x.is_alive()]
            if (Q.shape[0] >= self.max_batch
                    and len(self._bypass_threads) < self.MAX_BYPASS_LANES):
                # QoS bypass lane: a full-dispatch bulk batch skips the
                # FIFO coalescing wait so it can't head-of-line block
                # latency traffic; served on its own thread immediately.
                # The lane count is capped — a burst of bulk submits past
                # the cap degrades gracefully to the FIFO queue instead of
                # spawning one thread (and one resident concatenated
                # batch) per request.
                t = threading.Thread(
                    target=self._serve_group, args=([req],),
                    kwargs={"bypass": True}, daemon=True,
                    name="repro-microbatcher-bypass")
                self._bypass_threads.append(t)
                t.start()
            else:
                self._q.put(req)
        return fut

    def close(self, *, drain: bool = True) -> None:
        """Stop the dispatcher; by default after draining pending work.

        Idempotent: a second (or concurrent) ``close()`` does not re-drain —
        it blocks until the first call has finished, so no caller ever
        returns from ``close()`` while futures are still being resolved."""
        with self._submit_lock:
            already = self._closed
            self._closed = True
        if already:
            self._close_done.wait(timeout=600)
            return
        try:
            self._close(drain)
        finally:
            self._close_done.set()

    def _close(self, drain: bool) -> None:
        if not drain:
            # fail whatever is still queued
            try:
                while True:
                    req = self._q.get_nowait()
                    req.future.set_exception(
                        RuntimeError("MicroBatcher closed"))
            except _queue.Empty:
                pass
        self._q.put(None)  # sentinel wakes the dispatcher
        self._thread.join(timeout=60)
        # requests that raced the sentinel (accepted by submit before the
        # closed flag was set, enqueued behind None via dispatcher re-puts,
        # or left by a timed-out join): with drain=True those callers asked
        # in good faith before the close completed — serve them, in
        # max_batch-capped same-k groups like the dispatcher would; only
        # fail them when drain=False
        leftovers = []
        try:
            while True:
                req = self._q.get_nowait()
                if req is not None:
                    leftovers.append(req)
        except _queue.Empty:
            pass
        if not drain:
            for req in leftovers:
                req.future.set_exception(RuntimeError("MicroBatcher closed"))
            for t in self._bypass_threads:  # already-dispatched bulk work
                t.join()
            return
        while leftovers:
            req = leftovers.pop(0)
            if self._expired(req):   # QoS: stale even at shutdown
                self._expire(req)
                continue
            group = [req]
            total = group[0].Q.shape[0]
            while (leftovers and leftovers[0].k == group[0].k
                   and total < self.max_batch):
                nxt = leftovers.pop(0)
                if self._expired(nxt):
                    self._expire(nxt)
                    continue
                total += nxt.Q.shape[0]
                group.append(nxt)
            self._serve_group(group)
        # bypass-lane dispatches run on their own threads; a close() must
        # not return while their futures are still unresolved (unbounded
        # join: killing a daemon thread mid-query would leave a future
        # that never resolves, which is strictly worse than waiting)
        for t in self._bypass_threads:
            t.join()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- dispatcher side ----------------------------------------------------

    def _expired(self, req: _Request) -> bool:
        return req.deadline is not None and time.monotonic() > req.deadline

    def _expire(self, req: _Request) -> None:
        """Fail one request whose deadline passed before dispatch."""
        self.stats.record_expired()
        req.future.set_exception(DeadlineExceeded(
            "request expired before dispatch (deadline_ms elapsed while "
            "queued)"))

    def _next_group(self) -> list | None:
        """Block for the first request, then coalesce same-k co-riders until
        `max_batch` queries are aboard or `max_wait` elapses.  Returns None
        on shutdown.  Requests whose deadline passed while queued are
        expired at pop time — they never occupy a slot in the group."""
        first = self._carry
        self._carry = None
        while first is not None and self._expired(first):
            self._expire(first)
            first = None
        while first is None:
            first = self._q.get()
            if first is None:
                return None
            if self._expired(first):
                self._expire(first)
                first = None
        group = [first]
        total = first.Q.shape[0]
        deadline = time.monotonic() + self.max_wait_s
        while total < self.max_batch:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                break
            try:
                nxt = self._q.get(timeout=remaining)
            except _queue.Empty:
                break
            if nxt is None:  # shutdown after serving what we have
                self._q.put(None)
                break
            if self._expired(nxt):
                self._expire(nxt)
                continue
            if nxt.k != first.k:
                self._carry = nxt  # different compiled shape: next group
                break
            group.append(nxt)
            total += nxt.Q.shape[0]
        return group

    def _serve_group(self, group: list, *, bypass: bool = False) -> None:
        """One coalesced dispatch: concat, query, slice results back out."""
        Q = np.concatenate([r.Q for r in group], axis=0)
        self.stats.record_dispatch(len(group), Q.shape[0], bypass=bypass)
        try:
            ids, dists = self.engine.query(Q, k=group[0].k)
        except Exception as e:  # noqa: BLE001 — deliver, don't die
            for r in group:
                r.future.set_exception(e)
            return
        row = 0
        for r in group:
            b = r.Q.shape[0]
            out = (ids[row], dists[row]) if r.single \
                else (ids[row:row + b], dists[row:row + b])
            r.future.set_result(out)
            row += b

    def _loop(self) -> None:
        while True:
            group = self._next_group()
            if group is None:
                return
            self._serve_group(group)
