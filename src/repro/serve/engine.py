"""ANN serving engine: the paper's small/large-batch regime dispatch.

The paper's empirical split  (a·SMs + b) / d  decides which procedure a
batch takes; our TPU analogue compares the batch's *search population*
(B·t0 for the small procedure) against the device's matmul occupancy target
(`cfg.small_batch_threshold`, per DB shard).  One engine, one graph — the
λ-prefix trick means both procedures share the index (paper §3.3).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ANNConfig
from repro.core.diversify import PackedGraph, build_tsdg
from repro.core.search_large import large_batch_search
from repro.core.search_small import small_batch_search


@dataclasses.dataclass
class ServeStats:
    n_queries: int = 0
    n_batches: int = 0
    small_batches: int = 0
    large_batches: int = 0
    total_s: float = 0.0

    @property
    def qps(self) -> float:
        return self.n_queries / max(self.total_s, 1e-9)


class ANNEngine:
    """In-process serving: build once, answer batches of queries."""

    def __init__(self, X, cfg: ANNConfig | None = None, *, k: int = 10,
                 graph: PackedGraph | None = None):
        self.cfg = cfg or ANNConfig()
        self.X = jnp.asarray(X)
        self.k = k
        self.graph = graph if graph is not None else build_tsdg(self.X,
                                                                self.cfg)
        self.stats = ServeStats()
        self._small = None
        self._large = None

    def regime(self, batch: int) -> str:
        """Paper §4: the division threshold between small and large."""
        return ("small" if batch * self.cfg.small_t0
                < self.cfg.small_batch_threshold * 4 else "large")

    def query(self, Q, *, k: int | None = None):
        k = k or self.k
        Q = jnp.asarray(Q)
        B = Q.shape[0]
        kind = self.regime(B)
        t0 = time.perf_counter()
        if kind == "small":
            ids, dists = small_batch_search(
                self.X, self.graph, Q, k=k, t0=self.cfg.small_t0,
                hops=self.cfg.small_hops, hop_width=self.cfg.hop_width,
                n_seeds=self.cfg.n_seeds, lambda_limit=10,
                metric=self.cfg.metric)
            self.stats.small_batches += 1
        else:
            ids, dists = large_batch_search(
                self.X, self.graph, Q, k=k, ef=self.cfg.large_ef,
                hops=self.cfg.large_hops, lambda_limit=5,
                metric=self.cfg.metric,
                n_seeds=getattr(self.cfg, "large_n_seeds",
                                self.cfg.n_seeds),
                m_seg=self.cfg.queue_segments, seg=self.cfg.segment_size,
                mv_seg=self.cfg.visited_segments, delta=self.cfg.delta)
            self.stats.large_batches += 1
        ids.block_until_ready()
        dt = time.perf_counter() - t0
        self.stats.n_queries += B
        self.stats.n_batches += 1
        self.stats.total_s += dt
        return np.asarray(ids), np.asarray(dists)
