"""ANN serving engine: regime dispatch + shape-bucketed compile cache.

The paper's empirical split  (a·SMs + b) / d  decides which procedure a
batch takes; our TPU analogue compares the batch's *search population*
(B·t0 for the small procedure) against the device's matmul occupancy target
(`cfg.small_batch_threshold`, per DB shard) — or, with
``cfg.regime_calibration="probe"``, against a threshold *fitted* from timed
probe batches at engine init (:func:`repro.ann.dispatch.calibrate`, the
paper's per-GPU fit).  One engine, one graph — the λ-prefix trick means
both procedures share the index (paper §3.3).

Serving additions on top of the paper:

* **Shape buckets** — an incoming batch of B queries is padded up to the
  smallest bucket in ``cfg.serve_buckets`` that fits (edge-replicated rows),
  searched at the bucket shape, and sliced back to B rows.  Each
  (regime, bucket, k) triple is AOT-lowered and compiled exactly once and
  the executable is kept for the life of the engine, so steady-state
  traffic never re-traces or re-compiles.  Both search kernels derive their
  randomness per row (``fold_in`` by row index), so the padded call is
  bitwise-identical to the unpadded one on the real rows — padding is free
  in ids/recall, it only rounds up compute.
* **Execution planes** — the engine is device-layout agnostic: every
  lowering, operand, and fingerprint goes through an
  :class:`~repro.serve.plane.ExecutionPlane`.  The default
  ``SingleDevicePlane`` serves one resident database; pass ``mesh=`` and a
  ``MeshPlane`` shards the database + sub-indexes over the mesh
  (DESIGN.md §6) behind the same ``query()`` API — and, because the bucket
  ladder / compile cache / donation / stats all thread through the plane,
  a mesh engine gets per-(regime, bucket, k) cached executables, padded
  donated batches, AOT persistence and percentile stats for free.
* **Stats v2** — per-regime latency records (percentiles/histograms),
  compile and bucket-hit counters, and warmup (compile-triggering) batches
  excluded from steady-state QPS.
* **Streaming mutability (DESIGN.md §7)** — :meth:`add` appends vectors to
  a brute-force delta shard searched alongside the graph, :meth:`delete`
  tombstones ids via a persistent alive-mask threaded into the in-kernel
  keep-masks, and ``Index.compact()`` (:mod:`repro.ann.compaction`) folds
  both back into a fresh generation that hot-swaps under live traffic.
  The engine owns the host-side :class:`~repro.ann.delta.StreamState` and
  pushes device views to the plane; executables bind to operand *snapshots*
  so a same-shape generation swap re-uses every cached compile
  (``stats.compiles == 0`` across the swap), while a shape-changing swap
  surfaces as :class:`~repro.serve.plane.StaleGeneration` and ``query()``
  transparently re-dispatches.

This engine is the internal serving layer behind the :class:`repro.ann.Index`
facade (DESIGN.md §5): ``Index.search`` dispatches through ``query()``,
``Index.serve`` wires the engine to the micro-batching queue, and
``Index.save``/``Index.load`` persist the compile cache across processes via
:meth:`ANNEngine.export_executable` / :meth:`ANNEngine.prime_executable`.

Thread-safety: ``query()`` may be called from many threads (the
micro-batching queue in :mod:`repro.serve.queue` does); the compile cache
and stats are lock-protected.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ann.dispatch import regime_for
from repro.configs.base import ANNConfig
from repro.serve.plane import (MeshPlane, SingleDevicePlane, SMALL_WIDTH,
                               StaleGeneration)

# back-compat alias (pre-plane revisions defined the ranking width here)
_SMALL_WIDTH = SMALL_WIDTH


@dataclasses.dataclass
class RegimeStats:
    """Latency/throughput record for one regime, warmup split out."""

    n_batches: int = 0
    n_queries: int = 0
    total_s: float = 0.0            # steady-state wall time
    warmup_batches: int = 0
    warmup_s: float = 0.0           # compile-triggering calls (excluded)
    # bounded window of recent batch latencies (long-running engines must
    # not grow memory per request); totals above cover the full history
    latencies_s: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=8192))

    def record(self, n: int, dt: float, *, warmup: bool) -> None:
        if warmup:
            self.warmup_batches += 1
            self.warmup_s += dt
            return
        self.n_batches += 1
        self.n_queries += n
        self.total_s += dt
        self.latencies_s.append(dt)

    def percentiles(self, qs=(50, 90, 99)) -> dict:
        if not self.latencies_s:
            return {f"p{q}": float("nan") for q in qs}
        arr = np.asarray(self.latencies_s)
        return {f"p{q}": float(np.percentile(arr, q)) for q in qs}

    def histogram(self, bins: int = 16):
        """(counts, edges_s) over steady-state batch latencies."""
        if not self.latencies_s:
            return np.zeros((bins,), np.int64), np.zeros((bins + 1,))
        return np.histogram(np.asarray(self.latencies_s), bins=bins)


@dataclasses.dataclass
class ServeStats:
    n_queries: int = 0              # all queries, warmup included
    n_batches: int = 0
    small_batches: int = 0
    large_batches: int = 0
    total_s: float = 0.0            # steady-state wall time (both regimes)
    steady_queries: int = 0
    compiles: int = 0
    aot_primed: int = 0             # executables restored from a saved index
    bucket_hits: int = 0            # calls served by a cached executable
    bucket_misses: int = 0          # calls that had to compile
    padded_queries: int = 0         # wasted rows added by bucketing
    # streaming mutability (DESIGN.md §7)
    generation: int = 0             # completed compactions since build/load
    n_added: int = 0                # vectors appended via add()
    n_deleted: int = 0              # ids tombstoned via delete()
    compactions: int = 0
    stream_batches: int = 0         # batches answered by a streaming exe
    # pinned-host H2D staging (single plane): batches moved through the
    # plane's staging route, and how many reused an already-built route
    # (the proof the pinned bounce buffer is reused in steady state)
    h2d_staged: int = 0
    h2d_stage_reuses: int = 0
    per_regime: dict = dataclasses.field(
        default_factory=lambda: {"small": RegimeStats(),
                                 "large": RegimeStats()})

    @property
    def qps(self) -> float:
        """Steady-state queries/s — warmup (compile) batches excluded."""
        return self.steady_queries / max(self.total_s, 1e-9)

    @property
    def bucket_hit_rate(self) -> float:
        total = self.bucket_hits + self.bucket_misses
        return self.bucket_hits / max(total, 1)

    def snapshot(self) -> dict:
        out = {
            "n_queries": self.n_queries, "n_batches": self.n_batches,
            "small_batches": self.small_batches,
            "large_batches": self.large_batches,
            "qps": self.qps, "compiles": self.compiles,
            "aot_primed": self.aot_primed,
            "bucket_hit_rate": self.bucket_hit_rate,
            "padded_queries": self.padded_queries,
            "generation": self.generation, "n_added": self.n_added,
            "n_deleted": self.n_deleted, "compactions": self.compactions,
            "stream_batches": self.stream_batches,
            "h2d_staged": self.h2d_staged,
            "h2d_stage_reuses": self.h2d_stage_reuses,
        }
        for name, reg in self.per_regime.items():
            for key, val in reg.percentiles().items():
                out[f"{name}_{key}_ms"] = val * 1e3
        return out


class ANNEngine:
    """In-process serving: build once, answer batches of queries.

    Single-device by default; pass ``mesh=`` to shard the database over the
    mesh's ``data``(+``pod``) axes and fan queries/searches over ``model``
    (see :mod:`repro.core.distributed`), or ``plane=`` to inject any
    prebuilt :class:`~repro.serve.plane.ExecutionPlane`.  Everything above
    the plane — bucket ladder, compile cache, warmup, donation hand-off,
    stats — is identical for every plane.

    ``threshold=`` overrides the regime split (a float compared against the
    same ``B·t0 < 4·threshold`` rule as ``cfg.small_batch_threshold``);
    with ``cfg.regime_calibration="probe"`` and no explicit override the
    threshold is fitted from timed probe batches at init
    (:func:`repro.ann.dispatch.calibrate`) and recorded in
    ``self.calibration``.
    """

    def __init__(self, X, cfg: ANNConfig | None = None, *, k: int = 10,
                 graph=None, mesh=None, plane=None,
                 threshold: float | None = None,
                 quant: tuple | None = None, cache_from=None,
                 packed: bool = False):
        self.cfg = cfg or ANNConfig()
        self.k = k
        self.stats = ServeStats()
        self._lock = threading.Lock()
        # host-side mutation log (tombstones + delta shard); None while the
        # index is frozen — created lazily by the first add()/delete()
        self.stream = None
        self._mutlock = threading.Lock()   # serializes add/delete/compact
        # (regime, bucket, k, backend, gather_fused, quantization,
        #  plane shape token, stream token) -> executable
        self._compiled: dict = {}
        self.buckets = tuple(sorted(self.cfg.serve_buckets))
        if plane is not None:
            if mesh is not None or graph is not None or quant is not None:
                raise ValueError("plane= already fixes the device layout; "
                                 "mesh=/graph=/quant= only apply when the "
                                 "engine builds its own plane")
            self.plane = plane
        elif mesh is None:
            self.plane = SingleDevicePlane(X, self.cfg, graph=graph,
                                           quant=quant, packed=packed)
        else:
            if graph is not None or quant is not None:
                raise ValueError("mesh mode builds its own sharded graph "
                                 "(and codes); graph=/quant= are only for "
                                 "single-device engines")
            self.plane = MeshPlane(X, self.cfg, mesh)
        self.mesh = getattr(self.plane, "mesh", None)
        if cache_from is not None:
            # serving-replica mode (repro.serve.router): share the donor's
            # compile cache (and its lock — entries are keyed purely on
            # plane-derived state, identical across engines over one plane)
            # so an AOT-primed donor makes every replica start steady-state;
            # stats stay per-engine
            if cache_from.plane is not self.plane:
                raise ValueError(
                    "cache_from shares compiled executables, which bind to "
                    "the plane's operand snapshots; it requires plane= set "
                    "to the donor's own plane")
            self._compiled = cache_from._compiled
            self._lock = cache_from._lock
        self.calibration = None
        self.threshold = threshold
        if (threshold is None
                and getattr(self.cfg, "regime_calibration",
                            "static") == "probe"):
            from repro.ann.dispatch import calibrate
            self.calibration = calibrate(self.plane, self.cfg, k=k)
            self.threshold = self.calibration.threshold

    # -- plane delegation (the engine's device-layout view) -----------------

    @property
    def X(self):
        return self.plane.X

    @property
    def graph(self):
        return self.plane.graph

    @property
    def backend(self) -> str:
        return self.plane.backend

    @property
    def gather_fused(self) -> str:
        return self.plane.gather_fused

    @property
    def _donate(self) -> bool:
        return self.plane.donate

    # -- regime & buckets ---------------------------------------------------

    def regime(self, batch: int) -> str:
        """Paper §4's division threshold — owned by the facade
        (:func:`repro.ann.dispatch.regime_for`) so engine, ``Index``, and
        benchmarks can never disagree on the split.  A calibrated/override
        threshold (see class docstring) replaces the static config value.
        A live delta shard adds its brute-force population to the estimate
        (every query scores every delta row), nudging borderline batches
        into the large regime."""
        return regime_for(self.cfg, batch, threshold=self.threshold,
                          n_delta=self._n_delta())

    def _n_delta(self) -> int:
        stream = self.stream
        return 0 if stream is None else stream.delta.n_alive()

    def bucket_for(self, batch: int) -> int:
        """Smallest ladder bucket >= batch; beyond the ladder, the next
        multiple of the largest bucket (bounded shape variety either way).
        No ladder -> raw batch size (one cache entry per distinct B).
        Rounded up to the plane's batch multiple (a mesh plane splits
        large batches over its query shards)."""
        if not self.buckets:
            bucket = batch
        else:
            bucket = next((b for b in self.buckets if b >= batch), None)
            if bucket is None:
                top = self.buckets[-1]
                bucket = -(-batch // top) * top
        s = self.plane.batch_multiple()
        if s > 1:
            bucket = -(-bucket // s) * s
        return bucket

    def _validate_k(self, k, kind: str) -> int:
        if k is None:
            k = self.k
        if not isinstance(k, int) or isinstance(k, bool) or k <= 0:
            raise ValueError(f"k must be a positive int, got {k!r}")
        if kind == "large" and k > self.cfg.large_ef:
            raise ValueError(
                f"k={k} exceeds large-batch ranking size ef="
                f"{self.cfg.large_ef}; raise cfg.large_ef or lower k")
        if kind == "small" and k > self.cfg.small_t0 * SMALL_WIDTH:
            raise ValueError(
                f"k={k} exceeds small-batch candidate pool t0*width="
                f"{self.cfg.small_t0 * SMALL_WIDTH}; raise cfg.small_t0 "
                "or lower k")
        return k

    # -- compile cache ------------------------------------------------------

    def _get_executable(self, kind: str, bucket: int, k: int,
                        streaming: bool = False):
        """Cached executable for (regime, bucket, k, backend, gather_fused,
        quantization, shape token, stream token); the plane compiles on
        miss.

        The plane's shape token keys the operand generation: a compaction
        that preserves operand shapes leaves the token — and therefore
        every cache entry — valid (zero recompiles across the swap), while
        a shape-changing one naturally misses.  Streaming executables key
        additionally on the delta shard's capacity, which grows
        geometrically, so recompiles are logarithmic in adds.

        Returns (callable taking the padded query batch, compiled_now)."""
        stream_tok = self.plane.stream_token() if streaming else None
        cache_key = (kind, bucket, k, self.backend, self.gather_fused,
                     getattr(self.cfg, "quantization", "none"),
                     self.plane.shape_token(), stream_tok)
        with self._lock:
            hit = self._compiled.get(cache_key)
        if hit is not None:
            return hit, False
        if streaming:
            exe = self.plane.compile_stream(kind, bucket, k)
        else:
            exe = self.plane.compile(kind, bucket, k)
        with self._lock:
            # a racing thread may have compiled the same key; keep the first
            prior = self._compiled.get(cache_key)
            if prior is not None:
                return prior, False
            self._compiled[cache_key] = exe
            self.stats.compiles += 1
        return exe, True

    # -- serving ------------------------------------------------------------

    @staticmethod
    def _check_numeric(A, what: str):
        """Reject non-numeric inputs BEFORE jnp.asarray turns them into an
        opaque shape/dtype error deep inside the kernel call."""
        dt = getattr(A, "dtype", None)
        if dt is None:
            A = np.asarray(A)
            dt = A.dtype
        if np.dtype(dt).kind not in "fiu":
            raise ValueError(
                f"{what} must be numeric (float/int), got dtype {dt!r}")
        return A

    def query(self, Q, *, k: int | None = None):
        """Answer a batch: (ids [B, k], dists [B, k]) numpy arrays."""
        Q_in = Q
        Q = self._check_numeric(Q, "Q")
        stage = getattr(self.plane, "stage_query", None)
        host = None
        if stage is not None and not isinstance(Q_in, jax.Array):
            # host-resident batch on a staging-capable plane: keep it on
            # host, pad there, and let the plane move it through its
            # reusable pinned-host bounce route (one H2D DMA per call)
            host = np.ascontiguousarray(np.asarray(Q, np.float32))
            q_shape = host.shape
        else:
            Q = (jnp.asarray(Q, jnp.float32) if Q is not Q_in
                 else jnp.asarray(Q))
            if Q.dtype != jnp.float32:
                Q = Q.astype(jnp.float32)
            q_shape = tuple(Q.shape)
        if len(q_shape) != 2 or q_shape[1] != self.X.shape[1]:
            raise ValueError(
                f"Q must be [B, {self.X.shape[1]}], got {tuple(q_shape)}")
        B = q_shape[0]
        if B == 0:
            raise ValueError("empty query batch")
        kind = self.regime(B)
        k = self._validate_k(k, kind)
        bucket = self.bucket_for(B)
        # dispatch loop: a concurrent compaction/add can swap the plane's
        # generation between executable lookup and call — the stale binding
        # raises StaleGeneration and we re-dispatch against the new token
        # (bounded: generations move monotonically under _mutlock)
        for _ in range(3):
            streaming = self.plane.stream_active
            if host is not None:
                # edge-pad on host (bitwise the jnp.pad below: row
                # replication), then one staged H2D transfer; the staged
                # array is freshly ours, safe to donate
                Qh = (host if bucket == B else
                      np.pad(host, ((0, bucket - B), (0, 0)), mode="edge"))
                Qpad = stage(Qh)
            elif bucket > B:
                Qpad = jnp.pad(Q, ((0, bucket - B), (0, 0)), mode="edge")
            elif self._donate:
                # the executable donates its input buffer; never hand it a
                # device array the caller still owns (or our retry reuses)
                Qpad = jnp.copy(Q)
            else:
                Qpad = Q
            exe, compiled_now = self._get_executable(kind, bucket, k,
                                                     streaming)
            t0 = time.perf_counter()
            try:
                ids, dists = exe(Qpad)
            except StaleGeneration:
                continue
            break
        else:
            raise RuntimeError(
                "query kept racing generation swaps; mutation rate "
                "outpaces dispatch")
        ids.block_until_ready()
        dt = time.perf_counter() - t0
        with self._lock:
            st = self.stats
            st.n_queries += B
            st.n_batches += 1
            st.padded_queries += bucket - B
            if kind == "small":
                st.small_batches += 1
            else:
                st.large_batches += 1
            if streaming:
                st.stream_batches += 1
            if host is not None:
                st.h2d_staged += 1
                st.h2d_stage_reuses = self.plane.stage_reuses
            if compiled_now:
                st.bucket_misses += 1
            else:
                st.bucket_hits += 1
                st.total_s += dt
                st.steady_queries += B
            st.per_regime[kind].record(B, dt, warmup=compiled_now)
        # padded rows are discarded before any caller-visible merge
        return np.asarray(ids[:B]), np.asarray(dists[:B])

    # -- streaming mutability (DESIGN.md §7) --------------------------------

    def add(self, V) -> np.ndarray:
        """Append vectors to the delta shard; returns their global ids
        (``n_base + slot`` — disjoint from every base id and stable until
        the next :func:`repro.ann.compaction.compact`).  Accepts [m, d] or
        a single [d] vector; numeric dtypes are cast to float32."""
        V = self._check_numeric(V, "vectors")
        V = np.asarray(V, np.float32)
        if V.ndim == 1:
            V = V[None]
        d = int(self.X.shape[1])
        if V.ndim != 2 or V.shape[1] != d:
            raise ValueError(
                f"vectors must be [m, {d}] (or a single [{d}] vector), "
                f"got {tuple(V.shape)}")
        if V.shape[0] == 0:
            raise ValueError("empty add batch")
        with self._mutlock:
            stream = self._ensure_stream()
            ids = stream.add(V)
            self._push_stream()
            with self._lock:
                self.stats.n_added += len(ids)
        return ids

    def delete(self, ids) -> int:
        """Tombstone ids (base or delta).  All-or-nothing: unknown,
        out-of-range, duplicate, or already-deleted ids raise KeyError and
        nothing is tombstoned.  Returns the number of ids removed."""
        with self._mutlock:
            stream = self._ensure_stream()
            n = stream.delete(ids)
            self._push_stream()
            with self._lock:
                self.stats.n_deleted += n
        return n

    def n_active(self) -> int:
        """Rows a search can currently return (base + delta − tombstones)."""
        stream = self.stream
        base = int(self.X.shape[0])
        return base if stream is None else stream.n_active()

    def _ensure_stream(self):
        """Lazily create the host-side mutation log (caller holds
        ``_mutlock``)."""
        if self.stream is None:
            from repro.ann.delta import StreamState
            self.stream = StreamState(
                int(self.X.shape[0]), int(self.X.shape[1]),
                min_cap=getattr(self.cfg, "delta_min_cap", 256))
        return self.stream

    def _push_stream(self) -> None:
        """Publish the host-side stream state as device operands (caller
        holds ``_mutlock``)."""
        self.plane.set_stream(*self.stream.device_view())

    def compact(self, *, tile: int = 2048) -> np.ndarray:
        """Fold streamed mutations into a fresh generation
        (:func:`repro.ann.compaction.compact`); returns the old->new id
        map."""
        from repro.ann.compaction import compact
        return compact(self, tile=tile)

    def _prune_stale_entries(self) -> None:
        """Drop cache entries bound to a superseded generation: their
        shape token can never match again (tokens move monotonically), so
        they would only raise StaleGeneration and hold dead arrays alive."""
        tok = self.plane.shape_token()
        with self._lock:
            stale = [key for key in self._compiled if key[6] != tok]
            for key in stale:
                del self._compiled[key]

    def restore_stream(self, base_alive, delta_X, delta_alive) -> None:
        """Re-attach persisted mutation state (artifact format v3 load)."""
        from repro.ann.delta import StreamState
        with self._mutlock:
            self.stream = StreamState.restore(
                base_alive, delta_X, delta_alive,
                min_cap=getattr(self.cfg, "delta_min_cap", 256))
            if self.stream.dirty:
                self._push_stream()
            else:
                self.stream = None

    def warmup_probes(self) -> list:
        """``[(regime, bucket, probe_batch)]`` covering every (regime,
        ladder bucket) pair a real request can reach.  A bucket can be
        reached by both regimes when the regime boundary falls inside its
        range, so each bucket is probed at its smallest and largest mapped
        batch.  This enumeration is shared by :meth:`warmup` and the
        facade's AOT artifact export (``repro.ann.artifact``), so a saved
        index persists exactly the executables warmup would compile."""
        probes, done, prev = [], set(), 0
        for b_raw in self.buckets or (1,):
            # record the bucket a request in this ladder step actually
            # compiles (plane batch-multiple rounding), but keep the probe
            # batches at the RAW ladder step — a rounded probe batch would
            # fall through to the next ladder rung and mislabel the entry
            b = self.bucket_for(b_raw)
            for probe in (prev + 1, b_raw):
                pair = (self.regime(probe), b)
                if pair not in done:
                    done.add(pair)
                    probes.append((pair[0], b, probe))
            prev = b_raw
        return probes

    def warmup(self, k: int | None = None) -> int:
        """Pre-compile every reachable (regime, ladder bucket, k) pair so
        the first real request is steady-state.  Returns the number of
        fresh compiles (0 when a loaded index primed them all)."""
        before = self.stats.compiles
        d = self.X.shape[1]
        for _, _, probe in self.warmup_probes():
            self.query(np.zeros((probe, d), np.float32), k=k)
        return self.stats.compiles - before

    # -- AOT persistence (repro.ann facade: Index.save / Index.load) --------

    def export_executable(self, kind: str, bucket: int,
                          k: int | None = None) -> bytes:
        """Serialize one (regime, bucket, k) serving computation with
        ``jax.export`` — the persistent form of a compile-cache entry.
        Delegates to the plane (each plane owns its export scheme; the mesh
        plane records shardings + device count in the module)."""
        k = self._validate_k(k, kind)
        return self.plane.export(kind, bucket, k)

    def aot_operands(self) -> tuple:
        """The exported modules' leading runtime arguments, in order:
        (X, neighbors, lambdas, degrees[, hubs][, codes, scales]) — the
        padded query batch is appended last by the caller."""
        return self.plane.operands()

    def prime_executable(self, kind: str, bucket: int, k: int,
                         call) -> None:
        """Install a restored executable into the compile cache.

        ``call`` must accept the bucket-padded query batch and return
        (ids, dists) — the same convention :meth:`_get_executable` caches.
        Primed entries count as bucket *hits* (no compile is recorded):
        a loaded index serves its first request steady-state.  AOT blobs
        persist only the frozen (non-streaming) form, so the stream slot of
        the key is always None here; the shape-token slot binds the entry
        to the generation that was saved.
        """
        key = (kind, bucket, k, self.backend, self.gather_fused,
               getattr(self.cfg, "quantization", "none"),
               self.plane.shape_token(), None)
        with self._lock:
            if key not in self._compiled:
                self._compiled[key] = call
                self.stats.aot_primed += 1
