"""ANN serving engine: regime dispatch + shape-bucketed compile cache.

The paper's empirical split  (a·SMs + b) / d  decides which procedure a
batch takes; our TPU analogue compares the batch's *search population*
(B·t0 for the small procedure) against the device's matmul occupancy target
(`cfg.small_batch_threshold`, per DB shard).  One engine, one graph — the
λ-prefix trick means both procedures share the index (paper §3.3).

Serving additions on top of the paper:

* **Shape buckets** — an incoming batch of B queries is padded up to the
  smallest bucket in ``cfg.serve_buckets`` that fits (edge-replicated rows),
  searched at the bucket shape, and sliced back to B rows.  Each
  (regime, bucket, k) triple is AOT-lowered and compiled exactly once and
  the executable is kept for the life of the engine, so steady-state
  traffic never re-traces or re-compiles.  Both search kernels derive their
  randomness per row (``fold_in`` by row index), so the padded call is
  bitwise-identical to the unpadded one on the real rows — padding is free
  in ids/recall, it only rounds up compute.
* **Mesh backend** — pass ``mesh=`` and the engine builds the sharded
  sub-indices with :func:`repro.core.distributed.make_build_fn` and serves
  through the shard-mapped search fns, behind the same ``query()`` API and
  the same bucketing/compile-cache/stats machinery.
* **Stats v2** — per-regime latency records (percentiles/histograms),
  compile and bucket-hit counters, and warmup (compile-triggering) batches
  excluded from steady-state QPS.

This engine is the internal serving layer behind the :class:`repro.ann.Index`
facade (DESIGN.md §5): ``Index.search`` dispatches through ``query()``,
``Index.serve`` wires the engine to the micro-batching queue, and
``Index.save``/``Index.load`` persist the compile cache across processes via
:meth:`ANNEngine.export_executable` / :meth:`ANNEngine.prime_executable`.

Thread-safety: ``query()`` may be called from many threads (the
micro-batching queue in :mod:`repro.serve.queue` does); the compile cache
and stats are lock-protected.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ann.dispatch import regime_for
from repro.ann.pipeline import build_graph
from repro.configs.base import ANNConfig
from repro.core import hotpath
from repro.core.diversify import PackedGraph
from repro.core.search_large import _large_batch_search
from repro.core.search_small import _small_batch_search

# small_batch_search's compiled-in ranking width (its `width` kwarg default):
# the per-query candidate pool is t0 * width entries
_SMALL_WIDTH = 32


@dataclasses.dataclass
class RegimeStats:
    """Latency/throughput record for one regime, warmup split out."""

    n_batches: int = 0
    n_queries: int = 0
    total_s: float = 0.0            # steady-state wall time
    warmup_batches: int = 0
    warmup_s: float = 0.0           # compile-triggering calls (excluded)
    # bounded window of recent batch latencies (long-running engines must
    # not grow memory per request); totals above cover the full history
    latencies_s: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=8192))

    def record(self, n: int, dt: float, *, warmup: bool) -> None:
        if warmup:
            self.warmup_batches += 1
            self.warmup_s += dt
            return
        self.n_batches += 1
        self.n_queries += n
        self.total_s += dt
        self.latencies_s.append(dt)

    def percentiles(self, qs=(50, 90, 99)) -> dict:
        if not self.latencies_s:
            return {f"p{q}": float("nan") for q in qs}
        arr = np.asarray(self.latencies_s)
        return {f"p{q}": float(np.percentile(arr, q)) for q in qs}

    def histogram(self, bins: int = 16):
        """(counts, edges_s) over steady-state batch latencies."""
        if not self.latencies_s:
            return np.zeros((bins,), np.int64), np.zeros((bins + 1,))
        return np.histogram(np.asarray(self.latencies_s), bins=bins)


@dataclasses.dataclass
class ServeStats:
    n_queries: int = 0              # all queries, warmup included
    n_batches: int = 0
    small_batches: int = 0
    large_batches: int = 0
    total_s: float = 0.0            # steady-state wall time (both regimes)
    steady_queries: int = 0
    compiles: int = 0
    aot_primed: int = 0             # executables restored from a saved index
    bucket_hits: int = 0            # calls served by a cached executable
    bucket_misses: int = 0          # calls that had to compile
    padded_queries: int = 0         # wasted rows added by bucketing
    per_regime: dict = dataclasses.field(
        default_factory=lambda: {"small": RegimeStats(),
                                 "large": RegimeStats()})

    @property
    def qps(self) -> float:
        """Steady-state queries/s — warmup (compile) batches excluded."""
        return self.steady_queries / max(self.total_s, 1e-9)

    @property
    def bucket_hit_rate(self) -> float:
        total = self.bucket_hits + self.bucket_misses
        return self.bucket_hits / max(total, 1)

    def snapshot(self) -> dict:
        out = {
            "n_queries": self.n_queries, "n_batches": self.n_batches,
            "small_batches": self.small_batches,
            "large_batches": self.large_batches,
            "qps": self.qps, "compiles": self.compiles,
            "aot_primed": self.aot_primed,
            "bucket_hit_rate": self.bucket_hit_rate,
            "padded_queries": self.padded_queries,
        }
        for name, reg in self.per_regime.items():
            for key, val in reg.percentiles().items():
                out[f"{name}_{key}_ms"] = val * 1e3
        return out


class ANNEngine:
    """In-process serving: build once, answer batches of queries.

    Single-device by default; pass ``mesh=`` to shard the database over the
    mesh's ``data``(+``pod``) axes and fan queries/searches over ``model``
    (see :mod:`repro.core.distributed`).  In mesh mode ``X`` is placed with
    the DB sharding and the sub-indices are built shard-locally.
    """

    def __init__(self, X, cfg: ANNConfig | None = None, *, k: int = 10,
                 graph: PackedGraph | None = None, mesh=None):
        self.cfg = cfg or ANNConfig()
        self.k = k
        self.mesh = mesh
        self.stats = ServeStats()
        self._lock = threading.Lock()
        # (regime, bucket, k, backend, gather_fused) -> executable
        self._compiled: dict = {}
        self.buckets = tuple(sorted(self.cfg.serve_buckets))
        # kernel backend resolved once per engine; part of the AOT cache key
        # so an engine rebuilt with a different backend never aliases entries
        self.backend = hotpath.resolve_backend(
            getattr(self.cfg, "kernel_backend", "auto"))
        # gather placement for the Pallas backend ("auto"/"on"/"off"); part
        # of the AOT cache key like the backend itself
        self.gather_fused = getattr(self.cfg, "gather_fused", "auto")
        # donate the bucket-padded query buffer into each dispatch so steady
        # state reuses its HBM instead of re-allocating per call; skipped on
        # CPU where XLA cannot alias the input (it would warn every call)
        self._donate = jax.default_backend() != "cpu"
        if mesh is None:
            self.X = jnp.asarray(X)
            self.graph = graph if graph is not None \
                else build_graph(self.X, self.cfg)
        else:
            if graph is not None:
                raise ValueError("mesh mode builds its own sharded graph; "
                                 "graph= is only for single-device engines")
            from jax.sharding import NamedSharding, PartitionSpec as P

            from repro.core import distributed as D
            self._D = D
            d_ax = D.db_axes(mesh)
            self.X = jax.device_put(
                jnp.asarray(X), NamedSharding(mesh, P(d_ax, None)))
            nbrs, lams, degs, hubs = D.make_build_fn(mesh, self.cfg)(self.X)
            jax.block_until_ready(nbrs)
            self._db_parts = (nbrs, lams, degs, hubs)
            self.graph = PackedGraph(
                neighbors=nbrs, lambdas=lams, degrees=degs,
                hubs=hubs if hubs.shape[0] else None)
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            self._n_q_shards = 1
            for a in D.query_axes(mesh):
                self._n_q_shards *= sizes[a]

    # -- regime & buckets ---------------------------------------------------

    def regime(self, batch: int) -> str:
        """Paper §4's division threshold — owned by the facade
        (:func:`repro.ann.dispatch.regime_for`) so engine, ``Index``, and
        benchmarks can never disagree on the split."""
        return regime_for(self.cfg, batch)

    def bucket_for(self, batch: int) -> int:
        """Smallest ladder bucket >= batch; beyond the ladder, the next
        multiple of the largest bucket (bounded shape variety either way).
        No ladder -> raw batch size (one cache entry per distinct B)."""
        if not self.buckets:
            bucket = batch
        else:
            bucket = next((b for b in self.buckets if b >= batch), None)
            if bucket is None:
                top = self.buckets[-1]
                bucket = -(-batch // top) * top
        if self.mesh is not None and self._n_q_shards > 1:
            # sharded large-batch search splits B over the model axis
            s = self._n_q_shards
            bucket = -(-bucket // s) * s
        return bucket

    def _validate_k(self, k, kind: str) -> int:
        if k is None:
            k = self.k
        if not isinstance(k, int) or isinstance(k, bool) or k <= 0:
            raise ValueError(f"k must be a positive int, got {k!r}")
        if kind == "large" and k > self.cfg.large_ef:
            raise ValueError(
                f"k={k} exceeds large-batch ranking size ef="
                f"{self.cfg.large_ef}; raise cfg.large_ef or lower k")
        if kind == "small" and k > self.cfg.small_t0 * _SMALL_WIDTH:
            raise ValueError(
                f"k={k} exceeds small-batch candidate pool t0*width="
                f"{self.cfg.small_t0 * _SMALL_WIDTH}; raise cfg.small_t0 "
                "or lower k")
        return k

    # -- compile cache ------------------------------------------------------

    def _search_args(self, kind: str, Q, k: int):
        """(jitted fn, positional args, static kwargs) for one dispatch."""
        cfg = self.cfg
        if self.mesh is not None:
            fn = self._D.make_search_fn(self.mesh, cfg, kind=kind, k=k)
            return fn, (self.X, *self._db_parts, Q), {}
        if kind == "small":
            kwargs = dict(k=k, t0=cfg.small_t0, hops=cfg.small_hops,
                          hop_width=cfg.hop_width, n_seeds=cfg.n_seeds,
                          lambda_limit=10, metric=cfg.metric,
                          backend=self.backend,
                          gather_fused=self.gather_fused)
            return _small_batch_search, (self.X, self.graph, Q), kwargs
        kwargs = dict(k=k, ef=cfg.large_ef, hops=cfg.large_hops,
                      lambda_limit=5, metric=cfg.metric,
                      n_seeds=getattr(cfg, "large_n_seeds", cfg.n_seeds),
                      m_seg=cfg.queue_segments, seg=cfg.segment_size,
                      mv_seg=cfg.visited_segments, delta=cfg.delta,
                      backend=self.backend,
                      gather_fused=self.gather_fused)
        return _large_batch_search, (self.X, self.graph, Q), kwargs

    def _get_executable(self, kind: str, bucket: int, k: int, Qpad):
        """Cached AOT executable for (regime, bucket, k, backend,
        gather_fused); compiles on miss.

        Returns (callable taking the padded query batch, compiled_now).
        The database, graph, and every search parameter are closed over so
        the padded query batch is the executable's ONLY argument — which is
        what lets its bucket-sized buffer be donated (ROADMAP "Donated
        buffers"): steady-state serving reuses the input's device memory
        instead of re-allocating per call.
        """
        cache_key = (kind, bucket, k, self.backend, self.gather_fused)
        with self._lock:
            hit = self._compiled.get(cache_key)
        if hit is not None:
            return hit, False
        fn, pos, kwargs = self._search_args(kind, Qpad, k)
        head = pos[:-1]
        wrapped = jax.jit(lambda Qb: fn(*head, Qb, **kwargs),
                          donate_argnums=(0,) if self._donate else ())
        exe = wrapped.lower(Qpad).compile()
        with self._lock:
            # a racing thread may have compiled the same key; keep the first
            prior = self._compiled.get(cache_key)
            if prior is not None:
                return prior, False
            self._compiled[cache_key] = exe
            self.stats.compiles += 1
        return exe, True

    # -- serving ------------------------------------------------------------

    def query(self, Q, *, k: int | None = None):
        """Answer a batch: (ids [B, k], dists [B, k]) numpy arrays."""
        Q_in = Q
        Q = jnp.asarray(Q)
        if Q.ndim != 2 or Q.shape[1] != self.X.shape[1]:
            raise ValueError(
                f"Q must be [B, {self.X.shape[1]}], got {tuple(Q.shape)}")
        B = Q.shape[0]
        if B == 0:
            raise ValueError("empty query batch")
        kind = self.regime(B)
        k = self._validate_k(k, kind)
        bucket = self.bucket_for(B)
        if bucket > B:
            Qpad = jnp.pad(Q, ((0, bucket - B), (0, 0)), mode="edge")
        elif self._donate and Q is Q_in:
            # the executable donates its input buffer; never hand it a
            # device array the caller still owns
            Qpad = jnp.copy(Q)
        else:
            Qpad = Q
        exe, compiled_now = self._get_executable(kind, bucket, k, Qpad)
        t0 = time.perf_counter()
        ids, dists = exe(Qpad)
        ids.block_until_ready()
        dt = time.perf_counter() - t0
        with self._lock:
            st = self.stats
            st.n_queries += B
            st.n_batches += 1
            st.padded_queries += bucket - B
            if kind == "small":
                st.small_batches += 1
            else:
                st.large_batches += 1
            if compiled_now:
                st.bucket_misses += 1
            else:
                st.bucket_hits += 1
                st.total_s += dt
                st.steady_queries += B
            st.per_regime[kind].record(B, dt, warmup=compiled_now)
        # padded rows are discarded before any caller-visible merge
        return np.asarray(ids[:B]), np.asarray(dists[:B])

    def warmup_probes(self) -> list:
        """``[(regime, bucket, probe_batch)]`` covering every (regime,
        ladder bucket) pair a real request can reach.  A bucket can be
        reached by both regimes when the regime boundary falls inside its
        range, so each bucket is probed at its smallest and largest mapped
        batch.  This enumeration is shared by :meth:`warmup` and the
        facade's AOT artifact export (``repro.ann.artifact``), so a saved
        index persists exactly the executables warmup would compile."""
        probes, done, prev = [], set(), 0
        for b in self.buckets or (1,):
            for probe in (prev + 1, b):
                pair = (self.regime(probe), b)
                if pair not in done:
                    done.add(pair)
                    probes.append((pair[0], b, probe))
            prev = b
        return probes

    def warmup(self, k: int | None = None) -> int:
        """Pre-compile every reachable (regime, ladder bucket, k) pair so
        the first real request is steady-state.  Returns the number of
        fresh compiles (0 when a loaded index primed them all)."""
        before = self.stats.compiles
        d = self.X.shape[1]
        for _, _, probe in self.warmup_probes():
            self.query(np.zeros((probe, d), np.float32), k=k)
        return self.stats.compiles - before

    # -- AOT persistence (repro.ann facade: Index.save / Index.load) --------

    def export_executable(self, kind: str, bucket: int,
                          k: int | None = None) -> bytes:
        """Serialize one (regime, bucket, k) serving computation with
        ``jax.export`` — the persistent form of a compile-cache entry.

        The database and packed graph are *arguments* of the exported
        module (not embedded constants), so blobs stay graph-independent
        small and one artifact can hold many entries.  Loading closes the
        module back over the device-resident arrays and re-wraps it in the
        donated single-argument convention (:mod:`repro.ann.artifact`).
        Bitwise contract: the exported module is lowered from the same
        trace `_get_executable` compiles, so a primed executable answers
        identically to a locally-compiled one.
        """
        if self.mesh is not None:
            raise NotImplementedError(
                "mesh-sharded engines cannot export executables yet")
        k = self._validate_k(k, kind)
        from jax import export as jax_export
        Qspec = jax.ShapeDtypeStruct((bucket, self.X.shape[1]), jnp.float32)
        fn, _, kwargs = self._search_args(kind, Qspec, k)
        # flat array args (jax.export cannot serialize the PackedGraph
        # pytree type); aot_operands() is the shared flattening so the
        # loader feeds arguments in exactly this order
        parts = self.aot_operands()
        has_hubs = self.graph.hubs is not None

        def _call(*args):
            Xa, nbrs, lams, degs = args[:4]
            g = PackedGraph(neighbors=nbrs, lambdas=lams, degrees=degs,
                            hubs=args[4] if has_hubs else None)
            return fn(Xa, g, args[-1], **kwargs)

        specs = tuple(jax.ShapeDtypeStruct(a.shape, a.dtype) for a in parts)
        exported = jax_export.export(jax.jit(_call))(*specs, Qspec)
        return bytes(exported.serialize())

    def aot_operands(self) -> tuple:
        """The exported modules' leading runtime arguments, in order:
        (X, neighbors, lambdas, degrees[, hubs]) — the padded query batch
        is appended last by the caller."""
        g = self.graph
        parts = (self.X, g.neighbors, g.lambdas, g.degrees)
        return parts + ((g.hubs,) if g.hubs is not None else ())

    def prime_executable(self, kind: str, bucket: int, k: int,
                         call) -> None:
        """Install a restored executable into the compile cache.

        ``call`` must accept the bucket-padded query batch and return
        (ids, dists) — the same convention `_get_executable` compiles.
        Primed entries count as bucket *hits* (no compile is recorded):
        a loaded index serves its first request steady-state.
        """
        key = (kind, bucket, k, self.backend, self.gather_fused)
        with self._lock:
            if key not in self._compiled:
                self._compiled[key] = call
                self.stats.aot_primed += 1
