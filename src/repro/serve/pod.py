"""Pod execution plane — `jax.distributed` multi-process sharded serving.

:class:`PodPlane` extends the mesh plane across OS processes (hosts): the
mesh spans EVERY process's devices, the database + per-shard sub-indexes
are laid out over the global ``data`` axis, and the cross-shard top-k merge
(:func:`repro.core.distributed.merge_topk`) runs inside the shard-mapped
search as a cross-process collective.  Because the plane protocol is the
only seam the serving engine sees, a pod engine inherits the bucketed AOT
compile cache, donation, warmup, streaming snapshots and stats unchanged.

Execution model is SPMD serving: every process runs the same program and
calls ``engine.query`` with the SAME batch (the request router is the front
door that broadcasts requests in a real deployment); collectives inside the
compiled search do the cross-process work, and the replicated output is
materialized identically on every process.  Three multi-process specifics
live here, each an override of a hook the base planes expose:

* operands are assembled with ``jax.make_array_from_callback`` from each
  process's host copy (``_put``) — a plain ``device_put`` cannot address
  other processes' devices;
* the engine's process-local padded query batch is lifted into a global
  replicated array per call (``_place_query``);
* ``fingerprint()``/``topology()`` additionally pin the process count, so
  AOT artifacts saved by a pod are only re-primed on an identical pod.

On CPU, collectives need the gloo backend::

    # one process per host, all pointing at the same coordinator
    init_pod("10.0.0.1:29500", num_processes=4, process_id=i)
    plane = PodPlane(X, cfg)               # mesh over all global devices
    index = Index(None, cfg, k=10, plane=plane)

Registered as ``"pod"`` via the :func:`repro.serve.plane.register_plane`
seam; :func:`repro.serve.plane.get_plane` imports this module lazily so
single-process code never initializes jax.distributed.

One multi-process caveat: ``cfg.regime_calibration="probe"`` fits the
regime threshold from *timed* probe batches, which could diverge across
processes near the split point and desynchronize the SPMD dispatch — pin a
static ``threshold=`` (or ship the saved artifact's calibrated value) on a
pod.

NOTE `jax.distributed.initialize` must run before ANY jax computation, and
several repro modules trace constants at import — so this module defers
every repro (and backend-touching jax) import: ``init_pod`` only needs the
coordinator client, and :class:`PodPlane` itself is built on first
attribute access (PEP 562) rather than at import.
"""
from __future__ import annotations

_INITIALIZED = False


def init_pod(coordinator: str = "localhost:29500", *,
             num_processes: int = 1, process_id: int = 0) -> None:
    """Initialize ``jax.distributed`` for one pod process (idempotent).

    Must run before anything touches the jax backend (device queries and
    traced constants included — import this module FIRST).  On CPU the
    collectives implementation is switched to gloo — the only CPU backend
    that supports cross-process collectives — which is what makes the pod
    plane testable without TPUs."""
    global _INITIALIZED
    if _INITIALIZED:
        return
    if num_processes > 1:
        import jax
        try:
            jax.config.update("jax_cpu_collectives_implementation", "gloo")
        except Exception:  # noqa: BLE001 — unknown on some jax versions
            pass
        jax.distributed.initialize(coordinator,
                                   num_processes=num_processes,
                                   process_id=process_id)
    _INITIALIZED = True


def _default_mesh():
    """All global devices on one ``data`` axis: pure DB sharding, queries
    replicated — the layout where every process can hold the full answer."""
    import jax
    return jax.make_mesh((jax.device_count(),), ("data",))


_POD_CLS = None


def _build_pod_class():
    """Define + register :class:`PodPlane` on first use (deferred so that
    importing this module for ``init_pod`` stays free of backend-touching
    imports — see the module docstring)."""
    global _POD_CLS
    if _POD_CLS is not None:
        return _POD_CLS

    import numpy as np

    import jax

    from repro.serve.plane import MeshPlane, register_plane

    class PodPlane(MeshPlane):
        """Cross-process mesh plane (see module docstring).  ``mesh=``
        defaults to every global device on one ``data`` axis; a custom mesh
        may add ``pod``/``data`` DB axes but not a ``model`` (query-
        sharding) axis when spanning processes — pod serving keeps queries
        and answers fully replicated so each process materializes the
        result locally."""

        name = "pod"

        def __init__(self, X, cfg, mesh=None, *, parts: tuple | None = None):
            if jax.process_count() > 1 and mesh is not None:
                from repro.core import distributed as D
                if D.n_query_shards(mesh) > 1:
                    raise ValueError(
                        "the pod plane serves queries replicated (every "
                        "process must hold the full answer); drop the "
                        "'model' axis from the pod mesh")
            super().__init__(X, cfg, mesh if mesh is not None
                             else _default_mesh(), parts=parts)

        # -- multi-process hooks ------------------------------------------

        def _put(self, a, sharding):
            """Assemble a global array from this process's full host copy:
            each process contributes exactly the shards local to it (SPMD —
            every process passes the same host data, so the global array is
            consistent by construction)."""
            a = np.asarray(a)
            return jax.make_array_from_callback(a.shape, sharding,
                                                lambda idx: a[idx])

        def _place_query(self, Qb):
            """Lift the engine's process-local padded batch into the global
            replicated query array the compiled module expects.  Every
            process submits the same batch (SPMD serving), so replication
            is assembly, not communication."""
            return self._put(Qb, self._repl)

        # -- identity -----------------------------------------------------

        def topology(self) -> dict:
            t = super().topology()
            t["n_processes"] = jax.process_count()
            return t

        def fingerprint(self) -> dict:
            fp = super().fingerprint()
            fp["n_processes"] = jax.process_count()
            return fp

    register_plane("pod", lambda X, cfg, **kw: PodPlane(X, cfg, **kw))
    _POD_CLS = PodPlane
    return PodPlane


def __getattr__(name: str):
    if name == "PodPlane":
        return _build_pod_class()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
