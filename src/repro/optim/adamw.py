"""AdamW — pytree implementation (no optax in this environment).

State layout mirrors the param tree so FSDP sharding rules apply unchanged to
optimizer state (m/v inherit the param PartitionSpec).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: Any = jnp.float32  # bf16 halves optimizer HBM for huge models


def init(cfg: AdamWConfig, params):
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def update(cfg: AdamWConfig, grads, state, params, lr_scale=1.0):
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** c
    bc2 = 1.0 - cfg.b2 ** c
    lr = cfg.lr * lr_scale

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        mhat = m32 / bc1
        vhat = v32 / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (no decay on norms/bias/embeds-1d)
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * step
        return new_p.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "count": count}
