"""Uniform optimizer facade used by the trainer and the dry-run."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

from repro.optim import adamw, adafactor
from repro.optim.schedules import SCHEDULES


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"  # adamw | adafactor
    lr: float = 3e-4
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    schedule: str = "warmup_cosine"
    warmup_steps: int = 100
    total_steps: int = 10_000
    max_grad_norm: float = 1.0
    state_dtype: str = "float32"  # bfloat16 halves AdamW HBM
    momentum: float = 0.9  # adafactor only


class Optimizer:
    def __init__(self, cfg: OptimizerConfig):
        self.cfg = cfg
        sd = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
        if cfg.name == "adamw":
            self.impl = adamw
            self.icfg: Any = adamw.AdamWConfig(
                lr=cfg.lr, b1=cfg.b1, b2=cfg.b2,
                weight_decay=cfg.weight_decay, state_dtype=sd)
        elif cfg.name == "adafactor":
            self.impl = adafactor
            self.icfg = adafactor.AdafactorConfig(
                lr=cfg.lr, weight_decay=cfg.weight_decay,
                momentum=cfg.momentum)
        else:
            raise ValueError(f"unknown optimizer {cfg.name}")
        self._sched = SCHEDULES[cfg.schedule]

    def init(self, params):
        return self.impl.init(self.icfg, params)

    def lr_scale(self, step):
        kw = {}
        if self.cfg.schedule != "constant":
            kw = dict(warmup_steps=self.cfg.warmup_steps,
                      total_steps=self.cfg.total_steps)
        return self._sched(step, **kw)

    def update(self, grads, state, params):
        scale = self.lr_scale(state["count"])
        return self.impl.update(self.icfg, grads, state, params, lr_scale=scale)


def make_optimizer(cfg: OptimizerConfig) -> Optimizer:
    return Optimizer(cfg)
