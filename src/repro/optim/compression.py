"""Error-feedback int8 gradient compression (1-bit-Adam family trick).

At 1000+ node scale the data-parallel all-reduce of bf16 gradients is the
dominant cross-pod collective.  We quantize per-tensor to int8 with a scale,
carry the quantization residual in an error-feedback buffer (so the scheme is
unbiased over time), and all-reduce the int8 payload — a 2x/4x reduction of
DCN/ICI bytes on the `pod`/`data` axes.

Applied inside shard_map (see trainer) or standalone for tests.

The per-tensor quantize/dequantize helpers moved to
:mod:`repro.ann.quantize` when the serving side grew compressed residency
(DESIGN.md §8); they are re-exported here with a warn-once shim so
training-side callers keep working.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.ann import quantize as _q
from repro.utils.deprecation import warn_once


def quantize(x: jax.Array):
    """Symmetric per-tensor int8 quantization -> (q, scale)."""
    warn_once("repro.optim.compression.quantize",
              "repro.ann.quantize.quantize")
    return _q.quantize(x)


def dequantize(q: jax.Array, scale: jax.Array):
    warn_once("repro.optim.compression.dequantize",
              "repro.ann.quantize.dequantize")
    return _q.dequantize(q, scale)


def compress_with_feedback(grad: jax.Array, error: jax.Array):
    """Return (q, scale, new_error).  grad + error is quantized; the residual
    is carried forward so the long-run update is exact."""
    corrected = grad.astype(jnp.float32) + error
    q, scale = _q.quantize(corrected)
    new_error = corrected - _q.dequantize(q, scale)
    return q, scale, new_error


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grad_tree, error_tree, axis_name: str):
    """Inside shard_map: EF-int8 all-reduce over `axis_name`.

    All shards quantize against a SHARED scale (pmax of local maxima — one
    scalar all-reduce) so the int8 payloads are summable: Σ(q_i)·s is exact
    int32 arithmetic, error bounded by s/2 per shard and carried in the
    error-feedback buffer.  (Per-shard scales cannot be averaged after the
    fact — that was a measured 20 % error; see tests/test_distributed.)
    """

    def leaf(g, e):
        corrected = g.astype(jnp.float32) + e
        local_max = jnp.max(jnp.abs(corrected))
        scale = jax.lax.pmax(local_max, axis_name) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
        new_e = corrected - q.astype(jnp.float32) * scale
        acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return (acc.astype(jnp.float32) * scale).astype(g.dtype), new_e

    flat_g, tdef = jax.tree.flatten(grad_tree)
    flat_e = tdef.flatten_up_to(error_tree)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))
