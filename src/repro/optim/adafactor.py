"""Adafactor with factored second moments (Shazeer & Stern, 2018).

Used for the trillion-parameter config (kimi-k2): AdamW fp32 state is
8 TB for 1T params and cannot fit 512 x 16 GB; factored second moments are
O(rows+cols) and momentum is optional/bf16.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-3
    decay: float = 0.8  # beta2 hat via step^-decay schedule
    eps1: float = 1e-30
    eps2: float = 1e-3
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    momentum: float = 0.0  # 0 disables the first-moment buffer entirely
    momentum_dtype: Any = jnp.bfloat16


def _factored(shape) -> bool:
    return len(shape) >= 2


def init(cfg: AdafactorConfig, params):
    def leaf(p):
        st = {}
        if _factored(p.shape):
            st["vr"] = jnp.zeros(p.shape[:-1], jnp.float32)  # row stats
            st["vc"] = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        else:
            st["v"] = jnp.zeros(p.shape, jnp.float32)
        if cfg.momentum > 0:
            st["m"] = jnp.zeros(p.shape, cfg.momentum_dtype)
        return st

    return {
        "slots": jax.tree.map(leaf, params),
        "count": jnp.zeros((), jnp.int32),
    }


def _rms(x):
    return jnp.sqrt(jnp.mean(jnp.square(x)) + 1e-30)


def update(cfg: AdafactorConfig, grads, state, params, lr_scale=1.0):
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    beta2 = 1.0 - c ** (-cfg.decay)
    lr = cfg.lr * lr_scale

    def upd(g, st, p):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + cfg.eps1
        new_st = dict(st)
        if _factored(p.shape):
            vr = beta2 * st["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * st["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            new_st["vr"], new_st["vc"] = vr, vc
            r = vr / jnp.mean(vr, axis=-1, keepdims=True)
            u = g32 / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :])
        else:
            v = beta2 * st["v"] + (1 - beta2) * g2
            new_st["v"] = v
            u = g32 / jnp.sqrt(v)
        u = u / jnp.maximum(1.0, _rms(u) / cfg.clip_threshold)
        if cfg.momentum > 0:
            m = cfg.momentum * st["m"].astype(jnp.float32) + (1 - cfg.momentum) * u
            new_st["m"] = m.astype(cfg.momentum_dtype)
            u = m
        step_size = lr * jnp.maximum(cfg.eps2, _rms(p.astype(jnp.float32)))
        new_p = p.astype(jnp.float32) - step_size * u
        if cfg.weight_decay > 0 and p.ndim >= 2:
            new_p = new_p - lr * cfg.weight_decay * p.astype(jnp.float32)
        return new_p.astype(p.dtype), new_st

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state["slots"])
    out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_slots = jax.tree.unflatten(treedef, [o[1] for o in out])
    return new_params, {"slots": new_slots, "count": count}
