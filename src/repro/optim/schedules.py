"""LR schedules as pure fns of the step counter."""
from __future__ import annotations

import jax.numpy as jnp


def constant(step, **_):
    return jnp.ones_like(step, jnp.float32)


def warmup_cosine(step, *, warmup_steps: int, total_steps: int,
                  min_ratio: float = 0.1):
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(1.0, warmup_steps)
    prog = (s - warmup_steps) / jnp.maximum(1.0, total_steps - warmup_steps)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < warmup_steps, warm, cos)


def warmup_linear(step, *, warmup_steps: int, total_steps: int,
                  min_ratio: float = 0.0):
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(1.0, warmup_steps)
    prog = (s - warmup_steps) / jnp.maximum(1.0, total_steps - warmup_steps)
    lin = 1.0 - (1.0 - min_ratio) * jnp.clip(prog, 0.0, 1.0)
    return jnp.where(s < warmup_steps, warm, lin)


SCHEDULES = {
    "constant": constant,
    "warmup_cosine": warmup_cosine,
    "warmup_linear": warmup_linear,
}
