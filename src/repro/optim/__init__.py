"""Optimizers, schedules, clipping, gradient compression."""
from repro.optim import adamw, adafactor  # noqa: F401
from repro.optim.api import OptimizerConfig, make_optimizer  # noqa: F401
