"""Optimizer-state sharding: slots mirror their parameter's sharding (ZeRO),
scalars replicated, with divisibility-safe fallbacks.  Shared by the trainer
and the dry-run step builder."""
from __future__ import annotations

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.module import schema_shapes
from repro.parallel.sharding import schema_pspecs


def _fit_spec(pspec: P, shape, mesh: Mesh) -> P:
    """Truncate/repair a param PartitionSpec for a slot of `shape`."""
    spec = list(pspec)
    nd = len(shape)
    spec = spec[:nd] + [None] * max(0, nd - len(spec))
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    fixed = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            fixed.append(None)
            continue
        axs = (ax,) if isinstance(ax, str) else tuple(ax)
        prod = 1
        for a in axs:
            prod *= sizes[a]
        fixed.append(ax if dim % prod == 0 else None)
    return P(*fixed)


def opt_pspecs(schema, optimizer, mesh: Mesh):
    """PartitionSpec pytree for optimizer.init(params)'s state."""
    param_ps = schema_pspecs(schema, mesh)
    opt_shape = jax.eval_shape(optimizer.init, schema_shapes(schema))

    def mirror(ps: P, sub):
        if isinstance(sub, dict):  # adafactor slots {vr, vc, [m]}
            return {k: _fit_spec(ps, v.shape, mesh) for k, v in sub.items()}
        return _fit_spec(ps, sub.shape, mesh)

    out = {}
    for key, sub in opt_shape.items():
        if key in ("m", "v", "slots"):
            out[key] = jax.tree.map(
                mirror, param_ps, sub,
                is_leaf=lambda x: isinstance(x, P))
        else:
            out[key] = jax.tree.map(lambda _: P(), sub)
    return out
