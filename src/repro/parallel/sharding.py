"""Logical-axis sharding rules (MaxText-style) with graceful fallback.

A model annotates each tensor dim with a *logical* axis name ("batch",
"heads", "mlp", ...).  Rules map logical names to mesh axes.  The resolver
handles the awkward realities of the assigned architectures (36 heads on a
16-way model axis, 8 KV heads, prime-ish GNN dims): a logical axis is sharded
over the longest *prefix* of its mesh axes whose product divides the dim, and
never re-uses a mesh axis already consumed by another dim of the same tensor.
This keeps every (arch x shape x mesh) cell lowerable without per-arch
special-casing, while still taking the maximal legal sharding.
"""
from __future__ import annotations

from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.module import ParamSpec, is_param_spec

# Default logical -> mesh-axis rules.  Tuples are tried as a prefix.
# "pod" appears first so multi-pod meshes extend data-parallel axes naturally.
DEFAULT_RULES: dict = {
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),          # ZeRO-3 param sharding axis
    "seq": None,
    "kv_seq": ("pod", "data"),        # sequence parallelism for long KV caches
    "embed": None,
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "expert_mlp": None,
    "table": ("model",),              # recsys embedding-table vocab shard
    "nodes": ("pod", "data"),         # GNN node shard
    # edges take the model axis too: GNNs have no TP, so the (huge) per-edge
    # tensors spread over every chip; the edge->node scatter then all-reduces
    # over `model` (§Perf mace iteration 4)
    "edges": ("pod", "data", "model"),
    "db": ("pod", "data"),            # ANN database shard (the paper's index)
    "queries": ("model",),            # ANN query parallelism within a pod
    None: None,
}


def mesh_axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def resolve_dim(dim: int, logical: str | None, rules: Mapping, mesh: Mesh,
                used: set, allow_uneven: bool = False) -> tuple:
    """Return the tuple of mesh axes to shard `dim` over (possibly empty).

    `allow_uneven` — activation *constraints* tolerate non-divisible dims
    (GSPMD pads); explicit shardings (params, shard_map) stay exact.  This
    matters: a 61.8M-edge GNN tensor must not fall back to replication just
    because 61.8M % 16 != 0 (§Perf iteration 2).
    """
    spec = rules.get(logical, None)
    if spec is None:
        return ()
    if isinstance(spec, str):
        spec = (spec,)
    sizes = mesh_axis_sizes(mesh)
    # keep only axes present in this mesh and not already used by this tensor
    axes = [a for a in spec if a in sizes and a not in used]
    # longest prefix that divides dim (or merely fits, when uneven allowed)
    best: tuple = ()
    prod = 1
    for a in axes:
        prod *= sizes[a]
        ok = (dim >= prod) if allow_uneven else (dim % prod == 0)
        if ok:
            best = tuple(axes[: axes.index(a) + 1])
        else:
            break
    return best


def logical_to_pspec(shape: Sequence[int], logical_axes: Sequence[str | None],
                     mesh: Mesh, rules: Mapping | None = None,
                     allow_uneven: bool = False) -> P:
    rules = rules or DEFAULT_RULES
    used: set = set()
    parts = []
    for dim, name in zip(shape, logical_axes):
        axes = resolve_dim(dim, name, rules, mesh, used, allow_uneven)
        used.update(axes)
        if len(axes) == 0:
            parts.append(None)
        elif len(axes) == 1:
            parts.append(axes[0])
        else:
            parts.append(tuple(axes))
    return P(*parts)


def schema_pspecs(schema, mesh: Mesh, rules: Mapping | None = None):
    """PartitionSpec pytree matching a ParamSpec schema."""
    return jax.tree.map(
        lambda s: logical_to_pspec(s.shape, s.logical_axes, mesh, rules),
        schema,
        is_leaf=is_param_spec,
    )


def schema_shardings(schema, mesh: Mesh, rules: Mapping | None = None):
    return jax.tree.map(
        lambda p: NamedSharding(mesh, p),
        schema_pspecs(schema, mesh, rules),
        is_leaf=lambda x: isinstance(x, P),
    )


def with_logical(x: jax.Array, logical_axes: Sequence[str | None],
                 mesh: Mesh | None = None, rules: Mapping | None = None):
    """Apply a sharding constraint expressed in logical axes to an activation.

    Inside jit we use ``lax.with_sharding_constraint`` against the ambient
    mesh; outside (or with no mesh) this is the identity, so model code stays
    mesh-agnostic.
    """
    mesh = mesh or _current_mesh()
    if mesh is None or mesh.empty:
        return x
    pspec = logical_to_pspec(x.shape, logical_axes, mesh, rules,
                             allow_uneven=True)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, pspec))


def _current_mesh() -> Mesh | None:
    try:
        env = jax.sharding.get_abstract_mesh()
    except Exception:
        env = None
    phys = getattr(jax.interpreters.pxla, "thread_resources", None)
    if phys is not None and not phys.env.physical_mesh.empty:
        return phys.env.physical_mesh
    return None
