"""Wide & Deep [arXiv:1606.07792] with a real EmbeddingBag substrate.

JAX has no nn.EmbeddingBag: bags are `jnp.take` + mean-reduce (the fused
Pallas variant lives in repro.kernels.embedding_bag).  Tables are sharded
over the `model` axis (vocab dim) — the standard table-sharding layout for
10^6–10^9-row embeddings; the lookup becomes the hot collective.

The wide branch hashes raw ids and id-pair crosses into one bucketed table
(the paper's cross-product transformation, hash-trick form).  The retrieval
head (`retrieval_cand` shape) scores one user against 10^6 candidates with a
single GEMM — and is exactly the workload the TSDG index accelerates
(examples/recsys_retrieval.py wires them together).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RecsysConfig
from repro.models.module import ParamSpec
from repro.parallel.sharding import with_logical

RETRIEVAL_DIM = 64


def schema(cfg: RecsysConfig) -> dict:
    E = cfg.embed_dim
    tables = {
        f"field_{i}": ParamSpec((v, E), ("table", None), init="embed",
                                scale=0.05)
        for i, v in enumerate(cfg.vocab_sizes)
    }
    deep_in = cfg.n_sparse * E + cfg.n_dense
    dims = (deep_in,) + tuple(cfg.mlp)
    mlp = {}
    for i in range(len(cfg.mlp)):
        mlp[f"w{i}"] = ParamSpec((dims[i], dims[i + 1]), ("fsdp", "mlp"))
        mlp[f"b{i}"] = ParamSpec((dims[i + 1],), (None,), init="zeros")
    return {
        "tables": tables,
        "wide": ParamSpec((cfg.wide_hash_buckets, 1), ("table", None),
                          init="zeros"),
        "mlp": mlp,
        "head": ParamSpec((cfg.mlp[-1], 1), (None, None)),
        "retrieval_proj": ParamSpec((cfg.mlp[-1], RETRIEVAL_DIM),
                                    (None, None)),
    }


# --------------------------------------------------------------------------
# embedding bag (gather + segment mean) — the JAX-native EmbeddingBag
# --------------------------------------------------------------------------

def embedding_bag(table, ids, *, combine: str = "mean"):
    """table [V, E]; ids [..., bag] -> [..., E]."""
    emb = jnp.take(table, ids, axis=0)                        # [..., bag, E]
    if combine == "sum":
        return jnp.sum(emb, axis=-2)
    if combine == "mean":
        return jnp.mean(emb, axis=-2)
    raise ValueError(combine)


def _hash(x, a, buckets):
    return ((x.astype(jnp.uint32) * np.uint32(2654435761) + np.uint32(a))
            % np.uint32(buckets)).astype(jnp.int32)


# --------------------------------------------------------------------------
# towers
# --------------------------------------------------------------------------

def user_tower(params, cfg: RecsysConfig, batch):
    """-> deep activations [B, mlp[-1]] plus the wide logit [B]."""
    embs = []
    sparse = batch["sparse_ids"]                              # [B, n_sparse]
    for i in range(cfg.n_sparse):
        t = params["tables"][f"field_{i}"]
        if i in cfg.multi_hot_fields:
            bag = batch["bags"][:, list(cfg.multi_hot_fields).index(i)]
            embs.append(embedding_bag(t, bag))                # [B, E]
        else:
            embs.append(jnp.take(t, sparse[:, i], axis=0))
    x = jnp.concatenate(embs + [batch["dense"]], axis=-1)
    x = with_logical(x, ("batch", None))
    mp = params["mlp"]
    for i in range(len(cfg.mlp)):
        x = jax.nn.relu(x @ mp[f"w{i}"] + mp[f"b{i}"])
        x = with_logical(x, ("batch", "mlp"))
    # wide branch: unary hashes + pairwise crosses of the first 8 fields
    B = sparse.shape[0]
    wide_idx = [_hash(sparse[:, i] + np.int32(7919 * i), 13 * i + 1,
                      cfg.wide_hash_buckets) for i in range(cfg.n_sparse)]
    nc = min(8, cfg.n_sparse)
    for i in range(nc):
        for j in range(i + 1, nc):
            cross = sparse[:, i] * np.int32(31) + sparse[:, j]
            wide_idx.append(_hash(cross, 97 * (i * nc + j) + 3,
                                  cfg.wide_hash_buckets))
    widx = jnp.stack(wide_idx, axis=1)                        # [B, n_wide]
    wide_logit = jnp.sum(jnp.take(params["wide"], widx, axis=0)[..., 0],
                         axis=1)
    return x, wide_logit


def forward(params, cfg: RecsysConfig, batch):
    """CTR logit [B]."""
    deep, wide_logit = user_tower(params, cfg, batch)
    logit = (deep @ params["head"])[:, 0] + wide_logit
    return logit


def loss_fn(params, cfg: RecsysConfig, batch):
    logit = forward(params, cfg, batch).astype(jnp.float32)
    y = batch["labels"].astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(logit, 0) - logit * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logit))))
    auc_proxy = jnp.mean((logit > 0) == (y > 0.5))
    return loss, {"loss": loss, "acc": auc_proxy}


def serve_step(params, cfg: RecsysConfig, batch):
    """Online/bulk inference: calibrated CTR."""
    return jax.nn.sigmoid(forward(params, cfg, batch))


def retrieval_step(params, cfg: RecsysConfig, batch):
    """Score 1 user against `n_candidates` item vectors in one GEMM; top-100.

    batch: user features (batch=1) + item_vectors [n_cand, RETRIEVAL_DIM].
    """
    deep, _ = user_tower(params, cfg, batch)
    u = deep @ params["retrieval_proj"]                       # [1, Dv]
    items = batch["item_vectors"]
    items = with_logical(items, ("db", None))
    scores = (u @ items.T)[0]                                 # [n_cand]
    top, idx = jax.lax.top_k(scores, 100)
    return idx.astype(jnp.int32), top
