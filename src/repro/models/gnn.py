"""GNN family: GIN, GatedGCN, GraphSAGE over a shared packed-graph batch.

Message passing is implemented as gather -> (edge compute) -> segment-scatter
(`jax.ops.segment_sum` / `segment_max`) over an edge-index, per the
assignment note: JAX has no CSR SpMM, so the scatter substrate *is* part of
the system.  The same packed representation (edge_src/edge_dst + masks) is
shared with the ANN core's adjacency and the GraphSAGE sampler.

Batch format (all fixed-shape, padded, maskable):
  node_feat [N, F] · edge_src/edge_dst [E] · node_mask [N] · edge_mask [E]
  labels [N] (node tasks) or [G] + graph_ids [N] (graph tasks)
  seed_mask [N] (minibatch: loss restricted to seed nodes)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from repro.models.module import ParamSpec
from repro.parallel.sharding import with_logical


def _mlp_schema(name_dims, logical=("fsdp", "mlp")):
    din, dh, dout = name_dims
    return {
        "w1": ParamSpec((din, dh), logical),
        "b1": ParamSpec((dh,), (None,), init="zeros"),
        "w2": ParamSpec((dh, dout), (logical[1], logical[0])),
        "b2": ParamSpec((dout,), (None,), init="zeros"),
    }


def _mlp(p, x):
    h = jax.nn.relu(x @ p["w1"] + p["b1"])
    return h @ p["w2"] + p["b2"]


def schema(cfg: GNNConfig, d_feat: int, n_classes: int) -> dict:
    d, Ln = cfg.d_hidden, cfg.n_layers
    sch: dict = {
        "encoder": {
            "w": ParamSpec((d_feat, d), ("fsdp", None)),
            "b": ParamSpec((d,), (None,), init="zeros"),
        },
        "decoder": {
            "w": ParamSpec((d, n_classes), (None, None)),
            "b": ParamSpec((n_classes,), (None,), init="zeros"),
        },
    }
    if cfg.kind == "gin":
        sch["layers"] = {
            "mlp": {k: ParamSpec((Ln,) + s.shape, ("layers",) + s.logical_axes,
                                 init=s.init, scale=s.scale)
                    for k, s in _mlp_schema((d, 2 * d, d)).items()},
            # the GIN paper uses BatchNorm between layers; we use LN (the
            # jax-native batch-independent equivalent) to bound sum-agg growth
            "ln": ParamSpec((Ln, d), ("layers", None), init="zeros"),
        }
        if cfg.learnable_eps:
            sch["layers"]["eps"] = ParamSpec((Ln,), ("layers",), init="zeros")
    elif cfg.kind == "gatedgcn":
        def lin(shape, axes):
            return ParamSpec((Ln,) + shape, ("layers",) + axes)

        sch["layers"] = {
            "A": lin((d, d), (None, None)), "B": lin((d, d), (None, None)),
            "C": lin((d, d), (None, None)), "U": lin((d, d), (None, None)),
            "V": lin((d, d), (None, None)),
            "ln_h": ParamSpec((Ln, d), ("layers", None), init="zeros"),
            "ln_e": ParamSpec((Ln, d), ("layers", None), init="zeros"),
        }
        sch["edge_init"] = ParamSpec((d,), (None,), init="normal", scale=0.1)
    elif cfg.kind == "graphsage":
        sch["layers"] = {
            "w_self": ParamSpec((Ln, d, d), ("layers", None, None)),
            "w_nbr": ParamSpec((Ln, d, d), ("layers", None, None)),
            "b": ParamSpec((Ln, d), ("layers", None), init="zeros"),
        }
    else:
        raise ValueError(cfg.kind)
    return sch


# --------------------------------------------------------------------------
# message-passing primitives
# --------------------------------------------------------------------------

def aggregate(messages, dst, n_nodes: int, *, kind: str, edge_mask=None):
    """segment-reduce messages [E, d] by dst -> [N, d]."""
    if edge_mask is not None:
        messages = jnp.where(edge_mask[:, None], messages, 0.0)
    if kind == "sum":
        return jax.ops.segment_sum(messages, dst, n_nodes)
    if kind == "mean":
        s = jax.ops.segment_sum(messages, dst, n_nodes)
        ones = (edge_mask.astype(messages.dtype) if edge_mask is not None
                else jnp.ones((messages.shape[0],), messages.dtype))
        cnt = jax.ops.segment_sum(ones, dst, n_nodes)
        return s / jnp.maximum(cnt, 1.0)[:, None]
    if kind == "max":
        neg = jnp.finfo(messages.dtype).min
        if edge_mask is not None:
            messages = jnp.where(edge_mask[:, None], messages, neg)
        m = jax.ops.segment_max(messages, dst, n_nodes)
        return jnp.where(jnp.isfinite(m), m, 0.0)
    raise ValueError(kind)


def _ln(x, scale):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, -1, keepdims=True)
    var = jnp.var(x32, -1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + 1e-5)
            * (1 + scale.astype(jnp.float32))).astype(x.dtype)


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def forward(params, cfg: GNNConfig, batch) -> jax.Array:
    """Returns logits: [N, n_classes] (node tasks) or [G, n_classes]."""
    src, dst = batch["edge_src"], batch["edge_dst"]
    emask = batch.get("edge_mask")
    nmask = batch.get("node_mask")
    N = batch["node_feat"].shape[0]
    h = batch["node_feat"] @ params["encoder"]["w"] + params["encoder"]["b"]
    h = with_logical(h, ("nodes", None))
    lp = params["layers"]

    if cfg.kind == "gatedgcn":
        e = jnp.broadcast_to(params["edge_init"], (src.shape[0], cfg.d_hidden))

    for i in range(cfg.n_layers):
        li = jax.tree.map(lambda q: q[i], lp)
        if cfg.kind == "gin":
            agg = aggregate(h[src], dst, N, kind="sum", edge_mask=emask)
            eps = li.get("eps", jnp.zeros(()))
            h_new = _mlp(li["mlp"], (1.0 + eps) * h + agg)
            h = jax.nn.relu(_ln(h_new, li["ln"]))
        elif cfg.kind == "gatedgcn":
            e_new = h[src] @ li["A"] + h[dst] @ li["B"] + e @ li["C"]
            eta = jax.nn.sigmoid(e_new)
            msg = eta * (h[src] @ li["V"])
            num = aggregate(msg, dst, N, kind="sum", edge_mask=emask)
            den = aggregate(eta, dst, N, kind="sum", edge_mask=emask)
            h_new = h @ li["U"] + num / (den + 1e-6)
            h = h + jax.nn.relu(_ln(h_new, li["ln_h"]))     # residual
            e = e + jax.nn.relu(_ln(e_new, li["ln_e"]))
        elif cfg.kind == "graphsage":
            agg = aggregate(h[src], dst, N, kind=cfg.aggregator,
                            edge_mask=emask)
            h = jax.nn.relu(h @ li["w_self"] + agg @ li["w_nbr"] + li["b"])
            h = h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True),
                                1e-6)
        h = with_logical(h, ("nodes", None))

    # parameter-free LN ahead of the decoder bounds logit scale across the
    # heterogeneous layer types (GatedGCN residual streams grow with depth)
    h32 = h.astype(jnp.float32)
    h = (h32 - h32.mean(-1, keepdims=True)) \
        * jax.lax.rsqrt(h32.var(-1, keepdims=True) + 1e-5)

    if "graph_ids" in batch:  # graph-level readout (molecule shape)
        if nmask is not None:
            h = jnp.where(nmask[:, None], h, 0.0)
        n_graphs = batch["labels"].shape[0]  # static
        pooled = jax.ops.segment_sum(h, batch["graph_ids"], n_graphs)
        cnt = jax.ops.segment_sum(
            (nmask if nmask is not None
             else jnp.ones(h.shape[0], bool)).astype(jnp.float32),
            batch["graph_ids"], n_graphs)
        pooled = pooled / jnp.maximum(cnt, 1.0)[:, None]      # mean pool
        return pooled @ params["decoder"]["w"] + params["decoder"]["b"]
    return h @ params["decoder"]["w"] + params["decoder"]["b"]


def loss_fn(params, cfg: GNNConfig, batch):
    logits = forward(params, cfg, batch).astype(jnp.float32)
    labels = batch["labels"]
    if "graph_ids" in batch:
        mask = jnp.ones((logits.shape[0],), jnp.float32)
    else:
        mask = batch.get("seed_mask", batch.get("node_mask"))
        mask = (jnp.ones((logits.shape[0],), jnp.float32) if mask is None
                else mask.astype(jnp.float32))
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    nll = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * mask) \
        / jnp.maximum(jnp.sum(mask), 1.0)
    return nll, {"loss": nll, "acc": acc}
