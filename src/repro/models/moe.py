"""Mixture-of-Experts layer — sort-based capacity dispatch (TPU-native).

Design notes (see DESIGN.md §5):
  * top-k routing -> stable sort of (token, slot) pairs by expert id ->
    rank-in-expert via exclusive-cumsum of per-expert counts -> scatter into
    [E, C, d] buffers -> batched per-expert GEMM -> inverse gather + weighted
    combine.  No [T, E, C] one-hot dispatch tensor is ever materialized, so
    `cost_analysis` FLOPs stay ~active-only (capacity padding aside), keeping
    the §Roofline MODEL_FLOPS ratio honest.
  * experts live on the `model` mesh axis (EP); the scatter/gather becomes an
    all-to-all under pjit when EP is active.
  * aux losses: GShard load-balance + router z-loss, returned for logging.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.parallel.sharding import with_logical


def router_probs(x, w_router):
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        w_router.astype(jnp.float32))
    return logits, jax.nn.softmax(logits, axis=-1)


def capacity(n_tokens: int, cfg: MoEConfig) -> int:
    c = int(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to 8 for lane alignment


def _dispatch_group(x, top_e, top_p, E: int, K: int, C: int):
    """Sort-based dispatch of one token group.

    x [Tg, d]; top_e/top_p [Tg, K].  Returns (buffer [E, C, d],
    combine closure state (sorted_t, slot-in-[E*C), weights), counts [E]).
    """
    Tg, d = x.shape
    flat_e = top_e.reshape(Tg * K)                             # expert of slot
    flat_t = jnp.repeat(jnp.arange(Tg), K)                     # token of slot
    order = jnp.argsort(flat_e, stable=True)                   # [Tg*K]
    sorted_e = flat_e[order]
    sorted_t = flat_t[order]
    counts = jnp.bincount(flat_e, length=E)                    # [E]
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(Tg * K) - starts[sorted_e]               # rank in expert
    keep = rank < C                                            # capacity drop
    # out-of-bounds 2D scatter indices are dropped (no trash row needed)
    se = jnp.where(keep, sorted_e, E)
    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[se, rank].set(x[sorted_t], mode="drop")
    w = (top_p.reshape(Tg * K)[order] * keep)
    return buf, (sorted_t, se, rank, w), counts


def _combine_group(y, state, Tg: int):
    sorted_t, se, rank, w = state
    gathered = y.at[se, rank].get(mode="fill", fill_value=0.0)  # [Tg*K, d]
    contrib = gathered * w[:, None].astype(y.dtype)
    return jnp.zeros((Tg, y.shape[-1]), y.dtype).at[sorted_t].add(contrib)


def moe_ffn(x, params, cfg: MoEConfig):
    """x: [T, d] (tokens already flattened). Returns (y, aux_metrics).

    With cfg.dispatch_groups == G > 1, tokens are split into G contiguous
    groups (aligned with the data-parallel batch shard) and dispatched
    group-locally; the [G, E, Cg, d] buffers are sharded batch x expert, so
    the only cross-shard traffic is the expert all-to-all.
    """
    T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    G = cfg.dispatch_groups if T % cfg.dispatch_groups == 0 else 1
    Tg = T // G
    C = capacity(Tg, cfg)

    logits, probs = router_probs(x, params["router"])          # [T, E]
    top_p, top_e = jax.lax.top_k(probs, K)                     # [T, K]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    xg = x.reshape(G, Tg, d)
    xg = with_logical(xg, ("batch", None, None))
    eg = top_e.reshape(G, Tg, K)
    pg = top_p.reshape(G, Tg, K)
    buf, state, counts_g = jax.vmap(
        lambda a, b, c: _dispatch_group(a, b, c, E, K, C))(xg, eg, pg)
    buf = with_logical(buf, ("batch", "expert", None, None))   # [G, E, C, d]

    # ---- per-expert GEMMs (EP all-to-all happens here: G-shard -> E-shard)
    g = jnp.einsum("gecd,edf->gecf", buf, params["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", buf, params["w_up"])
    y = jnp.einsum("gecf,efd->gecd", jax.nn.silu(g) * u, params["w_down"])
    y = with_logical(y, ("batch", "expert", None, None))

    # ---- combine (inverse gather, group-local) ----------------------------
    out = jax.vmap(lambda a, b: _combine_group(a, b, Tg))(y, state)
    out = with_logical(out, ("batch", None, None)).reshape(T, d)

    # ---- aux losses (GShard) ------------------------------------------------
    counts = counts_g.sum(0)
    keep_frac = jnp.minimum(counts_g, C).sum() / (T * K)
    me = jnp.mean(probs, axis=0)                               # mean prob/expert
    ce = counts.astype(jnp.float32) / (T * K)                  # load fraction
    aux = {
        "load_balance_loss": cfg.aux_loss * E * jnp.sum(me * ce),
        "router_z_loss": cfg.router_z_loss
        * jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))),
        "dropped_fraction": 1.0 - keep_frac,
    }
    return out, aux
