"""Decoder-only transformer covering all five assigned LM architectures.

Features driven entirely by :class:`TransformerConfig`:
  * GQA attention + RoPE, optional QK-norm
  * sliding-window (starcoder2) and 5:1 local:global (gemma3) masking via a
    per-layer window vector scanned alongside the stacked layer params
  * MoE FFN (olmoe / kimi-k2) with sort-based capacity dispatch + shared
    experts, or dense SwiGLU FFN
  * non-parametric LN (olmo) vs RMSNorm
  * train path: lax.scan over stacked layer params + optional remat
  * serve path: unrolled layers with per-layer KV caches (uniform full caches
    by default; ring-buffer local caches are the documented hillclimb)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TransformerConfig
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models.module import ParamSpec
from repro.parallel.sharding import with_logical


def _dt(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


# --------------------------------------------------------------------------
# schema
# --------------------------------------------------------------------------

def schema(cfg: TransformerConfig) -> dict:
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    H, KV, hd, Ln = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, cfg.n_layers
    pdt = _dt(cfg.param_dtype)
    emb_std = 1.0 / np.sqrt(d)

    def P(shape, axes, init="fan_in", scale=1.0):
        return ParamSpec(tuple(shape), tuple(axes), init=init, scale=scale,
                         dtype=pdt)

    block: dict = {
        "wq": P((Ln, d, H, hd), ("layers", "fsdp", "heads", None)),
        "wk": P((Ln, d, KV, hd), ("layers", "fsdp", "kv_heads", None)),
        "wv": P((Ln, d, KV, hd), ("layers", "fsdp", "kv_heads", None)),
        "wo": P((Ln, H, hd, d), ("layers", "heads", None, "fsdp")),
    }
    if not cfg.nonparametric_ln:
        block["ln1"] = P((Ln, d), ("layers", None), init="zeros")
        block["ln2"] = P((Ln, d), ("layers", None), init="zeros")
    if cfg.moe is not None:
        E, fe = cfg.moe.n_experts, cfg.moe.d_expert
        block["moe"] = {
            "router": P((Ln, d, E), ("layers", None, "expert"),
                        init="normal", scale=emb_std),
            "w_gate": P((Ln, E, d, fe), ("layers", "expert", "fsdp", None)),
            "w_up": P((Ln, E, d, fe), ("layers", "expert", "fsdp", None)),
            "w_down": P((Ln, E, fe, d), ("layers", "expert", None, "fsdp")),
        }
        if cfg.moe.n_shared:
            fs = cfg.moe.d_expert * cfg.moe.n_shared
            block["shared"] = {
                "w_gate": P((Ln, d, fs), ("layers", "fsdp", "mlp")),
                "w_up": P((Ln, d, fs), ("layers", "fsdp", "mlp")),
                "w_down": P((Ln, fs, d), ("layers", "mlp", "fsdp")),
            }
    elif cfg.gated_ffn:
        block["mlp"] = {
            "w_gate": P((Ln, d, f), ("layers", "fsdp", "mlp")),
            "w_up": P((Ln, d, f), ("layers", "fsdp", "mlp")),
            "w_down": P((Ln, f, d), ("layers", "mlp", "fsdp")),
        }
    else:  # plain 2-matrix GELU MLP (starcoder2)
        block["mlp"] = {
            "w_up": P((Ln, d, f), ("layers", "fsdp", "mlp")),
            "w_down": P((Ln, f, d), ("layers", "mlp", "fsdp")),
        }

    sch: dict = {
        "embed": ParamSpec((v, d), ("vocab", "fsdp"), init="embed",
                           scale=emb_std, dtype=pdt),
        "blocks": block,
    }
    if not cfg.nonparametric_ln:
        sch["final_ln"] = P((d,), (None,), init="zeros")
    if not cfg.tie_embeddings:
        sch["lm_head"] = P((d, v), ("fsdp", "vocab"))
    return sch


def layer_windows(cfg: TransformerConfig) -> np.ndarray:
    """Per-layer attention window; <=0 = full causal."""
    if cfg.local_global_ratio:
        r = cfg.local_global_ratio
        w = [cfg.local_window if (i + 1) % (r + 1) != 0 else 0
             for i in range(cfg.n_layers)]
    elif cfg.window:
        w = [cfg.window] * cfg.n_layers
    else:
        w = [0] * cfg.n_layers
    return np.asarray(w, np.int32)


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------

def _norm(cfg, x, scale):
    if cfg.nonparametric_ln:
        return L.nonparametric_ln(x)
    return L.rms_norm(x, scale)


def _qk_norm(x):
    x32 = x.astype(jnp.float32)
    return (x32 * jax.lax.rsqrt(
        jnp.mean(jnp.square(x32), -1, keepdims=True) + 1e-6)).astype(x.dtype)


def attention_block(cfg, p, x, *, window, positions, kv_cache=None, pos=None,
                    slot_pos=None):
    """Returns (out, (k, v)) — k/v for cache collection during prefill."""
    cdt = _dt(cfg.compute_dtype)
    h = _norm(cfg, x, p.get("ln1"))
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(cdt))
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"].astype(cdt))
    q = with_logical(q, ("batch", None, "heads", None))
    k = with_logical(k, ("batch", None, "kv_heads", None))
    if getattr(cfg, "qk_norm", False):
        q, k = _qk_norm(q), _qk_norm(k)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)

    if kv_cache is None:  # train / prefill: attend within the sequence
        if isinstance(window, int) and window > 0:
            # static window -> skip out-of-window KV chunks entirely
            out = L.windowed_chunked_attention(q, k, v, window=window)
        else:
            out = L.chunked_attention(q, k, v, window=window,
                                      unroll=cfg.unroll)
    else:  # decode: single token against cache
        kc, vc = kv_cache
        out = L.decode_attention(q, kc, vc, pos=pos, slot_pos=slot_pos,
                                 window=window)
    out = with_logical(out, ("batch", None, "heads", None))
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cdt))
    return out, (k, v)


def ffn_block(cfg, p, x):
    """Returns (out, aux)."""
    cdt = _dt(cfg.compute_dtype)
    h = _norm(cfg, x, p.get("ln2"))
    aux = {}
    if cfg.moe is not None:
        B, S, d = h.shape
        flat = h.reshape(B * S, d)
        mp = {k2: v2.astype(cdt) for k2, v2 in p["moe"].items()}
        y, aux = moe_lib.moe_ffn(flat, mp, cfg.moe)
        y = y.reshape(B, S, d)
        if cfg.moe.n_shared:
            sp = p["shared"]
            y = y + L.swiglu(h, sp["w_gate"].astype(cdt),
                             sp["w_up"].astype(cdt), sp["w_down"].astype(cdt))
    elif cfg.gated_ffn:
        mp = p["mlp"]
        y = L.swiglu(h, mp["w_gate"].astype(cdt), mp["w_up"].astype(cdt),
                     mp["w_down"].astype(cdt))
        y = with_logical(y, ("batch", None, None))
    else:
        mp = p["mlp"]
        u = jnp.einsum("...d,df->...f", h, mp["w_up"].astype(cdt))
        y = jnp.einsum("...f,fd->...d", jax.nn.gelu(u),
                       mp["w_down"].astype(cdt))
        y = with_logical(y, ("batch", None, None))
    return y, aux


def block(cfg, p, x, *, window, positions):
    a, kv = attention_block(cfg, p, x, window=window, positions=positions)
    x = x + a
    f, aux = ffn_block(cfg, p, x)
    x = x + f
    return x, kv, aux


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------

def forward(params, cfg: TransformerConfig, tokens, *, collect_cache=False):
    """tokens [B, S] -> logits [B, S, V] (and stacked KV caches if asked)."""
    cdt = _dt(cfg.compute_dtype)
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cdt)
    x = with_logical(x, ("batch", None, None))
    positions = jnp.arange(S)[None, :]
    windows_np = layer_windows(cfg)
    windows = jnp.asarray(windows_np)
    # uniform window -> pass it statically so out-of-window KV chunks are
    # skipped at compile time (starcoder2's 4k window at 32k prefill: ~8x
    # fewer attention FLOPs; EXPERIMENTS §Perf cell 4)
    uniform_w = int(windows_np[0]) if len(set(windows_np.tolist())) == 1 \
        else None

    def body(x, scanned):
        p_layer, window = scanned
        if uniform_w is not None:
            window = uniform_w
        y, kv, aux = block(cfg, p_layer, x, window=window, positions=positions)
        moe_aux = aux.get("load_balance_loss", jnp.zeros((), jnp.float32)) \
            + aux.get("router_z_loss", jnp.zeros((), jnp.float32))
        out = (kv, moe_aux) if collect_cache else (None, moe_aux)
        return y, out

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)

    if cfg.scan_layers:
        x, (caches, moe_aux) = jax.lax.scan(body, x,
                                            (params["blocks"], windows),
                                            unroll=cfg.unroll)
        moe_loss = jnp.sum(moe_aux)
    else:
        caches_list, moe_loss = [], 0.0
        for i in range(cfg.n_layers):
            p_layer = jax.tree.map(lambda q: q[i], params["blocks"])
            x, (kv, aux) = body(x, (p_layer, windows[i]))
            caches_list.append(kv)
            moe_loss = moe_loss + aux
        caches = caches_list if collect_cache else None

    x = _norm(cfg, x, params.get("final_ln"))
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cdt))
    logits = with_logical(logits, ("batch", None, "vocab"))
    if collect_cache:
        return logits, caches, moe_loss
    return logits, moe_loss


def loss_fn(params, cfg: TransformerConfig, batch):
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    logits, moe_loss = forward(params, cfg, inputs)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    loss = nll + moe_loss
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, {"loss": loss, "nll": nll, "moe_loss": moe_loss, "acc": acc}


# --------------------------------------------------------------------------
# serving: prefill + decode with per-layer caches
# --------------------------------------------------------------------------

def init_cache(cfg: TransformerConfig, batch: int, max_len: int):
    """Uniform full KV caches, sequence-sharded over the data axis (SP)."""
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    cdt = _dt(cfg.compute_dtype)
    cache = {}
    for i in range(cfg.n_layers):
        cache[f"layer_{i}"] = {
            "k": jnp.zeros((batch, max_len, KV, hd), cdt),
            "v": jnp.zeros((batch, max_len, KV, hd), cdt),
        }
    return cache


def cache_logical_axes(cfg: TransformerConfig):
    return ("batch", "kv_seq", "kv_heads", None)


def prefill(params, cfg: TransformerConfig, tokens):
    """Returns (last_logits [B, V], cache dict)."""
    logits, caches, _ = forward(params, cfg, tokens, collect_cache=True)
    cache = {}
    if cfg.scan_layers:
        k_all, v_all = caches  # [L, B, S, KV, hd]
        for i in range(cfg.n_layers):
            cache[f"layer_{i}"] = {"k": k_all[i], "v": v_all[i]}
    else:
        for i, (k, v) in enumerate(caches):
            cache[f"layer_{i}"] = {"k": k, "v": v}
    return logits[:, -1], cache


def decode_step(params, cfg: TransformerConfig, cache, token, pos):
    """token [B] int32, pos scalar int32 (position being generated).

    Writes K/V at `pos`, attends over slots <= pos.  Layers are unrolled so
    per-layer cache shapes may differ (ring-buffer local caches plug in here).
    Returns (logits [B, V], new_cache).
    """
    cdt = _dt(cfg.compute_dtype)
    B = token.shape[0]
    x = params["embed"][token][:, None, :].astype(cdt)  # [B, 1, d]
    positions = jnp.full((B, 1), pos)
    windows = layer_windows(cfg)
    new_cache = {}
    for i in range(cfg.n_layers):
        p_layer = jax.tree.map(lambda q: q[i], params["blocks"])
        lc = cache[f"layer_{i}"]
        h = _norm(cfg, x, p_layer.get("ln1"))
        q = jnp.einsum("bsd,dhk->bshk", h, p_layer["wq"].astype(cdt))
        k = jnp.einsum("bsd,dhk->bshk", h, p_layer["wk"].astype(cdt))
        v = jnp.einsum("bsd,dhk->bshk", h, p_layer["wv"].astype(cdt))
        if getattr(cfg, "qk_norm", False):
            q, k = _qk_norm(q), _qk_norm(k)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        S_max = lc["k"].shape[1]
        slot = pos % S_max  # full cache: slot == pos; ring buffer: wraps
        kc = jax.lax.dynamic_update_slice_in_dim(lc["k"], k, slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(lc["v"], v, slot, axis=1)
        kc = with_logical(kc, cache_logical_axes(cfg))
        vc = with_logical(vc, cache_logical_axes(cfg))
        new_cache[f"layer_{i}"] = {"k": kc, "v": vc}
        out = L.decode_attention(q, kc, vc, pos=pos, window=int(windows[i]))
        out = jnp.einsum("bshk,hkd->bsd", out, p_layer["wo"].astype(cdt))
        x = x + out
        f, _ = ffn_block(cfg, p_layer, x)
        x = x + f
    x = _norm(cfg, x, params.get("final_ln"))
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cdt))[:, 0]
    return with_logical(logits, ("batch", "vocab")), new_cache
