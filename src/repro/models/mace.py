"""MACE — higher-order E(3)-equivariant message passing [arXiv:2206.07697].

Compact-but-real implementation (irreps up to l_max, correlation order ν):

  per layer t:
    A_i^{(l3)}  = Σ_{l1,l2} CG(l1,l2→l3) · Σ_{j∈N(i)} R^t_{l1l2l3}(r_ij)
                  Y^{(l1)}(r̂_ij) ⊗ W h_j^{(l2)}          (density A-basis)
    B_i         = symmetric self-contractions of A up to order ν
                  (A, A⊗A, A⊗A⊗A → channelwise CG products)
    h_i^{t+1}   = W_self h_i^t + W_msg B_i                (update)
  readout: invariant (l=0) channels -> per-site energy -> Σ = total energy.

Radial basis: Bessel(n_rbf) × polynomial cutoff envelope (as in MACE).
CG tensors come from repro.utils.so3 (real basis, verified consistent with
the real spherical harmonics).  Equivariance — energy invariance under
random O(3) rotations — is asserted in tests/test_mace.py.

Kernel regime per the taxonomy: irrep tensor-product + scatter; tensor
contractions are einsums (MXU), neighbor reduction is segment_sum.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from repro.models.module import ParamSpec
from repro.parallel.sharding import with_logical
from repro.utils import so3


def n_irrep_dims(l_max: int) -> int:
    return (l_max + 1) ** 2


def allowed_paths(l_max: int):
    """(l1, l2, l3) with non-vanishing real CG, all <= l_max."""
    paths = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(abs(l1 - l2), min(l_max, l1 + l2) + 1):
                paths.append((l1, l2, l3))
    return paths


def schema(cfg: GNNConfig) -> dict:
    C, Ln = cfg.d_hidden, cfg.n_layers
    n_paths = len(allowed_paths(cfg.l_max))
    sch: dict = {
        "species_embed": ParamSpec((cfg.n_species, C), (None, None),
                                   init="normal", scale=1.0),
        "radial": {  # MLP: n_rbf -> 2C -> n_paths*C (per layer)
            "w1": ParamSpec((Ln, cfg.n_rbf, 2 * C), ("layers", None, None)),
            "b1": ParamSpec((Ln, 2 * C), ("layers", None), init="zeros"),
            "w2": ParamSpec((Ln, 2 * C, n_paths * C),
                            ("layers", None, None)),
        },
        "w_h": ParamSpec((Ln, C, C), ("layers", None, None)),      # h mix
        "w_self": ParamSpec((Ln, C, C), ("layers", None, None)),
        "w_msg": ParamSpec((Ln, C, C), ("layers", None, None)),
        # per-order contraction weights (correlation 2..nu)
        "w_corr": ParamSpec((Ln, cfg.correlation_order - 1, C),
                            ("layers", None, None), init="normal", scale=0.3),
        "readout": {
            "w1": ParamSpec((C, C), (None, None)),
            # zero-init head: predictions start at 0 (targets standardized)
            "w2": ParamSpec((C, 1), (None, None), init="zeros"),
        },
    }
    return sch


# --------------------------------------------------------------------------
# radial basis
# --------------------------------------------------------------------------

def bessel_basis(r, n: int, r_cut: float):
    """[E] -> [E, n]; sin(n π r / rc) / r with smooth polynomial cutoff."""
    r = jnp.maximum(r, 1e-9)
    ns = jnp.arange(1, n + 1, dtype=jnp.float32)
    rb = jnp.sqrt(2.0 / r_cut) * jnp.sin(
        ns[None, :] * math.pi * r[:, None] / r_cut) / r[:, None]
    # polynomial cutoff (p=6)
    x = jnp.clip(r / r_cut, 0.0, 1.0)
    env = 1 - 28 * x ** 6 + 48 * x ** 7 - 21 * x ** 8
    return rb * env[:, None]


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def forward(params, cfg: GNNConfig, batch):
    """batch: positions [N,3], species [N], edge_src/dst [E], edge_mask [E],
    graph_ids [N], n_graphs, node_mask [N].  Returns energies [G]."""
    pos = batch["positions"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    emask = batch["edge_mask"].astype(pos.dtype)
    nmask = batch["node_mask"]
    N = pos.shape[0]
    C = cfg.d_hidden
    lmax = cfg.l_max
    dims = n_irrep_dims(lmax)
    paths = allowed_paths(lmax)
    slices = so3.irrep_slices(lmax)

    # edge geometry — keep every per-edge intermediate sharded over `edges`
    # (GSPMD otherwise replicates them; §Perf iteration 2: 61.8M-edge
    # tensors appeared unsharded in the per-device HLO)
    disp = pos[dst] - pos[src]                                 # [E, 3]
    disp = with_logical(disp, ("edges", None))
    r = jnp.linalg.norm(disp + 1e-12, axis=-1)
    unit = disp / jnp.maximum(r[:, None], 1e-9)
    Y = so3.spherical_harmonics(unit, lmax)                    # [E, dims]
    Y = with_logical(Y, ("edges", None))
    rbf = bessel_basis(r, cfg.n_rbf, cfg.r_cut) * emask[:, None]
    rbf = with_logical(rbf, ("edges", None))

    # node features: [N, C, dims]; init = species embed in l=0
    h = jnp.zeros((N, C, dims), pos.dtype)
    h = h.at[:, :, 0].set(params["species_embed"][batch["species"]])

    # MACE normalizes the density by the average neighbor count
    avg_deg = jnp.sum(emask) / jnp.maximum(jnp.sum(nmask.astype(pos.dtype)),
                                           1.0)
    inv_sqrt_deg = jax.lax.rsqrt(jnp.maximum(avg_deg, 1.0))

    site_energy = jnp.zeros((N,), pos.dtype)

    def one_layer(h, layer_params):
        """Checkpointed (remat) MACE layer: per-edge tensors are rebuilt in
        the backward pass instead of living across the whole graph."""
        rp, w_h, w_self, w_msg, w_corr_t, readout = layer_params
        R = jax.nn.silu(rbf @ rp["w1"] + rp["b1"]) @ rp["w2"]  # [E, P*C]
        R = with_logical(R.reshape(-1, len(paths), C),
                         ("edges", None, None))
        hj = jnp.einsum("ncd,cx->nxd", h, w_h)                 # premix
        hj = with_logical(hj, ("nodes", None, None))

        # ---- A-basis: density expansion with CG coupling -----------------
        # §Perf (EXPERIMENTS.md): gather the source features ONCE and
        # accumulate every path into a single per-edge message buffer so the
        # layer does 1 gather + 1 segment scatter instead of |paths| of each
        # (sum of scatters == scatter of sums).
        hsrc = with_logical(hj[src], ("edges", None, None))    # [E, C, dims]
        msg_full = jnp.zeros((hsrc.shape[0], C, dims), pos.dtype)
        for p_idx, (l1, l2, l3) in enumerate(paths):
            _, a1, b1 = slices[l1]
            _, a2, b2 = slices[l2]
            _, a3, b3 = slices[l3]
            cg = jnp.asarray(so3.real_cg(l1, l2, l3), pos.dtype)
            # message per edge: R(r) * CG(Y_l1, h_j^{l2})
            msg = jnp.einsum("ei,ecj,ijk,ec->eck",
                             Y[:, a1:b1], hsrc[:, :, a2:b2], cg,
                             R[:, p_idx])
            msg_full = msg_full.at[:, :, a3:b3].add(msg)
        msg_full = with_logical(msg_full, ("edges", None, None))
        A = jax.ops.segment_sum(msg_full * emask[:, None, None], dst, N) \
            * inv_sqrt_deg
        A = with_logical(A, ("nodes", None, None))

        # equivariant RMS normalization: a per-node *invariant* scalar
        # (rotation-safe) bounds the magnitude feeding the ν-order products
        # — stands in for MACE's hand-derived normalization constants
        def _eq_norm(z):
            s = jax.lax.rsqrt(jnp.mean(jnp.square(z), axis=(1, 2),
                                       keepdims=True) + 1e-6)
            return z * s

        A = _eq_norm(A)

        # ---- B-basis: symmetric self-contractions up to order ν ----------
        B = A
        prod = A
        for order in range(2, cfg.correlation_order + 1):
            nxt = jnp.zeros_like(A)
            for (l1, l2, l3) in paths:
                _, a1, b1 = slices[l1]
                _, a2, b2 = slices[l2]
                _, a3, b3 = slices[l3]
                cg = jnp.asarray(so3.real_cg(l1, l2, l3), pos.dtype)
                nxt = nxt.at[:, :, a3:b3].add(
                    jnp.einsum("nci,ncj,ijk->nck",
                               prod[:, :, a1:b1], A[:, :, a2:b2], cg))
            prod = _eq_norm(nxt)
            B = B + w_corr_t[order - 2][None, :, None] * prod

        # ---- update -------------------------------------------------------
        h = jnp.einsum("ncd,cx->nxd", h, w_self) \
            + jnp.einsum("ncd,cx->nxd", B, w_msg)
        h = with_logical(h, ("nodes", None, None))

        # per-layer invariant readout (MACE reads out every layer)
        inv = h[:, :, 0]                                       # [N, C]
        e_t = jax.nn.silu(inv @ readout["w1"]) @ readout["w2"]
        return h, e_t[:, 0]

    one_layer = jax.checkpoint(one_layer)
    for t in range(cfg.n_layers):
        lp = (jax.tree.map(lambda q: q[t], params["radial"]),
              params["w_h"][t], params["w_self"][t], params["w_msg"][t],
              params["w_corr"][t], params["readout"])
        h, e_t = one_layer(h, lp)
        site_energy = site_energy + e_t

    site_energy = jnp.where(nmask, site_energy, 0.0)
    n_graphs = batch["energies"].shape[0]  # static
    return jax.ops.segment_sum(site_energy, batch["graph_ids"], n_graphs)


def loss_fn(params, cfg: GNNConfig, batch):
    pred = forward(params, cfg, batch)
    err = pred - batch["energies"]
    loss = jnp.mean(jnp.square(err))
    mae = jnp.mean(jnp.abs(err))
    metrics = {"loss": loss, "energy_mae": mae}
    if "forces" in batch:  # force matching via autodiff (optional)
        def energy_of(pos):
            b = dict(batch)
            b["positions"] = pos
            return jnp.sum(forward(params, cfg, b))

        forces = -jax.grad(energy_of)(batch["positions"])
        f_loss = jnp.mean(jnp.square(forces - batch["forces"]))
        loss = loss + 10.0 * f_loss
        metrics["force_mse"] = f_loss
        metrics["loss"] = loss
    return loss, metrics
