"""Minimal functional param/module system.

No flax in this environment; models are pure functions over pytree param
dicts.  Each model declares a *schema*: a nested dict whose leaves are
:class:`ParamSpec` (shape + logical axis names + initializer).  From one
schema we derive
  - ``init_params``    : materialized param pytree (jit-able, shard-aware),
  - ``schema_pspecs``  : a matching pytree of ``PartitionSpec`` resolved
                         against the active mesh via the logical-axis rules in
                         ``repro.parallel.sharding``.
Keeping shapes and shardings in one declaration is what keeps the 40-cell
dry-run coherent.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Initializer = Callable[[jax.Array, tuple, Any], jax.Array]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declarative description of one parameter tensor."""

    shape: tuple
    logical_axes: tuple  # one logical axis name (or None) per dim
    init: str = "normal"  # normal | fan_in | zeros | ones | embed
    scale: float = 1.0
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), (
            f"shape {self.shape} vs logical axes {self.logical_axes}"
        )


def _init_leaf(key: jax.Array, spec: ParamSpec, dtype=None) -> jax.Array:
    dtype = dtype or spec.dtype
    shape = spec.shape
    if spec.init == "zeros":
        return jnp.zeros(shape, dtype)
    if spec.init == "ones":
        return jnp.ones(shape, dtype)
    if spec.init == "normal":
        return (spec.scale * jax.random.normal(key, shape)).astype(dtype)
    if spec.init == "embed":
        return (spec.scale * jax.random.normal(key, shape)).astype(dtype)
    if spec.init == "fan_in":
        fan_in = shape[0] if len(shape) <= 2 else int(np.prod(shape[:-1]))
        std = spec.scale / math.sqrt(max(1, fan_in))
        return (std * jax.random.normal(key, shape)).astype(dtype)
    raise ValueError(f"unknown init {spec.init}")


def is_param_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(schema, key: jax.Array, dtype=None):
    """Materialize a schema into a param pytree with per-leaf fold_in keys."""
    leaves, treedef = jax.tree.flatten(schema, is_leaf=is_param_spec)
    out = []
    for i, spec in enumerate(leaves):
        out.append(_init_leaf(jax.random.fold_in(key, i), spec, dtype))
    return jax.tree.unflatten(treedef, out)


def schema_shapes(schema, dtype=None):
    """ShapeDtypeStruct pytree for AOT lowering (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype or s.dtype),
        schema,
        is_leaf=is_param_spec,
    )


def param_count(schema) -> int:
    leaves = jax.tree.leaves(schema, is_leaf=is_param_spec)
    return sum(int(np.prod(s.shape)) for s in leaves)


def param_bytes(schema, bytes_per_param=None) -> int:
    leaves = jax.tree.leaves(schema, is_leaf=is_param_spec)
    total = 0
    for s in leaves:
        bp = bytes_per_param or jnp.dtype(s.dtype).itemsize
        total += int(np.prod(s.shape)) * bp
    return total
