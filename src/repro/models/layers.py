"""Transformer building blocks: norms, RoPE, chunked (flash-style) attention.

Attention never materializes the full [S, S] score matrix: the XLA path is a
running-softmax over KV chunks (the jnp formulation of FlashAttention), which
is also the oracle the Pallas kernel (`repro.kernels.flash_attention`) is
checked against.  Window masking covers starcoder2's sliding window and
gemma3's 5:1 local:global pattern.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rms_norm(x, scale=None, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * (1.0 + scale.astype(jnp.float32))
    return y.astype(x.dtype)


def nonparametric_ln(x, eps: float = 1e-5):
    """OLMo-style LayerNorm without learnable scale/bias."""
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def make_norm(cfg):
    if cfg.nonparametric_ln:
        return lambda x, scale=None: nonparametric_ln(x)
    return rms_norm


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., None, :]  # broadcast over heads
    cos = cos[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# chunked (memory-bounded) attention
# --------------------------------------------------------------------------

def _chunk_mask(q_pos, k_pos, window):
    """causal + optional sliding window; q_pos [Cq], k_pos [Ck] -> [Cq, Ck].

    `window` may be a traced scalar (per-layer window under scan): <=0 means
    full causal attention, >0 is a sliding window — expressed arithmetically
    so it stays jit/scan-friendly.
    """
    w = jnp.asarray(window)
    m = k_pos[None, :] <= q_pos[:, None]
    in_window = (w <= 0) | (k_pos[None, :] > (q_pos[:, None] - w))
    return m & in_window


def chunked_attention(q, k, v, *, window: int = 0, q_offset: int = 0,
                      chunk_q: int = 512, chunk_kv: int = 1024,
                      kv_valid: int | jax.Array | None = None,
                      unroll: bool = False):
    """FlashAttention-style running softmax over KV chunks (pure jnp).

    q: [B, Sq, H, hd]; k/v: [B, Skv, KV, hd] (GQA: H = KV * G).
    window: 0/negative = full causal; >0 = sliding window.
    q_offset: absolute position of q[0] (decode / chunked prefill).
    kv_valid: number of valid KV slots (decode with padded cache).
    """
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = hd ** -0.5

    nq = -(-Sq // chunk_q)
    nkv = -(-Skv // chunk_kv)
    pad_q = nq * chunk_q - Sq
    pad_kv = nkv * chunk_kv - Skv
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))

    # [B, nq, Cq, KV, G, hd] view of q
    qp = qp.reshape(B, nq, chunk_q, KV, G, hd) * scale
    kp = kp.reshape(B, nkv, chunk_kv, KV, hd)
    vp = vp.reshape(B, nkv, chunk_kv, KV, hd)

    q_pos = q_offset + jnp.arange(nq * chunk_q).reshape(nq, chunk_q)
    k_pos = jnp.arange(nkv * chunk_kv).reshape(nkv, chunk_kv)
    valid = jnp.asarray(Skv if kv_valid is None else kv_valid)

    def kv_step(carry, ikv):
        acc, m_run, l_run = carry
        kc, vc = kp[:, ikv], vp[:, ikv]
        kpos = k_pos[ikv]
        # scores: [B, nq, Cq, KV, G, Ck]
        s = jnp.einsum("bqckgh,bzkh->bqckgz", qp, kc,
                       preferred_element_type=jnp.float32)
        mask = _chunk_mask(q_pos.reshape(-1), kpos, window)
        mask = mask.reshape(nq, chunk_q, chunk_kv)[None, :, :, None, None, :]
        mask = mask & (kpos < valid)[None, None, None, None, None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqckgz,bzkh->bqckgh", p.astype(vc.dtype), vc,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((B, nq, chunk_q, KV, G, hd), jnp.float32)
    m0 = jnp.full((B, nq, chunk_q, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, nq, chunk_q, KV, G), jnp.float32)
    (acc, m_run, l_run), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                          jnp.arange(nkv), unroll=unroll)
    out = acc / jnp.maximum(l_run, 1e-30)[..., None]
    out = out.reshape(B, nq * chunk_q, H, hd)[:, :Sq]
    return out.astype(q.dtype)


def windowed_chunked_attention(q, k, v, *, window: int, q_offset: int = 0,
                               chunk_q: int = 1024, chunk_kv: int = 1024):
    """Sliding-window attention with *static* chunk skipping.

    Requires `window` to be a python int (per-layer-uniform archs like
    starcoder2, or gemma3's local layers on the unrolled path).  Each query
    chunk only touches KV chunks inside [q_lo - window, q_hi]: at 32k prefill
    with a 4k window this is ~8x fewer attention FLOPs than mask-only
    chunking — and the skipping is visible to `cost_analysis` because the
    loop bounds are static (EXPERIMENTS §Perf cell 4).
    """
    assert isinstance(window, int) and window > 0
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = hd ** -0.5
    nq = -(-Sq // chunk_q)
    nkv = -(-Skv // chunk_kv)
    pad_q = nq * chunk_q - Sq
    pad_kv = nkv * chunk_kv - Skv
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    kp = kp.reshape(B, nkv, chunk_kv, KV, hd)
    vp = vp.reshape(B, nkv, chunk_kv, KV, hd)

    outs = []
    for iq in range(nq):  # static python loop: bounds below are compile-time
        q_lo = q_offset + iq * chunk_q
        q_hi = q_offset + (iq + 1) * chunk_q - 1
        c_lo = max(0, (q_lo - window + 1) // chunk_kv)
        c_hi = min(nkv - 1, q_hi // chunk_kv)
        qc = qp[:, iq * chunk_q:(iq + 1) * chunk_q] \
            .reshape(B, chunk_q, KV, G, hd) * scale
        q_pos = q_lo + jnp.arange(chunk_q)
        acc = jnp.zeros((B, chunk_q, KV, G, hd), jnp.float32)
        m_run = jnp.full((B, chunk_q, KV, G), NEG_INF, jnp.float32)
        l_run = jnp.zeros((B, chunk_q, KV, G), jnp.float32)
        for ikv in range(c_lo, c_hi + 1):  # only in-window chunks
            kc, vc = kp[:, ikv], vp[:, ikv]
            k_pos = ikv * chunk_kv + jnp.arange(chunk_kv)
            s = jnp.einsum("bckgh,bzkh->bckgz", qc, kc,
                           preferred_element_type=jnp.float32)
            mask = _chunk_mask(q_pos, k_pos, window) \
                & (k_pos < Skv)[None, :]
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_run = l_run * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bckgz,bzkh->bckgh", p.astype(vc.dtype), vc,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            m_run = m_new
        out = acc / jnp.maximum(l_run, 1e-30)[..., None]
        outs.append(out.reshape(B, chunk_q, H, hd))
    return jnp.concatenate(outs, axis=1)[:, :Sq].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, *, pos, slot_pos=None,
                     window: int = 0):
    """Single-token attention against a (possibly ring-buffer) KV cache.

    q: [B, 1, H, hd]; k_cache/v_cache: [B, S, KV, hd].
    pos: current absolute position, scalar or [B].
    slot_pos: [B, S] absolute position stored in each cache slot (ring
      buffers); None means slot i holds position i.
    """
    B, S, KV, hd = k_cache.shape
    H = q.shape[2]
    G = H // KV
    scale = hd ** -0.5
    qg = q.reshape(B, KV, G, hd) * scale
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32)
    pos = jnp.asarray(pos)
    pos_b = jnp.broadcast_to(pos, (B,))
    if slot_pos is None:
        slot_pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    w = jnp.asarray(window)
    m = slot_pos <= pos_b[:, None]
    m &= slot_pos >= 0
    m &= (w <= 0) | (slot_pos > (pos_b[:, None] - w))
    s = jnp.where(m[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)
