"""GraphSAGE (Reddit) — 2 layers, mean aggregator, 25-10 fanout [arXiv:1706.02216]."""
import dataclasses

from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="graphsage-reddit", kind="graphsage", n_layers=2, d_hidden=128,
    aggregator="mean", sample_sizes=(25, 10),
)


def reduced():
    return dataclasses.replace(CONFIG, name="graphsage-reduced", n_layers=2,
                               d_hidden=16, sample_sizes=(5, 3))
