"""OLMoE-1B-7B — 64-expert top-8 MoE LM [arXiv:2409.02060]."""
import dataclasses

from repro.configs.base import MoEConfig, TransformerConfig

CONFIG = TransformerConfig(
    name="olmoe-1b-7b",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab=50304,
    moe=MoEConfig(n_experts=64, top_k=8, d_expert=1024),
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="olmoe-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=256,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=128))
