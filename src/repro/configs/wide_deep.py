"""Wide & Deep — 40 sparse fields, dim 32, 1024-512-256 MLP [arXiv:1606.07792]."""
import dataclasses

from repro.configs.base import RecsysConfig

CONFIG = RecsysConfig(name="wide-deep")


def reduced():
    return dataclasses.replace(
        CONFIG, name="wide-deep-reduced", n_sparse=6, embed_dim=8,
        mlp=(32, 16), vocab_sizes=tuple([1000] * 2 + [100] * 4),
        multi_hot_fields=(0,), bag_size=3, wide_hash_buckets=1000)
