"""Config dataclasses + shape specs + arch registry.

Every assigned architecture is a module in ``repro.configs`` exporting
``CONFIG``; the registry maps ``--arch <id>`` to it.  Shapes are defined per
family (LM / GNN / recsys / ANN) so every (arch x shape) cell is well-defined.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

# --------------------------------------------------------------------------
# shape specs
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode | serve | retrieval | build | search
    dims: dict


LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train",
                          dict(seq_len=4096, global_batch=256)),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill",
                             dict(seq_len=32768, global_batch=32)),
    "decode_32k": ShapeSpec("decode_32k", "decode",
                            dict(seq_len=32768, global_batch=128)),
    "long_500k": ShapeSpec("long_500k", "decode",
                           dict(seq_len=524288, global_batch=1)),
}

GNN_SHAPES = {
    "full_graph_sm": ShapeSpec("full_graph_sm", "train",
                               dict(n_nodes=2708, n_edges=10556, d_feat=1433)),
    "minibatch_lg": ShapeSpec("minibatch_lg", "train",
                              dict(n_nodes=232965, n_edges=114615892,
                                   batch_nodes=1024, fanout=(15, 10),
                                   d_feat=602)),
    "ogb_products": ShapeSpec("ogb_products", "train",
                              dict(n_nodes=2449029, n_edges=61859140,
                                   d_feat=100)),
    "molecule": ShapeSpec("molecule", "train",
                          dict(n_nodes=30, n_edges=64, batch=128)),
}

RECSYS_SHAPES = {
    "train_batch": ShapeSpec("train_batch", "train", dict(batch=65536)),
    "serve_p99": ShapeSpec("serve_p99", "serve", dict(batch=512)),
    "serve_bulk": ShapeSpec("serve_bulk", "serve", dict(batch=262144)),
    "retrieval_cand": ShapeSpec("retrieval_cand", "retrieval",
                                dict(batch=1, n_candidates=1_000_000)),
}

# The paper's own system, exercised through the same dry-run machinery.
ANN_SHAPES = {
    "build_1m": ShapeSpec("build_1m", "build", dict(n=1_048_576, d=128, k=32)),
    "search_small": ShapeSpec("search_small", "search",
                              dict(n=1_048_576, d=128, batch=10, t0=64)),
    "search_large": ShapeSpec("search_large", "search",
                              dict(n=1_048_576, d=128, batch=10240, t0=1)),
    "search_xlarge": ShapeSpec("search_xlarge", "search",
                               dict(n=16_777_216, d=96, batch=65536, t0=1)),
}


# --------------------------------------------------------------------------
# arch configs
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    aux_loss: float = 1e-2
    # group-local dispatch (GShard): scatters stay inside each data shard,
    # the expert exchange lowers to the canonical EP all-to-all instead of
    # GSPMD replicating a global [E, C, d] buffer (§Perf olmoe iteration)
    dispatch_groups: int = 1


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None      # None -> d_model // n_heads
    moe: MoEConfig | None = None
    window: int | None = None        # sliding-window size (starcoder2)
    local_global_ratio: int = 0      # gemma3: N local layers per global
    local_window: int = 1024
    nonparametric_ln: bool = False   # olmo
    gated_ffn: bool = True           # False -> plain 2-matrix GELU MLP
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    # roofline mode: unroll every lax.scan so compiled.cost_analysis counts
    # all trip iterations (XLA costs a while body exactly once)
    unroll: bool = False
    family: str = "lm"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def n_params(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.resolved_head_dim
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads \
            + hd * self.n_heads * d
        if self.moe:
            ff = 3 * d * self.moe.d_expert * (self.moe.n_experts
                                              + self.moe.n_shared) \
                + d * self.moe.n_experts
        else:
            ff = (3 if self.gated_ffn else 2) * d * f
        emb = v * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * (attn + ff) + emb

    def n_active_params(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if not self.moe:
            return self.n_params()
        d = self.d_model
        hd = self.resolved_head_dim
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads \
            + hd * self.n_heads * d
        ff = 3 * d * self.moe.d_expert * (self.moe.top_k + self.moe.n_shared) \
            + d * self.moe.n_experts
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * (attn + ff) + emb


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    kind: str                 # gin | gatedgcn | mace | graphsage
    n_layers: int
    d_hidden: int
    aggregator: str = "sum"   # sum | mean | max | gated
    learnable_eps: bool = False
    sample_sizes: tuple = ()  # graphsage fanouts
    # MACE extras
    l_max: int = 2
    correlation_order: int = 3
    n_rbf: int = 8
    r_cut: float = 5.0
    n_species: int = 10
    n_classes: int = 64
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    family: str = "gnn"


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    n_sparse: int = 40
    embed_dim: int = 32
    mlp: tuple = (1024, 512, 256)
    interaction: str = "concat"
    n_dense: int = 13
    # per-field vocabulary sizes (sums to ~49M rows)
    vocab_sizes: tuple = tuple([10_000_000] * 4 + [1_000_000] * 8
                               + [100_000] * 12 + [10_000] * 16)
    multi_hot_fields: tuple = (0, 1, 2, 3)  # bag-style fields
    bag_size: int = 10
    wide_hash_buckets: int = 1_000_000
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    family: str = "recsys"


@dataclasses.dataclass(frozen=True)
class ANNConfig:
    """The paper's system (TSDG index + search)."""

    name: str = "tsdg"
    metric: str = "l2"        # l2 | ip | cos
    k_graph: int = 32         # k-NN graph degree fed to diversification
    alpha: float = 1.2        # stage-1 relaxation (Eq. 2)
    lambda0: int = 8          # stage-2 occlusion-factor threshold
    max_degree: int = 32      # packed adjacency width M
    # search defaults (paper §4)
    n_seeds: int = 32
    hop_width: int = 32       # neighbors visited per hop (warp analogue)
    small_t0: int = 64        # independent greedy searches per query
    small_hops: int = 6
    large_ef: int = 64        # R size for large-batch search
    large_hops: int = 128
    # beyond-paper: the paper's 32 seeds match a GPU warp; on TPU one
    # [n_seeds, d] MXU pass makes 128-256 seeds free — measured recall
    # 0.62 -> 0.90+ at 20k scale (EXPERIMENTS §Perf). 32 = paper-faithful.
    large_n_seeds: int = 128
    delta: float = 0.0
    queue_segments: int = 8   # m segments for C and V
    segment_size: int = 32
    visited_segments: int = 8
    small_batch_threshold: int = 256  # regime split (paper's a*SMs+b / d)
    # regime-split source: "static" trusts small_batch_threshold as-is;
    # "probe" fits the paper's per-device division point from timed probe
    # batches at engine init (repro.ann.dispatch.calibrate — overridable
    # via ANNEngine(threshold=), cached in the index artifact manifest)
    regime_calibration: str = "static"
    faithful_rtemp: bool = True  # lane-paired R_temp update (paper Alg.1)
    # hot-path kernel backend (repro.core.hotpath): "pallas" | "xla" |
    # "auto" (pallas on TPU, xla fallback on CPU — explicit "pallas" off-TPU
    # runs the kernels in interpret mode, which the parity tests rely on)
    kernel_backend: str = "auto"
    # in-kernel neighbor gather (kernels/l2dist.gather_block_distances_pallas,
    # Pallas backend only): "auto" streams neighbor rows HBM->VMEM with
    # scalar-prefetch DMAs on real TPU and falls back to the XLA
    # gather-then-block path in interpret mode or when the tile exceeds the
    # VMEM budget; "on" forces the DMA path (the parity tests); "off" always
    # gathers at the XLA level (DESIGN.md §2)
    gather_fused: str = "auto"
    # staged build pipeline run by repro.ann.Index.build — stage names
    # resolve through repro.ann.pipeline.register_stage's registry, so
    # third-party stages slot in by name (mirrors the kernel-backend seam)
    build_pipeline: tuple = ("knn", "diversify", "bridges")
    # beyond-paper connectivity augmentation (0 = paper-faithful off)
    bridge_hubs: int = 256
    bridge_k: int = 8
    # roofline mode: unroll scans so cost_analysis counts all iterations
    unroll_scans: bool = False
    # beyond-paper search-side optimizations (0/False = paper-faithful):
    # store the database bf16 (distances accumulate fp32 on the MXU anyway)
    db_bf16: bool = False
    # gather only the first `gather_limit` λ-sorted columns of each row —
    # the paper's dynamic-degree prefix applied to the HBM gather itself
    gather_limit: int = 0
    # exact per-query visited byte-table in HBM replacing the lossy circular
    # V (+ the then-redundant C/R membership scans) — see EXPERIMENTS §Perf
    exact_visited: bool = False
    # --- serving engine (repro.serve.engine) ---
    # shape-bucket ladder for the compile cache: batches are padded up to the
    # smallest bucket >= B so steady-state traffic hits one persistent
    # compiled callable per (regime, bucket, k).  () disables bucketing
    # (every distinct raw batch size compiles its own entry).
    serve_buckets: tuple = (8, 32, 128, 512, 2048)
    # micro-batching queue (repro.serve.queue): coalesce concurrent small
    # requests into one device dispatch, waiting at most this long for
    # co-riders and never exceeding this many queries per dispatch
    queue_max_wait_ms: float = 2.0
    queue_max_batch: int = 512
    # streaming mutability (DESIGN.md §7): initial delta-shard capacity;
    # the shard grows by doubling from here, so streaming executables
    # recompile O(log adds) times
    delta_min_cap: int = 256
    # compressed residency (DESIGN.md §8): "int8" scores candidates against
    # per-row symmetric int8 codes in-kernel (~4x less HBM->VMEM DMA per
    # row) and exact-re-ranks the top rerank_mult*k survivors from the fp32
    # rows; "none" keeps today's bitwise-exact fp32 trace
    quantization: str = "none"
    rerank_mult: int = 4
    # in-kernel visited filter (DESIGN.md §10): "hash" consults a bucketed
    # open-addressing hash set (8-way, external-id keyed) before rows enter
    # the candidate pool, replacing the per-hop full-width dedup-by-id
    # membership scans; "none" keeps the paper-faithful frozen traces
    # bit-for-bit.  A full bucket treats the id as already-visited (safe
    # drop — never a duplicate).
    visited_filter: str = "none"
    family: str = "ann"

    def __post_init__(self):
        """Fail fast on knob typos — a bad metric/backend string used to
        surface as a KeyError deep inside kernel dispatch, long after the
        (expensive) build had started."""
        if self.metric not in ("l2", "ip", "cos"):
            raise ValueError(
                f"metric={self.metric!r} must be one of 'l2', 'ip', 'cos'")
        if self.gather_fused not in ("auto", "on", "off"):
            raise ValueError(
                f"gather_fused={self.gather_fused!r} must be 'auto', "
                "'on', or 'off'")
        if self.regime_calibration not in ("static", "probe"):
            raise ValueError(
                f"regime_calibration={self.regime_calibration!r} must be "
                "'static' or 'probe'")
        if self.delta_min_cap < 1:
            raise ValueError(
                f"delta_min_cap={self.delta_min_cap} must be >= 1")
        if self.quantization not in ("none", "int8"):
            raise ValueError(
                f"quantization={self.quantization!r} must be 'none' or "
                "'int8'")
        if self.rerank_mult < 1:
            raise ValueError(
                f"rerank_mult={self.rerank_mult} must be >= 1")
        if self.visited_filter not in ("none", "hash"):
            raise ValueError(
                f"visited_filter={self.visited_filter!r} must be 'none' "
                "or 'hash'")
        if self.visited_filter == "hash" and self.exact_visited:
            raise ValueError(
                "visited_filter='hash' replaces the visited structures; "
                "it cannot combine with exact_visited=True")
        if "layout" in self.build_pipeline:
            if self.gather_limit:
                raise ValueError(
                    "the 'layout' build stage re-sorts each neighbor row "
                    "by packed id, destroying the λ-ascending prefix that "
                    f"gather_limit={self.gather_limit} relies on; use "
                    "gather_limit=0 with packed layouts")
            if self.hop_width < self.max_degree:
                raise ValueError(
                    "packed layouts require hop_width >= max_degree "
                    f"(got {self.hop_width} < {self.max_degree}): the "
                    "small-batch chunked hop pairs lanes positionally, "
                    "which is only permutation-equivariant in one chunk")
        if self.kernel_backend not in ("auto", "pallas", "xla"):
            # third-party backends are legal if registered; consult the
            # registry lazily so importing configs stays jax-free
            try:
                from repro.core.hotpath import backends
                known = backends()
            except Exception:  # noqa: BLE001 — validation must not crash
                known = ("pallas", "xla")
            if self.kernel_backend not in known:
                raise ValueError(
                    f"kernel_backend={self.kernel_backend!r} not "
                    f"registered; known: {('auto',) + tuple(known)} "
                    "(repro.core.hotpath.register_backend adds more)")


ArchConfig = Any  # union of the dataclasses above


def shapes_for(cfg) -> dict:
    return {"lm": LM_SHAPES, "gnn": GNN_SHAPES,
            "recsys": RECSYS_SHAPES, "ann": ANN_SHAPES}[cfg.family]


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

_ARCH_MODULES = (
    "olmoe_1b_7b", "kimi_k2_1t_a32b", "starcoder2_7b", "gemma3_27b",
    "olmo_1b", "gin_tu", "gatedgcn", "mace", "graphsage_reddit",
    "wide_deep", "tsdg_paper",
)


def list_archs() -> list:
    return [m.replace("_", "-") for m in _ARCH_MODULES]


def get_arch(arch_id: str):
    mod_name = arch_id.replace("-", "_")
    if mod_name not in _ARCH_MODULES:
        import difflib

        close = difflib.get_close_matches(
            arch_id.replace("_", "-"), list_archs(), n=3, cutoff=0.5)
        hint = f"; did you mean {' or '.join(map(repr, close))}?" \
            if close else ""
        raise KeyError(
            f"unknown arch {arch_id!r}{hint}; known: {list_archs()}")
    import importlib

    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_reduced(arch_id: str):
    mod_name = arch_id.replace("-", "_")
    import importlib

    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.reduced()
