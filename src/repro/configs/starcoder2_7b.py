"""StarCoder2-7B — dense, GQA kv=4, RoPE, 4k sliding window [arXiv:2402.19173]."""
import dataclasses

from repro.configs.base import TransformerConfig

CONFIG = TransformerConfig(
    name="starcoder2-7b",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
    d_ff=18432, vocab=49152,
    window=4096,
    gated_ffn=False,  # starcoder2 uses a plain GELU MLP (c_fc/c_proj)
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="starcoder2-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256, window=32)
