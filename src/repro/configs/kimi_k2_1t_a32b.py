"""Kimi K2 — trillion-param MoE, 384 experts top-8 [arXiv:2501.kimi2].

Assignment specifies GQA kv=8 (not MLA); 1 shared expert per DeepSeek-style
MoE.  Trained with Adafactor + bf16 params: AdamW-fp32 state for 1T params is
~8 TB and cannot fit 512 x 16 GB v5e (see DESIGN.md §4).
"""
import dataclasses

from repro.configs.base import MoEConfig, TransformerConfig

CONFIG = TransformerConfig(
    name="kimi-k2-1t-a32b",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab=163840,
    moe=MoEConfig(n_experts=384, top_k=8, d_expert=2048, n_shared=1),
    param_dtype="bfloat16",
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="kimi-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab=256, param_dtype="float32",
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=64, n_shared=1))
