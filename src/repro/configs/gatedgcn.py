"""GatedGCN — 16 layers, gated edge aggregation [arXiv:2003.00982]."""
import dataclasses

from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="gatedgcn", kind="gatedgcn", n_layers=16, d_hidden=70,
    aggregator="gated",
)


def reduced():
    return dataclasses.replace(CONFIG, name="gatedgcn-reduced", n_layers=2,
                               d_hidden=16)
