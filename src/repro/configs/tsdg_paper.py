"""The paper's own system — TSDG index + GPU-style search procedures."""
import dataclasses

from repro.configs.base import ANNConfig

CONFIG = ANNConfig()


def reduced():
    return dataclasses.replace(
        CONFIG, name="tsdg-reduced", k_graph=8, max_degree=8, small_t0=4,
        small_hops=4, large_ef=16, large_hops=32, n_seeds=8, hop_width=8,
        queue_segments=4, segment_size=8, visited_segments=4)
