"""MACE — higher-order E(3)-equivariant message passing [arXiv:2206.07697]."""
import dataclasses

from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="mace", kind="mace", n_layers=2, d_hidden=128,
    l_max=2, correlation_order=3, n_rbf=8,
)


def reduced():
    return dataclasses.replace(CONFIG, name="mace-reduced", n_layers=1,
                               d_hidden=8, l_max=1, correlation_order=2,
                               n_rbf=4)
