"""Gemma3-27B — dense, 5:1 local:global attention, 128k context [hf:google/gemma-3]."""
import dataclasses

from repro.configs.base import TransformerConfig

CONFIG = TransformerConfig(
    name="gemma3-27b",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16,
    d_ff=21504, vocab=262144,
    head_dim=128,
    local_global_ratio=5, local_window=1024,
    tie_embeddings=True,
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="gemma3-reduced", n_layers=6, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256, head_dim=16, local_window=16)
