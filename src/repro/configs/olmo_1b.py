"""OLMo-1B — dense, non-parametric LayerNorm [arXiv:2402.00838]."""
import dataclasses

from repro.configs.base import TransformerConfig

CONFIG = TransformerConfig(
    name="olmo-1b",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=50304,
    nonparametric_ln=True,
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="olmo-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=256)
