"""GIN (TU benchmarks) — 5 layers, sum aggregator, learnable eps [arXiv:1810.00826]."""
import dataclasses

from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="gin-tu", kind="gin", n_layers=5, d_hidden=64,
    aggregator="sum", learnable_eps=True,
)


def reduced():
    return dataclasses.replace(CONFIG, name="gin-reduced", n_layers=2,
                               d_hidden=16)
