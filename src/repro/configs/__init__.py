from repro.configs.base import (  # noqa: F401
    ANN_SHAPES, ANNConfig, GNN_SHAPES, GNNConfig, LM_SHAPES, MoEConfig,
    RECSYS_SHAPES, RecsysConfig, ShapeSpec, TransformerConfig, get_arch,
    get_reduced, list_archs, shapes_for,
)
