"""Quickstart: build a TSDG index and search it, 30 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.diversify import build_tsdg
from repro.core.search_large import large_batch_search
from repro.core.search_small import small_batch_search
from repro.data.synthetic import make_clustered, recall_at_k

# 1. data (swap in your own [N, d] float32 matrix)
ds = make_clustered(n=20000, d=32, n_queries=100, n_clusters=64, noise=0.6)

# 2. build the two-stage diversified graph (paper §3)
cfg = get_arch("tsdg-paper")
graph = build_tsdg(jnp.asarray(ds.X), cfg)
print(f"TSDG built: N={graph.n} max_degree={graph.max_degree} "
      f"avg_degree={graph.avg_degree():.1f}")

# 3a. small-batch search (paper Alg. 1): many cheap greedy searches
ids, dists = small_batch_search(jnp.asarray(ds.X), graph,
                                jnp.asarray(ds.Q[:10]), k=10, t0=32, hops=6)
print("small-batch recall@10:",
      recall_at_k(np.asarray(ids), ds.gt[:10], 10))

# 3b. large-batch search (paper Alg. 2): best-first with hashed structures
# (n_seeds=128: one MXU pass evaluates 4x the paper's warp-width seed set)
ids, dists = large_batch_search(jnp.asarray(ds.X), graph,
                                jnp.asarray(ds.Q), k=10, ef=64, hops=128,
                                n_seeds=128)
print("large-batch recall@10:", recall_at_k(np.asarray(ids), ds.gt, 10))
