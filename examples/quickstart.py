"""Quickstart: the `repro.ann.Index` facade end-to-end, ~40 lines.

Build a TSDG index, search it under both batch regimes (dispatch is
automatic), persist it — graph, config, AND the AOT-compiled serving
executables — then reload and serve without rebuilding or recompiling.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

from repro.ann import Index
from repro.data.synthetic import make_clustered, recall_at_k

# 1. data (swap in your own [N, d] float32 matrix; REPRO_QUICKSTART_N
#    shrinks the corpus for the CI smoke run)
ds = make_clustered(n=int(os.environ.get("REPRO_QUICKSTART_N", 20000)),
                    d=32, n_queries=100, n_clusters=64, noise=0.6)

# 2. build — staged pipeline (knn -> diversify -> bridges, paper §3);
#    defaults come from ANNConfig, any knob is a dataclasses.replace away
index = Index.build(ds.X, k=10)
print(index)

# 3. search — one call, both regimes: the paper's §4 threshold routes a
#    small batch to Algorithm 1 (t0 parallel greedy searches) and a large
#    one to Algorithm 2 (batched best-first), behind the same API
ids, dists = index.search(ds.Q[:10])
print(f"B=10  -> {index.regime(10)}-batch procedure, "
      f"recall@10={recall_at_k(ids, ds.gt[:10], 10):.3f}")
ids, dists = index.search(ds.Q)
print(f"B=100 -> {index.regime(100)}-batch procedure, "
      f"recall@10={recall_at_k(ids, ds.gt, 10):.3f}")

# 4. persist: versioned artifact = packed graph + config + fingerprint +
#    jax-AOT-exported serving executables for every (regime, bucket) pair
with tempfile.TemporaryDirectory() as td:
    index.warmup()                       # compile the serving ladder once
    index.save(f"{td}/tsdg-20k")

    # 5. a "restarted process": load answers bitwise-identically with ZERO
    #    compiles — the warmup sweep is restored from disk, not re-traced
    loaded = Index.load(f"{td}/tsdg-20k")
    ids2, _ = loaded.search(ds.Q)
    s = loaded.stats
    print(f"reloaded: identical={bool((ids == ids2).all())} "
          f"compiles={s.compiles} aot_primed={s.aot_primed}")

    # 6. serve concurrent callers through the micro-batching queue (QoS:
    #    bulk submits >= max_batch take the bypass lane, never blocking
    #    latency traffic)
    with loaded.serve(max_wait_ms=2.0, max_batch=64) as mb:
        futs = [mb.submit(q) for q in ds.Q[:32]]         # singles coalesce
        bulk = mb.submit(ds.Q)                           # bypass lane
        ids1, _ = futs[0].result()
        print(f"queue: {mb.stats.snapshot()['n_dispatches']} dispatches, "
              f"bypass={mb.stats.bypass}")

# 7. compressed residency (DESIGN.md §8): score int8 codes in-kernel
#    (~4x less DMA per candidate row), then re-rank the top rerank_mult*k
#    survivors against the exact fp32 rows — recall stays within a whisker
#    of fp32 at a fraction of the memory traffic
import dataclasses

from repro.configs import get_arch

qcfg = dataclasses.replace(get_arch("tsdg-paper"), quantization="int8")
qindex = Index.build(ds.X, qcfg, k=10)
qids, _ = qindex.search(ds.Q)
print(f"int8+rerank -> recall@10={recall_at_k(qids, ds.gt, 10):.3f}")
