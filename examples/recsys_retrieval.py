"""TSDG x wide&deep: candidate retrieval for the `retrieval_cand` shape.

Scores one user against a candidate corpus two ways:
  (a) exact brute force — one GEMM + top-k (the dry-run baseline);
  (b) the paper's TSDG index over the item vectors (inner-product metric).
This is the paper's technique powering an assigned architecture's serving
path (DESIGN.md §4 applicability table).

  PYTHONPATH=src python examples/recsys_retrieval.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ann import Index
from repro.configs import get_arch, get_reduced
from repro.data.recsys import CTRStream
from repro.models import recsys as R
from repro.models.module import init_params

N_ITEMS = 100_000

# --- user tower ------------------------------------------------------------
cfg = get_reduced("wide-deep")
params = init_params(R.schema(cfg), jax.random.key(0))
batch = {k: jnp.asarray(v[:1]) for k, v in next(CTRStream(cfg, 4)).items()}
deep, _ = R.user_tower(params, cfg, batch)
user_vec = deep @ params["retrieval_proj"]                   # [1, 64]

# --- item corpus -----------------------------------------------------------
# clustered like real item embeddings (i.i.d.-gaussian corpora have no
# neighborhood structure — the known ANN worst case, LID ≈ d)
rng = np.random.default_rng(0)
centers = rng.normal(size=(256, R.RETRIEVAL_DIM)).astype(np.float32)
items = (centers[rng.integers(0, 256, N_ITEMS)]
         + 0.5 * rng.normal(size=(N_ITEMS, R.RETRIEVAL_DIM))
         ).astype(np.float32)
items_j = jnp.asarray(items)

# (a) exact: one GEMM + top-k
t0 = time.perf_counter()
scores = (user_vec @ items_j.T)[0]
top_exact = np.asarray(jax.lax.top_k(scores, 100)[1])
t_exact = time.perf_counter() - t0
print(f"brute force: {t_exact * 1e3:.1f} ms")

# (b) TSDG index on inner-product metric, via the repro.ann facade
# (small_t0=64 matches the old direct small_batch_search(t0=64) call; a
# B=1 retrieval batch always takes the small regime)
ann_cfg = dataclasses.replace(get_arch("tsdg-paper"), metric="ip",
                              k_graph=24, max_degree=32, small_t0=64,
                              small_hops=8)
t0 = time.perf_counter()
index = Index.build(items_j, ann_cfg, k=100)
print(f"TSDG build: {time.perf_counter() - t0:.1f} s "
      f"(one-off, amortized over the query stream; "
      f"index.save() persists it across restarts)")

t0 = time.perf_counter()
ids, dists = index.search(user_vec)
t_ann = time.perf_counter() - t0
overlap = len(set(ids[0].tolist()) & set(top_exact.tolist()))
print(f"TSDG search ({index.regime(1)} regime): {t_ann * 1e3:.1f} ms "
      f"(incl. compile), recall@100 vs exact: {overlap / 100:.2f}")
