"""Train a small LM end-to-end with the production trainer: grad accum,
warmup-cosine, checkpointing + resume — the same code path the 40-cell
dry-run lowers at 256/512 chips.

  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--d-model 256]
"""
import argparse
import dataclasses

from repro.configs import get_arch
from repro.configs.base import TransformerConfig
from repro.data.lm import LMStream
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.optim.api import OptimizerConfig
from repro.train.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = TransformerConfig(
        name="demo-lm", n_layers=args.layers, d_model=args.d_model,
        n_heads=4, n_kv_heads=2, d_ff=4 * args.d_model, vocab=2048)
    print(f"model: {cfg.n_params() / 1e6:.1f}M params")

    trainer = Trainer(
        schema=T.schema(cfg),
        loss_fn=lambda p, b: T.loss_fn(p, cfg, b),
        mesh=make_host_mesh(),
        opt_cfg=OptimizerConfig(lr=3e-3, warmup_steps=20,
                                total_steps=args.steps),
        train_cfg=TrainConfig(steps=args.steps, log_every=20, ckpt_every=50,
                              ckpt_dir=args.ckpt, microbatches=2))
    data = iter(LMStream(cfg.vocab, args.seq, args.batch, microbatches=2))
    _, hist = trainer.run(
        data, resume=args.resume,
        on_metrics=lambda s, m: print(
            f"step {s:4d} loss {m['loss']:.3f} acc {m['acc']:.3f} "
            f"gnorm {m['grad_norm']:.2f}"))
    print(f"done: loss {hist[0][1]['loss']:.3f} -> {hist[-1][1]['loss']:.3f}")


if __name__ == "__main__":
    main()
