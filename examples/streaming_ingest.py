"""Streaming ingest: a mutable index without rebuild-per-change, ~50 lines.

Build once, then keep serving while the corpus evolves: `add` appends to
a brute-force delta shard fused into every search, `delete` tombstones
rows in place, and `compact` folds delta+base into a new generation that
the serving plane hot-swaps — with zero recompiles for shapes already in
the AOT cache (DESIGN.md §7).

  PYTHONPATH=src python examples/streaming_ingest.py
"""
import os

import numpy as np

from repro.ann import Index
from repro.data.synthetic import make_clustered

# 1. build a frozen index and warm the serving ladder
ds = make_clustered(n=int(os.environ.get("REPRO_STREAMING_N", 8000)),
                    d=32, n_queries=64, n_clusters=32, noise=0.6)
index = Index.build(ds.X, k=10)
index.search(ds.Q[:8]); index.search(ds.Q)       # compile both regimes
print(f"built n={ds.X.shape[0]}  generation={index.generation}  "
      f"compiles={index.stats.compiles}")

# 2. ingest — new vectors are searchable IMMEDIATELY (scored brute-force
#    in the delta shard, merged with the graph candidates in-executable)
fresh = np.random.default_rng(0).normal(size=(4, 32)).astype(np.float32)
new_ids = index.add(fresh)
ids, dists = index.search(fresh)
print(f"added {len(new_ids)} -> ids {new_ids.tolist()}; "
      f"self-search hits={int((ids[:, 0] == new_ids).sum())}/4 "
      f"(top-1 dist max {float(dists[:, 0].max()):.2e})")

# 3. delete — tombstoned rows vanish from results at once (keep-mask
#    threaded into the in-kernel candidate filter, base or delta rows)
pool = [int(i) for i in ids[:, 1:].ravel() if 0 <= int(i) < len(ds.X)]
pool = list(dict.fromkeys(pool))                 # distinct base neighbors
victims = pool[:4]
index.delete(victims)
ids, _ = index.search(fresh)
print(f"deleted {victims}; still returned="
      f"{bool(np.isin(victims, ids).any())}  n_active={index.n_active}")

# 4. serve through the micro-batching queue while mutating — generation
#    state swaps between micro-batches, in-flight futures all resolve
with index.serve(max_wait_ms=1.0) as mb:
    futs = [mb.submit(q) for q in ds.Q[:16]]
    index.add(fresh[:2] + 0.01)                  # mutate under live traffic
    index.delete(pool[4:6])
    assert all(f.result()[0].shape == (10,) for f in futs)

# 5. compact — rebuild delta+base into generation 1. The result is
#    bitwise what Index.build would produce on the effective corpus, and
#    (net adds == net deletes here, so shapes match the warm cache) the
#    generation swap costs ZERO recompiles.
before = index.stats.compiles
id_map = index.compact()
ids, _ = index.search(ds.Q)                      # cached large-regime shape
print(f"compacted -> generation={index.generation}  "
      f"n={index.n_active}  remapped_deleted={int((id_map < 0).sum())}  "
      f"swap_compiles={index.stats.compiles - before}")
assert index.stats.compiles == before, "same-shape swap must stay cached"
