"""Pod-scale serving demo (DESIGN.md §9): a request router fronting a
set of replica endpoints, all on CPU in one process.

Builds a TSDG index once, AOT-warms it, then stands up a 2-replica
*replicated* router where both replicas share the donor's compile cache
(`replicate_engine` / `ANNEngine(cache_from=)`) — so the whole pod serves
with aggregated ``compiles=0`` beyond the donor's warmup.  A mixed query
stream runs against the router; halfway through, one replica is killed to
show the failover path: the dead replica's in-flight and future requests
retry on the healthy peer (zero lost futures), the health prober ejects
it within one probe interval, and after revival it is readmitted.

A *sharded* router over the same corpus (two half-corpus engines, answers
merged with `merge_shard_results`) then answers the same queries —
bitwise identical to a 2-DB-shard mesh plane over the concatenated corpus
(the router's host-side merge mirrors the mesh's in-collective one).

Knobs: ``REPRO_POD_N`` (corpus size, default 8000), ``REPRO_POD_REPLICAS``
(replica count, default 2).

  PYTHONPATH=src python examples/pod_serving.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=2")

import time

import jax
import numpy as np

from repro.ann import Index
from repro.configs import get_arch
from repro.data.synthetic import make_clustered, recall_at_k
from repro.serve.router import (Router, RouterConfig, replicate_engine,
                                shard_engines)

N = int(os.environ.get("REPRO_POD_N", "8000"))
R = int(os.environ.get("REPRO_POD_REPLICAS", "2"))

ds = make_clustered(n=N, d=32, n_queries=256, n_clusters=32, noise=0.6)
cfg = get_arch("tsdg-paper")
thresh = 8.0 * cfg.small_t0          # static regime split: B<32 small

t0 = time.perf_counter()
index = Index.build(ds.X, cfg, k=10, threshold=thresh)
index.warmup()
print(f"index built + warmed in {time.perf_counter() - t0:.1f}s "
      f"(compiles={index.stats.compiles})")

# --- replicated router: QPS scaling + failover ----------------------------

rc = RouterConfig(mode="replicated", replicas=R, policy="least_loaded",
                  health_interval_s=0.2, max_retries=2, backoff_s=0.01)
router = Router(replicate_engine(index.engine, R), rc)
print(f"\n[replicated] {R} replicas sharing one compile cache, "
      f"health probe every {rc.health_interval_s}s")

rng = np.random.default_rng(0)
futures, kill_at = [], 15
for i in range(30):
    if i == kill_at:
        router.endpoints[0].kill()   # simulate a replica crash mid-stream
        print(f"  !! killed replica r0 at request {i} "
              f"(in-flight + future requests fail over to peers)")
    B = int(rng.choice([1, 4, 8, 64]))
    sel = rng.integers(0, len(ds.Q), B)
    futures.append((sel, router.submit(ds.Q[sel])))

recs = [recall_at_k(np.asarray(f.result()[0]), ds.gt[sel], 10)
        for sel, f in futures]
snap = router.snapshot()
agg, rt = snap["aggregate"], snap["router"]
print(f"  30/30 requests answered, mean recall@10 "
      f"{sum(recs) / len(recs):.3f}")
print(f"  lost_futures={rt['lost_futures']} retries={rt['retries']} "
      f"ejects={rt['ejects']} compiles={agg['compiles']} "
      f"(shared cache: zero beyond the donor's warmup)")

router.endpoints[0].revive()
deadline = time.time() + 10.0
while time.time() < deadline and snap["router"]["readmits"] < 1:
    time.sleep(0.1)
    snap = router.snapshot()
print(f"  r0 revived -> readmitted after "
      f"{rc.readmit_probes} clean probes "
      f"(readmits={snap['router']['readmits']}, "
      f"probes={snap['router']['probes']})")
router.close()

# --- sharded router: capacity scaling, bitwise the mesh cut ---------------

print("\n[sharded] 2 half-corpus engines, host-side merge")
sc = RouterConfig(mode="sharded", replicas=2, health_interval_s=0.0)
shards = shard_engines(ds.X, cfg, shards=2, k=10, threshold=thresh)
srouter = Router(shards, sc)
ids, dists = srouter.query(ds.Q[:64])
mesh_ix = Index.build(ds.X, cfg, k=10,
                      mesh=jax.make_mesh((2,), ("data",)),
                      threshold=thresh)
ref_ids, _ = mesh_ix.search(ds.Q[:64])
same = np.array_equal(np.asarray(ids), np.asarray(ref_ids))
print(f"  64-query batch: bitwise == 2-DB-shard mesh plane: {same}")
assert same
srouter.close()
print("\npod serving demo OK")
