"""Multi-device sharded TSDG (the production layout at toy scale), consumed
through the `repro.ann.Index` facade: ``Index.build(X, cfg, mesh=mesh)``
builds one independent sub-index per DB shard and ``index.search`` serves
both regimes through the shard-mapped procedures — same API as the
single-device path (DESIGN.md §6).

Runs on 8 emulated host devices: DB sharded 4 ways (data axis), queries /
search-populations over 2 model columns — the same shard_map code the
512-chip dry-run lowers.

  PYTHONPATH=src python examples/distributed_search.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses
import time

import jax
import numpy as np

from repro.ann import Index
from repro.configs import get_arch
from repro.data.synthetic import make_clustered, recall_at_k

mesh = jax.make_mesh((4, 2), ("data", "model"))
print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

ds = make_clustered(n=16384, d=32, n_queries=64, n_clusters=64, noise=0.6)
cfg = dataclasses.replace(get_arch("tsdg-paper"), k_graph=16, max_degree=24,
                          bridge_hubs=64)

t0 = time.perf_counter()
index = Index.build(ds.X, cfg, k=10, mesh=mesh)
print(f"sharded build (4 independent sub-indexes): "
      f"{time.perf_counter() - t0:.1f}s")

for Bq in (64, 4):  # large then small — dispatch is automatic
    t0 = time.perf_counter()
    ids, dists = index.search(ds.Q[:Bq])
    r = recall_at_k(np.asarray(ids), ds.gt[:Bq], 10)
    print(f"{index.regime(Bq)}-batch (B={Bq}): recall@10={r:.3f} "
          f"({time.perf_counter() - t0:.1f}s incl. compile)")

s = index.stats
print(f"engine: {s.n_batches} batches, compiles={s.compiles} "
      f"({s.small_batches} small / {s.large_batches} large)")
