"""Multi-device sharded TSDG (the production layout at toy scale).

Runs on 8 emulated host devices: DB sharded 4 ways (data axis), queries /
search-populations over 2 model columns — the same shard_map code the
512-chip dry-run lowers.

  PYTHONPATH=src python examples/distributed_search.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch
from repro.core import distributed as D
from repro.data.synthetic import make_clustered, recall_at_k

mesh = jax.make_mesh((4, 2), ("data", "model"))
print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

ds = make_clustered(n=16384, d=32, n_queries=64, n_clusters=64, noise=0.6)
cfg = dataclasses.replace(get_arch("tsdg-paper"), k_graph=16, max_degree=24,
                          bridge_hubs=64)

X = jax.device_put(jnp.asarray(ds.X), NamedSharding(mesh, P("data", None)))
t0 = time.perf_counter()
nbrs, lams, degs, hubs = D.make_build_fn(mesh, cfg)(X)
jax.block_until_ready(nbrs)
print(f"sharded build (4 independent sub-indexes): "
      f"{time.perf_counter() - t0:.1f}s")

for kind, Bq in (("large", 64), ("small", 4)):
    search = D.make_search_fn(mesh, cfg, kind=kind, k=10)
    spec = P(None, None) if kind == "small" else P("model", None)
    Q = jax.device_put(jnp.asarray(ds.Q[:Bq]), NamedSharding(mesh, spec))
    t0 = time.perf_counter()
    ids, dists = search(X, nbrs, lams, degs, hubs, Q)
    jax.block_until_ready(ids)
    r = recall_at_k(np.asarray(ids), ds.gt[:Bq], 10)
    print(f"{kind}-batch (B={Bq}): recall@10={r:.3f} "
          f"({time.perf_counter() - t0:.1f}s incl. compile)")
