"""Multi-device sharded TSDG (the production layout at toy scale), consumed
through the `repro.ann.Index` facade: the mesh is an *execution plane*
(DESIGN.md §6), so the four verbs are the same as single-device —

    Index.build(X, cfg, mesh=mesh)   one independent sub-index per DB shard
    index.search(Q)                  both regimes, shard-mapped, one merge
    index.save(dir)                  shard-major artifact + mesh AOT cache
    Index.load(dir, mesh=mesh)       zero rebuilds AND zero compiles

Runs on 8 emulated host devices: DB sharded 4 ways (data axis), queries /
search-populations over 2 model columns — the same shard_map code the
512-chip dry-run lowers.

  PYTHONPATH=src python examples/distributed_search.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses
import shutil
import tempfile
import time

import jax
import numpy as np

from repro.ann import Index
from repro.configs import get_arch
from repro.data.synthetic import make_clustered, recall_at_k

mesh = jax.make_mesh((4, 2), ("data", "model"))
print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

ds = make_clustered(n=8192, d=32, n_queries=64, n_clusters=64, noise=0.6)
cfg = dataclasses.replace(get_arch("tsdg-paper"), k_graph=16, max_degree=24,
                          bridge_hubs=64, serve_buckets=(8, 64))

t0 = time.perf_counter()
index = Index.build(ds.X, cfg, k=10, mesh=mesh)
print(f"sharded build (4 independent sub-indexes): "
      f"{time.perf_counter() - t0:.1f}s")

for Bq in (64, 4):  # large then small — dispatch is automatic
    t0 = time.perf_counter()
    ids, dists = index.search(ds.Q[:Bq])
    r = recall_at_k(np.asarray(ids), ds.gt[:Bq], 10)
    print(f"{index.regime(Bq)}-batch (B={Bq}): recall@10={r:.3f} "
          f"({time.perf_counter() - t0:.1f}s incl. compile)")

s = index.stats
print(f"engine: {s.n_batches} batches, compiles={s.compiles} "
      f"({s.small_batches} small / {s.large_batches} large)")

# --- sharded save -> load round-trip: no rebuild, no warmup sweep ----------
index.warmup()           # cover every (regime, bucket) before exporting
td = tempfile.mkdtemp(prefix="repro_mesh_demo_")
try:
    t0 = time.perf_counter()
    index.save(td)
    print(f"shard-major artifact written in {time.perf_counter() - t0:.1f}s "
          f"(arrays/<i>.npz per DB shard + mesh AOT cache)")
    t0 = time.perf_counter()
    restored = Index.load(td, mesh=mesh)
    ids2, _ = restored.search(ds.Q[:64])
    print(f"restored + first query in {time.perf_counter() - t0:.1f}s: "
          f"compiles={restored.stats.compiles} "
          f"aot_primed={restored.stats.aot_primed} "
          f"(bitwise match: {bool(np.array_equal(ids2, index.search(ds.Q[:64])[0]))})")
    assert restored.stats.compiles == 0
finally:
    shutil.rmtree(td, ignore_errors=True)
