"""End-to-end serving driver (the paper is a serving system): build a TSDG
index once through the `repro.ann.Index` facade, then serve a mixed stream
of small and large query batches (regime dispatch is the paper §4
threshold, owned by `repro.ann.dispatch`).

Demonstrates the production serving layer on top of the paper:
shape-bucketed compile cache (one compile per (regime, bucket), steady
state never re-traces), warmup pre-compilation, save/load with the
persistent AOT cache (a restart skips rebuild AND warmup), stats v2
(per-regime percentiles, bucket hit rate), and the async micro-batching
queue with the QoS bypass lane for bulk submits.

  PYTHONPATH=src python examples/ann_serving.py
"""
import tempfile
import threading
import time

import numpy as np

from repro.ann import Index
from repro.configs import get_arch
from repro.data.synthetic import make_clustered, recall_at_k

ds = make_clustered(n=20000, d=32, n_queries=512, n_clusters=64, noise=0.6)

t0 = time.perf_counter()
index = Index.build(ds.X, get_arch("tsdg-paper"), k=10)
print(f"index built in {time.perf_counter() - t0:.1f}s "
      f"(avg degree {index.graph.avg_degree():.1f})")

t0 = time.perf_counter()
n = index.warmup()
print(f"warmup: {n} compiles (regime x bucket x k) "
      f"in {time.perf_counter() - t0:.1f}s — steady state never re-traces")

rng = np.random.default_rng(0)
recalls = []
for step in range(20):
    B = int(rng.choice([1, 2, 8, 32, 256]))       # bursty traffic
    sel = rng.integers(0, len(ds.Q), B)
    ids, dists = index.search(ds.Q[sel])
    r = recall_at_k(ids, ds.gt[sel], 10)
    recalls.append((r, B))
    print(f"batch={B:4d} regime={index.regime(B):5s} "
          f"bucket={index.engine.bucket_for(B):4d} recall@10={r:.3f}")

s = index.stats
avg = sum(r * b for r, b in recalls) / sum(b for _, b in recalls)
print(f"\nserved {s.n_queries} queries in {s.n_batches} batches "
      f"({s.small_batches} small / {s.large_batches} large), "
      f"{s.qps:.0f} QPS steady-state, weighted recall@10 {avg:.3f}")
print(f"compiles={s.compiles} bucket_hit_rate={s.bucket_hit_rate:.2f} "
      f"padded_queries={s.padded_queries}")
for regime in ("small", "large"):
    p = s.per_regime[regime].percentiles()
    print(f"{regime:5s} latency ms: " + " ".join(
        f"{k}={v * 1e3:.1f}" for k, v in p.items()))

# --- restart without the cold start ---------------------------------------
with tempfile.TemporaryDirectory() as td:
    t0 = time.perf_counter()
    index.save(f"{td}/ix")
    t_save = time.perf_counter() - t0
    t0 = time.perf_counter()
    restarted = Index.load(f"{td}/ix")
    print(f"\nsave {t_save:.1f}s / load {time.perf_counter() - t0:.1f}s — "
          f"restart primed {restarted.stats.aot_primed} executables "
          f"(rebuild AND warmup sweep skipped)")
    ids2, _ = restarted.search(ds.Q[:8])
    assert restarted.stats.compiles == 0, "loaded index must not compile"

# --- async micro-batching: concurrent single-query callers ----------------
print("\nmicro-batching queue: 64 concurrent single-query callers "
      "+ one bulk job on the bypass lane")
hits = []
with index.serve(max_wait_ms=5.0, max_batch=64) as mb:
    bulk_fut = mb.submit(ds.Q[:256])  # >= max_batch -> QoS bypass lane

    def caller(i):
        ids, _ = mb.submit(ds.Q[i]).result(timeout=60)
        hits.append(recall_at_k(ids[None], ds.gt[i:i + 1], 10))

    threads = [threading.Thread(target=caller, args=(i,)) for i in range(64)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    bulk_fut.result(timeout=120)
    dt = time.perf_counter() - t0
q = mb.stats.snapshot()
print(f"{q['n_requests']} requests -> {q['n_dispatches']} device dispatches "
      f"(mean coalesced {q['mean_coalesced']:.1f}, bypass={q['bypass']}), "
      f"{dt * 1e3:.0f} ms total, recall@10 {np.mean(hits):.3f}")
