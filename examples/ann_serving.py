"""End-to-end serving driver (the paper is a serving system): build a TSDG
index once, then serve a mixed stream of small and large query batches
through the regime-dispatching engine (paper §4's threshold).

  PYTHONPATH=src python examples/ann_serving.py
"""
import time

import numpy as np

from repro.configs import get_arch
from repro.data.synthetic import make_clustered, recall_at_k
from repro.serve.engine import ANNEngine

ds = make_clustered(n=20000, d=32, n_queries=512, n_clusters=64, noise=0.6)

t0 = time.perf_counter()
engine = ANNEngine(ds.X, get_arch("tsdg-paper"), k=10)
print(f"index built in {time.perf_counter() - t0:.1f}s "
      f"(avg degree {engine.graph.avg_degree():.1f})")

rng = np.random.default_rng(0)
recalls = []
for step in range(20):
    B = int(rng.choice([1, 2, 8, 32, 256]))       # bursty traffic
    sel = rng.integers(0, len(ds.Q), B)
    ids, dists = engine.query(ds.Q[sel])
    r = recall_at_k(ids, ds.gt[sel], 10)
    recalls.append((r, B))
    print(f"batch={B:4d} regime={engine.regime(B):5s} recall@10={r:.3f}")

s = engine.stats
avg = sum(r * b for r, b in recalls) / sum(b for _, b in recalls)
print(f"\nserved {s.n_queries} queries in {s.n_batches} batches "
      f"({s.small_batches} small / {s.large_batches} large), "
      f"{s.qps:.0f} QPS, weighted recall@10 {avg:.3f}")
